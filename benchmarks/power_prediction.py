"""Paper Fig. 2 analogue — power prediction across a DVFS sweep.

Trains the paper's three predictors on design points (arch x shape x chip x
frequency), k-fold cross-validated, and reports MAPE / R^2 per model for the
POWER target.  Paper reference: Random Forest MAPE 5.03%, R^2 0.9561 on a
V100S 397-1590 MHz sweep.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import ART_DIR, csv_row, timed, write_report
from repro.core import dataset, predictors


def run() -> list:
    X, y_power, y_cycles, meta = dataset.build_dataset(ART_DIR)
    rows, report = [], ["# Power prediction (paper Fig. 2 analogue)",
                        f"design points: {len(X)}", ""]
    best = None
    for name in ("knn", "decision_tree", "random_forest"):
        res, wall = timed(predictors.kfold_evaluate, name, X, y_power, repeats=1)
        report.append(f"{name:16s} MAPE {res['mape']:6.2f}%   R2 {res['r2']:.4f}")
        rows.append(csv_row(f"power_pred_{name}", wall * 1e6 / max(len(X), 1),
                            f"mape={res['mape']:.2f}%;r2={res['r2']:.4f}"))
        if best is None or res["mape"] < best[1]["mape"]:
            best = (name, res)
    report += ["", f"best: {best[0]} (paper: random_forest 5.03% / 0.9561)"]

    # per-frequency trace for three archs (the Fig. 2 picture, textual)
    m = predictors.RandomForestRegressor().fit(X, y_power)
    pred = m.predict(X)
    lines = {}
    for x, yt, yp, mt in zip(X, y_power, pred, meta):
        if mt.chip == "tpu-v5e" and mt.shape == "train_4k":
            lines.setdefault(mt.arch, []).append((mt.freq_mhz, yt, yp))
    report.append("")
    for arch in list(lines)[:3]:
        report.append(f"## {arch} (tpu-v5e, train_4k)")
        report.append("freq_mhz,real_w,predicted_w")
        for f, yt, yp in sorted(lines[arch]):
            report.append(f"{f:.0f},{yt:.1f},{yp:.1f}")
        report.append("")
    write_report("power_prediction.md", "\n".join(report))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
