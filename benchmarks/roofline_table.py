"""§Roofline generator — the full per-cell table from dry-run artifacts.

Per (arch x shape x mesh): the three roofline terms in seconds, dominant
bottleneck, MODEL_FLOPS / HLO_FLOPS ratio, per-device residency, and a note
on what would move the dominant term.  Writes experiments/bench/roofline.md
(the table embedded in EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, ensure_artifacts, write_report

_NOTES = {
    ("memory_s", "train"): "fuse attention scores into VMEM (pallas) + bf16 intermediates",
    ("memory_s", "prefill"): "pallas flash kernel keeps S^2 scores on-chip",
    ("memory_s", "decode"): "KV-cache width: MLA latent / int8 KV / more batch per cache read",
    ("compute_s", "train"): "cut remat recompute; larger per-device tiles",
    ("compute_s", "prefill"): "already MXU-bound: raise per-chip batch",
    ("compute_s", "decode"): "decode should not be compute-bound: check head sharding",
    ("collective_s", "train"): "seq-shard activations into MoE dispatch; reduce-scatter grads",
    ("collective_s", "prefill"): "overlap TP all-gathers with layer compute (scan pipelining)",
    ("collective_s", "decode"): "replicate small weights: trade HBM for ICI; batch collectives",
}


def run() -> list:
    arts = ensure_artifacts()
    header = ("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,state_gb_pd,fits_16g,note")
    lines = [header]
    rows = []
    frac = []
    for (arch, shape, pod), art in sorted(arts.items()):
        r = art["roofline"]
        kind = ("train" if shape.startswith("train") else
                "prefill" if shape.startswith("prefill") else "decode")
        dom = r["dominant"]
        note = _NOTES.get((dom, kind), "")
        state = art["memory"]["state_gb_per_device"]
        lines.append(
            f"{arch},{shape},{art['mesh']},{r['compute_s']:.4g},"
            f"{r['memory_s']:.4g},{r['collective_s']:.4g},{dom},"
            f"{art['useful_flops_ratio']:.3f},{state:.2f},"
            f"{'Y' if state <= 16.0 else 'N'},{note}")
        # roofline fraction: compute term / modeled latency (how close to
        # the compute roof the cell runs)
        sim = art["sim"]
        frac.append(sim["t_compute"] / max(sim["latency_s"], 1e-12))
    report = ["# Roofline table (all cells)", "", "```", *lines, "```", "",
              f"mean compute-roofline fraction: {np.mean(frac) * 100:.1f}%",
              f"best cell: {np.max(frac) * 100:.1f}%  worst: "
              f"{np.min(frac) * 100:.1f}%"]
    write_report("roofline.md", "\n".join(report))
    rows.append(csv_row("roofline_cells", 0.0, f"n={len(lines) - 1}"))
    rows.append(csv_row("roofline_mean_fraction", 0.0,
                        f"frac={np.mean(frac) * 100:.1f}%"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
