"""Accelerator-selection serving benchmark — the query layer as a CI artifact.

Runs an offline campaign over ALL cached dry-run workloads, builds the
``FrontierIndex`` from it (through a real save/load round trip), and drives
a ``SelectionEngine`` through the three answer paths:

  * index-hit     — every cached cell queried ``HIT_REPEATS`` times; the
                    answers-identity verdict (served frontier == offline
                    campaign pick, exact candidate identity, every cell) and
                    p50/p99 query latency;
  * mini-campaign — novel census-perturbed workloads through the fused exact
                    fallback; parity verdict vs a standalone campaign on the
                    same config, p50/p99 latency, and the batched-window
                    check: N concurrent novel queries must ride exactly ONE
                    fused sweep launch (read from ``fused_launches`` —
                    measured, not assumed) with answers identical to
                    sequential ones;
  * predictor-only — KNN/RF predictors + an expired deadline; provenance
                    verdict and p50/p99 latency.

Persists ``BENCH_serving.json`` with all verdicts and latency percentiles;
hard gates (identity on every cell, fallback parity, one-launch batching,
batched==sequential) assert AFTER the artifact is written so a red run
still uploads evidence.
"""

from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

from benchmarks.common import (ART_DIR, OUT_DIR, csv_row, ensure_artifacts,
                               write_report)
from repro.core import dataset, dse, predictors
from repro.dse_campaign import (Campaign, CampaignConfig,
                                frontiers_identical, tiny_campaign_space)
from repro.serving.engine import SelectionEngine
from repro.serving.frontier_index import FrontierIndex
from repro.telemetry import Telemetry

SERVING_BENCH_NAME = "BENCH_serving.json"
INDEX_ARTIFACT_NAME = "frontier_index.json"
HIT_REPEATS = 30          # index-hit latency samples per cached cell
MINI_REPEATS = 8          # mini-campaign latency samples (each a real sweep)


def _pcts(samples_s) -> dict:
    s = np.asarray(samples_s, np.float64) * 1e3
    return {"n": int(s.size),
            "p50_ms": float(np.percentile(s, 50)),
            "p99_ms": float(np.percentile(s, 99)),
            "mean_ms": float(s.mean())}


def _perturb(wl: dse.Workload, scale: float) -> dse.Workload:
    """A novel workload family: the cached census uniformly scaled — the
    cost model sees a different key, so the index cannot serve it."""
    return dse.Workload(wl.arch, wl.shape,
                        {k: v * scale for k, v in wl.base_analysis.items()},
                        wl.base_chips, wl.state_gb_per_device)


def run() -> list:
    ensure_artifacts()
    cfg = CampaignConfig(
        space=tiny_campaign_space(chunk_size=128), evaluator="jit",
        constraint=dse.Constraint(max_power_w=40_000, min_hbm_fit=False))
    campaign = Campaign.from_artifacts(ART_DIR, cfg)
    offline = campaign.run()
    assert offline.complete

    os.makedirs(OUT_DIR, exist_ok=True)
    index_path = FrontierIndex.from_campaign(campaign).save(
        os.path.join(OUT_DIR, INDEX_ARTIFACT_NAME))
    index = FrontierIndex.load(index_path)

    # -- index-hit: identity on every cached cell + latency -----------------
    # the main engine is fully instrumented: its per-path latency
    # histograms / counters snapshot into the artifact under "telemetry"
    tel = Telemetry()
    engine = SelectionEngine(index, telemetry=tel)
    hit_lat, identity = [], {}
    for wl in campaign.workloads:
        key = (wl.arch, wl.shape)
        answer = engine.select(wl)                   # correctness probe
        identity["|".join(key)] = bool(
            answer.provenance == "index_exact"
            and frontiers_identical(answer.frontier(), offline.frontiers[key]))
        for _ in range(HIT_REPEATS):
            t0 = time.perf_counter()
            engine.select(wl)
            hit_lat.append(time.perf_counter() - t0)
    launches_during_hits = engine.fused_launches

    # -- mini-campaign: novel-family fallback + latency ---------------------
    novel = [_perturb(wl, 1.0 + 0.03 * (i + 1))
             for i, wl in enumerate(campaign.workloads)]
    probe = engine.select(novel[0])
    standalone = Campaign([novel[0]], engine.config).run()
    fallback_parity = bool(
        probe.provenance == "mini_campaign"
        and frontiers_identical(
            probe.frontier(),
            standalone.frontiers[(novel[0].arch, novel[0].shape)]))
    mini_lat = []
    for i in range(MINI_REPEATS):
        q = _perturb(novel[i % len(novel)], 1.0 + 1e-4 * (i + 1))
        t0 = time.perf_counter()
        a = engine.select(q)
        mini_lat.append(time.perf_counter() - t0)
        assert a.provenance == "mini_campaign"

    # -- batched window: one fused launch, answers == sequential ------------
    batch_engine = SelectionEngine(index)
    for wl in novel:
        batch_engine.submit(wl)
    batch_engine.submit(campaign.workloads[0])       # hit rides along
    before = batch_engine.fused_launches
    t0 = time.perf_counter()
    batched = batch_engine.flush()
    batched_wall_s = time.perf_counter() - t0
    batched_launches = batch_engine.fused_launches - before
    seq_engine = SelectionEngine(index)
    batched_eq_sequential = all(
        frontiers_identical(got.frontier(), seq_engine.select(wl).frontier())
        for wl, got in zip(novel, batched))

    # -- predictor-only: deadline degradation -------------------------------
    X, y_power, y_cycles, _ = dataset.build_dataset(ART_DIR)
    rf = predictors.RandomForestRegressor().fit(X, y_power)
    knn = predictors.KNNRegressor().fit(X, y_cycles)
    deg_tel = Telemetry()
    deg_engine = SelectionEngine(index, SelectionEngine._config_from_index(
        index).replace(power_model=rf, cycles_model=knn), telemetry=deg_tel)
    deg_lat, deg_prov = [], []
    for i in range(HIT_REPEATS):
        q = _perturb(novel[i % len(novel)], 1.0 + 2e-4 * (i + 1))
        t0 = time.perf_counter()
        a = deg_engine.select(q, deadline_s=0.0)
        deg_lat.append(time.perf_counter() - t0)
        deg_prov.append(a.provenance)
    predictor_only_ok = all(p == "predictor_only" for p in deg_prov)

    payload = {
        "bench": "serving",
        "python": platform.python_version(),
        "space": cfg.space.to_dict(),
        "workloads": sorted("|".join(k) for k in offline.frontiers),
        "index_path": index_path,
        "index_families": len(index),
        "latency": {
            "index_hit": _pcts(hit_lat),
            "mini_campaign": _pcts(mini_lat),
            "predictor_only": _pcts(deg_lat),
        },
        "verdicts": {
            "answers_identity_per_cell": identity,
            "answers_identity_all_cells": all(identity.values()),
            "index_hits_launch_no_sweep": launches_during_hits == 0,
            "novel_fallback_parity": fallback_parity,
            "batched_one_fused_launch": batched_launches == 1,
            "batched_equals_sequential": batched_eq_sequential,
            "deadline_degrades_to_predictor_only": predictor_only_ok,
        },
        "batched": {
            "queries": len(batched),
            "fused_launches": int(batched_launches),
            "wall_s": batched_wall_s,
            "provenance": [a.provenance for a in batched],
        },
        "stats": dict(engine.stats),
        # engine-measured observability: per-path selection_latency_s
        # histograms, selection_queries_total counters, the deadline-EMA
        # gauge (main engine) and the degraded engine's counterpart
        "telemetry": {"engine_metrics": tel.snapshot(),
                      "degraded_engine_metrics": deg_tel.snapshot()},
    }
    path = os.path.join(OUT_DIR, SERVING_BENCH_NAME)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)

    report = ["# Serving benchmark", "",
              f"families indexed: {len(index)}", "",
              "| path | p50 ms | p99 ms |", "|---|---|---|"]
    for name in ("index_hit", "mini_campaign", "predictor_only"):
        p = payload["latency"][name]
        report.append(f"| {name} | {p['p50_ms']:.2f} | {p['p99_ms']:.2f} |")
    report += ["", "verdicts: " + ", ".join(
        f"{k}={v}" for k, v in payload["verdicts"].items()
        if k != "answers_identity_per_cell")]
    write_report("serving.md", "\n".join(report) + "\n")

    # gates — AFTER the artifact is on disk
    assert payload["verdicts"]["answers_identity_all_cells"], (
        "served index answers diverged from offline campaign picks", identity)
    assert launches_during_hits == 0, "an index hit triggered a sweep"
    assert fallback_parity, "mini-campaign fallback diverged from standalone"
    assert batched_launches == 1, (
        f"batched flush used {batched_launches} fused launches, expected 1")
    assert batched_eq_sequential, "batched answers != sequential answers"
    assert predictor_only_ok, f"degraded provenances: {set(deg_prov)}"

    hit = payload["latency"]["index_hit"]
    mini = payload["latency"]["mini_campaign"]
    deg = payload["latency"]["predictor_only"]
    return [
        csv_row("serving_index_hit", hit["p50_ms"] * 1e3,
                f"p99={hit['p99_ms']:.2f}ms identity="
                f"{payload['verdicts']['answers_identity_all_cells']}"),
        csv_row("serving_mini_campaign", mini["p50_ms"] * 1e3,
                f"p99={mini['p99_ms']:.2f}ms parity={fallback_parity}"),
        csv_row("serving_predictor_only", deg["p50_ms"] * 1e3,
                f"p99={deg['p99_ms']:.2f}ms"),
        csv_row("serving_batched", batched_wall_s * 1e6 / len(batched),
                f"queries={len(batched)} fused_launches={batched_launches}"),
    ]


if __name__ == "__main__":
    for row in run():
        print(row)
