"""Chaos scenario matrix — every fault, one gate: bitwise-identical frontiers.

Runs the fault-free reference campaign on a small synthetic space (no dry-run
artifacts required, so the gating CI job needs nothing but the repo), then
replays a matrix of ``ChaosPolicy`` scenarios through ``ChaosRunner`` —
worker kills, coordinator restarts (recovering from checksummed checkpoints),
checkpoint bit-flips and truncations, a poison tile, duplicate deliveries,
slow workers holding leases past expiry, a kitchen-sink combination, and a
seeded random policy sweep — plus one scenario through the REAL
``MultiprocessFabric`` (worker crash via ``os._exit`` + poison tile +
duplicate delivery, with ``RetryPolicy``-paced respawns).

Persists ``BENCH_chaos.json`` (per-scenario identity verdict, fault/recovery
counts, recovery virtual-seconds, retry counts) BEFORE asserting the gate:
every scenario's final frontiers must be BITWISE-identical to the fault-free
single-process run.  Survival is not the bar — exact recovery is.

``--smoke`` runs the three-scenario gating subset (worker kill, coordinator
restart, corrupt checkpoint) CI blocks on; the full matrix runs in the
non-gating bench job via ``benchmarks.run``.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

from benchmarks.common import OUT_DIR, csv_row

from repro.core import dse
from repro.dse_campaign import (Campaign, ChaosEvent, ChaosPolicy,
                                ChaosRunner, FaultInjection, SliceVariant,
                                SpaceSpec, frontiers_identical,
                                run_distributed)
from repro.dse_campaign.config import CampaignConfig
from repro.runtime.fault_tolerance import RetryPolicy

CHAOS_BENCH_NAME = "BENCH_chaos.json"

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}
WORKLOADS = [
    dse.Workload("qwen3_14b", "train_4k", BASE, 256, 0.5),
    dse.Workload("stablelm_1_6b", "serve_2k",
                 {k: v * 0.3 for k, v in BASE.items()}, 64, 0.2),
]
CONSTRAINT = dse.Constraint(max_power_w=50_000)
N_RANDOM_POLICIES = 3


def bench_space() -> SpaceSpec:
    return SpaceSpec(chips=("tpu-v5e", "tpu-v4", "tpu-edge"),
                     chip_counts=(16, 64), freq_points=7,
                     variants=(SliceVariant(), SliceVariant("bin85", 0.85)),
                     chunk_size=32)


def bench_config() -> CampaignConfig:
    return CampaignConfig(space=bench_space(), constraint=CONSTRAINT)


# The named scenario matrix.  ``smoke`` marks the gating CI subset: a worker
# kill, a coordinator restart, and a restart recovering from a corrupted
# checkpoint — the three headline failure modes.
def scenario_matrix(n_tiles: int):
    return [
        ("worker_kill", True, ChaosPolicy(events=(
            ChaosEvent(2, "kill_worker"), ChaosEvent(4, "kill_worker", 1)))),
        ("coordinator_restart", True, ChaosPolicy(events=(
            ChaosEvent(3, "restart_coordinator"),))),
        # corrupt/truncate fire at the SAME completion as the restart (in
        # authored order): a later checkpoint would overwrite the damage
        # before anyone reads it, and the quarantine path would never run
        ("corrupt_checkpoint", True, ChaosPolicy(events=(
            ChaosEvent(3, "corrupt_checkpoint", 17),
            ChaosEvent(3, "restart_coordinator")))),
        ("truncate_checkpoint", False, ChaosPolicy(events=(
            ChaosEvent(3, "truncate_checkpoint", 10),
            ChaosEvent(3, "restart_coordinator")))),
        ("poison_tile", False, ChaosPolicy(poison_tile=2)),
        ("duplicate_delivery", False, ChaosPolicy(events=(
            ChaosEvent(2, "duplicate_delivery"),))),
        ("slow_worker", False, ChaosPolicy(events=(
            ChaosEvent(2, "slow_worker"),))),
        ("combined", False, ChaosPolicy(events=(
            ChaosEvent(1, "kill_worker"),
            ChaosEvent(3, "corrupt_checkpoint", 5),
            ChaosEvent(3, "restart_coordinator"),
            ChaosEvent(4, "slow_worker"),
            ChaosEvent(5, "duplicate_delivery")), poison_tile=4)),
    ] + [
        (f"random_seed{seed}", False,
         ChaosPolicy.random(seed=seed, n_events=5, horizon=n_tiles))
        for seed in range(N_RANDOM_POLICIES)
    ]


def run_scenario(name, policy, cfg, ref_frontiers):
    """One chaos scenario end-to-end; returns its report record."""
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as d:
        runner = ChaosRunner(WORKLOADS, cfg, policy, n_workers=3)
        result, report = runner.run(os.path.join(d, "chaos_ckpt.json"))
    wall_s = time.perf_counter() - t0
    identical = (set(result.frontiers) == set(ref_frontiers) and all(
        frontiers_identical(result.frontiers[k], ref_frontiers[k])
        for k in ref_frontiers))
    return {
        "scenario": name,
        "policy": policy.to_dict(),
        "identical": identical,
        "wall_s": wall_s,
        "virtual_s": report["virtual_s"],
        "recovery_virtual_s": report["recovery_virtual_s"],
        "events_fired": len(report["events_fired"]),
        "kills": report["kills"],
        "restarts": report["restarts"],
        "corruptions": report["corruptions"],
        "truncations": report["truncations"],
        "slowdowns": report["slowdowns"],
        "duplicates_injected": report["duplicates_injected"],
        "duplicates_folded": report["duplicates_folded"],
        "respawns": report["respawns"],
        "reissued_tiles": report["reissued_tiles"],
        "worker_crashes": report["worker_crashes"],
        "poison_tiles": report["poison_tiles"],
        "poison_retried": report["poison_retried"],
        "quarantined_files": report["quarantined_files"],
        "recoveries": report["recoveries"],
        "deliveries": report["deliveries"],
        "n_completions": report["n_completions"],
    }


def run_multiprocess_scenario(cfg, ref_frontiers):
    """The same invariant through real processes: a worker killed by
    ``os._exit`` mid-tile plus a poison tile plus a duplicated payload,
    recovered by ``RetryPolicy``-paced respawns and the poison quarantine."""
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as d:
        mp_cfg = CampaignConfig(
            space=cfg.space, constraint=CONSTRAINT, n_workers=2,
            lease_timeout_s=60.0,
            checkpoint_path=os.path.join(d, "mp_ckpt.json"))
        result, stats = run_distributed(
            WORKLOADS, mp_cfg,
            fault=FaultInjection(kill_worker=0, kill_after_tiles=1,
                                 duplicate=True, poison_tile=3),
            retry=RetryPolicy(base_s=0.05, max_s=0.2, seed=0),
            max_respawns=4, poison_threshold=2)
    wall_s = time.perf_counter() - t0
    identical = (set(result.frontiers) == set(ref_frontiers) and all(
        frontiers_identical(result.frontiers[k], ref_frontiers[k])
        for k in ref_frontiers))
    return {
        "scenario": "multiprocess_kill_poison_duplicate",
        "identical": identical,
        "wall_s": wall_s,
        "worker_crashes": len(stats["worker_crashes"]),
        "clean_exits": len(stats["worker_clean_exits"]),
        "respawns": len(stats["worker_crashes"]),
        "reissued_tiles": stats["reissued_tiles"],
        "duplicates_folded": stats["duplicates"],
        "poison_tiles": stats["poison_tiles"],
        "poison_retried": stats["poison_retried"],
        "deliveries": stats["deliveries"],
    }


def run_matrix(smoke: bool = False, multiprocess: bool = True):
    """Build the reference, replay the matrix, persist BENCH_chaos.json,
    THEN gate on every scenario being bitwise-identical."""
    cfg = bench_config()
    ref = Campaign(WORKLOADS, cfg).run()
    n_tiles = cfg.resolved_space.n_tiles()
    records = []
    for name, in_smoke, policy in scenario_matrix(n_tiles):
        if smoke and not in_smoke:
            continue
        records.append(run_scenario(name, policy, cfg, ref.frontiers))
    if multiprocess and not smoke:
        records.append(run_multiprocess_scenario(cfg, ref.frontiers))
    payload = {
        "bench": "chaos",
        "smoke": smoke,
        "python": platform.python_version(),
        "n_tiles": n_tiles,
        "n_scenarios": len(records),
        "gate": "frontiers bitwise-identical to fault-free run, every scenario",
        "all_identical": all(r["identical"] for r in records),
        "scenarios": records,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, CHAOS_BENCH_NAME)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[chaos] wrote {out}", file=sys.stderr)
    # gates AFTER the artifact lands — a failed run still leaves evidence
    broken = [r["scenario"] for r in records if not r["identical"]]
    assert not broken, (
        f"chaos scenarios diverged from the fault-free frontier: {broken}")
    return payload


def rows(payload):
    for r in payload["scenarios"]:
        derived = (f"identical={r['identical']}"
                   f";respawns={r.get('respawns', 0)}"
                   f";restarts={r.get('restarts', 0)}"
                   f";reissued={r.get('reissued_tiles', 0)}"
                   f";poison={len(r.get('poison_tiles', []))}")
        yield csv_row(f"chaos_{r['scenario']}", r["wall_s"] * 1e6, derived)


def run():
    """benchmarks.run entry point: full matrix, one csv row per scenario."""
    return list(rows(run_matrix(smoke=False)))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="gating CI subset: worker kill + coordinator "
                         "restart + corrupt checkpoint")
    ap.add_argument("--no-multiprocess", action="store_true",
                    help="skip the real-process scenario")
    args = ap.parse_args(argv)
    payload = run_matrix(smoke=args.smoke,
                         multiprocess=not args.no_multiprocess)
    print("name,us_per_call,derived")
    for row in rows(payload):
        print(row)
    print(f"[chaos] {payload['n_scenarios']} scenarios, all identical: "
          f"{payload['all_identical']}", file=sys.stderr)


if __name__ == "__main__":
    main()
