"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle, with the
structural VMEM-traffic delta (the quantity that matters on real TPU —
interpret-mode wall times are NOT TPU times and are labeled as such)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timed
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def run() -> list:
    rows = []
    # flash attention: bytes the kernel keeps on-chip vs the XLA path
    B, S, H, hd = 1, 512, 4, 64
    q, k, v = (_rand((B, S, H, hd)) for _ in range(3))
    _, t_ref = timed(lambda: ref.attention_ref(q, k, v).block_until_ready())
    _, t_ker = timed(lambda: ops.flash_attention(q, k, v).block_until_ready())
    score_bytes = B * H * S * S * 4 * 2          # s + p, fp32, one round-trip
    rows.append(csv_row("flash_attention_interp", t_ker * 1e6,
                        f"ref_us={t_ref * 1e6:.0f};vmem_saved_bytes={score_bytes}"))

    b, S2, nh, hp, ds = 1, 512, 4, 32, 32
    x = _rand((b, S2, nh, hp))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, S2, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2, nh), jnp.float32)
    Bm, Cm = _rand((b, S2, 1, ds)), _rand((b, S2, 1, ds))
    _, t_ref = timed(lambda: ref.ssd_ref(x, dt, A, Bm, Cm).block_until_ready())
    _, t_ker = timed(lambda: ops.ssd_scan(x, dt, A, Bm, Cm, chunk=128)
                     .block_until_ready())
    nc = S2 // 128
    ssd_bytes = b * nh * nc * 128 * 128 * 4 * 2  # L + CB blocks
    rows.append(csv_row("ssd_scan_interp", t_ker * 1e6,
                        f"ref_us={t_ref * 1e6:.0f};vmem_saved_bytes={ssd_bytes}"))

    xc = _rand((2, 32, 32, 16))
    wc = _rand((3, 3, 16, 32)) * 0.1
    _, t_ref = timed(lambda: ref.conv2d_ref(
        jnp.pad(xc, ((0, 0), (1, 1), (1, 1), (0, 0))), wc).block_until_ready())
    _, t_ker = timed(lambda: ops.conv2d(xc, wc).block_until_ready())
    rows.append(csv_row("conv2d_interp", t_ker * 1e6,
                        f"ref_us={t_ref * 1e6:.0f};mxu_tiles={(32 * 32) // 128 + 1}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
