"""Offloading analysis (paper §IV future work) — bandwidth/latency sweep.

Where should a LLM-prefill-class inference run: edge TPU or cloud v5e slice?
Reports the latency- and battery-optimal decision across bandwidths, and the
crossover bandwidth (the paper's Jetson 7W-vs-2W example, systematized)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, write_report
from repro.core import offload

LOCAL = {"flops": 2.0e12, "hbm_bytes": 2.0e10, "collective_bytes": 0.0,
         "wire_bytes": 0.0}
REMOTE = {"flops": 1.2e11, "hbm_bytes": 1.5e9, "collective_bytes": 0.02e9,
          "wire_bytes": 0.02e9}
REQ, RESP = 1.5e6 * 8, 4e3 * 8   # prompt+image payload up


def run() -> list:
    report = ["# Offload analysis (bandwidth sweep)",
              "bw_mbps,local_ms,remote_ms,latency_choice,battery_choice"]
    crossover = None
    for bw in np.geomspace(1, 2000, 24):
        d = offload.analyze(LOCAL, REMOTE, REQ, RESP,
                            offload.NetworkSpec(bandwidth_bps=bw * 1e6))
        report.append(f"{bw:.1f},{d.local_latency_s * 1e3:.2f},"
                      f"{d.remote_latency_s * 1e3:.2f},"
                      f"{'offload' if d.choose_remote_latency else 'local'},"
                      f"{'offload' if d.choose_remote_battery else 'local'}")
        if crossover is None and d.choose_remote_latency:
            crossover = bw
    report.append("")
    report.append(f"latency crossover bandwidth: "
                  f"{crossover:.1f} Mbps" if crossover else "no crossover")
    write_report("offload_analysis.md", "\n".join(report))
    return [csv_row("offload_crossover_mbps", 0.0,
                    f"bw={crossover:.1f}" if crossover else "bw=inf")]


if __name__ == "__main__":
    for r in run():
        print(r)
