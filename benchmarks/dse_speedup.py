"""DSE speedup — the paper's motivation quantified.

Compares, per design point:
  * fast path  — trained predictors, vectorized (the paper's contribution)
  * slow path  — calibrated simulator on a scaled census (needs a compile)
  * compile    — the real cost of the compile the fast path avoids (measured
    wall from the dry-run artifacts; the GPGPU-Sim / prototype analogue)
and end-to-end: does the fast path pick (nearly) the same accelerator?
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ART_DIR, csv_row, ensure_artifacts, write_report
from repro.core import costmodel, dataset, dse, features, predictors
from repro.hw import get_chip


def run() -> list:
    arts = ensure_artifacts()
    X, y_power, y_cycles, meta = dataset.build_dataset(ART_DIR)
    rf = predictors.RandomForestRegressor().fit(X, y_power)
    knn = predictors.KNNRegressor().fit(X, y_cycles)

    space = dse.default_space()
    rows, agree, quality = [], 0, []
    compile_walls = []
    n_workloads = 0
    t_fast_total, t_slow_total = 0.0, 0.0
    for (arch, shape, pod), art in sorted(arts.items()):
        if pod != "pod1" or shape != "train_4k":
            continue
        n_workloads += 1
        compile_walls.append(art["wall_s"])
        base = {k: art["hxa"][k] for k in
                ("flops", "hbm_bytes", "collective_bytes", "wire_bytes")}
        cons = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)
        best_slow, results, t_slow = dse.slow_path_search(
            arch, shape, base, art["roofline"]["n_chips"],
            art["memory"]["state_gb_per_device"], space, cons)
        best_fast, _, t_fast = dse.fast_path_search(
            arch, shape, rf, knn, space, cons, verify_top_k=5,
            slow_verify=lambda c: costmodel.simulate(
                dse._scale_analysis(base, art["roofline"]["n_chips"], c),
                get_chip(c.chip), c.n_chips, freq_mhz=c.freq_mhz))
        t_fast_total += t_fast
        t_slow_total += t_slow
        if best_slow and best_fast:
            e_s = results[best_slow]["sim"].energy_j
            e_f = results[best_fast]["sim"].energy_j
            quality.append(e_f / e_s)
            agree += int(best_fast == best_slow)

    per_point_fast = t_fast_total / max(n_workloads * len(space), 1) * 1e6
    per_point_slow = t_slow_total / max(n_workloads * len(space), 1) * 1e6
    per_point_compile = float(np.mean(compile_walls)) * 1e6
    report = [
        "# DSE speedup (fast predictors vs simulation vs compile)",
        f"workloads: {n_workloads}; candidates/workload: {len(space)}",
        f"fast path:      {per_point_fast:10.1f} us/point",
        f"simulator path: {per_point_slow:10.1f} us/point "
        f"({per_point_slow / max(per_point_fast, 1e-9):.1f}x slower)",
        f"compile path:   {per_point_compile:10.0f} us/point "
        f"({per_point_compile / max(per_point_fast, 1e-9):.0f}x slower — "
        "the cost the paper's method avoids)",
        f"exact-agreement with slow path: {agree}/{n_workloads}",
        f"mean energy gap of fast pick: "
        f"{(np.mean(quality) - 1) * 100 if quality else 0:.2f}%",
    ]
    rows.append(csv_row("dse_fast_path", per_point_fast,
                        f"speedup_vs_compile={per_point_compile / max(per_point_fast, 1e-9):.0f}x"))
    rows.append(csv_row("dse_quality_gap", 0.0,
                        f"energy_gap={(np.mean(quality) - 1) * 100 if quality else 0:.2f}%"))
    write_report("dse_speedup.md", "\n".join(report))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
