"""DSE speedup — the paper's motivation quantified.

Compares, per design point:
  * fast path    — trained predictors, vectorized (the paper's contribution)
  * batched slow — ``simulate_batch`` over the whole space in one vector pass
  * scalar slow  — the per-candidate Python loop (the seed baseline the
    batched engine replaced; kept as ``slow_path_search_scalar``)
  * compile      — the real cost of the compile the fast path avoids (measured
    wall from the dry-run artifacts; the GPGPU-Sim / prototype analogue)
and end-to-end: does the fast path pick (nearly) the same accelerator?
Also reports max relative batch-vs-scalar simulator disagreement over the
whole space (must be <= 1e-6) and the energy/latency Pareto frontier swept
across all workloads in one batched call.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (ART_DIR, csv_row, ensure_artifacts, timed,
                               write_report)
from repro.core import costmodel, dataset, dse, features, predictors
from repro.hw import get_chip


def _agreement_rel_err(batch: dse.CandidateBatch, batch_results,
                       scalar_results: dict) -> float:
    """Max relative |simulate_batch - simulate| over the whole space, from
    the two searches' already-computed result sets (no extra sweep)."""
    sim = batch_results.sim
    worst = 0.0
    for i, cand in enumerate(batch.candidates):
        ref = scalar_results[cand]["sim"]
        for field in ("latency_s", "power_w", "energy_j", "cycles"):
            a = float(getattr(sim, field)[i])
            b = getattr(ref, field)
            worst = max(worst, abs(a - b) / max(abs(b), 1e-300))
    return worst


def run() -> list:
    arts = ensure_artifacts()
    X, y_power, y_cycles, meta = dataset.build_dataset(ART_DIR)
    rf = predictors.RandomForestRegressor().fit(X, y_power)
    knn = predictors.KNNRegressor().fit(X, y_cycles)

    batch = dse.default_space_batch()
    space = batch.candidates
    rows, agree, quality = [], 0, []
    per_wl = []
    compile_walls = []
    workloads = []
    n_workloads = 0
    t_fast_total, t_slow_total, t_scalar_total = 0.0, 0.0, 0.0
    rel_err = 0.0
    for (arch, shape, pod), art in sorted(arts.items()):
        # every single-pod cell counts: with the grown CI artifact cache
        # (>= 6 arch x shape cells) the exact-agreement and energy-gap
        # pick-quality metrics below average over a meaningful sample
        if pod != "pod1":
            continue
        n_workloads += 1
        compile_walls.append(art["wall_s"])
        base = {k: art["hxa"][k] for k in
                ("flops", "hbm_bytes", "collective_bytes", "wire_bytes")}
        base_chips = art["roofline"]["n_chips"]
        state_gb = art["memory"]["state_gb_per_device"]
        workloads.append(dse.Workload(arch, shape, base, base_chips, state_gb))
        cons = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)
        # one warm-up per path (jit/alloc), then best-of-3 steady-state wall
        run_slow = lambda: dse.slow_path_search(
            arch, shape, base, base_chips, state_gb, batch, cons)
        run_scalar = lambda: dse.slow_path_search_scalar(
            arch, shape, base, base_chips, state_gb, space, cons)
        run_fast = lambda: dse.fast_path_search(
            arch, shape, rf, knn, batch, cons, verify_top_k=5,
            slow_verify=lambda c: costmodel.simulate(
                dse._scale_analysis(base, base_chips, c),
                get_chip(c.chip), c.n_chips, freq_mhz=c.freq_mhz,
                mesh=c.mesh))
        best_slow, results, _ = run_slow()
        best_scalar, scalar_results, _ = run_scalar()
        best_fast, _, _ = run_fast()
        # same pick, or an exact-score tie broken differently by argmin vs
        # the scalar loop's first-strict-improvement
        assert best_scalar == best_slow or (
            best_scalar is not None and best_slow is not None
            and abs(scalar_results[best_scalar]["sim"].energy_j
                    - results[best_slow]["sim"].energy_j)
            <= 1e-12 * abs(scalar_results[best_scalar]["sim"].energy_j)
        ), (best_scalar, best_slow)
        # timed() wraps the WHOLE call, so the fast-path number includes the
        # top-k slow verification, not just the predict+rank inner timer
        t_fast_total += timed(run_fast)[1]
        t_slow_total += timed(run_slow)[1]
        t_scalar_total += timed(run_scalar)[1]
        rel_err = max(rel_err, _agreement_rel_err(batch, results,
                                                  scalar_results))
        if best_slow and best_fast:
            e_s = results[best_slow]["sim"].energy_j
            e_f = results[best_fast]["sim"].energy_j
            quality.append(e_f / e_s)
            agree += int(best_fast == best_slow)
            per_wl.append(
                f"  {arch} x {shape}: gap {(e_f / e_s - 1) * 100:7.2f}%  "
                f"slow {best_slow.chip} x{best_slow.n_chips} "
                f"mesh {'x'.join(map(str, best_slow.mesh))} "
                f"@{best_slow.freq_mhz:.0f}  ->  fast {best_fast.chip} "
                f"x{best_fast.n_chips} "
                f"mesh {'x'.join(map(str, best_fast.mesh))} "
                f"@{best_fast.freq_mhz:.0f}")

    # multi-workload Pareto sweep: every (arch, shape) x the whole space in
    # ONE batched simulate call
    t0 = time.perf_counter()
    fronts = dse.pareto_search(workloads, batch,
                               dse.Constraint(max_power_w=40_000,
                                              min_hbm_fit=False))
    t_pareto = time.perf_counter() - t0

    n_points = max(n_workloads * len(space), 1)
    per_point_fast = t_fast_total / n_points * 1e6
    per_point_slow = t_slow_total / n_points * 1e6
    per_point_scalar = t_scalar_total / n_points * 1e6
    per_point_compile = float(np.mean(compile_walls)) * 1e6 if compile_walls else 0.0
    batch_speedup = t_scalar_total / max(t_slow_total, 1e-12)
    report = [
        "# DSE speedup (fast predictors vs simulation vs compile)",
        f"workloads: {n_workloads}; candidates/workload: {len(space)}",
        f"fast path:         {per_point_fast:10.2f} us/point "
        "(predictors + top-k slow verification)",
        f"batched simulator: {per_point_slow:10.2f} us/point",
        f"scalar simulator:  {per_point_scalar:10.2f} us/point "
        f"(seed baseline; batched engine is {batch_speedup:.1f}x faster)",
        f"compile path:      {per_point_compile:10.0f} us/point "
        f"({per_point_compile / max(per_point_fast, 1e-9):.0f}x slower — "
        "the cost the paper's method avoids)",
        f"batch-vs-scalar simulate max rel err: {rel_err:.3e} (<= 1e-6 required)",
        f"exact-agreement with slow path: {agree}/{n_workloads}",
        f"mean energy gap of fast pick: "
        f"{(np.mean(quality) - 1) * 100 if quality else 0:.2f}%",
        "per-workload fast-vs-slow picks:",
        *per_wl,
        f"pareto frontier ({n_workloads} workloads x {len(space)} candidates "
        f"in one call, {t_pareto * 1e3:.1f} ms):",
    ]
    for (arch, shape), fr in sorted(fronts.items()):
        report.append(f"  {arch} x {shape}: {len(fr)} frontier points "
                      f"of {fr.feasible_count} feasible")
    rows.append(csv_row("dse_fast_path", per_point_fast,
                        f"speedup_vs_compile={per_point_compile / max(per_point_fast, 1e-9):.0f}x"))
    rows.append(csv_row("dse_batched_slow_path", per_point_slow,
                        f"speedup_vs_scalar={batch_speedup:.1f}x"))
    rows.append(csv_row("dse_batch_agreement", 0.0,
                        f"max_rel_err={rel_err:.3e}"))
    rows.append(csv_row("dse_quality_gap", 0.0,
                        f"energy_gap={(np.mean(quality) - 1) * 100 if quality else 0:.2f}%"))
    # gate AFTER the report/rows so a disagreement still leaves diagnostics
    write_report("dse_speedup.md", "\n".join(report))
    assert rel_err <= 1e-6, f"batch-vs-scalar disagreement {rel_err:.3e}"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
