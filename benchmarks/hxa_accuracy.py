"""HyPA analogue table — what loop-aware static analysis buys.

For every cached cell: HxA's trip-count-aware FLOPs vs XLA cost_analysis
(which counts loop bodies once), the useful-flops ratio vs MODEL_FLOPS, and
HxA analysis wall-time vs the compile wall-time it replaces (the paper's
"faster than simulators, no GPU needed" claim)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, ensure_artifacts, write_report


def run() -> list:
    arts = ensure_artifacts()
    rows = []
    report = ["# HxA static analysis accuracy (HyPA analogue)", "",
              "arch,shape,hxa_flops,xla_flops,loop_gain,useful_ratio,compile_s"]
    gains, ratios = [], []
    for (arch, shape, pod), art in sorted(arts.items()):
        if pod != "pod1":
            continue
        hxa_f = art["hxa"]["flops"]
        xla_f = max(art["cost"]["flops"], 1.0)
        gain = hxa_f / xla_f
        ratio = art["useful_flops_ratio"]
        gains.append(gain)
        ratios.append(ratio)
        report.append(f"{arch},{shape},{hxa_f:.3e},{xla_f:.3e},"
                      f"{gain:.1f}x,{ratio:.3f},{art['wall_s']}")
    report += ["", f"median loop-awareness gain: {np.median(gains):.1f}x "
               "(XLA cost_analysis counts scan bodies ONCE — HyPA's gap, "
               "reproduced on HLO)",
               f"median useful-flops ratio: {np.median(ratios):.3f}"]
    rows.append(csv_row("hxa_loop_gain_median", 0.0,
                        f"gain={np.median(gains):.2f}x"))
    rows.append(csv_row("hxa_useful_ratio_median", 0.0,
                        f"ratio={np.median(ratios):.3f}"))
    write_report("hxa_accuracy.md", "\n".join(report))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
