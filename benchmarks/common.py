"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import os
import time

import numpy as np

ART_DIR = os.environ.get("REPRO_ART_DIR",
                         os.path.join(os.getcwd(), "experiments", "dryrun"))
OUT_DIR = os.environ.get("REPRO_BENCH_DIR",
                         os.path.join(os.getcwd(), "experiments", "bench"))


def ensure_artifacts():
    from repro.core import dataset
    arts = dataset.load_dryrun_artifacts(ART_DIR)
    if not arts:
        raise SystemExit(
            f"no dry-run artifacts in {ART_DIR}; run "
            "`PYTHONPATH=src python -m repro.launch.dryrun --all` first")
    return arts


def timed(fn, *args, repeats: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def write_report(fname: str, text: str):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, fname)
    with open(path, "w") as f:
        f.write(text)
    return path
