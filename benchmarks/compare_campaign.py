"""Cross-PR frontier regression check for ``BENCH_dse_campaign.json``.

CI uploads the campaign artifact on every run; this script diffs the current
artifact against the previous run's and fails when a workload's final
hypervolume proxy regresses by more than ``--hv-rel-tol`` (the ROADMAP's
"diff frontiers across PRs" open item).  Frontier-size and best-extreme
changes are reported but informational — intentional model changes move
them, while a hypervolume collapse on an unchanged model is a real bug.

  python -m benchmarks.compare_campaign PREV.json NEW.json [--hv-rel-tol 0.05]

A missing/unreadable PREV (first run, expired artifact) is a clean pass so
the step can be wired unconditionally into CI.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple


def final_hypervolumes(payload: Dict) -> Dict[str, float]:
    """Workload key -> last trajectory snapshot's hypervolume proxy."""
    return {key: snaps[-1]["hypervolume"]
            for key, snaps in payload.get("trajectory", {}).items() if snaps}


def compare_campaigns(prev: Dict, new: Dict,
                      hv_rel_tol: float = 0.05) -> Tuple[bool, List[str]]:
    """(ok, report lines) for a prev -> new campaign artifact pair.

    ``ok`` is False iff a workload present in BOTH artifacts regressed its
    final hypervolume by more than ``hv_rel_tol`` relative.  Workloads that
    appear or disappear (artifact-cache growth) are reported, not gated, and
    artifacts from different ``sim_model_version``s (intentional cost-model
    changes) are never gated against each other — their hypervolume proxies
    are not comparable.
    """
    hv_prev = final_hypervolumes(prev)
    hv_new = final_hypervolumes(new)
    lines, ok = [], True
    gate = True
    vp, vn = prev.get("sim_model_version"), new.get("sim_model_version")
    if vp != vn:
        # intentional cost-model change: hypervolumes are not comparable
        lines.append(f"sim model version changed ({vp} -> {vn}); "
                     "reporting only, hv regression not gated")
        gate = False
    if prev.get("space", {}).get("size") != new.get("space", {}).get("size"):
        lines.append(f"space size changed: {prev.get('space', {}).get('size')}"
                     f" -> {new.get('space', {}).get('size')}")
    for key in sorted(set(hv_prev) | set(hv_new)):
        if key not in hv_prev:
            lines.append(f"{key}: NEW workload (hv {hv_new[key]:.6e})")
            continue
        if key not in hv_new:
            lines.append(f"{key}: workload DROPPED from artifact")
            continue
        p, n = hv_prev[key], hv_new[key]
        rel = (n - p) / abs(p) if p else 0.0
        fp = len(prev["frontiers"].get(key, {}).get("points", []))
        fn = len(new["frontiers"].get(key, {}).get("points", []))
        tag = "ok"
        if gate and p and rel < -hv_rel_tol:
            tag = f"REGRESSION (> {hv_rel_tol:.0%} hv loss)"
            ok = False
        lines.append(f"{key}: hv {p:.6e} -> {n:.6e} ({rel:+.2%}), "
                     f"frontier {fp} -> {fn} points  [{tag}]")
    if not hv_prev:
        lines.append("previous artifact has no trajectories; nothing gated")
    return ok, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev", help="previous BENCH_dse_campaign.json")
    ap.add_argument("new", help="current BENCH_dse_campaign.json")
    ap.add_argument("--hv-rel-tol", type=float, default=0.05,
                    help="max allowed relative hypervolume regression")
    args = ap.parse_args(argv)
    try:
        with open(args.prev) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[compare_campaign] no usable previous artifact "
              f"({args.prev}: {e}); skipping compare")
        return 0
    with open(args.new) as f:
        new = json.load(f)
    ok, lines = compare_campaigns(prev, new, args.hv_rel_tol)
    for ln in lines:
        print(f"[compare_campaign] {ln}")
    if not ok:
        print("[compare_campaign] FAIL: frontier hypervolume regressed")
        return 1
    print("[compare_campaign] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
