"""Cross-PR frontier regression check for ``BENCH_dse_campaign.json``.

CI uploads the campaign artifact on every run; this script diffs the current
artifact against the previous run's and fails when a workload's final
hypervolume proxy regresses by more than ``--hv-rel-tol`` (the ROADMAP's
"diff frontiers across PRs" open item).  Frontier-size and best-extreme
changes are reported but informational — intentional model changes move
them, while a hypervolume collapse on an unchanged model is a real bug.

  python -m benchmarks.compare_campaign PREV.json NEW.json [--hv-rel-tol 0.05]

A missing/unreadable PREV (first run, expired artifact) is a clean pass so
the step can be wired unconditionally into CI.

A second mode diffs the fused evaluators WITHIN one run's
``BENCH_evaluator_speedup.json``: the Pallas kernel frontier against the
fused ``"jit"`` frontier (float64-interpret vs float32 — candidate-set
drift is reported, hypervolume divergence beyond ``--evaluator-hv-tol``
gates) plus the artifact's recorded pallas-vs-numpy identity verdict:

  python -m benchmarks.compare_campaign --evaluators BENCH_evaluator_speedup.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple


def final_hypervolumes(payload: Dict) -> Dict[str, float]:
    """Workload key -> last trajectory snapshot's hypervolume proxy."""
    return {key: snaps[-1]["hypervolume"]
            for key, snaps in payload.get("trajectory", {}).items() if snaps}


def compare_campaigns(prev: Dict, new: Dict,
                      hv_rel_tol: float = 0.05) -> Tuple[bool, List[str]]:
    """(ok, report lines) for a prev -> new campaign artifact pair.

    ``ok`` is False iff a workload present in BOTH artifacts regressed its
    final hypervolume by more than ``hv_rel_tol`` relative.  Workloads that
    appear or disappear (artifact-cache growth) are reported, not gated, and
    artifacts from different ``sim_model_version``s (intentional cost-model
    changes) are never gated against each other — their hypervolume proxies
    are not comparable.
    """
    hv_prev = final_hypervolumes(prev)
    hv_new = final_hypervolumes(new)
    lines, ok = [], True
    gate = True
    vp, vn = prev.get("sim_model_version"), new.get("sim_model_version")
    if vp != vn:
        # intentional cost-model change: hypervolumes are not comparable
        lines.append(f"sim model version changed ({vp} -> {vn}); "
                     "reporting only, hv regression not gated")
        gate = False
    if prev.get("space", {}).get("size") != new.get("space", {}).get("size"):
        lines.append(f"space size changed: {prev.get('space', {}).get('size')}"
                     f" -> {new.get('space', {}).get('size')}")
    for key in sorted(set(hv_prev) | set(hv_new)):
        if key not in hv_prev:
            lines.append(f"{key}: NEW workload (hv {hv_new[key]:.6e})")
            continue
        if key not in hv_new:
            lines.append(f"{key}: workload DROPPED from artifact")
            continue
        p, n = hv_prev[key], hv_new[key]
        rel = (n - p) / abs(p) if p else 0.0
        fp = len(prev["frontiers"].get(key, {}).get("points", []))
        fn = len(new["frontiers"].get(key, {}).get("points", []))
        tag = "ok"
        if gate and p and rel < -hv_rel_tol:
            tag = f"REGRESSION (> {hv_rel_tol:.0%} hv loss)"
            ok = False
        lines.append(f"{key}: hv {p:.6e} -> {n:.6e} ({rel:+.2%}), "
                     f"frontier {fp} -> {fn} points  [{tag}]")
    if not hv_prev:
        lines.append("previous artifact has no trajectories; nothing gated")
    return ok, lines


def point_key(p: Dict) -> Tuple:
    return (p["chip"], p["n_chips"], tuple(p["mesh"]), p["freq_mhz"],
            p["index"])


def compare_evaluators(payload: Dict,
                       hv_rel_tol: float = 1e-3) -> Tuple[bool, List[str]]:
    """(ok, report lines) for one run's pallas-vs-jit evaluator frontiers.

    The two fused evaluators run different precisions (float64 interpret vs
    float32), so exact candidate-set equality is reported, not required;
    ``ok`` is False when a workload's pallas/jit hypervolumes diverge by
    more than ``hv_rel_tol`` relative, or when the artifact records that
    the pallas frontier failed to reproduce the numpy evaluator's candidate
    set (the hard identity the acceptance gate demands)."""
    lines, ok = [], True
    fronts = payload.get("frontiers", {})
    hv = payload.get("hv", {})
    jf, pf = fronts.get("jit", {}), fronts.get("pallas", {})
    for key in sorted(set(jf) | set(pf)):
        a = {point_key(p) for p in jf.get(key, {}).get("points", [])}
        b = {point_key(p) for p in pf.get(key, {}).get("points", [])}
        hj = hv.get("jit", {}).get(key)
        hp = hv.get("pallas", {}).get(key)
        if hj is None or hp is None:
            # one evaluator missing the workload entirely is a divergence
            rel = 0.0 if hj == hp else float("inf")
        elif hj == 0.0:
            # a collapsed jit hv must not mask a positive pallas hv
            rel = 0.0 if hp == 0.0 else float("inf")
        else:
            rel = abs(hp - hj) / abs(hj)
        tag = "ok"
        if rel > hv_rel_tol:
            tag = f"DIVERGED (> {hv_rel_tol:.0e} hv)"
            ok = False
        lines.append(f"{key}: pallas {len(b)} vs jit {len(a)} frontier "
                     f"points, {len(a & b)} shared; hv rel diff {rel:.2e}  "
                     f"[{tag}]")
    pvn = payload.get("pallas_vs_numpy", {})
    lines.append(f"pallas vs numpy: identical candidate set = "
                 f"{pvn.get('identical_candidate_set')}, max hv rel diff = "
                 f"{pvn.get('max_hv_rel_diff', float('nan')):.2e}")
    if not pvn.get("identical_candidate_set", False):
        lines.append("pallas frontier failed numpy identity")
        ok = False
    speedup = payload.get("speedup_pallas_vs_jit_baseline")
    if speedup is not None:
        lines.append(f"fused pallas speedup vs jit baseline: {speedup:.2f}x")
    return ok, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev", nargs="?", help="previous BENCH_dse_campaign.json")
    ap.add_argument("new", nargs="?", help="current BENCH_dse_campaign.json")
    ap.add_argument("--hv-rel-tol", type=float, default=0.05,
                    help="max allowed relative hypervolume regression")
    ap.add_argument("--evaluators", metavar="PATH",
                    help="BENCH_evaluator_speedup.json to self-diff (pallas "
                         "vs jit frontiers) instead of a prev/new compare")
    ap.add_argument("--evaluator-hv-tol", type=float, default=1e-3,
                    help="max pallas-vs-jit relative hypervolume divergence")
    args = ap.parse_args(argv)
    if args.evaluators:
        try:
            with open(args.evaluators) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[compare_campaign] no usable evaluator artifact "
                  f"({args.evaluators}: {e}); skipping compare")
            return 0
        ok, lines = compare_evaluators(payload, args.evaluator_hv_tol)
        for ln in lines:
            print(f"[compare_campaign] {ln}")
        print(f"[compare_campaign] {'PASS' if ok else 'FAIL: evaluator frontiers diverged'}")
        return 0 if ok else 1
    if not args.prev or not args.new:
        ap.error("prev and new artifacts required (or use --evaluators)")
    try:
        with open(args.prev) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[compare_campaign] no usable previous artifact "
              f"({args.prev}: {e}); skipping compare")
        return 0
    with open(args.new) as f:
        new = json.load(f)
    ok, lines = compare_campaigns(prev, new, args.hv_rel_tol)
    for ln in lines:
        print(f"[compare_campaign] {ln}")
    if not ok:
        print("[compare_campaign] FAIL: frontier hypervolume regressed")
        return 1
    print("[compare_campaign] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
