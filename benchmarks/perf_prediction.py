"""Paper Fig. 3 analogue — performance (cycles) prediction.

Paper reference: KNN MAPE 5.94% for number-of-cycles prediction.
Adds a leave-one-architecture-out split (harder than the paper's setup) as a
beyond-paper generalization check.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import ART_DIR, csv_row, timed, write_report
from repro.core import dataset, predictors


def _leave_one_arch_out(X, y, meta, model_name: str) -> float:
    archs = sorted({m.arch for m in meta})
    mapes = []
    arch_arr = np.asarray([m.arch for m in meta])
    for a in archs:
        test = arch_arr == a
        if test.sum() < 4 or (~test).sum() < 20:
            continue
        m = predictors.MODELS[model_name]()
        m.fit(X[~test], y[~test])
        mapes.append(predictors.mape(y[test], m.predict(X[test])))
    return float(np.mean(mapes)) if mapes else float("nan")


def run() -> list:
    X, y_power, y_cycles, meta = dataset.build_dataset(ART_DIR)
    rows, report = [], ["# Cycles prediction (paper Fig. 3 analogue)",
                        f"design points: {len(X)}", ""]
    for name in ("knn", "decision_tree", "random_forest"):
        res, wall = timed(predictors.kfold_evaluate, name, X, y_cycles, repeats=1)
        report.append(f"{name:16s} MAPE {res['mape']:6.2f}%   R2 {res['r2']:.4f}")
        rows.append(csv_row(f"cycles_pred_{name}", wall * 1e6 / max(len(X), 1),
                            f"mape={res['mape']:.2f}%;r2={res['r2']:.4f}"))
    report.append("(paper: KNN 5.94%)")
    loo = _leave_one_arch_out(X, y_cycles, meta, "random_forest")
    report += ["", f"leave-one-arch-out (beyond paper), random_forest: "
               f"MAPE {loo:.2f}%"]
    rows.append(csv_row("cycles_pred_loo_rf", 0.0, f"mape={loo:.2f}%"))
    write_report("perf_prediction.md", "\n".join(report))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
