"""Streaming DSE campaign benchmark — the mega-space sweep as a CI artifact.

Runs the default campaign (ALL cached dry-run workloads x the >=100k-point
``default_campaign_space``) with the float64 engine, verifies the streamed
frontier of one workload is IDENTICAL to one-shot ``dse.pareto_search`` on
the same concatenated space, and persists ``BENCH_dse_campaign.json``
(frontier members + per-tile trajectory + candidates/sec throughput) so CI
can diff frontiers across PRs.

It then races the evaluator tiers on the same space — the PR-3 ``"jit"``
per-workload baseline (``pipeline=False``), the fused multi-workload jit
sweep, and the fused Pallas DSE-sweep kernel (interpret mode on CPU) — and
persists ``BENCH_evaluator_speedup.json`` with per-evaluator
``candidates_per_sec`` (best of ``EVAL_REPEATS`` runs, the suite's standard
best-of timing), the pallas-vs-numpy frontier-identity verdict, and both
fused frontiers for the CI evaluator diff.  Gates (after artifacts are
written): pallas must reproduce the numpy frontier's exact candidate set
with hypervolume within 1e-6 relative, and the fused pallas pipeline must
beat the jit baseline's throughput by >= 3x.

Finally the distributed matrix: the same default campaign through the
multiprocess fabric at 1 and 2 workers on the jit evaluator — including a
2-worker run with an injected worker crash mid-tile plus a duplicated
payload delivery — persisted as ``BENCH_distributed_campaign.json``.
Gates: every fabric frontier must be BITWISE-identical to the
single-process jit frontier, and 2 workers must reach >= 1.8x the
1-worker candidates/sec on the busy-CPU clock (total candidate evaluations
divided by the slowest worker's summed per-tile ``time.process_time`` —
CPU actually burned on tiles, excluding compile warm-up, so the scaling
row measures work-splitting rather than host core count; the wall-clock
window from all-workers-ready to last fold is reported unguarded).

The adaptive matrix (``BENCH_adaptive_campaign.json``) runs the
surrogate-guided ``AdaptiveCampaign`` with default knobs against the exact
sweep: per-cell frontier hypervolume ratios under the exact campaign's
pinned reference points, the fraction of the space evaluated exactly, and
the budget=100% degenerate-identity check.  Gates: worst-cell hv ratio
>= 0.99 while evaluating <= 10% of the space, and budget=100% bitwise
equal to the exact jit sweep.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import (ART_DIR, OUT_DIR, csv_row, ensure_artifacts,
                               write_report)
from repro.core import costmodel, dse
from repro.dse_campaign import (AdaptiveCampaign, AdaptiveConfig, Campaign,
                                CampaignConfig, FaultInjection, LocalFabric,
                                MultiprocessFabric, canonical_frontier,
                                candidate_to_dict, default_campaign_space,
                                frontiers_identical, hypervolume_2d, store)
from repro.hw import get_chip, mesh_factorizations
from repro.telemetry import Telemetry

EVAL_REPEATS = 3          # best-of runs per evaluator (benchmarks.common.timed
                          # convention: min over repeats rides out CI noise)
FUSED_CHUNK = 32768       # fused evaluators amortize per-launch overhead over
                          # bigger staging tiles; the frontier is tile-size
                          # invariant (tests/test_dse_campaign.py), so this is
                          # an execution detail, not a space change
EVALUATOR_BENCH_NAME = "BENCH_evaluator_speedup.json"
DISTRIBUTED_BENCH_NAME = "BENCH_distributed_campaign.json"
ADAPTIVE_BENCH_NAME = "BENCH_adaptive_campaign.json"
ADAPTIVE_CHUNK = 512      # adaptive tiles are acquisition quanta: small
                          # enough that a 10% budget buys many rounds, big
                          # enough that fused launches stay amortized
ADAPTIVE_HV_GATE = 0.99   # adaptive frontier hv / exact-sweep hv, worst cell
ADAPTIVE_BUDGET_GATE = 0.10  # fraction of the space evaluated exactly
TRACE_ARTIFACT_NAME = "trace_dse_campaign.json"
SCALING_GATE = 1.8        # 2-worker busy-CPU throughput vs 1 worker
TELEMETRY_OVERHEAD_GATE = 0.02  # attributed instrumentation cost / sweep wall


def mesh_tie_report(wl: dse.Workload, chip_name: str = "tpu-v5e",
                    n_chips: int = 64) -> dict:
    """Before/after view of the factorization axis on one same-count family:
    the mesh-agnostic model ties every factorization of ``n_chips``; the
    topology model separates them.  Returns the counts the report prints."""
    chip = get_chip(chip_name)
    meshes = mesh_factorizations(n_chips, 3)
    legacy, topo = [], []
    for mesh in meshes:
        cand = dse.Candidate(chip_name, n_chips, mesh, chip.max_freq_mhz)
        ana = dse._scale_analysis(wl.base_analysis, wl.base_chips, cand)
        legacy.append(costmodel.simulate(
            ana, chip, n_chips, chip.max_freq_mhz).t_collective)
        topo.append(costmodel.simulate(
            ana, chip, n_chips, chip.max_freq_mhz, mesh=mesh).t_collective)
    ties_before = len(meshes) - len(set(legacy))
    ties_after = len(meshes) - len(set(topo))
    return {"chip": chip_name, "n_chips": n_chips, "meshes": meshes,
            "t_coll_topology": topo, "ties_before": ties_before,
            "ties_after": ties_after,
            "ties_broken": ties_before - ties_after}


def frontier_points(result) -> dict:
    """Campaign frontiers in the BENCH points shape (for the CI diff)."""
    out = {}
    for (arch, shape), front in sorted(result.frontiers.items()):
        out[f"{arch}|{shape}"] = {
            "feasible_count": front.feasible_count,
            "points": [{**candidate_to_dict(c), "energy_j": float(e),
                        "latency_s": float(l), "index": int(i)}
                       for c, e, l, i in zip(front.candidates, front.energy_j,
                                             front.latency_s, front.indices)],
        }
    return out


def final_hv(result) -> dict:
    return {f"{a}|{s}": snaps[-1].hypervolume
            for (a, s), snaps in sorted(result.trajectories.items()) if snaps}


def hv_with_ref(front, ref_e, ref_l) -> float:
    """Frontier hypervolume under an EXPLICIT reference point (shared
    ``hypervolume_2d``) — the trajectory proxy pins its ref from the first
    feasible tile, which depends on chunk size, so cross-chunk evaluator
    comparisons must re-anchor both frontiers to one ref."""
    return hypervolume_2d(front.energy_j, front.latency_s, ref_e, ref_l)


def same_candidate_set(a, b) -> bool:
    """Frontiers hold the same candidates at the same space indices (values
    may differ by evaluator precision)."""
    ca, _, _, ia = canonical_frontier(a)
    cb, _, _, ib = canonical_frontier(b)
    return ca == cb and np.array_equal(ia, ib)


def evaluator_matrix(workloads, cons, numpy_result, refs) -> tuple:
    """Race the evaluator tiers; returns (payload, report_lines, rows).

    ``refs`` maps workload key -> the numpy campaign's hypervolume reference
    point, re-used for every evaluator so the identity comparison is
    ref-consistent across chunk sizes."""
    configs = [
        # name, evaluator, pipeline, chunk, precision note
        ("jit-baseline", "jit", False, 4096, "float32, per-workload loop"),
        ("jit", "jit", True, FUSED_CHUNK, "float32, fused sweep"),
        ("pallas", "pallas", True, FUSED_CHUNK, "float64 (interpret), "
                                                "fused Pallas kernel"),
    ]
    evaluators = {"numpy": {
        "candidates_per_sec": numpy_result.candidates_per_sec,
        "sweep_wall_s": numpy_result.sweep_wall_s,
        "chunk_size": 4096, "pipeline": True,
        "precision": "float64, per-workload loop",
    }}
    results = {"numpy": numpy_result}
    for name, ev, pipe, chunk, note in configs:
        spec = default_campaign_space(chunk_size=chunk)
        best = None
        for _ in range(EVAL_REPEATS):
            r = Campaign(workloads, spec, constraint=cons, evaluator=ev,
                         pipeline=pipe).run()
            assert r.complete
            if best is None or r.candidates_per_sec > best.candidates_per_sec:
                best = r
        results[name] = best
        evaluators[name] = {
            "candidates_per_sec": best.candidates_per_sec,
            "sweep_wall_s": best.sweep_wall_s,
            "chunk_size": chunk, "pipeline": pipe, "precision": note,
        }

    keys = sorted(numpy_result.frontiers)
    identical = all(same_candidate_set(numpy_result.frontiers[k],
                                       results["pallas"].frontiers[k])
                    for k in keys)
    hv_rel = 0.0
    for k in keys:
        ref_e, ref_l = refs[k]
        a = hv_with_ref(numpy_result.frontiers[k], ref_e, ref_l)
        b = hv_with_ref(results["pallas"].frontiers[k], ref_e, ref_l)
        if a:
            hv_rel = max(hv_rel, abs(b - a) / abs(a))
    speedup = (evaluators["pallas"]["candidates_per_sec"]
               / evaluators["jit-baseline"]["candidates_per_sec"])

    payload = {
        "bench": "dse_evaluator_speedup",
        "python": platform.python_version(),
        "sim_model_version": costmodel.SIM_MODEL_VERSION,
        "space": default_campaign_space().to_dict(),
        "fused_chunk_size": FUSED_CHUNK,
        "repeats": EVAL_REPEATS,
        "workloads": [f"{a}|{s}" for a, s in keys],
        "evaluators": evaluators,
        "speedup_pallas_vs_jit_baseline": speedup,
        "pallas_vs_numpy": {"identical_candidate_set": identical,
                            "max_hv_rel_diff": hv_rel},
        "hv": {name: final_hv(r) for name, r in results.items()},
        "frontiers": {"jit": frontier_points(results["jit"]),
                      "pallas": frontier_points(results["pallas"])},
    }
    lines = ["", "## evaluator matrix (best of "
             f"{EVAL_REPEATS} runs, {len(keys)} workloads)", ""]
    for name, row in evaluators.items():
        lines.append(
            f"  {name:>12}: {row['candidates_per_sec']:>12,.0f} cands/sec "
            f"(sweep {row['sweep_wall_s']:6.2f}s, chunk {row['chunk_size']}, "
            f"{row['precision']})")
    lines += [
        f"  pallas vs numpy: identical candidate set = {identical}, "
        f"max hv rel diff = {hv_rel:.2e}",
        f"  fused pallas speedup vs PR-3 jit baseline: {speedup:.2f}x",
    ]
    rows = [csv_row(f"dse_evaluator_{name}",
                    1e6 / max(row["candidates_per_sec"], 1e-9),
                    f"cands_per_sec={row['candidates_per_sec']:.0f};"
                    f"chunk={row['chunk_size']}")
            for name, row in evaluators.items()]
    rows.append(csv_row("dse_evaluator_speedup", 0.0,
                        f"pallas_vs_jit_baseline={speedup:.2f}x;"
                        f"identical_set={identical};hv_rel={hv_rel:.2e}"))
    return payload, lines, rows


def distributed_matrix(workloads, cons) -> tuple:
    """The fabric scaling + identity matrix on the default campaign space.

    Runs the default jit campaign single-process (the bitwise reference),
    then through ``MultiprocessFabric`` at 1 worker, 2 workers, and
    2 workers with the full injected-failure script (worker crash mid-tile
    + duplicated payload delivery).  Throughput is busy-CPU based: total
    candidate evaluations / the slowest worker's summed per-tile
    ``process_time`` — a machine-independent work-splitting metric that
    holds on single-core CI runners where two workers cannot beat one on
    wall clock.  Returns (payload, report_lines, csv_rows).
    """
    spec = default_campaign_space()
    single = Campaign(workloads, spec, constraint=cons, evaluator="jit").run()
    assert single.complete
    total_cands = single.candidates_evaluated

    configs = [
        ("1-worker", 1, None),
        ("2-worker", 2, None),
        ("2-worker-faults", 2, FaultInjection(kill_worker=1,
                                              kill_after_tiles=1,
                                              duplicate=True)),
    ]
    runs = {}
    for name, n_workers, fault in configs:
        campaign = Campaign(workloads, spec, constraint=cons, evaluator="jit")
        fabric = MultiprocessFabric(campaign, n_workers=n_workers,
                                    fault=fault, lease_timeout_s=600.0)
        result = fabric.run()
        assert result.complete, (name, result.tiles_done, result.n_tiles)
        stats = fabric.stats
        identical = all(
            frontiers_identical(single.frontiers[k], result.frontiers[k])
            for k in single.frontiers)
        runs[name] = {
            "n_workers": n_workers,
            "identical_to_single_process": identical,
            "cands_per_busy_sec": total_cands
            / max(stats["max_worker_busy_s"], 1e-9),
            "worker_busy_s": {str(w): b
                              for w, b in sorted(stats["worker_busy_s"].items())},
            "max_worker_busy_s": stats["max_worker_busy_s"],
            "total_busy_s": stats["total_busy_s"],
            "window_s": stats["window_s"],
            "deliveries": stats["deliveries"],
            "duplicates": stats["duplicates"],
            "reissued_tiles": stats["reissued_tiles"],
            "lost_workers": stats["lost_workers"],
        }
    faults = runs["2-worker-faults"]
    assert faults["lost_workers"], "injected worker crash never fired"
    assert faults["duplicates"] >= 1, "duplicate delivery never folded"
    scaling = (runs["2-worker"]["cands_per_busy_sec"]
               / runs["1-worker"]["cands_per_busy_sec"])
    all_identical = all(r["identical_to_single_process"]
                       for r in runs.values())

    payload = {
        "bench": "dse_distributed_campaign",
        "python": platform.python_version(),
        "sim_model_version": costmodel.SIM_MODEL_VERSION,
        "space": spec.to_dict(),
        "evaluator": "jit",
        "workloads": sorted(f"{a}|{s}" for a, s in single.frontiers),
        "candidates_evaluated": total_cands,
        "single_process": {
            "cands_per_busy_sec": total_cands / max(single.sweep_wall_s, 1e-9),
            "sweep_wall_s": single.sweep_wall_s,
        },
        "runs": runs,
        "scaling_2w_vs_1w": scaling,
        "scaling_gate": SCALING_GATE,
        "all_identical_to_single_process": all_identical,
        "hv": final_hv(single),
    }
    lines = ["", f"## distributed fabric ({len(single.frontiers)} workloads, "
             f"{spec.n_tiles()} tiles, jit evaluator)", ""]
    for name, row in runs.items():
        busy = ", ".join(f"w{w}={b:.2f}s"
                         for w, b in row["worker_busy_s"].items())
        lines.append(
            f"  {name:>16}: {row['cands_per_busy_sec']:>12,.0f} cands/busy-sec "
            f"(busy {busy}; window {row['window_s']:5.2f}s; "
            f"{row['duplicates']} dup, {row['reissued_tiles']} reissued, "
            f"lost {row['lost_workers']}) "
            f"identical={row['identical_to_single_process']}")
    lines += [
        f"  2-worker scaling vs 1-worker (busy-CPU): {scaling:.2f}x "
        f"(gate >= {SCALING_GATE}x)",
        f"  all fabric frontiers bitwise == single process: {all_identical}",
    ]
    rows = [csv_row(f"dse_distributed_{name}",
                    1e6 / max(row["cands_per_busy_sec"], 1e-9),
                    f"cands_per_busy_sec={row['cands_per_busy_sec']:.0f};"
                    f"workers={row['n_workers']};"
                    f"identical={row['identical_to_single_process']}")
            for name, row in runs.items()]
    rows.append(csv_row("dse_distributed_scaling", 0.0,
                        f"scaling_2w_vs_1w={scaling:.2f}x;"
                        f"identical={all_identical};"
                        f"faults_lost={faults['lost_workers']};"
                        f"faults_dup={faults['duplicates']}"))
    return payload, lines, rows


def adaptive_matrix(workloads, cons, exact_result, refs) -> tuple:
    """Surrogate-guided campaign vs the exact sweep: the >=99%-hypervolume-
    at-<=10%-evaluated headline.  Returns (payload, report_lines, csv_rows).

    The adaptive run uses the default ``AdaptiveConfig`` on the default
    space re-tiled to ``ADAPTIVE_CHUNK`` (the frontier is tile-size
    invariant, so this is an acquisition granularity, not a space change).
    Hypervolume ratios are computed per workload cell against the exact
    float64 campaign's frontier under ITS pinned reference points — the
    worst cell is the gated quantity.  A second pair of runs checks the
    degenerate contract: ``budget_fraction=1.0`` must reproduce the exact
    jit sweep on the same config bitwise.

    Wall clock is reported but NOT gated: on this ~125k-point space the
    exact fused sweep is already sub-second, so surrogate fitting and
    acquisition scoring eat most of what the skipped evaluations save — the
    evaluation-count reduction (1 / fraction evaluated) is the quantity
    that transfers to spaces where a single tile costs minutes.  The gates
    are frontier quality and budget only.
    """
    sweep_spec = default_campaign_space(chunk_size=FUSED_CHUNK)
    sweep = Campaign(workloads, sweep_spec, constraint=cons,
                     evaluator="jit").run()
    assert sweep.complete
    hv_exact = {k: hv_with_ref(exact_result.frontiers[k], *refs[k])
                for k in refs}

    spec = default_campaign_space(chunk_size=ADAPTIVE_CHUNK)
    acfg = AdaptiveConfig()
    tel = Telemetry()
    adaptive = AdaptiveCampaign(
        workloads, CampaignConfig(space=spec, evaluator="jit",
                                  constraint=cons, adaptive=acfg),
        telemetry=tel)
    ares = adaptive.run()

    ratios = {}
    for k in sorted(refs):
        hv_a = hv_with_ref(adaptive.frontiers[k], *refs[k])
        ratios[f"{k[0]}|{k[1]}"] = hv_a / hv_exact[k] if hv_exact[k] else 1.0
    min_ratio = min(ratios.values())
    eval_reduction = 1.0 / max(ares.fraction_evaluated, 1e-12)
    wall_speedup = sweep.sweep_wall_s / max(ares.result.sweep_wall_s, 1e-9)

    # degenerate contract: budget=100% == the exact jit sweep, bitwise
    exact_jit = Campaign(workloads, CampaignConfig(
        space=spec, evaluator="jit", constraint=cons))
    exact_jit.run()
    full = AdaptiveCampaign(workloads, CampaignConfig(
        space=spec, evaluator="jit", constraint=cons,
        adaptive=AdaptiveConfig(budget_fraction=1.0)))
    full.run()
    budget100_identical = all(
        frontiers_identical(exact_jit.frontiers[k], full.frontiers[k])
        for k in exact_jit.frontiers)

    counters = {c["name"]: c["value"] for c in tel.snapshot()["counters"]
                if c["name"].startswith("adaptive_")}
    payload = {
        "bench": "dse_adaptive_campaign",
        "python": platform.python_version(),
        "sim_model_version": costmodel.SIM_MODEL_VERSION,
        "space": spec.to_dict(),
        "adaptive_config": acfg.to_dict(),
        "workloads": sorted(ratios),
        "candidates_evaluated": ares.candidates_evaluated,
        "space_size": ares.space_size,
        "fraction_evaluated": ares.fraction_evaluated,
        "budget_gate": ADAPTIVE_BUDGET_GATE,
        "hv_ratio": ratios,
        "min_hv_ratio": min_ratio,
        "hv_ratio_gate": ADAPTIVE_HV_GATE,
        "rounds": len(ares.rounds),
        "tiles_evaluated": ares.tiles_evaluated,
        "n_tiles": ares.n_tiles,
        "stopped_on": ares.stopped_on,
        "hv_history": ares.hv_history,
        "budget100_identical_to_exact": budget100_identical,
        "adaptive_wall_s": ares.result.sweep_wall_s,
        "exact_sweep_wall_s": sweep.sweep_wall_s,
        "wall_speedup_vs_fused_sweep": wall_speedup,
        "eval_count_reduction": eval_reduction,
        "counters": dict(sorted(counters.items())),
        "frontiers": frontier_points(ares.result),
    }
    lines = ["", f"## adaptive campaign (surrogate-guided, chunk "
             f"{ADAPTIVE_CHUNK}, {len(ratios)} workloads)", ""]
    for cell, r in sorted(ratios.items()):
        lines.append(f"  {cell:>24}: hv ratio {r:.5f}")
    lines += [
        f"  evaluated {ares.candidates_evaluated:,} / {ares.space_size:,} "
        f"candidates = {ares.fraction_evaluated:.2%} "
        f"(gate <= {ADAPTIVE_BUDGET_GATE:.0%}; {eval_reduction:.1f}x fewer "
        f"evaluations)",
        f"  min hv ratio {min_ratio:.5f} (gate >= {ADAPTIVE_HV_GATE}); "
        f"{len(ares.rounds)} rounds, stopped on {ares.stopped_on}",
        f"  wall: adaptive {ares.result.sweep_wall_s:.1f}s vs exact fused "
        f"sweep {sweep.sweep_wall_s:.1f}s ({wall_speedup:.2f}x — not gated; "
        f"the eval-count reduction is the transferable quantity)",
        f"  budget=100% bitwise == exact sweep: {budget100_identical}",
    ]
    rows = [
        csv_row("dse_adaptive_campaign", ares.result.sweep_wall_s * 1e6,
                f"min_hv_ratio={min_ratio:.5f};"
                f"fraction_evaluated={ares.fraction_evaluated:.4f};"
                f"rounds={len(ares.rounds)};stopped={ares.stopped_on}"),
        csv_row("dse_adaptive_identity", 0.0,
                f"budget100_identical={budget100_identical};"
                f"eval_reduction={eval_reduction:.1f}x;"
                f"wall_speedup={wall_speedup:.2f}x"),
    ]
    return payload, lines, rows


def _op_cost_s(fn, n: int) -> float:
    """Mean wall cost of one ``fn()`` call over ``n`` in-process repeats."""
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def telemetry_matrix(workloads, cons) -> tuple:
    """Telemetry cost + trace artifact: (payload, report_lines, csv_rows).

    Races the fused jit campaign uninstrumented (default ``NullTelemetry``)
    against fully instrumented (``Telemetry()``, tracing on), interleaved
    best of ``EVAL_REPEATS`` each.  Gates (asserted in ``run`` after
    artifacts are written): the two frontiers are BITWISE identical — no
    instrumented value feeds computation — and the instrumentation's
    *attributed* cost stays < ``TELEMETRY_OVERHEAD_GATE`` of the sweep.

    The gated overhead is attributed, not end-to-end differenced: the run's
    instrumentation totals ~100 µs of spans and counter bumps, while the
    sweep's run-to-run wall spread on a shared CI box is several percent —
    a none-vs-``NullTelemetry()`` control (byte-identical code paths)
    differed by ~7% in calibration, so an end-to-end delta gates machine
    noise, not telemetry.  Instead the instrumented run is charged for
    every operation it actually performed — exact span count from its
    tracer ring, counter-inc count reconstructed from its own counters — at
    per-op costs measured in-process on the same primitives.  The raw
    end-to-end delta still rides in the artifact (``end_to_end_frac``) as
    an informational reading.

    Then an instrumented ``LocalFabric`` run (leases + checkpoints + tile
    evaluation in one process) produces the Perfetto-ready
    ``trace_dse_campaign.json`` artifact, validated by
    ``tools/trace_report.py --check`` (required spans present, parent/depth
    nesting sane).
    """
    spec = default_campaign_space(chunk_size=FUSED_CHUNK)

    def one(telemetry):
        r = Campaign(workloads, spec, constraint=cons, evaluator="jit",
                     telemetry=telemetry).run()
        assert r.complete
        return r

    one(None)                              # jit compile warm-up
    # interleaved best-of: alternating uninstrumented / instrumented runs so
    # machine drift (thermal, cache, background load) cannot bias one side
    base = instr = instr_tel = None
    for _ in range(EVAL_REPEATS):
        b = one(None)
        t = Telemetry()
        i = one(t)
        if base is None or b.sweep_wall_s < base.sweep_wall_s:
            base = b
        if instr is None or i.sweep_wall_s < instr.sweep_wall_s:
            instr, instr_tel = i, t
    identical = all(
        frontiers_identical(base.frontiers[k], instr.frontiers[k])
        for k in base.frontiers)
    end_to_end = (instr.sweep_wall_s - base.sweep_wall_s) / base.sweep_wall_s

    # per-op calibration on the same primitives the campaign uses
    cal = Telemetry()
    cal_counter = cal.counter("calibration_total")

    def _span_once():
        with cal.span("calibration", tile=0):
            pass

    span_cost = _op_cost_s(_span_once, 20_000)
    inc_cost = _op_cost_s(cal_counter.inc, 50_000)

    # what the best instrumented run actually did: spans from its ring,
    # counter incs from its own counters (one inc per fused launch;
    # candidates + survivors + tiles_total per tile; one per checkpoint)
    n_spans_run = len(instr_tel.tracer.records)
    tiles = instr_tel.counter("campaign_tiles_total").value
    launches = instr_tel.counter("evaluator_fused_launches_total").value
    ckpts = instr_tel.counter("campaign_checkpoint_writes_total").value
    counter_ops = launches + 3 * tiles + ckpts
    attributed_s = n_spans_run * span_cost + counter_ops * inc_cost
    overhead = attributed_s / instr.sweep_wall_s

    # the trace artifact: one instrumented LocalFabric sweep — the single
    # process that emits lease AND checkpoint_write AND tile_eval spans
    tel = Telemetry()
    campaign = Campaign(workloads, spec, constraint=cons, evaluator="jit",
                        telemetry=tel)
    with tempfile.TemporaryDirectory() as tmp:
        LocalFabric(campaign, n_workers=2, seed=0).run(
            checkpoint_path=os.path.join(tmp, "fabric_ckpt.json"))
    os.makedirs(OUT_DIR, exist_ok=True)
    trace_path = tel.export_trace(os.path.join(OUT_DIR, TRACE_ARTIFACT_NAME))
    n_spans = len(tel.tracer.records)
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    check = subprocess.run(
        [sys.executable, os.path.join(repo_root, "tools", "trace_report.py"),
         trace_path, "--check"], capture_output=True, text=True)
    trace_ok = check.returncode == 0

    payload = {
        "overhead": {
            "base_sweep_wall_s": base.sweep_wall_s,
            "instrumented_sweep_wall_s": instr.sweep_wall_s,
            # informational only — noise-bound on a shared box (see
            # telemetry_matrix docstring); the gate rides overhead_frac
            "end_to_end_frac": end_to_end,
            "span_cost_us": span_cost * 1e6,
            "counter_inc_cost_us": inc_cost * 1e6,
            "spans_recorded": n_spans_run,
            "counter_ops": counter_ops,
            "attributed_s": attributed_s,
            "overhead_frac": overhead,
            "gate": TELEMETRY_OVERHEAD_GATE,
            "repeats": EVAL_REPEATS,
            "identical_frontiers": identical,
        },
        "trace_artifact": TRACE_ARTIFACT_NAME,
        "trace_spans": n_spans,
        "trace_check_ok": trace_ok,
        "trace_check_output": (check.stdout + check.stderr)[-2000:],
        "metrics": tel.snapshot(),
    }
    lines = [
        "", "## telemetry (fused jit sweep, interleaved best of "
        f"{EVAL_REPEATS} runs)", "",
        f"  uninstrumented sweep: {base.sweep_wall_s:6.3f}s; "
        f"instrumented: {instr.sweep_wall_s:6.3f}s "
        f"(end-to-end delta {end_to_end:+.2%}, informational)",
        f"  attributed cost: {n_spans_run} spans x {span_cost * 1e6:.2f}us "
        f"+ {counter_ops:.0f} counter incs x {inc_cost * 1e6:.2f}us = "
        f"{attributed_s * 1e3:.3f}ms -> {overhead:.3%} of sweep "
        f"(gate < {TELEMETRY_OVERHEAD_GATE:.0%})",
        f"  instrumented frontier bitwise == uninstrumented: {identical}",
        f"  trace artifact: {trace_path} ({n_spans} spans, "
        f"trace_report --check {'OK' if trace_ok else 'FAILED'})",
    ]
    rows = [csv_row("dse_telemetry_overhead", overhead * 1e6,
                    f"overhead_frac={overhead:.6f};identical={identical};"
                    f"trace_spans={n_spans};trace_check_ok={trace_ok}")]
    return payload, lines, rows


def run() -> list:
    ensure_artifacts()
    spec = default_campaign_space()
    cons = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)
    campaign = Campaign.from_artifacts(ART_DIR, spec, constraint=cons)
    result = campaign.run()
    assert result.complete, (result.tiles_done, result.n_tiles)

    n_cands = len(spec)
    n_workloads = len(campaign.workloads)
    us_per_cand = result.sweep_wall_s / max(result.candidates_evaluated, 1) * 1e6

    # acceptance gate: streamed frontier == one-shot pareto_search on the
    # SAME space (first workload; the one-shot side materializes the whole
    # space once, which is exactly the cost the campaign path avoids)
    wl = campaign.workloads[0]
    key = (wl.arch, wl.shape)
    oneshot = dse.pareto_search(wl, spec.slice(0, n_cands), cons)[key]
    identical = frontiers_identical(result.frontiers[key], oneshot)

    # telemetry: overhead/identity gates + the Perfetto trace artifact; its
    # metrics snapshot rides in BENCH_dse_campaign.json under "telemetry"
    tel_payload, tel_lines, tel_rows = telemetry_matrix(
        campaign.workloads, cons)

    path = store.save_campaign(
        result, spec.to_dict(), dataclasses.asdict(cons), campaign.evaluator,
        OUT_DIR, seed=0, extra={"telemetry": tel_payload})

    report = [
        "# Streaming DSE campaign (mega-space sweep)",
        f"space: {n_cands} candidates ({spec.n_rows} rows x "
        f"{spec.freq_points} DVFS points), {result.n_tiles} tiles of "
        f"{spec.chunk_size}",
        f"workloads: {n_workloads}; evaluations: "
        f"{result.candidates_evaluated}",
        f"throughput: {result.candidates_per_sec:,.0f} candidates/sec "
        f"({us_per_cand:.2f} us/candidate incl. tile materialization)",
        f"streamed-vs-oneshot frontier identical: {identical}",
        f"artifact: {path}",
        "",
        "frontier trajectory (first workload, every 5th tile):",
    ]
    for snap in result.trajectories[key][::5]:
        report.append(
            f"  tile {snap.tile:3d}: evaluated {snap.evaluated:7d}, "
            f"frontier {snap.frontier_size:4d}, "
            f"best {snap.best_energy_j:10.1f} J / "
            f"{snap.best_latency_s * 1e3:8.2f} ms, "
            f"hv {snap.hypervolume:.3e}")
    for (arch, shape), front in sorted(result.frontiers.items()):
        report.append(f"  {arch} x {shape}: {len(front)} frontier points of "
                      f"{front.feasible_count} feasible")

    # topology model: the factorization axis now carries signal — report the
    # frontier rows WITH their meshes and the same-count ties it broke
    ties = mesh_tie_report(wl)
    report += [
        "",
        f"mesh factorization signal ({ties['chip']} x{ties['n_chips']}, "
        f"{len(ties['meshes'])} same-count meshes):",
        f"  frontier ties before (mesh-agnostic model): {ties['ties_before']}",
        f"  frontier ties after  (topology model):      {ties['ties_after']}",
        f"  ties broken: {ties['ties_broken']}",
    ]
    for mesh, t in zip(ties["meshes"], ties["t_coll_topology"]):
        report.append(f"    mesh {'x'.join(map(str, mesh)):>8}: "
                      f"t_coll {t * 1e3:9.3f} ms")
    front = result.frontiers[key]
    report.append("")
    report.append("mesh-differentiated frontier rows (first workload, "
                  "first 12 by latency):")
    for cand, e, lat in list(zip(front.candidates, front.energy_j,
                                 front.latency_s))[:12]:
        report.append(
            f"    {cand.chip:>8} x{cand.n_chips:<4} "
            f"mesh {'x'.join(map(str, cand.mesh)):>8} @ "
            f"{cand.freq_mhz:7.1f} MHz   {lat * 1e3:9.2f} ms   "
            f"{e:12.1f} J")

    # evaluator race: PR-3 jit baseline vs fused jit vs fused Pallas kernel
    refs = {k: (fr.ref_energy_j, fr.ref_latency_s)
            for k, fr in campaign.frontiers.items()}
    eval_payload, eval_lines, eval_rows = evaluator_matrix(
        campaign.workloads, cons, result, refs)
    report += eval_lines
    os.makedirs(OUT_DIR, exist_ok=True)
    eval_path = os.path.join(OUT_DIR, EVALUATOR_BENCH_NAME)
    with open(eval_path, "w") as f:
        json.dump(eval_payload, f, indent=1)
    report.append(f"  artifact: {eval_path}")

    # distributed fabric: N workers, one frontier, same bits
    dist_payload, dist_lines, dist_rows = distributed_matrix(
        campaign.workloads, cons)
    report += dist_lines
    dist_path = os.path.join(OUT_DIR, DISTRIBUTED_BENCH_NAME)
    with open(dist_path, "w") as f:
        json.dump(dist_payload, f, indent=1)
    report.append(f"  artifact: {dist_path}")

    # adaptive campaign: the surrogate-guided budgeted search vs the sweep
    ad_payload, ad_lines, ad_rows = adaptive_matrix(
        campaign.workloads, cons, result, refs)
    report += ad_lines
    ad_path = os.path.join(OUT_DIR, ADAPTIVE_BENCH_NAME)
    with open(ad_path, "w") as f:
        json.dump(ad_payload, f, indent=1)
    report.append(f"  artifact: {ad_path}")
    report += tel_lines
    write_report("dse_campaign.md", "\n".join(report))

    rows = eval_rows + dist_rows + ad_rows + tel_rows + [
        csv_row("dse_campaign_throughput", us_per_cand,
                f"cands_per_sec={result.candidates_per_sec:.0f};"
                f"space={n_cands};tiles={result.n_tiles};"
                f"workloads={n_workloads}"),
        csv_row("dse_campaign_frontier", 0.0,
                ";".join(f"{a}x{s}={len(f)}" for (a, s), f
                         in sorted(result.frontiers.items()))),
        csv_row("dse_campaign_identity", 0.0,
                f"streamed_equals_oneshot={identical}"),
        csv_row("dse_campaign_mesh_signal", 0.0,
                f"ties_before={ties['ties_before']};"
                f"ties_after={ties['ties_after']};"
                f"ties_broken={ties['ties_broken']}"),
    ]
    # gate AFTER report/rows so a mismatch still leaves diagnostics behind
    assert identical, "streamed frontier diverged from one-shot pareto_search"
    assert ties["ties_broken"] > 0, \
        "topology model failed to break same-count mesh ties"
    pvn = eval_payload["pallas_vs_numpy"]
    assert pvn["identical_candidate_set"], \
        "pallas evaluator frontier candidate set diverged from numpy"
    assert pvn["max_hv_rel_diff"] <= 1e-6, \
        f"pallas hypervolume drifted {pvn['max_hv_rel_diff']:.2e} (> 1e-6)"
    assert dist_payload["all_identical_to_single_process"], \
        "a distributed fabric frontier diverged from the single-process run"
    assert ad_payload["budget100_identical_to_exact"], \
        "adaptive campaign at budget=100% diverged from the exact jit sweep"
    assert ad_payload["min_hv_ratio"] >= ADAPTIVE_HV_GATE, \
        f"adaptive frontier hypervolume ratio {ad_payload['min_hv_ratio']:.5f}" \
        f" (worst cell) below the {ADAPTIVE_HV_GATE} gate"
    assert ad_payload["fraction_evaluated"] <= ADAPTIVE_BUDGET_GATE, \
        f"adaptive campaign evaluated {ad_payload['fraction_evaluated']:.2%} " \
        f"of the space (gate <= {ADAPTIVE_BUDGET_GATE:.0%})"
    tover = tel_payload["overhead"]
    assert tover["identical_frontiers"], \
        "instrumented campaign frontier diverged from uninstrumented"
    assert tel_payload["trace_check_ok"], \
        "trace_dse_campaign.json failed tools/trace_report.py --check:\n" \
        + tel_payload["trace_check_output"]
    # throughput gates LAST: machine-sensitive, must never mask a
    # correctness verdict above
    assert tover["overhead_frac"] < TELEMETRY_OVERHEAD_GATE, \
        f"attributed telemetry cost {tover['overhead_frac']:.3%} " \
        f"({tover['spans_recorded']} spans + {tover['counter_ops']:.0f} " \
        f"counter incs) exceeds {TELEMETRY_OVERHEAD_GATE:.0%} of the sweep"
    speedup = eval_payload["speedup_pallas_vs_jit_baseline"]
    assert speedup >= 3.0, \
        f"fused pallas pipeline only {speedup:.2f}x over the jit baseline"
    scaling = dist_payload["scaling_2w_vs_1w"]
    assert scaling >= SCALING_GATE, \
        f"2-worker fabric only {scaling:.2f}x over 1 worker (busy-CPU)"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
