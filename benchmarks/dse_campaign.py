"""Streaming DSE campaign benchmark — the mega-space sweep as a CI artifact.

Runs the default campaign (ALL cached dry-run workloads x the >=100k-point
``default_campaign_space``) with the float64 engine, verifies the streamed
frontier of one workload is IDENTICAL to one-shot ``dse.pareto_search`` on
the same concatenated space, and persists ``BENCH_dse_campaign.json``
(frontier members + per-tile trajectory + candidates/sec throughput) so CI
can diff frontiers across PRs — the first entry in the bench trajectory the
roadmap asked for.
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import (ART_DIR, OUT_DIR, csv_row, ensure_artifacts,
                               write_report)
from repro.core import costmodel, dse
from repro.dse_campaign import (Campaign, default_campaign_space,
                                frontiers_identical, store)
from repro.hw import get_chip, mesh_factorizations


def mesh_tie_report(wl: dse.Workload, chip_name: str = "tpu-v5e",
                    n_chips: int = 64) -> dict:
    """Before/after view of the factorization axis on one same-count family:
    the mesh-agnostic model ties every factorization of ``n_chips``; the
    topology model separates them.  Returns the counts the report prints."""
    chip = get_chip(chip_name)
    meshes = mesh_factorizations(n_chips, 3)
    legacy, topo = [], []
    for mesh in meshes:
        cand = dse.Candidate(chip_name, n_chips, mesh, chip.max_freq_mhz)
        ana = dse._scale_analysis(wl.base_analysis, wl.base_chips, cand)
        legacy.append(costmodel.simulate(
            ana, chip, n_chips, chip.max_freq_mhz).t_collective)
        topo.append(costmodel.simulate(
            ana, chip, n_chips, chip.max_freq_mhz, mesh=mesh).t_collective)
    ties_before = len(meshes) - len(set(legacy))
    ties_after = len(meshes) - len(set(topo))
    return {"chip": chip_name, "n_chips": n_chips, "meshes": meshes,
            "t_coll_topology": topo, "ties_before": ties_before,
            "ties_after": ties_after,
            "ties_broken": ties_before - ties_after}


def run() -> list:
    ensure_artifacts()
    spec = default_campaign_space()
    cons = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)
    campaign = Campaign.from_artifacts(ART_DIR, spec, constraint=cons)
    result = campaign.run()
    assert result.complete, (result.tiles_done, result.n_tiles)

    n_cands = len(spec)
    n_workloads = len(campaign.workloads)
    us_per_cand = result.sweep_wall_s / max(result.candidates_evaluated, 1) * 1e6

    # acceptance gate: streamed frontier == one-shot pareto_search on the
    # SAME space (first workload; the one-shot side materializes the whole
    # space once, which is exactly the cost the campaign path avoids)
    wl = campaign.workloads[0]
    key = (wl.arch, wl.shape)
    oneshot = dse.pareto_search(wl, spec.slice(0, n_cands), cons)[key]
    identical = frontiers_identical(result.frontiers[key], oneshot)

    path = store.save_campaign(
        result, spec.to_dict(), dataclasses.asdict(cons), campaign.evaluator,
        OUT_DIR, seed=0)

    report = [
        "# Streaming DSE campaign (mega-space sweep)",
        f"space: {n_cands} candidates ({spec.n_rows} rows x "
        f"{spec.freq_points} DVFS points), {result.n_tiles} tiles of "
        f"{spec.chunk_size}",
        f"workloads: {n_workloads}; evaluations: "
        f"{result.candidates_evaluated}",
        f"throughput: {result.candidates_per_sec:,.0f} candidates/sec "
        f"({us_per_cand:.2f} us/candidate incl. tile materialization)",
        f"streamed-vs-oneshot frontier identical: {identical}",
        f"artifact: {path}",
        "",
        "frontier trajectory (first workload, every 5th tile):",
    ]
    for snap in result.trajectories[key][::5]:
        report.append(
            f"  tile {snap.tile:3d}: evaluated {snap.evaluated:7d}, "
            f"frontier {snap.frontier_size:4d}, "
            f"best {snap.best_energy_j:10.1f} J / "
            f"{snap.best_latency_s * 1e3:8.2f} ms, "
            f"hv {snap.hypervolume:.3e}")
    for (arch, shape), front in sorted(result.frontiers.items()):
        report.append(f"  {arch} x {shape}: {len(front)} frontier points of "
                      f"{front.feasible_count} feasible")

    # topology model: the factorization axis now carries signal — report the
    # frontier rows WITH their meshes and the same-count ties it broke
    ties = mesh_tie_report(wl)
    report += [
        "",
        f"mesh factorization signal ({ties['chip']} x{ties['n_chips']}, "
        f"{len(ties['meshes'])} same-count meshes):",
        f"  frontier ties before (mesh-agnostic model): {ties['ties_before']}",
        f"  frontier ties after  (topology model):      {ties['ties_after']}",
        f"  ties broken: {ties['ties_broken']}",
    ]
    for mesh, t in zip(ties["meshes"], ties["t_coll_topology"]):
        report.append(f"    mesh {'x'.join(map(str, mesh)):>8}: "
                      f"t_coll {t * 1e3:9.3f} ms")
    front = result.frontiers[key]
    report.append("")
    report.append("mesh-differentiated frontier rows (first workload, "
                  "first 12 by latency):")
    for cand, e, lat in list(zip(front.candidates, front.energy_j,
                                 front.latency_s))[:12]:
        report.append(
            f"    {cand.chip:>8} x{cand.n_chips:<4} "
            f"mesh {'x'.join(map(str, cand.mesh)):>8} @ "
            f"{cand.freq_mhz:7.1f} MHz   {lat * 1e3:9.2f} ms   "
            f"{e:12.1f} J")
    write_report("dse_campaign.md", "\n".join(report))

    rows = [
        csv_row("dse_campaign_throughput", us_per_cand,
                f"cands_per_sec={result.candidates_per_sec:.0f};"
                f"space={n_cands};tiles={result.n_tiles};"
                f"workloads={n_workloads}"),
        csv_row("dse_campaign_frontier", 0.0,
                ";".join(f"{a}x{s}={len(f)}" for (a, s), f
                         in sorted(result.frontiers.items()))),
        csv_row("dse_campaign_identity", 0.0,
                f"streamed_equals_oneshot={identical}"),
        csv_row("dse_campaign_mesh_signal", 0.0,
                f"ties_before={ties['ties_before']};"
                f"ties_after={ties['ties_after']};"
                f"ties_broken={ties['ties_broken']}"),
    ]
    # gate AFTER report/rows so a mismatch still leaves diagnostics behind
    assert identical, "streamed frontier diverged from one-shot pareto_search"
    assert ties["ties_broken"] > 0, \
        "topology model failed to break same-count mesh ties"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
