"""Benchmark suite — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract) and writes
markdown reports under experiments/bench/.

  PYTHONPATH=src python -m benchmarks.run [--only power,perf,...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    ("power", "benchmarks.power_prediction"),     # paper Fig. 2
    ("perf", "benchmarks.perf_prediction"),       # paper Fig. 3
    ("hxa", "benchmarks.hxa_accuracy"),           # HyPA table
    ("dse", "benchmarks.dse_speedup"),            # DSE motivation
    ("offload", "benchmarks.offload_analysis"),   # paper §IV
    ("roofline", "benchmarks.roofline_table"),    # §Roofline generator
    ("kernels", "benchmarks.kernel_bench"),       # Pallas kernels
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="comma-separated subset of: "
                    + ",".join(k for k, _ in MODULES))
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for key, modname in MODULES:
        if want and key not in want:
            continue
        try:
            import importlib
            mod = importlib.import_module(modname)
            for row in mod.run():
                print(row)
        except SystemExit as e:
            print(f"{key},0,SKIPPED:{e}")
        except Exception:
            failed.append(key)
            traceback.print_exc()
    if failed:
        sys.exit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
