"""Benchmark suite — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract), writes markdown
reports under experiments/bench/, and optionally a machine-readable JSON
trajectory (``--json``) for CI smoke runs and BENCH_*.json comparisons.
All RNGs are seeded up front so runs are deterministic.

  PYTHONPATH=src python -m benchmarks.run [--only power,perf,...]
                                          [--json experiments/bench/run.json]
                                          [--seed 0]
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import random
import sys
import traceback

MODULES = [
    ("power", "benchmarks.power_prediction"),     # paper Fig. 2
    ("perf", "benchmarks.perf_prediction"),       # paper Fig. 3
    ("hxa", "benchmarks.hxa_accuracy"),           # HyPA table
    ("dse", "benchmarks.dse_speedup"),            # DSE motivation
    ("campaign", "benchmarks.dse_campaign"),      # streaming mega-space sweep
    ("serving", "benchmarks.serving"),            # selection query layer
    ("chaos", "benchmarks.chaos"),                # fault-recovery identity
    ("offload", "benchmarks.offload_analysis"),   # paper §IV
    ("roofline", "benchmarks.roofline_table"),    # §Roofline generator
    ("kernels", "benchmarks.kernel_bench"),       # Pallas kernels
]


def seed_everything(seed: int) -> None:
    """Deterministic CI smoke runs: seed the python and numpy global RNGs.
    (Hash randomization is fixed at interpreter startup; set PYTHONHASHSEED
    in the environment if a benchmark ever depends on hash order.)"""
    random.seed(seed)
    try:
        import numpy as np
        np.random.seed(seed)
    except ImportError:
        pass


def parse_row(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="comma-separated subset of: "
                    + ",".join(k for k, _ in MODULES))
    ap.add_argument("--json", dest="json_path", metavar="PATH",
                    help="also write results as JSON to PATH")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for python/numpy RNGs (default 0)")
    args = ap.parse_args()
    seed_everything(args.seed)
    want = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed, skipped, results = [], {}, []
    for key, modname in MODULES:
        if want and key not in want:
            continue
        try:
            import importlib
            mod = importlib.import_module(modname)
            for row in mod.run():
                print(row)
                results.append({"suite": key, **parse_row(row)})
        except SystemExit as e:
            print(f"{key},0,SKIPPED:{e}")
            skipped[key] = str(e)
        except Exception:
            failed.append(key)
            traceback.print_exc()
    if args.json_path:
        payload = {
            "seed": args.seed,
            "python": platform.python_version(),
            "results": results,
            "skipped": skipped,
            "failed": failed,
        }
        d = os.path.dirname(args.json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[bench] wrote {args.json_path}", file=sys.stderr)
    if failed:
        sys.exit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
