"""Markdown link checker for the repo's docs.

Walks README.md, ROADMAP.md, and docs/*.md, extracts inline markdown links
(``[text](target)``), and verifies that every **local** target resolves to a
real file or directory relative to the file containing the link.  Fragments
(``#section``) are checked for existence of the file only; pure-fragment
links and external URLs (``http(s)://``, ``mailto:``) are skipped, as are
links inside fenced code blocks.  Targets that escape the repo root (the
GitHub-relative ``../../actions/...`` badge URL) are skipped too — they are
resolved by github.com, not the working tree.

Exit status is non-zero (with one line per broken link) if anything dangles,
so CI can gate on it:

    python tools/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# Inline links only; reference-style links are not used in this repo.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^(```|~~~)")

SKIP_SCHEMES = ("http://", "https://", "mailto:")


def iter_links(md_path: Path):
    """Yield (lineno, target) for every inline link outside code fences."""
    in_fence = False
    for lineno, line in enumerate(md_path.read_text().splitlines(), start=1):
        if _FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK_RE.finditer(line):
            yield lineno, m.group(1)


def check_file(md_path: Path) -> list[str]:
    errors = []
    for lineno, target in iter_links(md_path):
        if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
            continue
        path_part = target.split("#", 1)[0]
        if not path_part:
            continue
        resolved = (md_path.parent / path_part).resolve()
        if not resolved.is_relative_to(REPO):
            continue
        if not resolved.exists():
            rel = md_path.relative_to(REPO)
            errors.append(f"{rel}:{lineno}: broken link -> {target}")
    return errors


def main() -> int:
    md_files = [REPO / "README.md", REPO / "ROADMAP.md"]
    md_files += sorted((REPO / "docs").glob("*.md"))
    md_files = [p for p in md_files if p.exists()]

    all_errors: list[str] = []
    n_links = 0
    for md in md_files:
        n_links += sum(1 for _ in iter_links(md))
        all_errors.extend(check_file(md))

    if all_errors:
        print(f"{len(all_errors)} broken link(s):")
        for err in all_errors:
            print(f"  {err}")
        return 1
    print(f"OK: {n_links} links across {len(md_files)} files, none broken")
    return 0


if __name__ == "__main__":
    sys.exit(main())
