"""Summarize and validate a campaign Chrome trace (``trace_*.json``).

Reads the ``trace_event`` JSON written by ``Telemetry.export_trace`` /
``SpanTracer.export`` (complete ``"ph": "X"`` events whose ``args`` carry
the span id, parent id and nesting depth) and prints:

* **top spans** — per-name count / total / mean / max duration, sorted by
  total time;
* **per-stage share** — each span name's share of the total ``tile_eval``
  time (the campaign's unit of work), so "where does a tile's wall go?"
  (pad vs. launch vs. compact vs. merge) is one glance;
* **worker utilization** — per-worker busy time from ``tile_eval`` spans
  that carry a ``worker`` attr (fabric traces), as a share of the trace's
  observed wall.

``--check`` turns the reader into a CI gate: it exits non-zero unless every
required span name (default ``tile_eval``, ``checkpoint_write``, ``lease``
— the instrumented smoke campaign must produce all three) is present, and
every event's parent/depth bookkeeping is sane — a named parent id exists
in the trace, the child starts no earlier than its parent, ends no later
(small float slack), and sits at ``parent.depth + 1`` on the same thread.

    python tools/trace_report.py artifacts/bench/trace_dse_campaign.json
    python tools/trace_report.py trace.json --check
    python tools/trace_report.py trace.json --check --require tile_eval
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List, Optional

# containment slack (µs): a child's end may exceed its parent's by float
# rounding of the two (t - epoch) * 1e6 conversions, never by real time
SLACK_US = 0.5

DEFAULT_REQUIRED = ("tile_eval", "checkpoint_write", "lease")


def load_events(path: str) -> List[Dict]:
    """The trace's complete ("X") events; raises on a malformed file."""
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents list — not a Chrome trace")
    return [e for e in events if e.get("ph") == "X"]


def summarize(events: List[Dict]) -> Dict[str, Dict]:
    """Per-name aggregates over the events' ``dur`` (µs)."""
    agg: Dict[str, Dict] = {}
    for e in events:
        row = agg.setdefault(e["name"], {"count": 0, "total_us": 0.0,
                                         "max_us": 0.0})
        row["count"] += 1
        row["total_us"] += e["dur"]
        row["max_us"] = max(row["max_us"], e["dur"])
    for row in agg.values():
        row["mean_us"] = row["total_us"] / row["count"]
    return agg


def print_report(events: List[Dict], top: int = 15) -> None:
    if not events:
        print("trace holds no complete spans")
        return
    agg = summarize(events)

    print(f"{len(events)} spans, {len(agg)} distinct names\n")
    print(f"{'span':<20} {'count':>7} {'total_ms':>10} {'mean_us':>10} "
          f"{'max_us':>10}")
    for name, row in sorted(agg.items(), key=lambda kv: -kv[1]["total_us"])[:top]:
        print(f"{name:<20} {row['count']:>7} {row['total_us'] / 1e3:>10.3f} "
              f"{row['mean_us']:>10.1f} {row['max_us']:>10.1f}")

    tile_total = agg.get("tile_eval", {}).get("total_us", 0.0)
    if tile_total > 0:
        print(f"\nper-stage share of tile_eval "
              f"({tile_total / 1e3:.3f} ms total):")
        for name in ("tile_slice", "pad", "launch", "compact", "merge"):
            if name in agg:
                print(f"  {name:<18} {agg[name]['total_us'] / tile_total:>7.1%}")

    by_worker: Dict[object, float] = defaultdict(float)
    for e in events:
        if e["name"] == "tile_eval" and "worker" in e.get("args", {}):
            by_worker[e["args"]["worker"]] += e["dur"]
    if by_worker:
        t0 = min(e["ts"] for e in events)
        t1 = max(e["ts"] + e["dur"] for e in events)
        wall = max(t1 - t0, 1e-9)
        print("\nworker utilization (tile_eval busy / trace wall):")
        for w in sorted(by_worker, key=str):
            print(f"  worker {w!s:<6} {by_worker[w] / 1e3:>10.3f} ms "
                  f"{by_worker[w] / wall:>7.1%}")


def check(events: List[Dict], required) -> List[str]:
    """The CI gate: missing required spans + nesting violations."""
    errors: List[str] = []
    names = {e["name"] for e in events}
    for name in required:
        if name not in names:
            errors.append(f"required span {name!r} absent from trace")

    by_sid: Dict[int, Dict] = {}
    for e in events:
        args = e.get("args", {})
        if "sid" not in args or "parent" not in args or "depth" not in args:
            errors.append(f"span {e['name']!r} lacks sid/parent/depth args")
            continue
        by_sid[args["sid"]] = e
    for e in by_sid.values():
        args = e["args"]
        parent_sid = args["parent"]
        if parent_sid == -1:
            if args["depth"] != 0:
                errors.append(f"root span {e['name']!r} (sid {args['sid']}) "
                              f"has depth {args['depth']}, expected 0")
            continue
        parent = by_sid.get(parent_sid)
        if parent is None:
            # the ring buffer may have evicted an old parent; only flag a
            # dangling parent when the buffer never wrapped (all sids seen)
            continue
        p_args = parent["args"]
        if args["depth"] != p_args["depth"] + 1:
            errors.append(
                f"span {e['name']!r} (sid {args['sid']}) at depth "
                f"{args['depth']} under parent {parent['name']!r} at depth "
                f"{p_args['depth']}")
        if e.get("tid") != parent.get("tid"):
            errors.append(
                f"span {e['name']!r} (sid {args['sid']}) nests under "
                f"{parent['name']!r} on a different thread")
        if e["ts"] < parent["ts"] - SLACK_US:
            errors.append(
                f"span {e['name']!r} (sid {args['sid']}) starts before its "
                f"parent {parent['name']!r}")
        if e["ts"] + e["dur"] > parent["ts"] + parent["dur"] + SLACK_US:
            errors.append(
                f"span {e['name']!r} (sid {args['sid']}) ends after its "
                f"parent {parent['name']!r}")
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON written by "
                                  "Telemetry.export_trace")
    ap.add_argument("--check", action="store_true",
                    help="gate: fail on missing required spans or bad "
                         "nesting")
    ap.add_argument("--require", default=",".join(DEFAULT_REQUIRED),
                    help="comma-separated span names --check requires "
                         f"(default: {','.join(DEFAULT_REQUIRED)})")
    ap.add_argument("--top", type=int, default=15,
                    help="rows in the top-spans table")
    args = ap.parse_args(argv)

    events = load_events(args.trace)
    print_report(events, top=args.top)
    if args.check:
        required = [n for n in args.require.split(",") if n]
        errors = check(events, required)
        if errors:
            print(f"\nFAIL: {len(errors)} trace violation(s):",
                  file=sys.stderr)
            for err in errors:
                print(f"  - {err}", file=sys.stderr)
            return 1
        print(f"\nOK: required spans {required} present, nesting sane "
              f"({len(events)} spans)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
