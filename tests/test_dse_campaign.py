"""Streaming DSE campaign tests: generator space addressing, streamed-vs-
one-shot frontier identity, tile-boundary invariance, checkpoint/resume,
and merge idempotence/commutativity properties."""

import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare installs
    from _hypothesis_stub import given, settings, st

from repro.core import dse
from repro.dse_campaign import (Campaign, SliceVariant, SpaceSpec,
                                StreamingFrontier, canonical_frontier,
                                default_campaign_space, frontiers_identical,
                                store, tiny_campaign_space)
from repro.hw import CHIPS, frequency_sweep, mesh_factorizations

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}
WL = dse.Workload("qwen3_14b", "train_4k", BASE, 256, 0.5)
CONS = dse.Constraint(max_power_w=50_000)


def small_spec(**kw):
    kw.setdefault("chips", ("tpu-v5e", "tpu-v4", "tpu-edge"))
    kw.setdefault("chip_counts", (16, 64))
    kw.setdefault("freq_points", 7)
    kw.setdefault("variants", (SliceVariant(), SliceVariant("bin85", 0.85)))
    kw.setdefault("chunk_size", 64)
    return SpaceSpec(**kw)


def assert_fronts_identical(a: dse.ParetoFrontier, b: dse.ParetoFrontier):
    # one assert per axis (diagnosable failures); frontiers_identical is the
    # same comparison the bench gate and resume example use
    ca, ea, la, ia = canonical_frontier(a)
    cb, eb, lb, ib = canonical_frontier(b)
    assert ca == cb
    np.testing.assert_array_equal(ea, eb)
    np.testing.assert_array_equal(la, lb)
    np.testing.assert_array_equal(ia, ib)
    assert frontiers_identical(a, b)


def stream_frontier(spec, wl=WL, cons=CONS, chunk_size=None) -> dse.ParetoFrontier:
    fr = StreamingFrontier()
    for t, lo, batch in spec.tiles(chunk_size=chunk_size):
        sim, feas = dse.evaluate_workload_tile(wl, batch, cons)
        fr.merge(batch.candidates, sim.energy_j, sim.latency_s, feas,
                 indices=np.arange(lo, lo + len(batch)), tile=t)
    return fr.as_pareto_frontier(wl)


# --- SpaceSpec: index arithmetic, never-materialized addressing ---------------


def test_spacespec_len_and_point_addressing():
    spec = small_spec()
    batch = spec.slice(0, len(spec))
    assert len(batch) == len(spec) == spec.n_rows * spec.freq_points
    for i in [0, 1, len(spec) // 2, len(spec) - 1]:
        assert batch[i] == spec.candidate(i)
    with pytest.raises(IndexError):
        spec.candidate(len(spec))


def test_spacespec_slice_matches_full_enumeration():
    spec = small_spec()
    full = spec.slice(0, len(spec))
    lo, hi = 13, 101
    sub = spec.slice(lo, hi)
    assert sub.candidates == full.candidates[lo:hi]
    np.testing.assert_array_equal(sub.chip_idx, full.chip_idx[lo:hi])
    np.testing.assert_array_equal(sub.n_chips, full.n_chips[lo:hi])
    np.testing.assert_array_equal(sub.freq_mhz, full.freq_mhz[lo:hi])


def test_spacespec_tiles_bounded_by_chunk_size():
    spec = small_spec(chunk_size=17)
    seen, total = 0, 0
    for t, lo, batch in spec.tiles():
        assert len(batch) <= 17
        assert lo == t * 17 == total
        total += len(batch)
        seen += 1
    assert total == len(spec)
    assert seen == spec.n_tiles()


def test_spacespec_uniform_variant_matches_frequency_sweep_bitwise():
    spec = small_spec(variants=(SliceVariant(),), freq_points=12)
    batch = spec.slice(0, len(spec))
    for chip in spec.chips:
        sweep = frequency_sweep(chip, 12)
        rows = np.flatnonzero(
            np.asarray([c.chip == chip for c in batch.candidates]))
        got = sorted(set(batch.freq_mhz[rows].tolist()))
        assert got == sorted(set(sweep)), chip


def test_spacespec_edge_chip_collapses_to_single_chip():
    spec = small_spec()
    batch = spec.slice(0, len(spec))
    for c in batch.candidates:
        if CHIPS[c.chip].ici_bw == 0:
            assert c.n_chips == 1 and c.mesh == (1, 1)


def test_spacespec_roundtrip_and_registry_guard():
    spec = small_spec()
    again = SpaceSpec.from_dict(spec.to_dict())
    assert again == spec
    bad = spec.to_dict()
    bad["size"] += 1
    with pytest.raises(ValueError):
        SpaceSpec.from_dict(bad)


def test_default_campaign_space_is_mega():
    spec = default_campaign_space()
    assert len(spec) >= 100_000
    # resident state is the row table, orders of magnitude below the space
    assert spec.n_rows * spec.freq_points == len(spec)
    assert spec.n_rows < len(spec) // 100


def test_mesh_factorizations_products_and_dedup():
    for n in (1, 4, 12, 64, 256):
        for dims in (2, 3):
            ms = mesh_factorizations(n, dims)
            assert len(set(ms)) == len(ms)
            for m in ms:
                assert int(np.prod(m)) == n
                assert list(m) == sorted(m)        # nondecreasing
                if len(m) == 3:
                    assert m[0] >= 2               # real pod dimension
    assert mesh_factorizations(16, 2) == ((1, 16), (2, 8), (4, 4))
    assert (2, 2, 4) in mesh_factorizations(16, 3)
    with pytest.raises(ValueError):
        mesh_factorizations(0)


# --- frequency_sweep endpoint regression (satellite fix) ----------------------


def test_frequency_sweep_exact_endpoints():
    for name, spec in CHIPS.items():
        for points in (2, 3, 7, 12, 51):
            s = frequency_sweep(name, points)
            assert len(s) == points
            assert s[0] == spec.min_freq_mhz        # exact, not approx
            assert s[-1] == spec.max_freq_mhz
            assert all(a <= b for a, b in zip(s, s[1:]))
        assert frequency_sweep(name, 1) == [spec.max_freq_mhz]


# --- streamed frontier == one-shot pareto_search ------------------------------


def test_streaming_equals_oneshot_on_seeded_subspace():
    spec = small_spec()
    oneshot = dse.pareto_search(WL, spec.slice(0, len(spec)), CONS)[
        ("qwen3_14b", "train_4k")]
    assert_fronts_identical(stream_frontier(spec), oneshot)


def test_tile_boundary_invariance():
    """chunk_size must not change the frontier: {1, 7, 4096} all identical."""
    spec = small_spec(chip_counts=(16,), freq_points=5)
    fronts = [stream_frontier(spec, chunk_size=c) for c in (1, 7, 4096)]
    assert_fronts_identical(fronts[0], fronts[1])
    assert_fronts_identical(fronts[0], fronts[2])


def test_streaming_equals_oneshot_mega_space():
    """The acceptance gate: >=100k generated candidates, chunked, identical
    frontier to one-shot pareto_search on the same concatenated space."""
    spec = default_campaign_space(chunk_size=8192)
    assert len(spec) >= 100_000
    streamed = stream_frontier(spec, chunk_size=8192)
    oneshot = dse.pareto_search(WL, spec.slice(0, len(spec)), CONS)[
        ("qwen3_14b", "train_4k")]
    assert_fronts_identical(streamed, oneshot)


# --- StreamingFrontier merge properties ---------------------------------------


def _merge_points(fr, pts, indices):
    cands = [dse.Candidate("tpu-v5e", 1, (1, 1), 1000.0 + i) for i in indices]
    e = np.asarray([p[0] for p in pts], np.float64)
    l = np.asarray([p[1] for p in pts], np.float64)
    fr.merge(cands, e, l, indices=np.asarray(indices, np.int64))
    return fr


def test_merge_idempotent_by_global_index():
    pts = [(3.0, 1.0), (2.0, 2.0), (1.0, 3.0), (5.0, 5.0)]
    fr = _merge_points(StreamingFrontier(), pts, [0, 1, 2, 3])
    size1 = len(fr)
    snap = (fr.energy_j.copy(), fr.latency_s.copy(), fr.indices.copy())
    _merge_points(fr, pts, [0, 1, 2, 3])            # re-merge the same tile
    assert len(fr) == size1
    np.testing.assert_array_equal(fr.energy_j, snap[0])
    np.testing.assert_array_equal(fr.latency_s, snap[1])
    np.testing.assert_array_equal(fr.indices, snap[2])
    # accounting is idempotent too, not just the frontier set
    assert fr.evaluated == 4 and fr.feasible_seen == 4
    _merge_points(fr, [pts[1], (9.0, 9.0)], [1, 7])  # partial overlap
    assert fr.evaluated == 5 and fr.feasible_seen == 5


def test_evaluate_workload_tile_rejects_unknown_engine():
    spec = small_spec()
    batch = spec.slice(0, 8)
    with pytest.raises(ValueError, match="unknown engine"):
        dse.evaluate_workload_tile(WL, batch, CONS, engine="fast")


def test_merge_keeps_equal_duplicates_like_oneshot():
    # equal (energy, latency) at DIFFERENT indices: neither dominates, both
    # stay — matching pareto_search's duplicate semantics
    fr = _merge_points(StreamingFrontier(), [(1.0, 1.0)], [0])
    _merge_points(fr, [(1.0, 1.0)], [1])
    assert len(fr) == 2


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.floats(0.1, 100.0), st.floats(0.1, 100.0)),
                min_size=1, max_size=24),
       st.integers(1, 5),
       st.randoms(use_true_random=False))
def test_merge_commutative_and_idempotent_property(pts, n_chunks, rng):
    """Any tiling AND any tile order AND re-merged duplicates give the same
    frontier as a single merge of all points."""
    idx = list(range(len(pts)))
    ref = _merge_points(StreamingFrontier(), pts, idx)

    order = idx[:]
    rng.shuffle(order)
    bounds = sorted(rng.sample(range(len(pts) + 1), min(n_chunks, len(pts)))
                    ) + [len(pts)]
    fr = StreamingFrontier()
    lo = 0
    for hi in bounds:
        if hi > lo:
            chunk = order[lo:hi]
            _merge_points(fr, [pts[i] for i in chunk], chunk)
            if rng.random() < 0.5:                  # idempotence under repeats
                _merge_points(fr, [pts[i] for i in chunk], chunk)
        lo = hi
    np.testing.assert_array_equal(fr.energy_j, ref.energy_j)
    np.testing.assert_array_equal(fr.latency_s, ref.latency_s)
    np.testing.assert_array_equal(fr.indices, ref.indices)


def test_trajectory_snapshots_monotone_accounting():
    spec = small_spec()
    fr = StreamingFrontier()
    for t, lo, batch in spec.tiles():
        sim, feas = dse.evaluate_workload_tile(WL, batch, CONS)
        fr.merge(batch.candidates, sim.energy_j, sim.latency_s, feas,
                 indices=np.arange(lo, lo + len(batch)), tile=t)
    traj = fr.trajectory
    assert len(traj) == spec.n_tiles()
    assert traj[-1].evaluated == len(spec)
    for a, b in zip(traj, traj[1:]):
        assert b.evaluated > a.evaluated
        assert b.feasible >= a.feasible
        assert b.best_energy_j <= a.best_energy_j       # extremes only improve
        assert b.best_latency_s <= a.best_latency_s
        # hv never shrinks (rel slack: summation-order float noise only)
        assert b.hypervolume >= a.hypervolume * (1 - 1e-12)


# --- Campaign: resume == fresh, persistence -----------------------------------


ART_WORKLOADS = [
    dse.Workload("qwen3_14b", "train_4k", BASE, 256, 0.5),
    dse.Workload("stablelm_1_6b", "train_4k",
                 {k: v * 0.2 for k, v in BASE.items()}, 256, 0.1),
]


def test_campaign_resume_equals_fresh(tmp_path):
    spec = small_spec(chunk_size=48)
    ckpt = str(tmp_path / "ckpt.json")
    cons = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)

    interrupted = Campaign(ART_WORKLOADS, spec, constraint=cons)
    partial = interrupted.run(checkpoint_path=ckpt, max_tiles=2)
    assert not partial.complete and partial.tiles_done == 2

    resumed = Campaign.from_checkpoint(ckpt)
    assert resumed.next_tile == 2
    final = resumed.run(checkpoint_path=ckpt)
    assert final.complete

    fresh = Campaign(ART_WORKLOADS, spec, constraint=cons).run()
    assert set(final.frontiers) == set(fresh.frontiers)
    for key in fresh.frontiers:
        assert_fronts_identical(final.frontiers[key], fresh.frontiers[key])
        assert ([s.as_dict() for s in final.trajectories[key]]
                == [s.as_dict() for s in fresh.trajectories[key]])


def test_campaign_resume_restores_sim_config(tmp_path):
    """A non-default SimConfig must survive checkpoint/resume — otherwise a
    resumed frontier would silently mix two different simulators."""
    from repro.core import costmodel
    spec = small_spec(chunk_size=48)
    sim = costmodel.SimConfig(overlap=1.0, coll_model_frac=0.25)
    ckpt = str(tmp_path / "ckpt.json")
    camp = Campaign(ART_WORKLOADS[:1], spec, sim=sim)
    camp.run(checkpoint_path=ckpt, max_tiles=1)
    resumed = Campaign.from_checkpoint(ckpt)
    assert resumed.sim == sim
    final = resumed.run()
    fresh = Campaign(ART_WORKLOADS[:1], spec, sim=sim).run()
    for key in fresh.frontiers:
        assert_fronts_identical(final.frontiers[key], fresh.frontiers[key])


def test_campaign_checkpoint_roundtrip_and_version_guard(tmp_path):
    spec = small_spec(chunk_size=48)
    camp = Campaign(ART_WORKLOADS[:1], spec)
    camp.run(max_tiles=1)
    path = str(tmp_path / "state.json")
    store.save_checkpoint(camp.state_dict(), path)
    state = store.load_checkpoint(path)
    assert state["next_tile"] == 1
    again = Campaign.from_checkpoint(path)
    assert again.space == spec
    assert [(w.arch, w.shape) for w in again.workloads] == [
        (w.arch, w.shape) for w in camp.workloads]
    state["version"] = 99
    with open(path, "w") as f:
        json.dump(state, f)
    with pytest.raises(ValueError):
        store.load_checkpoint(path)


def test_campaign_matches_oneshot_pareto_per_workload():
    spec = small_spec()
    cons = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)
    result = Campaign(ART_WORKLOADS, spec, constraint=cons).run()
    fronts = dse.pareto_search(ART_WORKLOADS, spec.slice(0, len(spec)), cons)
    for key, front in fronts.items():
        assert_fronts_identical(result.frontiers[key], front)


def test_compare_campaigns_hv_threshold():
    """CI's cross-PR frontier gate: small hv drift passes, a collapse fails,
    added/dropped workloads are reported but never gated."""
    from benchmarks.compare_campaign import compare_campaigns

    def payload(hv_by_key, n_points=3, size=100, version=2):
        return {
            "space": {"size": size},
            "sim_model_version": version,
            "frontiers": {k: {"points": [{}] * n_points} for k in hv_by_key},
            "trajectory": {k: [{"hypervolume": hv * 0.5},
                               {"hypervolume": hv}]
                           for k, hv in hv_by_key.items()},
        }

    prev = payload({"a|s": 100.0, "b|s": 50.0})
    ok, lines = compare_campaigns(prev, payload({"a|s": 98.0, "b|s": 50.0}))
    assert ok and any("ok" in ln for ln in lines)
    ok, _ = compare_campaigns(prev, payload({"a|s": 80.0, "b|s": 50.0}))
    assert not ok                                     # 20% hv loss > 5% tol
    ok, _ = compare_campaigns(prev, payload({"a|s": 80.0, "b|s": 50.0}),
                              hv_rel_tol=0.25)
    assert ok                                         # within loosened tol
    ok, lines = compare_campaigns(prev, payload({"a|s": 100.0, "c|s": 1.0}))
    assert ok                                         # add/drop not gated
    assert any("NEW workload" in ln for ln in lines)
    assert any("DROPPED" in ln for ln in lines)
    # a cost-model version bump makes hv incomparable: report, don't gate
    ok, lines = compare_campaigns(payload({"a|s": 100.0}, version=1),
                                  payload({"a|s": 10.0}, version=2))
    assert ok
    assert any("not gated" in ln for ln in lines)
    ok, _ = compare_campaigns({}, payload({"a|s": 1.0}))
    assert ok                                         # empty previous passes


def test_compare_campaign_main_missing_prev(tmp_path):
    from benchmarks.compare_campaign import main
    new = tmp_path / "new.json"
    new.write_text(json.dumps({"space": {}, "frontiers": {},
                               "trajectory": {}}))
    assert main([str(tmp_path / "absent.json"), str(new)]) == 0


def test_campaign_report_payload_shape(tmp_path):
    spec = small_spec(chunk_size=48)
    cons = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)
    camp = Campaign(ART_WORKLOADS, spec, constraint=cons)
    result = camp.run()
    path = store.save_campaign(result, spec.to_dict(),
                               {"max_power_w": 40_000}, camp.evaluator,
                               str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert payload["bench"] == "dse_campaign"
    assert payload["complete"] and payload["tiles_done"] == spec.n_tiles()
    assert payload["space"]["size"] == len(spec)
    assert payload["throughput"]["candidates_evaluated"] == 2 * len(spec)
    for key, fr in payload["frontiers"].items():
        arch, shape = key.split("|")
        front = result.frontiers[(arch, shape)]
        assert len(fr["points"]) == len(front)
        assert fr["feasible_count"] == front.feasible_count
        p = fr["points"][0]
        assert set(p) == {"chip", "n_chips", "mesh", "freq_mhz", "energy_j",
                          "latency_s", "index"}
    assert all(len(t) == spec.n_tiles() for t in payload["trajectory"].values())
