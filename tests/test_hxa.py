"""HxA analyzer unit tests: parsing, trip counts, collective census —
validated against a real compiled module AND synthetic HLO text."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hxa

SYNTH = """
HloModule test

%loop_cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %it = s32[] get-tuple-element(%p), index=0
  %bound = s32[] constant(13)
  ROOT %cmp = pred[] compare(%it, %bound), direction=LT
}

%loop_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %it = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %nit = s32[] add(%it, %one)
  %y = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%y), replica_groups=[2,4]<=[8], to_apply=%adder
  ROOT %t = (s32[], f32[8,8]) tuple(%nit, %ar)
}

%adder (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %x)
  %w = (s32[], f32[8,8]) while(%init), condition=%loop_cond, body=%loop_body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_synthetic_loop_census():
    res = hxa.analyze_hlo_text(SYNTH)
    assert res["loops"] and res["loops"][0]["trips"] == 13
    # dot: 2*8*8*8 = 1024 flops per iteration, x13 (+ the trivial adds)
    assert 13 * 1024 <= res["flops"] <= 13 * 1024 + 13 * 8 + 16
    # all-reduce: 8*8*4 bytes, 13 iterations
    assert res["collectives"]["all-reduce"]["count"] == 13
    assert res["collectives"]["all-reduce"]["bytes"] == 13 * 256


def test_real_module_trip_aware_flops():
    """HxA multiplies scan bodies by trip count; XLA cost_analysis does not."""
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    w = jnp.zeros((9, 64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)
    comp = jax.jit(f).lower(w, x).compile()
    res = hxa.analyze_hlo_text(comp.as_text())
    from repro import compat
    xla_flops = compat.cost_analysis(comp)["flops"]
    per_iter = 2 * 8 * 64 * 64
    assert res["flops"] >= 9 * per_iter
    assert xla_flops < 2 * per_iter  # body counted once


def test_dot_flops_contracting_dims():
    comp = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((32, 128)), jnp.zeros((128, 16))).compile()
    res = hxa.analyze_hlo_text(comp.as_text())
    assert abs(res["flops"] - 2 * 32 * 128 * 16) / (2 * 32 * 128 * 16) < 0.05


def test_bytes_positive_and_finite():
    comp = jax.jit(lambda x: jnp.sum(jnp.exp(x))).lower(
        jnp.zeros((256, 256))).compile()
    res = hxa.analyze_hlo_text(comp.as_text())
    assert 0 < res["hbm_bytes"] < 1e9
