"""Fused DSE-sweep kernel tests: kernel-vs-scalar-oracle parity across chunk
sizes, constraint-mask edge cases, merge_reduced == raw-merge identity
(hypothesis property), campaign frontier identity and resume==fresh under
``evaluator="pallas"``, and the Pallas interpret auto-detection."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare installs
    from _hypothesis_stub import given, settings, st

from repro.core import costmodel, dse
from repro.dse_campaign import (Campaign, SliceVariant, SpaceSpec,
                                StreamingFrontier, canonical_frontier,
                                frontiers_identical)
from repro.hw import get_chip
from repro.kernels import ops

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}
WLS = [dse.Workload("qwen3_14b", "train_4k", BASE, 256, 0.5),
       dse.Workload("stablelm_1_6b", "train_4k",
                    {k: v * 0.2 for k, v in BASE.items()}, 256, 0.1)]
CONS = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)


def small_spec(**kw):
    kw.setdefault("chips", ("tpu-v5e", "tpu-edge"))
    kw.setdefault("chip_counts", (16,))
    kw.setdefault("freq_points", 5)
    kw.setdefault("variants", (SliceVariant(), SliceVariant("bin85", 0.85)))
    kw.setdefault("chunk_size", 64)
    return SpaceSpec(**kw)


def sweep_tile(spec, workloads, lo, hi, cons=CONS, **kw):
    """One fused kernel launch over spec[lo:hi) via the campaign's packing."""
    camp = Campaign(workloads, spec, constraint=cons, evaluator="pallas", **kw)
    batch = spec.slice(lo, hi, with_candidates=False)
    return camp._sweep_tile_reduced(batch), batch


def oracle_rows(spec, wl, cons=CONS):
    """Scalar ``costmodel.simulate`` loop — the ground-truth oracle."""
    energy, latency, feasible = [], [], []
    for i in range(len(spec)):
        cand = spec.candidate(i)
        chip = get_chip(cand.chip)
        ana = dse._scale_analysis(wl.base_analysis, wl.base_chips, cand)
        res = costmodel.simulate(ana, chip, cand.n_chips,
                                 freq_mhz=cand.freq_mhz, mesh=cand.mesh)
        ok = True
        if cons.min_hbm_fit:
            state_pd = wl.state_gb_per_device * wl.base_chips / cand.n_chips
            ok &= state_pd * 1e9 <= chip.hbm_bytes * 0.9
        if cons.max_power_w is not None:
            ok &= res.power_w * cand.n_chips <= cons.max_power_w
        if cons.max_latency_s is not None:
            ok &= res.latency_s <= cons.max_latency_s
        energy.append(res.energy_j)
        latency.append(res.latency_s)
        feasible.append(ok)
    return (np.asarray(energy), np.asarray(latency), np.asarray(feasible))


# --- kernel vs scalar oracle --------------------------------------------------


@pytest.mark.parametrize("chunk", [1, 7, 4096])
def test_kernel_matches_scalar_oracle_over_chunks(chunk):
    """The fused Pallas launch reproduces the scalar oracle's energy /
    latency / constraint mask on every tile, for tile sizes {1, 7, 4096}
    (4096 = whole space in one padded launch).  float32 tolerance is the
    contract; interpret mode actually runs float64 (~1 ulp)."""
    spec = small_spec(chunk_size=chunk)
    oracles = [oracle_rows(spec, wl) for wl in WLS]
    n = len(spec)
    for t, lo, _ in spec.tiles(with_candidates=False):
        hi = min(lo + chunk, n)
        red, _ = sweep_tile(spec, WLS, lo, hi)
        for wi, (o_e, o_l, o_f) in enumerate(oracles):
            e = np.asarray(red.energy_full)[wi][:hi - lo]
            l = np.asarray(red.latency_full)[wi][:hi - lo]
            f = np.asarray(red.feasible_full)[wi][:hi - lo]
            np.testing.assert_allclose(e, o_e[lo:hi], rtol=1e-6)
            np.testing.assert_allclose(l, o_l[lo:hi], rtol=1e-6)
            np.testing.assert_array_equal(f, o_f[lo:hi])
            # the on-device screen keeps a feasible SUPERSET of the tile's
            # exact Pareto set, and its aggregates are the oracle's
            keep_exact, n_feas, ref_e, ref_l = costmodel.skyline_reduce(
                o_e[lo:hi], o_l[lo:hi], o_f[lo:hi])
            k = int(red.n_survivors[wi])
            assert k <= red.max_survivors
            surv = set(red.surv_idx[wi][:k].tolist())
            assert set(np.flatnonzero(keep_exact).tolist()) <= surv
            assert all(o_f[lo:hi][i] for i in surv)
            assert int(red.n_feasible[wi]) == int(n_feas)
            if n_feas:
                np.testing.assert_allclose(
                    [red.ref_energy[wi], red.ref_latency[wi]],
                    [ref_e, ref_l], rtol=1e-6)


@pytest.mark.parametrize("cons", [
    # HBM fit: 2 GB/device at base 256 fits 64-chip v5e (8 GB/dev) but not
    # 16-chip (32 GB/dev) — the hbm branch splits the space
    dse.Constraint(min_hbm_fit=True),
    # latency cap splits the space along the chip-count axis
    dse.Constraint(max_latency_s=500.0, min_hbm_fit=False),
    dse.Constraint(max_power_w=40_000, max_latency_s=500.0,
                   min_hbm_fit=True),
])
def test_kernel_matches_oracle_constraint_branches(cons):
    """The in-kernel constraint mask covers every branch: HBM fit, slice
    power budget, and the latency cap — each actually splitting the space."""
    spec = small_spec(chip_counts=(16, 64))
    wl = dse.Workload("qwen3_14b", "train_4k", BASE, 256, 2.0)
    o_e, o_l, o_f = oracle_rows(spec, wl, cons)
    assert 0 < o_f.sum() < len(spec)            # the mask actually bites
    red, _ = sweep_tile(spec, [wl], 0, len(spec), cons=cons)
    np.testing.assert_array_equal(
        np.asarray(red.feasible_full)[0][:len(spec)], o_f)
    assert int(red.n_feasible[0]) == int(o_f.sum())


def test_all_infeasible_tile():
    """Constraint-mask edge case: a power budget nothing satisfies."""
    spec = small_spec()
    cons = dse.Constraint(max_power_w=1e-3, min_hbm_fit=False)
    red, batch = sweep_tile(spec, WLS[:1], 0, len(spec), cons=cons)
    assert int(red.n_feasible[0]) == 0
    assert int(red.n_survivors[0]) == 0
    assert not np.asarray(red.feasible_full)[0].any()
    fr = StreamingFrontier()
    fr.merge_reduced([], [], [], [], span=(0, len(batch)), n_feasible=0,
                     tile=0)
    assert len(fr) == 0 and fr.ref_energy_j is None
    assert fr.evaluated == len(batch) and fr.feasible_seen == 0


def test_campaign_all_infeasible_matches_numpy():
    cons = dse.Constraint(max_power_w=1e-3, min_hbm_fit=False)
    spec = small_spec()
    a = Campaign(WLS, spec, constraint=cons, evaluator="numpy").run()
    b = Campaign(WLS, spec, constraint=cons, evaluator="pallas").run()
    for key in a.frontiers:
        assert len(a.frontiers[key]) == len(b.frontiers[key]) == 0
        assert ([s.as_dict() for s in a.trajectories[key]]
                == [s.as_dict() for s in b.trajectories[key]])


# --- merge_reduced == raw merge ----------------------------------------------


def _cands(indices):
    return [dse.Candidate("tpu-v5e", 1, (1, 1), 1000.0 + i) for i in indices]


def _reduced_merge_span(fr, e, l, feas, lo, hi, tile=-1, superset=False):
    """Feed one [lo, hi) span through merge_reduced the way the fused
    evaluators do: survivors from the skyline (or a feasible superset) plus
    the tile aggregates."""
    keep, n_feas, ref_e, ref_l = costmodel.skyline_reduce(e, l, feas)
    if superset:
        keep = feas                      # every feasible point rides along
    idx = np.flatnonzero(keep)
    fr.merge_reduced(_cands(lo + idx), e[idx], l[idx], lo + idx,
                     span=(lo, hi), n_feasible=int(n_feas),
                     ref_energy_j=float(ref_e), ref_latency_s=float(ref_l),
                     tile=tile)
    return fr


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.floats(0.1, 100.0), st.floats(0.1, 100.0),
                          st.booleans()), min_size=1, max_size=32),
       st.integers(1, 5), st.booleans())
def test_merge_reduced_equals_raw_merge_property(pts, n_chunks, superset):
    """Any contiguous tiling merged reduced (exact skyline survivors OR the
    full feasible superset) produces the same frontier AND the same
    trajectory snapshots as raw merges of the full tiles."""
    e = np.asarray([p[0] for p in pts])
    l = np.asarray([p[1] for p in pts])
    feas = np.asarray([p[2] for p in pts])
    bounds = np.unique(np.linspace(0, len(pts), n_chunks + 1).astype(int))
    raw, red = StreamingFrontier(), StreamingFrontier()
    for lo, hi in zip(bounds, bounds[1:]):
        raw.merge(_cands(range(lo, hi)), e[lo:hi], l[lo:hi], feas[lo:hi],
                  indices=np.arange(lo, hi), tile=int(lo))
        _reduced_merge_span(red, e[lo:hi], l[lo:hi], feas[lo:hi],
                            int(lo), int(hi), tile=int(lo),
                            superset=superset)
    np.testing.assert_array_equal(raw.energy_j, red.energy_j)
    np.testing.assert_array_equal(raw.latency_s, red.latency_s)
    np.testing.assert_array_equal(raw.indices, red.indices)
    assert raw.candidates == red.candidates
    assert ([s.as_dict() for s in raw.trajectory]
            == [s.as_dict() for s in red.trajectory])
    assert (raw.evaluated, raw.feasible_seen) == (red.evaluated,
                                                  red.feasible_seen)


def test_merge_reduced_idempotent_and_rejects_partial_overlap():
    e = np.asarray([3.0, 2.0, 1.0, 5.0])
    l = np.asarray([1.0, 2.0, 3.0, 5.0])
    feas = np.ones(4, bool)
    fr = _reduced_merge_span(StreamingFrontier(), e, l, feas, 0, 4)
    size, ev = len(fr), fr.evaluated
    _reduced_merge_span(fr, e, l, feas, 0, 4)         # re-merge: no-op
    assert len(fr) == size and fr.evaluated == ev
    with pytest.raises(ValueError, match="partially overlaps"):
        fr.merge_reduced(_cands([4]), [1.0], [1.0], [4], span=(2, 6),
                         n_feasible=1, ref_energy_j=1.0, ref_latency_s=1.0)
    with pytest.raises(ValueError, match="outside span"):
        fr.merge_reduced(_cands([9]), [1.0], [1.0], [9], span=(4, 8),
                         n_feasible=1, ref_energy_j=1.0, ref_latency_s=1.0)


def test_compact_rows_device_matches_host():
    """The compiled-backend compaction and the host compaction agree."""
    import jax.numpy as jnp
    rng = np.random.default_rng(7)
    keep = rng.random((3, 64)) < 0.2
    e = rng.random((3, 64))
    l = rng.random((3, 64))
    hi, he, hl = costmodel._compact_rows_host(keep, e, l, 16)
    di, de, dl = costmodel._compact_rows_device(
        jnp.asarray(keep), jnp.asarray(e, jnp.float32),
        jnp.asarray(l, jnp.float32), 16)
    for w in range(3):
        k = int(keep[w].sum())
        np.testing.assert_array_equal(hi[w][:k], np.asarray(di)[w][:k])
        np.testing.assert_allclose(he[w][:k], np.asarray(de)[w][:k],
                                   rtol=1e-6)
        np.testing.assert_allclose(hl[w][:k], np.asarray(dl)[w][:k],
                                   rtol=1e-6)


# --- campaign-level identity --------------------------------------------------


def assert_same_candidate_set(a: dse.ParetoFrontier, b: dse.ParetoFrontier,
                              rtol=1e-9):
    ca, ea, la, ia = canonical_frontier(a)
    cb, eb, lb, ib = canonical_frontier(b)
    assert ca == cb
    np.testing.assert_array_equal(ia, ib)
    np.testing.assert_allclose(ea, eb, rtol=rtol)
    np.testing.assert_allclose(la, lb, rtol=rtol)


@pytest.mark.parametrize("chunk", [7, 64])
def test_campaign_pallas_matches_numpy_frontier(chunk):
    """The acceptance gate in miniature: evaluator='pallas' (interpret mode)
    produces the numpy evaluator's exact frontier candidate set, values to
    ~1 ulp, hypervolume to well within 1e-6 relative."""
    spec = small_spec(chunk_size=chunk)
    a = Campaign(WLS, spec, constraint=CONS, evaluator="numpy").run()
    b = Campaign(WLS, spec, constraint=CONS, evaluator="pallas").run()
    for key in a.frontiers:
        assert_same_candidate_set(a.frontiers[key], b.frontiers[key],
                                  rtol=1e-12)
        assert (a.frontiers[key].feasible_count
                == b.frontiers[key].feasible_count)
        ha = a.trajectories[key][-1].hypervolume
        hb = b.trajectories[key][-1].hypervolume
        assert hb == pytest.approx(ha, rel=1e-6)


def test_campaign_jit_fused_matches_numpy_candidate_set():
    """The float32 fused jit evaluator lands on the same frontier candidate
    set (values only to float32 tolerance)."""
    spec = small_spec()
    a = Campaign(WLS, spec, constraint=CONS, evaluator="numpy").run()
    b = Campaign(WLS, spec, constraint=CONS, evaluator="jit").run()
    for key in a.frontiers:
        assert_same_candidate_set(a.frontiers[key], b.frontiers[key],
                                  rtol=1e-5)


def test_campaign_pallas_overflow_fallback_identical():
    """max_survivors=1 forces the full-array fallback on every tile; the
    frontier must not change."""
    spec = small_spec()
    a = Campaign(WLS, spec, constraint=CONS, evaluator="pallas").run()
    b = Campaign(WLS, spec, constraint=CONS, evaluator="pallas",
                 max_survivors=1).run()
    for key in a.frontiers:
        assert frontiers_identical(a.frontiers[key], b.frontiers[key])
        assert ([s.as_dict() for s in a.trajectories[key]]
                == [s.as_dict() for s in b.trajectories[key]])


def test_campaign_pallas_resume_equals_fresh(tmp_path):
    spec = small_spec(chunk_size=16)
    ckpt = str(tmp_path / "ckpt.json")
    interrupted = Campaign(WLS, spec, constraint=CONS, evaluator="pallas")
    partial = interrupted.run(checkpoint_path=ckpt, max_tiles=2)
    assert not partial.complete and partial.tiles_done == 2
    resumed = Campaign.from_checkpoint(ckpt)
    assert resumed.evaluator == "pallas" and resumed.next_tile == 2
    final = resumed.run(checkpoint_path=ckpt)
    assert final.complete
    fresh = Campaign(WLS, spec, constraint=CONS, evaluator="pallas").run()
    for key in fresh.frontiers:
        assert frontiers_identical(final.frontiers[key], fresh.frontiers[key])
        assert ([s.as_dict() for s in final.trajectories[key]]
                == [s.as_dict() for s in fresh.trajectories[key]])


def test_partial_tile_padding_is_masked():
    """Fused evaluators pad the last tile to chunk_size; the padded lanes
    must never count as evaluated, feasible, or frontier members."""
    spec = small_spec(chunk_size=15)            # 20 candidates -> 15 + 5
    assert len(spec) % 15 != 0
    a = Campaign(WLS, spec, constraint=CONS, evaluator="numpy").run()
    b = Campaign(WLS, spec, constraint=CONS, evaluator="pallas").run()
    for key in a.frontiers:
        assert_same_candidate_set(a.frontiers[key], b.frontiers[key],
                                  rtol=1e-12)
        assert (a.trajectories[key][-1].evaluated
                == b.trajectories[key][-1].evaluated == len(spec))


# --- runner plumbing ----------------------------------------------------------


def test_tile_prefetcher_propagates_and_closes():
    from repro.dse_campaign.runner import _TilePrefetcher

    def gen():
        yield 1
        yield 2
        raise RuntimeError("boom")

    pf = _TilePrefetcher(gen())
    assert next(pf) == 1 and next(pf) == 2
    with pytest.raises(RuntimeError, match="boom"):
        next(pf)
    pf.close()

    slow = _TilePrefetcher(iter(range(100)))
    assert next(slow) == 0
    slow.close()                                 # early stop must not hang
    slow._thread.join(timeout=5)
    assert not slow._thread.is_alive()


def test_campaign_rejects_unknown_evaluator():
    with pytest.raises(ValueError, match="unknown evaluator"):
        Campaign(WLS, small_spec(), evaluator="warp")


def test_candidates_at_matches_candidate():
    spec = small_spec()
    idx = [0, 3, len(spec) - 1]
    assert spec.candidates_at(idx) == [spec.candidate(i) for i in idx]
    with pytest.raises(IndexError):
        spec.candidates_at([len(spec)])


def test_legacy_checkpoint_without_pipeline_key_stays_legacy(tmp_path):
    """Pre-fusion checkpoints (no 'pipeline' key) ran the per-workload jit
    loop; resuming them must stay on that engine rather than splicing the
    fused float32 sweep into a half-done frontier."""
    from repro.dse_campaign import store

    spec = small_spec(chunk_size=16)
    camp = Campaign(WLS, spec, constraint=CONS, evaluator="jit")
    camp.run(max_tiles=1)
    state = camp.state_dict()
    assert state["pipeline"] is True
    del state["pipeline"]
    path = str(tmp_path / "legacy.json")
    store.save_checkpoint(state, path)
    resumed = Campaign.from_checkpoint(path)
    assert resumed.pipeline is False and not resumed.fused
    # new-format checkpoints round-trip the flag
    path2 = str(tmp_path / "new.json")
    store.save_checkpoint(camp.state_dict(), path2)
    assert Campaign.from_checkpoint(path2).pipeline is True


# --- CI evaluator diff --------------------------------------------------------


def test_compare_evaluators_gates():
    from benchmarks.compare_campaign import compare_evaluators

    def payload(hv_jit, hv_pallas, identical=True):
        return {"frontiers": {"jit": {"a|s": {"points": []}},
                              "pallas": {"a|s": {"points": []}}},
                "hv": {"jit": {"a|s": hv_jit}, "pallas": {"a|s": hv_pallas}},
                "pallas_vs_numpy": {"identical_candidate_set": identical,
                                    "max_hv_rel_diff": 0.0}}

    ok, _ = compare_evaluators(payload(100.0, 100.0 + 1e-6))
    assert ok
    ok, _ = compare_evaluators(payload(100.0, 90.0))       # 10% divergence
    assert not ok
    ok, _ = compare_evaluators(payload(0.0, 50.0))         # collapsed jit hv
    assert not ok
    ok, _ = compare_evaluators(payload(0.0, 0.0))
    assert ok
    ok, _ = compare_evaluators(payload(100.0, 100.0, identical=False))
    assert not ok                                          # numpy identity


# --- interpret auto-detection -------------------------------------------------


def test_default_interpret_autodetect(monkeypatch):
    import jax
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    expected = jax.default_backend() != "tpu"
    assert ops.default_interpret() is expected   # CPU container -> True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.default_interpret() is True
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert ops._resolve_interpret(None) is expected
    assert ops._resolve_interpret(False) is False
