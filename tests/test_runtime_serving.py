"""Fault-tolerance runtime + serving engine tests."""

import itertools

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import api
from repro.runtime.fault_tolerance import (HeartbeatMonitor, PreemptionHandler,
                                           StragglerDetector, recoverable_step)
from repro.serving.engine import Request, ServingEngine


def test_straggler_detector_flags_outlier():
    d = StragglerDetector(window=20, k=4.0, min_samples=5)
    for _ in range(10):
        assert not d.observe(0.100 + np.random.default_rng(0).normal() * 1e-4)
    assert d.observe(0.500)
    assert d.summary()["flagged"] == 1


def test_straggler_detector_tolerates_drift():
    d = StragglerDetector(window=10, k=6.0)
    for t in np.linspace(0.1, 0.12, 30):
        assert not d.observe(float(t))


def test_heartbeat_monitor():
    clock = itertools.count(0, 10).__next__
    m = HeartbeatMonitor(["a", "b"], timeout_s=25, clock=lambda: clock())
    m.beat("a")          # t=10
    m.beat("a")          # t=20
    # next reads advance the clock past b's deadline
    dead = m.dead_hosts()
    assert "b" in dead and "a" not in dead


def test_recoverable_step_retries_then_succeeds():
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return state + batch

    failures = []
    out = recoverable_step(flaky, 1, 2, max_retries=3,
                           on_failure=lambda a, e: failures.append(a))
    assert out == 3 and calls["n"] == 3 and failures == [1, 2]


def test_recoverable_step_gives_up():
    def always_fails(state, batch):
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        recoverable_step(always_fails, 0, 0, max_retries=1)


def test_preemption_flag():
    h = PreemptionHandler(install=False)
    assert not h.requested
    h._handler(15, None)
    assert h.requested


# --- serving engine ---------------------------------------------------------------------

def test_engine_completes_requests():
    cfg = get_config("stablelm_1_6b").reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    eng = ServingEngine(model, slots=2, max_len=64)
    eng.load(params)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=5) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.tokens_out) == 5 for r in reqs)
    # first token of each request comes from prefill; 4 more via step()
    assert stats["decoded_tokens"] >= 4 * 4


def test_engine_matches_direct_decode():
    """Greedy tokens from the engine == greedy tokens from a plain decode loop."""
    cfg = get_config("stablelm_1_6b").reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(1), max_seq=32)
    prompt = np.asarray([3, 5, 7], np.int32)

    eng = ServingEngine(model, slots=1, max_len=32)
    eng.load(params)
    req = Request(rid=0, prompt=prompt, max_new_tokens=4)
    eng.submit(req)
    eng.run_until_drained()

    import jax.numpy as jnp
    cache = model.init_cache(1, 32)
    tok = None
    toks = []
    seq = list(prompt)
    for t in seq:
        logits, cache = model.decode(params, {"tokens": jnp.asarray([[t]], jnp.int32)},
                                     cache)
    tok = int(np.argmax(np.asarray(logits[0, -1])))
    toks.append(tok)
    for _ in range(3):
        logits, cache = model.decode(params, {"tokens": jnp.asarray([[tok]], jnp.int32)},
                                     cache)
        tok = int(np.argmax(np.asarray(logits[0, -1])))
        toks.append(tok)
    assert req.tokens_out == toks
