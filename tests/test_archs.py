"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values; decode step where applicable."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_NAMES, get_config
from repro.models import api
from repro import optim

B, S = 2, 64


def _batch(cfg):
    if cfg.family == "cnn":
        return {"images": jnp.ones((B, cfg.image_size, cfg.image_size, 3)),
                "labels": jnp.zeros((B,), jnp.int32)}
    text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
    batch = {"tokens": jnp.arange(B * text).reshape(B, text) % cfg.vocab_size,
             "labels": jnp.arange(B * text).reshape(B, text) % cfg.vocab_size}
    batch["tokens"] = batch["tokens"].astype(jnp.int32)
    batch["labels"] = batch["labels"].astype(jnp.int32)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.ones((B, cfg.num_patches, cfg.d_model),
                                          jnp.bfloat16) * 0.01
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.num_frames, cfg.d_model),
                                   jnp.bfloat16) * 0.01
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=S)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    # one optimizer step must keep everything finite
    opt = optim.make_optimizer(cfg.optimizer, total_steps=10)
    state = api.TrainState(params, opt.init(params))
    step = jax.jit(api.make_train_step(model, opt))
    state, m = step(state, batch)
    assert jnp.isfinite(m["loss"])
    assert jnp.isfinite(m["grad_norm"])
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all(), f"{arch}: NaN in params"


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES if a != "resnet50"])
def test_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=S)
    cache = model.init_cache(B, S)
    cache = {**cache, "len": jnp.asarray(3, jnp.int32)}
    logits, new_cache = jax.jit(lambda p, b, c: model.decode(p, b, c))(
        params, {"tokens": jnp.ones((B, 1), jnp.int32)}, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert int(new_cache["len"]) == 4


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES if a != "resnet50"])
def test_prefill_matches_stepwise_decode(arch):
    """Prefill-then-decode must equal decoding the whole prompt token by token."""
    cfg = get_config(arch).reduced()
    if cfg.family in ("vlm", "audio"):
        pytest.skip("stub-frontend families: covered by decode smoke")
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(1), max_seq=S)
    toks = (jnp.arange(2 * 8).reshape(2, 8) % cfg.vocab_size).astype(jnp.int32)

    logits_pre, cache = jax.jit(lambda p, b: model.prefill(p, b))(
        params, {"tokens": toks})

    cache2 = model.init_cache(2, S)
    logits_step = None
    for i in range(8):
        logits_step, cache2 = model.decode(
            params, {"tokens": toks[:, i: i + 1]}, cache2)
    assert jnp.allclose(logits_pre[:, -1], logits_step[:, -1],
                        atol=0.1, rtol=0.05), f"{arch}: prefill/decode mismatch"
