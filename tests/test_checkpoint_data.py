"""Checkpoint store + data pipeline tests (fault-tolerance substrate)."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs.base import ShapeConfig, get_config
from repro.data.pipeline import DataConfig, DataIterator, synth_batch


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 7, t, extra={"seed": 1})
    step, r, extra = store.restore(str(tmp_path))
    assert step == 7 and extra == {"seed": 1}
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_gc(tmp_path):
    for s in (1, 2, 3, 4, 5):
        store.save(str(tmp_path), s, _tree())
    assert store.latest_step(str(tmp_path)) == 5
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 3  # gc keeps 3


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(str(tmp_path))
    ck.save_async(11, _tree())
    ck.wait()
    assert store.latest_step(str(tmp_path)) == 11


def test_atomicity_no_partial_checkpoints(tmp_path):
    store.save(str(tmp_path), 1, _tree())
    # a stale tmp dir from a crashed writer must not be visible as a ckpt
    os.makedirs(tmp_path / "step_9.tmp")
    assert store.latest_step(str(tmp_path)) == 1


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        store.restore(str(tmp_path / "nope"))


# --- data pipeline --------------------------------------------------------------------

CFG = get_config("stablelm_1_6b").reduced()
SHAPE = ShapeConfig("t", 32, 4, "train")


def test_data_deterministic_per_step():
    a = synth_batch(CFG, SHAPE, DataConfig(seed=5), step=3)
    b = synth_batch(CFG, SHAPE, DataConfig(seed=5), step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(CFG, SHAPE, DataConfig(seed=5), step=4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_shards_disjoint():
    a = synth_batch(CFG, SHAPE, DataConfig(seed=5, host_index=0, host_count=2), 0)
    b = synth_batch(CFG, SHAPE, DataConfig(seed=5, host_index=1, host_count=2), 0)
    assert a["tokens"].shape[0] == SHAPE.global_batch // 2
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_iterator_restart_reproducible():
    it = DataIterator(CFG, SHAPE, DataConfig(seed=9), start_step=0)
    b0, b1 = next(it), next(it)
    it.close()
    it2 = DataIterator(CFG, SHAPE, DataConfig(seed=9), start_step=1)
    b1_again = next(it2)
    it2.close()
    np.testing.assert_array_equal(b1["tokens"], b1_again["tokens"])


def test_tokens_in_vocab_range():
    b = synth_batch(CFG, SHAPE, DataConfig(), 0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < CFG.vocab_size
