"""Predictor suite tests + hypothesis properties (paper core: KNN/DT/RF)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; plain unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.core import predictors as P

RNG = np.random.default_rng(3)


def _synthetic(n=800, d=4, noise=0.02):
    X = RNG.uniform(0.5, 4.0, (n, d)).astype(np.float32)
    # multiplicative ground truth (like power ~ util * f^3): log-linear
    y = 5.0 * X[:, 0] * X[:, 1] ** 2 / X[:, 2] + X[:, 3]
    y = y * np.exp(RNG.normal(0, noise, n))
    return X, y


@pytest.mark.parametrize("name", ["knn", "decision_tree", "random_forest"])
def test_fits_synthetic_with_low_mape(name):
    X, y = _synthetic()
    res = P.kfold_evaluate(name, X, y, k=4)
    assert res["mape"] < 25.0, res
    assert res["r2"] > 0.82, res


def test_random_forest_beats_single_tree_on_noise():
    X, y = _synthetic(noise=0.15)
    tree = P.kfold_evaluate("decision_tree", X, y, k=4)
    forest = P.kfold_evaluate("random_forest", X, y, k=4)
    assert forest["mape"] <= tree["mape"] * 1.25


def test_metrics_match_definitions():
    y, p = np.array([1.0, 2.0, 4.0]), np.array([1.1, 1.8, 4.4])
    assert abs(P.mape(y, p) - 100 * np.mean([0.1, 0.1, 0.1])) < 1e-6
    assert abs(P.r2_score(y, y) - 1.0) < 1e-12


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 60), st.integers(2, 5))
def test_tree_predictions_within_training_range(n, d):
    """CART leaves are means of training targets: predictions are bounded by
    the training target range (a safety property for the DSE ranking)."""
    rng = np.random.default_rng(n * 17 + d)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = np.abs(rng.normal(size=n)) + 0.1
    m = P.DecisionTreeRegressor(max_depth=6).fit(X, y)
    pred = m.predict(rng.normal(size=(32, d)).astype(np.float32))
    assert pred.min() >= y.min() / 1.001
    assert pred.max() <= y.max() * 1.001


@settings(max_examples=20, deadline=None)
@given(st.integers(5, 40))
def test_knn_k1_interpolates_training_points(n):
    rng = np.random.default_rng(n)
    X = rng.uniform(1, 10, (n, 4)).astype(np.float32)
    y = np.abs(rng.normal(size=n)).astype(np.float64) + 0.5
    m = P.KNNRegressor(k=1).fit(X, y)
    pred = m.predict(X)
    np.testing.assert_allclose(pred, y, rtol=5e-3)


@settings(max_examples=15, deadline=None)
@given(st.floats(1.1, 3.0), st.floats(0.1, 0.9))
def test_predictor_scale_monotonicity(a, b):
    """Scaling a feature the target grows with must not DECREASE prediction
    on average (sanity for DVFS-style sweeps)."""
    n = 200
    rng = np.random.default_rng(int(a * 100) + int(b * 10))
    X = rng.uniform(0.5, 2.0, (n, 3)).astype(np.float32)
    y = X[:, 0] ** 3 * 10 + 1.0
    m = P.RandomForestRegressor(n_trees=15, max_depth=8).fit(X, y)
    lo = X.copy(); lo[:, 0] = 0.7
    hi = X.copy(); hi[:, 0] = 1.8
    assert m.predict(hi).mean() > m.predict(lo).mean()
