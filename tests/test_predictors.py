"""Predictor suite tests + hypothesis properties (paper core: KNN/DT/RF)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; plain unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.core import predictors as P

RNG = np.random.default_rng(3)


def _synthetic(n=800, d=4, noise=0.02):
    X = RNG.uniform(0.5, 4.0, (n, d)).astype(np.float32)
    # multiplicative ground truth (like power ~ util * f^3): log-linear
    y = 5.0 * X[:, 0] * X[:, 1] ** 2 / X[:, 2] + X[:, 3]
    y = y * np.exp(RNG.normal(0, noise, n))
    return X, y


@pytest.mark.parametrize("name", ["knn", "decision_tree", "random_forest"])
def test_fits_synthetic_with_low_mape(name):
    X, y = _synthetic()
    res = P.kfold_evaluate(name, X, y, k=4)
    assert res["mape"] < 25.0, res
    assert res["r2"] > 0.82, res


def test_random_forest_beats_single_tree_on_noise():
    X, y = _synthetic(noise=0.15)
    tree = P.kfold_evaluate("decision_tree", X, y, k=4)
    forest = P.kfold_evaluate("random_forest", X, y, k=4)
    assert forest["mape"] <= tree["mape"] * 1.25


def test_metrics_match_definitions():
    y, p = np.array([1.0, 2.0, 4.0]), np.array([1.1, 1.8, 4.4])
    assert abs(P.mape(y, p) - 100 * np.mean([0.1, 0.1, 0.1])) < 1e-6
    assert abs(P.r2_score(y, y) - 1.0) < 1e-12


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 60), st.integers(2, 5))
def test_tree_predictions_within_training_range(n, d):
    """CART leaves are means of training targets: predictions are bounded by
    the training target range (a safety property for the DSE ranking)."""
    rng = np.random.default_rng(n * 17 + d)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = np.abs(rng.normal(size=n)) + 0.1
    m = P.DecisionTreeRegressor(max_depth=6).fit(X, y)
    pred = m.predict(rng.normal(size=(32, d)).astype(np.float32))
    assert pred.min() >= y.min() / 1.001
    assert pred.max() <= y.max() * 1.001


@settings(max_examples=20, deadline=None)
@given(st.integers(5, 40))
def test_knn_k1_interpolates_training_points(n):
    rng = np.random.default_rng(n)
    X = rng.uniform(1, 10, (n, 4)).astype(np.float32)
    y = np.abs(rng.normal(size=n)).astype(np.float64) + 0.5
    m = P.KNNRegressor(k=1).fit(X, y)
    pred = m.predict(X)
    np.testing.assert_allclose(pred, y, rtol=5e-3)


@settings(max_examples=15, deadline=None)
@given(st.floats(1.1, 3.0), st.floats(0.1, 0.9))
def test_predictor_scale_monotonicity(a, b):
    """Scaling a feature the target grows with must not DECREASE prediction
    on average (sanity for DVFS-style sweeps)."""
    n = 200
    rng = np.random.default_rng(int(a * 100) + int(b * 10))
    X = rng.uniform(0.5, 2.0, (n, 3)).astype(np.float32)
    y = X[:, 0] ** 3 * 10 + 1.0
    m = P.RandomForestRegressor(n_trees=15, max_depth=8).fit(X, y)
    lo = X.copy(); lo[:, 0] = 0.7
    hi = X.copy(); hi[:, 0] = 1.8
    assert m.predict(hi).mean() > m.predict(lo).mean()


# --- warm-start (partial_fit) forest: incremental refits for the adaptive
# --- campaign loop -----------------------------------------------------------


def _rows(seed, n=60, d=4):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0.5, 4.0, (n, d)).astype(np.float32)
    y = 5.0 * X[:, 0] * X[:, 1] ** 2 / X[:, 2] + X[:, 3]
    return X, y


def _warm_forest():
    return P.RandomForestRegressor(n_trees=8, max_depth=6, min_leaf=2,
                                   refresh_trees=3, log_target=True)


def test_partial_fit_same_call_sequence_is_bitwise_deterministic():
    Xq = _rows(99)[0][:16]
    preds = []
    for _ in range(2):
        m = _warm_forest()
        for step, seed in enumerate([7, 11, 13]):
            m.partial_fit(*_rows(step), seed=seed)
        preds.append(np.asarray(m.predict(Xq)))
    np.testing.assert_array_equal(preds[0], preds[1])


def test_partial_fit_accumulates_rows_and_cycles_refresh_slots():
    m = _warm_forest()
    X0, y0 = _rows(0)
    m.partial_fit(X0, y0, seed=1)
    assert m.n_rows == len(X0)
    cold = [t for t in m._trees]
    X1, y1 = _rows(1)
    m.partial_fit(X1, y1, seed=1)
    assert m.n_rows == len(X0) + len(X1)
    # exactly refresh_trees slots rebuilt, starting at slot 0
    changed = [i for i, (a, b) in enumerate(zip(cold, m._trees)) if a is not b]
    assert changed == [0, 1, 2]
    warm1 = [t for t in m._trees]
    m.partial_fit(*_rows(2), seed=1)
    changed = [i for i, (a, b) in enumerate(zip(warm1, m._trees))
               if a is not b]
    assert changed == [3, 4, 5]


def test_partial_fit_refreshed_trees_see_new_rows():
    m = _warm_forest()
    m.partial_fit(*_rows(0, n=40), seed=5)
    before = np.asarray(m.predict(_rows(42)[0][:8]))
    # feed rows from a shifted distribution: refreshed trees must move
    rng = np.random.default_rng(8)
    X = rng.uniform(0.5, 4.0, (80, 4)).astype(np.float32)
    m.partial_fit(X, np.full(80, 1e-3), seed=5)
    after = np.asarray(m.predict(_rows(42)[0][:8]))
    assert not np.array_equal(before, after)
    assert after.mean() < before.mean()


def test_fit_resets_warm_state():
    m = _warm_forest()
    m.partial_fit(*_rows(0), seed=2)
    m.partial_fit(*_rows(1), seed=2)
    X2, y2 = _rows(2)
    m.fit(X2, y2)
    assert m.n_rows == len(X2)
    # next partial_fit behaves like the first warm call again: slot 0 onward
    cold = [t for t in m._trees]
    m.partial_fit(*_rows(3), seed=2)
    changed = [i for i, (a, b) in enumerate(zip(cold, m._trees)) if a is not b]
    assert changed == [0, 1, 2]


def test_predict_log_stats_mean_matches_predict():
    m = _warm_forest()
    m.partial_fit(*_rows(4), seed=3)
    Xq = _rows(5)[0][:24]
    mu, sd = m.predict_log_stats(Xq)
    assert mu.shape == sd.shape == (24,)
    assert np.all(sd >= 0.0)
    np.testing.assert_allclose(np.exp(mu), np.asarray(m.predict(Xq)),
                               rtol=1e-5)


def test_predict_log_stats_zero_spread_on_duplicate_target():
    # all-identical targets: every tree predicts the same constant
    X = _rows(6, n=32)[0]
    m = _warm_forest()
    m.partial_fit(X, np.full(32, 7.0), seed=0)
    mu, sd = m.predict_log_stats(X[:8])
    np.testing.assert_allclose(mu, np.log(7.0), rtol=1e-6)
    np.testing.assert_allclose(sd, 0.0, atol=1e-7)
