"""Adaptive (surrogate-guided) campaign tests: budget=100% degenerates
bitwise to the exact sweep, resume == fresh, the frontier only ever contains
exactly-evaluated candidates, the distributed runner is bitwise-identical to
single-process (crashes and duplicates included), and the hypervolume-gain
acquisition matches the brute-force oracle."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare installs
    from _hypothesis_stub import given, settings, st

from repro.core import dse
from repro.dse_campaign import (AdaptiveCampaign, AdaptiveConfig, Campaign,
                                CampaignConfig, FaultInjection, LeaseBoard,
                                frontiers_identical, hypervolume_2d,
                                hypervolume_gain_2d, run_adaptive_distributed,
                                tile_span, tiny_campaign_space)

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}
WL = dse.Workload("qwen3_14b", "train_4k", BASE, 256, 0.5)
KEY = ("qwen3_14b", "train_4k")
CONS = dse.Constraint(max_power_w=50_000)


def adaptive_cfg(**kw):
    """Tiny-space knobs: enough budget for a seed round plus a few acquire
    rounds at chunk 64 (800 candidates / 13 tiles)."""
    kw.setdefault("budget_fraction", 0.6)
    kw.setdefault("seed_fraction", 0.15)
    kw.setdefault("round_fraction", 0.08)
    kw.setdefault("train_sample", 48)
    kw.setdefault("plateau_rounds", 2)
    return AdaptiveConfig(**kw)


def campaign_cfg(acfg=None, **kw):
    kw.setdefault("space", tiny_campaign_space(chunk_size=64))
    kw.setdefault("evaluator", "jit")
    kw.setdefault("constraint", CONS)
    return CampaignConfig(adaptive=acfg, **kw)


# --- config ------------------------------------------------------------------


def test_adaptive_config_validation():
    for bad in [dict(budget_fraction=0.0), dict(budget_fraction=1.5),
                dict(seed_fraction=0.0), dict(round_fraction=0.0),
                dict(plateau_rounds=0), dict(train_sample=0),
                dict(n_trees=0), dict(refresh_trees=9, n_trees=8)]:
        with pytest.raises(ValueError):
            AdaptiveConfig(**bad)


def test_adaptive_config_dict_roundtrip():
    acfg = adaptive_cfg(explore_weight=1.7, seed=3)
    assert AdaptiveConfig.from_dict(acfg.to_dict()) == acfg


def test_adaptive_campaign_requires_adaptive_config():
    with pytest.raises(ValueError, match="config.adaptive"):
        AdaptiveCampaign([WL], campaign_cfg(acfg=None))


# --- budget=100%: the degenerate exact sweep ---------------------------------


def test_budget_100_is_bitwise_exact_sweep():
    exact = Campaign([WL], campaign_cfg())
    er = exact.run()
    ad = AdaptiveCampaign(
        [WL], campaign_cfg(adaptive_cfg(budget_fraction=1.0)))
    ar = ad.run()
    assert frontiers_identical(ad.frontiers[KEY], exact.frontiers[KEY])
    assert ar.candidates_evaluated == er.candidates_evaluated == ar.space_size
    assert ar.fraction_evaluated == 1.0
    assert ar.tiles_evaluated == ar.n_tiles


# --- budget + frontier-subset invariants -------------------------------------


def run_tiny(acfg, telemetry=None):
    ad = AdaptiveCampaign([WL], campaign_cfg(acfg), telemetry=telemetry)
    return ad, ad.run()


def assert_frontier_subset_of_evaluated(ad, res):
    evaluated = set()
    for rtiles in res.rounds:
        for t in rtiles:
            lo, hi = tile_span(ad.space, t)
            evaluated.update(range(lo, hi))
    for key, fr in ad.frontiers.items():
        assert len(fr.indices), f"empty frontier for {key}"
        missing = [int(i) for i in fr.indices if int(i) not in evaluated]
        assert not missing, (
            f"{key}: frontier indices {missing} were never exactly evaluated")


def test_adaptive_respects_budget_and_frontier_is_exact():
    ad, res = run_tiny(adaptive_cfg())
    assert res.stopped_on in ("plateau", "budget", "exhausted")
    assert res.fraction_evaluated <= ad.acfg.budget_fraction + 1e-12
    assert res.candidates_evaluated == sum(
        tile_span(ad.space, t)[1] - tile_span(ad.space, t)[0]
        for r in res.rounds for t in r)
    assert_frontier_subset_of_evaluated(ad, res)
    # hv against the pinned refs only ever grows as the frontier accretes
    hv = np.asarray(res.hv_history)
    assert np.all(np.diff(hv) >= -1e-12)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000), budget=st.sampled_from([0.3, 0.45, 0.6]))
def test_adaptive_frontier_subset_property(seed, budget):
    """Whatever the rng seed and budget, every frontier point comes from an
    exactly-evaluated tile — surrogate scores never fabricate candidates."""
    ad, res = run_tiny(adaptive_cfg(budget_fraction=budget, seed=seed))
    assert_frontier_subset_of_evaluated(ad, res)
    assert res.fraction_evaluated <= budget + 1e-12


# --- resume == fresh ---------------------------------------------------------


def test_adaptive_resume_matches_fresh(tmp_path):
    acfg = adaptive_cfg()
    fresh, fr = run_tiny(acfg)

    ckpt = str(tmp_path / "adaptive.ckpt.json")
    part = AdaptiveCampaign([WL], campaign_cfg(acfg))
    pr = part.run(checkpoint_path=ckpt, max_rounds=2)
    assert pr.stopped_on == "max_rounds"
    assert len(pr.rounds) == 2

    resumed = AdaptiveCampaign.from_checkpoint(ckpt)
    assert resumed.rounds == fresh.rounds[:2]
    assert resumed.acq_refs == {k: v for k, v in part.acq_refs.items()}
    rr = resumed.run(checkpoint_path=ckpt)

    assert rr.rounds == fr.rounds
    assert rr.hv_history == fr.hv_history
    assert rr.stopped_on == fr.stopped_on
    assert rr.candidates_evaluated == fr.candidates_evaluated
    assert frontiers_identical(resumed.frontiers[KEY], fresh.frontiers[KEY])


def test_adaptive_checkpoint_serializes_acquisition_refs(tmp_path):
    acfg = adaptive_cfg()
    ad = AdaptiveCampaign([WL], campaign_cfg(acfg))
    ckpt = str(tmp_path / "refs.ckpt.json")
    ad.run(checkpoint_path=ckpt, max_rounds=1)
    state = ad.state_dict()
    # the acquisition reference points are explicit in the schema — a resume
    # must score candidates against the same (pinned) refs, not re-derive them
    refs = state["adaptive"]["acq_refs"]
    assert set(refs) == {f"{a}|{s}" for a, s in ad.acq_refs}
    for (a, s), v in ad.acq_refs.items():
        assert v is not None
        assert refs[f"{a}|{s}"] == [v[0], v[1]]
    resumed = AdaptiveCampaign.from_checkpoint(ckpt)
    assert resumed.acq_refs == ad.acq_refs


def test_plain_campaign_resume_rejects_missing_adaptive_state(tmp_path):
    ckpt = str(tmp_path / "plain.ckpt.json")
    camp = Campaign([WL], campaign_cfg())
    camp.run(checkpoint_path=ckpt)
    with pytest.raises(ValueError, match="no 'adaptive' state"):
        AdaptiveCampaign.from_checkpoint(ckpt)


# --- hypervolume-gain acquisition vs brute-force oracle ----------------------


def hv_union(e, l, ref_e, ref_l):
    """Brute-force dominated area of an ARBITRARY point set (running-min
    sweep; ``hypervolume_2d`` itself assumes a non-dominated input)."""
    e, l = np.asarray(e, np.float64), np.asarray(l, np.float64)
    inside = (e < ref_e) & (l < ref_l)
    if not inside.any():
        return 0.0
    e, l = e[inside], l[inside]
    order = np.lexsort((e, l))
    e, l = e[order], l[order]
    e_run = np.minimum.accumulate(e)
    right = np.append(l[1:], ref_l)
    return float(np.sum((ref_e - e_run) * (right - l)))


def hv_gain_oracle(e, l, fe, fl, ref_e, ref_l):
    base = hv_union(fe, fl, ref_e, ref_l)
    return np.array([
        hv_union(np.append(fe, ei), np.append(fl, li), ref_e, ref_l) - base
        for ei, li in zip(e, l)])


def test_hv_union_oracle_matches_hypervolume_2d_on_frontier():
    # on a genuinely non-dominated set the two definitions coincide — the
    # oracle below is anchored to the library's own hypervolume
    fe = np.array([8.0, 5.0, 3.0, 1.0])
    fl = np.array([1.0, 2.0, 4.0, 7.0])
    assert hv_union(fe, fl, 10.0, 10.0) == hypervolume_2d(fe, fl, 10.0, 10.0)


def test_hypervolume_gain_matches_oracle():
    rng = np.random.default_rng(7)
    fe, fl = rng.uniform(1, 9, 40), rng.uniform(1, 9, 40)
    e, l = rng.uniform(0.5, 11, 300), rng.uniform(0.5, 11, 300)
    gains = hypervolume_gain_2d(e, l, fe, fl, 10.0, 10.0)
    np.testing.assert_allclose(gains, hv_gain_oracle(e, l, fe, fl, 10.0, 10.0),
                               rtol=1e-12, atol=1e-12)


def test_hypervolume_gain_edge_cases():
    fe = np.array([2.0, 1.0])
    fl = np.array([1.0, 3.0])
    # dominated candidate: zero gain; outside the ref box: zero gain
    gains = hypervolume_gain_2d(np.array([2.5, 12.0, 0.5]),
                                np.array([2.5, 1.0, 0.5]),
                                fe, fl, 10.0, 10.0)
    assert gains[0] == 0.0 and gains[1] == 0.0 and gains[2] > 0.0
    # empty frontier: gain is the candidate's own rectangle
    alone = hypervolume_gain_2d(np.array([4.0]), np.array([6.0]),
                                np.array([]), np.array([]), 10.0, 10.0)
    np.testing.assert_allclose(alone, [(10.0 - 4.0) * (10.0 - 6.0)])


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.floats(0.1, 9.9), st.floats(0.1, 9.9)),
                min_size=0, max_size=25),
       st.lists(st.tuples(st.floats(0.05, 12.0), st.floats(0.05, 12.0)),
                min_size=1, max_size=25))
def test_hypervolume_gain_oracle_property(front, cands):
    fe = np.array([p[0] for p in front])
    fl = np.array([p[1] for p in front])
    e = np.array([p[0] for p in cands])
    l = np.array([p[1] for p in cands])
    gains = hypervolume_gain_2d(e, l, fe, fl, 10.0, 10.0, chunk=4)
    oracle = hv_gain_oracle(e, l, fe, fl, 10.0, 10.0)
    np.testing.assert_allclose(gains, oracle, rtol=1e-9, atol=1e-9)
    assert np.all(gains >= 0.0)


# --- LeaseBoard acquisition-priority leasing ---------------------------------


def test_leaseboard_set_priority_orders_leases():
    board = LeaseBoard(6, done=[5])
    board.set_priority([4, 1])
    order = [board.next_tile(0) for _ in range(5)]
    # ranked tiles first (in rank order), then the rest by index
    assert order == [4, 1, 0, 2, 3]
    assert board.next_tile(0) is None


def test_leaseboard_set_priority_survives_revoke():
    board = LeaseBoard(5)
    board.set_priority([3, 0, 2])
    assert board.next_tile(1) == 3
    board.revoke_worker(1)         # tile 3 re-pends at its rank
    assert [board.next_tile(0) for _ in range(5)] == [3, 0, 2, 1, 4]


def test_leaseboard_set_priority_rejects_duplicates():
    with pytest.raises(ValueError):
        LeaseBoard(4).set_priority([1, 1])


# --- distributed == single-process -------------------------------------------


@pytest.mark.parametrize("fault", [
    None,
    FaultInjection(kill_worker=1, kill_after_tiles=1),
], ids=["clean", "worker_crash"])
def test_adaptive_distributed_matches_single_process(fault):
    acfg = adaptive_cfg()
    cfg = campaign_cfg(acfg, n_workers=2)
    single = AdaptiveCampaign([WL], cfg)
    sr = single.run()

    dr, stats = run_adaptive_distributed([WL], cfg, fault=fault)
    assert dr.rounds == sr.rounds
    assert dr.hv_history == sr.hv_history
    assert dr.stopped_on == sr.stopped_on
    assert frontiers_identical(dr.frontiers[KEY], single.frontiers[KEY])
    if fault is not None:
        assert stats["lost_workers"] == [1]
        assert stats["reissued_tiles"] >= 1
