"""Resilience-layer tests: checksummed/journaled checkpoints (corrupt-and-
recover property — any byte flipped or truncated, resume still equals fresh
bitwise), RetryPolicy backoff determinism, poison-tile quarantine, coordinator
crash-recovery from the journal, the serving circuit breaker under a fake
clock, and the chaos harness itself."""

import json
import os
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare installs
    from _hypothesis_stub import given, settings, st

from repro.core import dse
from repro.dse_campaign import (Campaign, ChaosEvent, ChaosPolicy,
                                ChaosRunner, FabricCoordinator, FakeClock,
                                FaultInjection, LeaseBoard, LocalFabric,
                                SliceVariant, SpaceSpec, frontiers_identical,
                                run_distributed, store)
from repro.dse_campaign.chaos import _corrupt_file, _truncate_file
from repro.dse_campaign.config import CampaignConfig
from repro.runtime.fault_tolerance import RetryPolicy
from repro.serving.engine import CircuitBreaker
from repro.telemetry import metric_value

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}
WLS = [dse.Workload("qwen3_14b", "train_4k", BASE, 256, 0.5),
       dse.Workload("stablelm_1_6b", "serve_2k",
                    {k: v * 0.3 for k, v in BASE.items()}, 64, 0.2)]
CONS = dse.Constraint(max_power_w=50_000)


def small_spec(**kw):
    kw.setdefault("chips", ("tpu-v5e", "tpu-v4", "tpu-edge"))
    kw.setdefault("chip_counts", (16, 64))
    kw.setdefault("freq_points", 7)
    kw.setdefault("variants", (SliceVariant(), SliceVariant("bin85", 0.85)))
    kw.setdefault("chunk_size", 32)
    return SpaceSpec(**kw)


def campaign(**kw):
    spec = kw.pop("spec", None) or small_spec()
    return Campaign(WLS, spec, constraint=CONS, **kw)


def assert_identical_frontiers(a, b):
    assert set(a) == set(b)
    for key in a:
        assert frontiers_identical(a[key], b[key]), key


@pytest.fixture(scope="module")
def fresh_result():
    """The fault-free reference every recovery path must reproduce."""
    return campaign().run()


# --- RetryPolicy: bounded backoff, deterministic jitter -----------------------


def test_retry_backoff_bounded_and_growing():
    p = RetryPolicy(base_s=0.1, multiplier=2.0, max_s=1.0, jitter_frac=0.2,
                    max_attempts=8)
    sched = p.schedule()
    assert len(sched) == 8
    for a, s in enumerate(sched):
        raw = min(0.1 * 2.0 ** a, 1.0)
        assert raw * 0.8 <= s <= raw * 1.2
    # capped tail: every late attempt within jitter of max_s
    assert all(0.8 <= s <= 1.2 for s in sched[4:])


def test_retry_jitter_deterministic_across_instances():
    a = RetryPolicy(seed=7).schedule()
    b = RetryPolicy(seed=7).schedule()
    assert a == b
    c = RetryPolicy(seed=8).schedule()
    assert a != c  # different seed, different jitter
    # jitter actually varies by attempt (not one constant factor)
    p = RetryPolicy(base_s=1.0, multiplier=1.0, max_s=1.0, jitter_frac=0.5)
    sched = p.schedule()
    assert len(set(sched)) > 1


def test_retry_zero_jitter_is_exact():
    p = RetryPolicy(base_s=0.5, multiplier=2.0, max_s=4.0, jitter_frac=0.0,
                    max_attempts=5)
    assert p.schedule() == (0.5, 1.0, 2.0, 4.0, 4.0)


def test_retry_call_uses_injected_sleep_and_reraises():
    p = RetryPolicy(base_s=0.5, multiplier=2.0, max_s=4.0, jitter_frac=0.0,
                    max_attempts=3)
    sleeps, calls = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    assert p.call(flaky, sleep=sleeps.append, retry_on=(OSError,)) == "ok"
    assert sleeps == [0.5, 1.0]  # no wall sleeping, schedule respected

    sleeps.clear()
    with pytest.raises(OSError):
        p.call(lambda: (_ for _ in ()).throw(OSError("always")),
               sleep=sleeps.append, retry_on=(OSError,))
    assert len(sleeps) == 2  # max_attempts - 1 backoffs, then re-raise

    with pytest.raises(ValueError):  # non-matching exception: no retry
        p.call(lambda: (_ for _ in ()).throw(ValueError("bug")),
               sleep=sleeps.append, retry_on=(OSError,))


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(base_s=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(multiplier=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(max_s=0.01, base_s=0.05)
    with pytest.raises(ValueError):
        RetryPolicy(jitter_frac=1.0)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


# --- store: checksums, journal, generations, quarantine -----------------------


def _state(n=1, extra=0.0):
    return {"version": 1, "next_tile": n, "payload": [extra, n * 2]}


def test_atomic_write_json_returns_bytes_written(tmp_path):
    path = str(tmp_path / "x.json")
    n = store.atomic_write_json({"a": 1}, path)
    assert n == os.path.getsize(path) > 0
    assert json.load(open(path)) == {"a": 1}


def test_save_checkpoint_stamps_integrity_and_journal(tmp_path):
    path = str(tmp_path / "ckpt.json")
    store.save_checkpoint(_state(1), path)
    on_disk = json.load(open(path))
    env = on_disk[store.INTEGRITY_KEY]
    assert env["generation"] == 1 and env["algo"] == "crc32/json-c14n"
    body = {k: v for k, v in on_disk.items() if k != store.INTEGRITY_KEY}
    crc = zlib.crc32(json.dumps(body, sort_keys=True,
                                separators=(",", ":")).encode())
    assert env["crc32"] == crc
    records, torn = store.CheckpointJournal(path).records()
    assert torn == 0 and [r["generation"] for r in records] == [1]
    assert records[0]["crc32"] == crc


def test_generation_retention_keeps_last_k(tmp_path):
    path = str(tmp_path / "ckpt.json")
    for i in range(1, 6):
        store.save_checkpoint(_state(i), path, keep=3)
    gens = [g for g, _ in store.generation_paths(path)]
    assert gens == [3, 4, 5]
    # journal remembers the full history even after pruning
    records, torn = store.CheckpointJournal(path).records()
    assert torn == 0 and [r["generation"] for r in records] == [1, 2, 3, 4, 5]
    assert store.load_checkpoint(path)["next_tile"] == 5


def test_journal_skips_torn_lines(tmp_path):
    path = str(tmp_path / "ckpt.json")
    store.save_checkpoint(_state(1), path)
    store.save_checkpoint(_state(2), path)
    with open(path + ".journal", "a") as f:
        f.write('deadbeef {"generation": 99, "torn')  # no newline, bad json
    records, torn = store.CheckpointJournal(path).records()
    assert [r["generation"] for r in records] == [1, 2]
    assert torn == 1


def test_corrupt_canonical_quarantines_and_falls_back(tmp_path):
    path = str(tmp_path / "ckpt.json")
    for i in range(1, 4):
        store.save_checkpoint(_state(i), path)
    with open(path, "r+b") as f:  # flip a byte inside the payload
        raw = f.read()
        pos = raw.index(b'"next_tile"') + 13
        f.seek(pos)
        f.write(bytes([raw[pos] ^ 0xFF]))
    state, report = store.load_checkpoint_recovering(path)
    assert state["next_tile"] == 3  # newest generation file, same content
    assert report["quarantined"] == [path + ".corrupt"]
    assert os.path.exists(path + ".corrupt")
    assert report["fallback_generation"] == 3


def test_corruption_cascade_falls_back_generation_by_generation(tmp_path):
    path = str(tmp_path / "ckpt.json")
    for i in range(1, 4):
        store.save_checkpoint(_state(i), path)
    _truncate_file(path, 7)
    gens = dict((g, p) for g, p in store.generation_paths(path))
    _corrupt_file(gens[3], 40)
    state, report = store.load_checkpoint_recovering(path)
    assert state["next_tile"] == 2
    assert report["fallback_generation"] == 2
    assert len(report["quarantined"]) == 2


def test_all_corrupt_raises_corruption_error(tmp_path):
    path = str(tmp_path / "ckpt.json")
    store.save_checkpoint(_state(1), path, keep=1)
    _truncate_file(path, 3)
    for _, p in store.generation_paths(path):
        _truncate_file(p, 3)
    with pytest.raises(store.CheckpointCorruptionError):
        store.load_checkpoint_recovering(path)
    with pytest.raises(FileNotFoundError):
        store.load_checkpoint_recovering(str(tmp_path / "never.json"))


def test_legacy_checkpoint_without_envelope_still_loads(tmp_path):
    path = str(tmp_path / "legacy.json")
    with open(path, "w") as f:
        json.dump(_state(4), f)
    state, report = store.load_checkpoint_recovering(path)
    assert state["next_tile"] == 4 and report["quarantined"] == []


# --- corruption-recovery property: resume == fresh, never a traceback ---------


def _corrupt_resume_equals_fresh(tmp_path, fresh_result, offset, mode):
    """Interrupt mid-campaign, damage the checkpoint at ``offset``, resume.
    Whatever the byte hit — integrity envelope, payload, or whitespace whose
    flip still parses — the resumed run must finish with frontiers bitwise
    equal to the fresh run, with no traceback ever."""
    ckpt = str(tmp_path / f"ckpt_{mode}_{offset}.json")
    interrupted = campaign()
    interrupted.run(checkpoint_path=ckpt, max_tiles=3)
    if mode == "flip":
        assert _corrupt_file(ckpt, offset)
    else:
        assert _truncate_file(ckpt, offset)
    resumed = Campaign.from_checkpoint(ckpt)  # quarantine + fallback inside
    final = resumed.run(checkpoint_path=ckpt)
    assert final.complete
    assert_identical_frontiers(final.frontiers, fresh_result.frontiers)


@pytest.mark.parametrize("mode", ["flip", "truncate"])
@pytest.mark.parametrize("offset", [0, 1, 17, 101, 997, 10007])
def test_corrupt_any_byte_resume_equals_fresh(tmp_path, fresh_result,
                                              offset, mode):
    _corrupt_resume_equals_fresh(tmp_path, fresh_result, offset, mode)


@settings(max_examples=15, deadline=None)
@given(offset=st.integers(min_value=0, max_value=1 << 20),
       mode=st.sampled_from(["flip", "truncate"]))
def test_corrupt_random_byte_resume_equals_fresh(tmp_path_factory,
                                                 fresh_result, offset, mode):
    _corrupt_resume_equals_fresh(tmp_path_factory.mktemp("fuzz"),
                                 fresh_result, offset, mode)


# --- LeaseBoard: park / unpark / settled --------------------------------------


def test_lease_board_park_unpark_settled():
    board = LeaseBoard(3)
    assert board.next_tile("a") == 0
    assert board.park(0) is True  # parking drops the lease
    assert board.leases == {}
    assert board.park(0) is False  # already parked
    assert board.next_tile("a") == 1  # parked tile never re-issues
    assert board.complete(1) and board.complete(2)
    assert board.all_settled and not board.all_done
    assert board.parked_tiles == [0] and board.n_pending == 0
    assert board.unpark(0) is True
    assert board.next_tile("b") == 0  # retry path re-issues it
    assert board.complete(0)
    assert board.all_done
    assert board.unpark(0) is False  # nothing parked anymore


def test_lease_board_late_delivery_of_parked_tile_completes_it():
    board = LeaseBoard(2)
    board.next_tile("a")
    board.park(0)
    assert board.complete(0) is True  # delivered evidence beats quarantine
    assert board.parked_tiles == []
    with pytest.raises(IndexError):
        board.park(9)


# --- poison-tile quarantine through the fabric --------------------------------


def test_local_fabric_poison_tile_quarantine_and_retry(fresh_result):
    camp = campaign()
    fabric = LocalFabric(camp, n_workers=3,
                         fault=FaultInjection(poison_tile=2),
                         poison_threshold=2,
                         retry=RetryPolicy(base_s=1.0, max_s=4.0))
    result = fabric.run()
    stats = fabric.coord.stats
    assert stats["poison_tiles"] == [2]
    assert stats["poison_retried"] == [2]
    assert len(stats["worker_crashes"]) == 2  # exactly threshold deaths
    assert_identical_frontiers(result.frontiers, fresh_result.frontiers)
    snap = camp.telemetry.metrics.snapshot()
    assert metric_value(snap, "fabric_poison_tiles_total") == 1
    assert metric_value(snap, "fabric_worker_crashed") == 2


def test_poison_tile_requires_fake_clock():
    with pytest.raises(ValueError):
        LocalFabric(campaign(), fault=FaultInjection(poison_tile=0),
                    clock=__import__("time").monotonic)


def test_worker_lost_counters_distinguish_crash_from_clean_exit():
    camp = campaign()
    coord = FabricCoordinator(camp, clock=FakeClock())
    coord.register_worker("a")
    coord.register_worker("b")
    coord.lease("a")
    coord.lease("b")
    coord.worker_lost("a", crashed=True)
    coord.worker_lost("b", crashed=False)
    assert coord.stats["worker_crashes"] == ["a"]
    assert coord.stats["worker_clean_exits"] == ["b"]
    snap = camp.telemetry.metrics.snapshot()
    assert metric_value(snap, "fabric_worker_crashed") == 1
    assert metric_value(snap, "fabric_worker_done") == 1


# --- coordinator crash-recovery from checkpoint + journal ---------------------


def test_coordinator_from_checkpoint_recovers_mid_campaign(tmp_path,
                                                           fresh_result):
    ckpt = str(tmp_path / "fab.json")
    camp = campaign()
    clock = FakeClock()
    coord = FabricCoordinator(camp, lease_timeout_s=10.0, clock=clock)
    fabric = LocalFabric(coord, n_workers=2)
    fabric.run(max_completions=3, checkpoint_path=ckpt)
    _corrupt_file(ckpt, 23)  # the restart must survive a damaged canonical

    coord2 = FabricCoordinator.from_checkpoint(ckpt, lease_timeout_s=10.0,
                                               clock=clock)
    rec = coord2.stats["recovery"]
    assert rec["tiles_done_at_restart"] == 3
    assert rec["quarantined"] == [ckpt + ".corrupt"]
    # 3 per-completion checkpoints + the final interrupt checkpoint = gen 4
    assert rec["journal_generation"] == rec["fallback_generation"] == 4
    assert rec["journal_torn_lines"] == 0
    snap = coord2.campaign.telemetry.metrics.snapshot()
    assert metric_value(snap, "fabric_coordinator_recoveries_total") == 1
    assert metric_value(snap, "fabric_checkpoints_quarantined_total") == 1

    final = LocalFabric(coord2, n_workers=2).run(checkpoint_path=ckpt)
    assert_identical_frontiers(final.frontiers, fresh_result.frontiers)


def test_coordinator_recovery_restores_parked_tiles(tmp_path):
    ckpt = str(tmp_path / "parked.json")
    camp = campaign()
    coord = FabricCoordinator(camp, clock=FakeClock(), poison_threshold=1)
    coord.register_worker("w")
    tile = coord.lease("w")
    coord.worker_lost("w", crashed=True)  # threshold 1: parked immediately
    assert coord.board.parked_tiles == [tile]
    coord.checkpoint(ckpt)
    coord2 = FabricCoordinator.from_checkpoint(ckpt, clock=FakeClock())
    assert coord2.board.parked_tiles == [tile]
    assert coord2.stats["poison_tiles"] == [tile]


# --- circuit breaker (unit, fake clock) ---------------------------------------


def test_circuit_breaker_trips_cools_probes_and_closes():
    clock = FakeClock()
    seen = []
    br = CircuitBreaker(fail_threshold=2, cooldown_s=10.0, clock=clock,
                        on_transition=lambda a, b: seen.append((a, b)))
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed"  # below threshold
    br.record_failure()
    assert br.state == "open" and not br.allow()
    clock.advance(9.9)
    assert not br.allow()  # still cooling
    clock.advance(0.2)
    assert br.allow() and br.state == "half_open"  # one probe admitted
    br.record_failure()  # probe failed: re-open for a full cooldown
    assert br.state == "open" and not br.allow()
    clock.advance(10.1)
    assert br.allow() and br.state == "half_open"
    br.record_success()
    assert br.state == "closed" and br.allow()
    assert seen == [("closed", "open"), ("open", "half_open"),
                    ("half_open", "open"), ("open", "half_open"),
                    ("half_open", "closed")]


def test_circuit_breaker_success_resets_failure_streak():
    br = CircuitBreaker(fail_threshold=3, clock=FakeClock())
    br.record_failure()
    br.record_failure()
    br.record_success()  # streak broken
    br.record_failure()
    br.record_failure()
    assert br.state == "closed"
    br.record_failure()
    assert br.state == "open"


def test_circuit_breaker_validation():
    with pytest.raises(ValueError):
        CircuitBreaker(fail_threshold=0)
    with pytest.raises(ValueError):
        CircuitBreaker(cooldown_s=-1.0)


# --- chaos harness ------------------------------------------------------------


def test_chaos_policy_roundtrip_and_validation():
    pol = ChaosPolicy(events=(ChaosEvent(2, "kill_worker", 1),
                              ChaosEvent(3, "corrupt_checkpoint", 17)),
                      poison_tile=4, seed=9)
    assert ChaosPolicy.from_dict(pol.to_dict()) == pol
    with pytest.raises(ValueError):
        ChaosEvent(1, "set_on_fire")
    with pytest.raises(ValueError):
        ChaosEvent(-1, "kill_worker")


def test_chaos_policy_random_is_deterministic():
    a = ChaosPolicy.random(seed=3, n_events=4, horizon=7)
    assert a == ChaosPolicy.random(seed=3, n_events=4, horizon=7)
    assert a != ChaosPolicy.random(seed=4, n_events=4, horizon=7)
    assert len(a.events) == 4


def test_chaos_run_kill_restart_corrupt_identical_to_fresh(tmp_path,
                                                           fresh_result):
    """The harness's own headline scenario: a worker kill, an on-disk
    corruption, and a coordinator restart in one run — frontiers must come
    out bitwise-identical, and the report must show the recovery."""
    policy = ChaosPolicy(events=(ChaosEvent(1, "kill_worker"),
                                 ChaosEvent(3, "corrupt_checkpoint", 31),
                                 ChaosEvent(3, "restart_coordinator")))
    runner = ChaosRunner(WLS, CampaignConfig(space=small_spec(),
                                             constraint=CONS),
                         policy, n_workers=3)
    result, report = runner.run(str(tmp_path / "chaos.json"))
    assert_identical_frontiers(result.frontiers, fresh_result.frontiers)
    assert report["kills"] == 1 and report["restarts"] == 1
    assert report["corruptions"] == 1
    assert len(report["quarantined_files"]) == 1
    assert report["respawns"] == 1
    assert report["recoveries"][0]["tiles_done_at_restart"] >= 1


def test_chaos_run_is_deterministic(tmp_path, fresh_result):
    policy = ChaosPolicy.random(seed=11, n_events=5, horizon=7)
    reports = []
    for i in range(2):
        runner = ChaosRunner(WLS, CampaignConfig(space=small_spec(),
                                                 constraint=CONS),
                             policy, n_workers=3)
        result, report = runner.run(str(tmp_path / f"det{i}.json"))
        assert_identical_frontiers(result.frontiers, fresh_result.frontiers)
        reports.append(report)
    assert reports[0] == reports[1]  # same policy, same faults, same counts


# --- multiprocess exit-code distinction (real processes) ----------------------


def test_multiprocess_crash_vs_clean_exit_counters(tmp_path, fresh_result):
    """The ONLY worker is killed by ``os._exit`` mid-tile, so the run can
    complete only through a RetryPolicy-paced respawn; the kill is counted
    as a crash, the respawned worker's shutdown as a clean exit — and the
    frontier still matches the fault-free run."""
    camp = campaign()
    result, stats = run_distributed(
        camp, fault=FaultInjection(kill_worker=0, kill_after_tiles=1),
        retry=RetryPolicy(base_s=0.05, max_s=0.2),
        max_respawns=2, n_workers=1, lease_timeout_s=60.0,
        checkpoint_path=str(tmp_path / "mp.json"))
    assert_identical_frontiers(result.frontiers, fresh_result.frontiers)
    assert stats["worker_crashes"] == [0]
    assert len(stats["worker_clean_exits"]) >= 1  # the respawned worker
    snap = camp.telemetry.metrics.snapshot()
    assert metric_value(snap, "fabric_worker_crashed") == 1
    assert metric_value(snap, "fabric_worker_done") >= 1
    assert metric_value(snap, "fabric_worker_respawns_total") >= 1
