import os

# Smoke tests and benches see ONE device; multi-device behaviour is tested in
# subprocesses that set XLA_FLAGS themselves (see test_distributed.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
