"""Layer-level unit tests: chunked flash vs naive, MLA absorbed decode,
SSD chunked vs sequential, MoE dense-vs-EP (singleton mesh), rope properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels import ref
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssd

RNG = np.random.default_rng(7)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# --- chunked flash attention --------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(64, 64), (256, 64), (96, 64), (200, 64)])
def test_chunked_flash_vs_naive(S, chunk):
    B, H, hd = 2, 4, 32
    q, k, v = (_rand((B, S, H, hd)) for _ in range(3))
    o = L.flash_attention(q, k, v, scale=hd ** -0.5, chunk=chunk)
    o_ref = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)


def test_prefix_lm_mask():
    """With a bidirectional prefix, prefix tokens see each other."""
    B, S, H, hd, P = 1, 64, 2, 16, 8
    q, k, v = (_rand((B, S, H, hd)) for _ in range(3))
    o_pref = L.flash_attention(q, k, v, scale=hd ** -0.5, prefix_len=P, chunk=32)
    o_causal = L.flash_attention(q, k, v, scale=hd ** -0.5, chunk=32)
    # rows inside the prefix differ (they can attend forward within the prefix)
    assert not np.allclose(np.asarray(o_pref[:, :P]), np.asarray(o_causal[:, :P]))
    # rows after the prefix are unchanged (they already saw the whole prefix)
    np.testing.assert_allclose(np.asarray(o_pref[:, P:]),
                               np.asarray(o_causal[:, P:]), atol=2e-5)


def test_gqa_grouping_matches_repeat():
    B, S, H, KV, hd = 2, 128, 8, 2, 16
    q = _rand((B, S, H, hd))
    k, v = _rand((B, S, KV, hd)), _rand((B, S, KV, hd))
    o = L.flash_attention(q, k, v, scale=hd ** -0.5, chunk=64)
    kk, vv = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
    o_ref = ref.attention_ref(q, kk, vv)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)


# --- rope ---------------------------------------------------------------------------

def test_rope_preserves_norm_and_relative_angles():
    B, S, H, hd = 1, 16, 1, 32
    x = _rand((B, S, H, hd))
    pos = jnp.arange(S)
    y = L.apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = _rand((1, 1, 1, hd))
    k = _rand((1, 1, 1, hd))
    def dot_at(p, d):
        qr = L.apply_rope(q, jnp.asarray([p]), 10000.0)
        kr = L.apply_rope(k, jnp.asarray([p + d]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(3, 5) - dot_at(11, 5)) < 1e-3


# --- MLA ----------------------------------------------------------------------------

def test_mla_absorbed_decode_matches_prefill():
    cfg = get_config("deepseek_v2_236b").reduced()
    key = jax.random.PRNGKey(0)
    p = MLA.init_mla(key, cfg)
    B, S = 2, 12
    x = _rand((B, S, cfg.d_model), jnp.float32, 0.1).astype(jnp.bfloat16)
    positions = jnp.arange(S)[None, :]
    out_full = MLA.mla_block(p, cfg, x, positions)

    cache = MLA.init_mla_cache(cfg, B, S, 1)
    cache_l = {"c_kv": cache["c_kv"][0], "k_rope": cache["k_rope"][0]}
    outs = []
    for i in range(S):
        o, cache_l = MLA.mla_decode(p, cfg, x[:, i: i + 1], cache_l, i)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec, np.float32),
                               np.asarray(out_full, np.float32),
                               atol=0.08, rtol=0.08)


def test_mla_cache_is_compressed():
    cfg = get_config("deepseek_v3_671b")      # FULL config arithmetic
    cache = MLA.init_mla_cache(cfg, batch=1, max_len=4, num_layers=1)
    per_tok = (cache["c_kv"].shape[-1] + cache["k_rope"].shape[-1])
    full = cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
                            + cfg.v_head_dim)
    assert per_tok * 50 < full, "V3 latent cache is ~71x smaller than full KV"


# --- SSD ----------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (64, 64)])
def test_ssd_chunked_matches_sequential(S, chunk):
    b, nh, hp, ds = 2, 2, 8, 16
    x = _rand((b, S, nh, hp))
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (b, S, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.3, 1.5, nh), jnp.float32)
    B = _rand((b, S, 1, ds))
    C = _rand((b, S, 1, ds))
    y, final_state = ssd.ssd_chunked(x, dt, A, B, C, chunk)
    y_ref = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


def test_mamba_prefill_state_matches_decode_replay():
    """Final state from the chunked prefill == state after stepwise decode."""
    cfg = get_config("mamba2_130m").reduced()
    p = ssd.init_mamba_block(jax.random.PRNGKey(0), cfg)
    B, S = 1, 32
    x = _rand((B, S, cfg.d_model), jnp.float32, 0.1).astype(jnp.bfloat16)
    y_full, (conv_tail, final_state) = ssd.mamba_block(p, cfg, x, return_cache=True)

    cache = {"conv": jnp.zeros((B, cfg.ssm_conv_width - 1,
                                cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state),
                               jnp.bfloat16),
             "state": jnp.zeros((B, cfg.ssm_nheads, cfg.ssm_headdim,
                                 cfg.ssm_state), jnp.float32)}
    ys = []
    for i in range(S):
        y_i, cache = ssd.mamba_decode(p, cfg, x[:, i: i + 1], cache)
        ys.append(y_i)
    y_dec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                               np.asarray(y_full, np.float32), atol=0.05, rtol=0.05)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(final_state), atol=2e-2, rtol=2e-2)


# --- MoE ----------------------------------------------------------------------------

def test_moe_routing_weights_normalized():
    cfg = get_config("deepseek_v3_671b").reduced()
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    xf = _rand((32, cfg.d_model))
    idx, w, aux = MOE._route(p, cfg, xf)
    assert idx.shape == (32, cfg.experts_per_token)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert float(aux) > 0


def test_moe_dense_shared_expert_contributes():
    cfg = get_config("deepseek_v2_236b").reduced()
    p = MOE.init_moe(jax.random.PRNGKey(1), cfg)
    x = _rand((2, 8, cfg.d_model), jnp.float32, 0.1).astype(jnp.bfloat16)
    y, aux = MOE.moe_dense(p, cfg, x)
    assert y.shape == x.shape
    p2 = dict(p)
    p2["shared"] = jax.tree_util.tree_map(jnp.zeros_like, p["shared"])
    y2, _ = MOE.moe_dense(p2, cfg, x)
    assert not np.allclose(np.asarray(y, np.float32), np.asarray(y2, np.float32))
