"""Distributed campaign fabric tests: lease ledger invariants, deterministic
lease-timeout expiry under a fake clock, and the headline identity — the
distributed frontier is bitwise-equal to the single-process frontier for any
worker count, interleaving (seeded), injected worker death, duplicated
payload delivery, or hang recovered by lease timeout."""

import numpy as np
import pytest

from repro.core import costmodel, dse
from repro.dse_campaign import (Campaign, FabricCoordinator, FakeClock,
                                FaultInjection, LeaseBoard, LocalFabric,
                                MultiprocessFabric, SliceVariant, SpaceSpec,
                                campaign_config, evaluator_from_config,
                                frontiers_identical, store, tile_span)
from repro.dse_campaign.fabric import _expand_intervals, _tile_intervals
from repro.runtime.fault_tolerance import HeartbeatMonitor

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}
WLS = [dse.Workload("qwen3_14b", "train_4k", BASE, 256, 0.5),
      dse.Workload("stablelm_1_6b", "serve_2k",
                   {k: v * 0.3 for k, v in BASE.items()}, 64, 0.2)]
CONS = dse.Constraint(max_power_w=50_000)


def small_spec(**kw):
    kw.setdefault("chips", ("tpu-v5e", "tpu-v4", "tpu-edge"))
    kw.setdefault("chip_counts", (16, 64))
    kw.setdefault("freq_points", 7)
    kw.setdefault("variants", (SliceVariant(), SliceVariant("bin85", 0.85)))
    kw.setdefault("chunk_size", 32)
    return SpaceSpec(**kw)


def campaign(**kw):
    kw.setdefault("evaluator", "numpy")
    spec = kw.pop("spec", None) or small_spec()
    return Campaign(WLS, spec, constraint=CONS, **kw)


def assert_identical_frontiers(a, b):
    assert set(a) == set(b)
    for key in a:
        assert frontiers_identical(a[key], b[key]), key


@pytest.fixture(scope="module")
def single_process_result():
    """The reference frontier every fabric variant must reproduce bitwise."""
    return campaign().run()


# --- LeaseBoard: the tile ownership ledger -----------------------------------


def test_lease_board_issues_smallest_pending_first():
    board = LeaseBoard(5)
    assert [board.next_tile("a"), board.next_tile("b")] == [0, 1]
    assert board.complete(0) is True
    assert board.next_tile("a") == 2
    assert board.n_done == 1 and not board.all_done


def test_lease_board_complete_is_first_write_wins():
    board = LeaseBoard(3)
    board.next_tile("a")
    assert board.complete(0) is True
    assert board.complete(0) is False  # duplicate delivery: stats-only no-op
    assert board.n_done == 1


def test_lease_board_revoke_repends_and_reissues():
    board = LeaseBoard(4)
    assert board.next_tile("a") == 0
    assert board.next_tile("b") == 1
    assert board.revoke_worker("a") == [0]
    # the revoked tile is the smallest pending again, for any worker
    assert board.next_tile("b") == 0
    assert board.revoke_worker("a") == []  # nothing left to revoke


def test_lease_board_never_reissues_done_tiles():
    board = LeaseBoard(3)
    t = board.next_tile("a")
    board.revoke_worker("a")          # tile 0 re-pends ...
    assert board.complete(t) is True  # ... but the "dead" worker delivers it
    # re-issue must skip it: the pending heap entry is stale
    assert board.next_tile("b") == 1
    assert board.next_tile("b") == 2
    assert board.next_tile("b") is None


def test_lease_board_contiguous_prefix_and_preseeded_done():
    board = LeaseBoard(6, done=[0, 1, 3])
    assert board.contiguous_done_prefix() == 2
    assert board.next_tile("a") == 2    # holes first, never 0/1/3
    board.complete(2)
    assert board.contiguous_done_prefix() == 4
    assert board.done_tiles == [0, 1, 2, 3]


def test_tile_interval_roundtrip():
    tiles = [0, 1, 2, 5, 7, 8]
    assert _tile_intervals(tiles) == [[0, 3], [5, 6], [7, 9]]
    assert _expand_intervals(_tile_intervals(tiles)) == tiles


def test_tile_span_matches_tiles_iteration():
    spec = small_spec()
    for t, lo, batch in spec.tiles():
        assert tile_span(spec, t) == (lo, lo + len(batch))
    with pytest.raises(IndexError):
        tile_span(spec, spec.n_tiles())


# --- HeartbeatMonitor + coordinator expiry: deterministic under FakeClock ----


def test_heartbeat_register_forget_and_fake_clock_expiry():
    clock = FakeClock()
    mon = HeartbeatMonitor([], timeout_s=10.0, clock=clock)
    mon.register("w0")
    clock.advance(6.0)
    mon.register("w1")
    clock.advance(5.0)            # w0 silent 11s > 10; w1 silent 5s
    assert mon.dead_hosts() == ["w0"]
    mon.beat("w0")
    assert mon.healthy()
    mon.forget("w0")
    clock.advance(100.0)
    assert mon.dead_hosts() == ["w1"]  # forgotten hosts never report dead


def test_coordinator_expires_only_lease_holders():
    clock = FakeClock()
    coord = FabricCoordinator(campaign(), lease_timeout_s=10.0, clock=clock)
    coord.register_worker("busy")
    coord.register_worker("idle")
    assert coord.lease("busy") == 0    # only "busy" holds a lease
    clock.advance(11.0)
    expired = coord.expire()
    # the hung lease holder is expelled and its tile re-pends; the idle
    # worker owes nothing and silence alone must not expel it
    assert expired == {"busy": [0]}
    assert coord.board.next_tile("idle") == 0


# --- worker config: serialization + version gates ----------------------------


def test_campaign_config_roundtrips_evaluator():
    camp = campaign(evaluator="numpy")
    ev = evaluator_from_config(campaign_config(camp))
    assert ev.evaluator == "numpy"
    assert ev.workload_keys == camp.engine.workload_keys
    assert len(ev.space) == len(camp.space)
    # the rebuilt evaluator reduces a tile identically to the original
    lo, hi = tile_span(camp.space, 1)
    batch = camp.space.slice(lo, hi)
    a = camp.engine.reduce_tile(batch, lo)
    b = ev.reduce_tile(batch, lo)
    for wi in range(a.n_workloads):
        np.testing.assert_array_equal(a.surv_gidx[wi], b.surv_gidx[wi])
        np.testing.assert_array_equal(a.surv_energy[wi], b.surv_energy[wi])
        np.testing.assert_array_equal(a.surv_latency[wi], b.surv_latency[wi])
    assert a.n_feasible == b.n_feasible
    assert a.ref_energy_j == b.ref_energy_j


def test_campaign_config_refuses_fast_evaluator():
    class Fitted:
        def predict(self, X):  # pragma: no cover - never called
            return np.zeros(len(X))

    camp = campaign(evaluator="fast", power_model=Fitted(),
                    cycles_model=Fitted())
    with pytest.raises(ValueError, match="fast"):
        campaign_config(camp)


def test_evaluator_from_config_refuses_mixed_cost_model_versions():
    cfg = campaign_config(campaign())
    cfg["sim_model_version"] = costmodel.SIM_MODEL_VERSION + 1
    with pytest.raises(ValueError, match="version"):
        evaluator_from_config(cfg)


# --- LocalFabric: interleaving + fault-injection identity --------------------


@pytest.mark.parametrize("n_workers,seed", [(1, 0), (2, 0), (3, 1), (5, 2)])
def test_local_fabric_identity_any_workers_any_interleaving(
        n_workers, seed, single_process_result):
    res = LocalFabric(campaign(), n_workers=n_workers, seed=seed).run()
    assert res.complete
    assert_identical_frontiers(single_process_result.frontiers, res.frontiers)
    # stats ledger is exact despite arbitrary completion order
    assert res.candidates_evaluated == len(small_spec()) * len(WLS)


def test_local_fabric_survives_worker_death_and_duplicate_delivery(
        single_process_result):
    fab = LocalFabric(
        campaign(), n_workers=3, seed=1,
        fault=FaultInjection(kill_worker=1, kill_after_tiles=1,
                             duplicate=True))
    res = fab.run()
    assert res.complete
    # the scripted faults actually fired (seeded interleaving is stable)
    assert fab.coord.stats["lost_workers"] == [1]
    assert fab.coord.stats["reissued_tiles"] >= 1
    assert fab.coord.stats["duplicates"] == 1
    # ... and neither the re-issued tile nor the duplicate fold perturbed
    # the frontier or the candidate accounting
    assert_identical_frontiers(single_process_result.frontiers, res.frontiers)
    assert res.candidates_evaluated == len(small_spec()) * len(WLS)


def test_local_fabric_recovers_hung_worker_via_lease_timeout(
        single_process_result):
    fab = LocalFabric(campaign(), n_workers=2, seed=3, lease_timeout_s=5.0,
                      fault=FaultInjection(hang_worker=0))
    res = fab.run()
    assert res.complete
    assert fab.coord.stats["lost_workers"] == [0]
    assert fab.coord.stats["reissued_tiles"] == 1
    assert_identical_frontiers(single_process_result.frontiers, res.frontiers)


def test_local_fabric_hang_requires_fake_clock():
    with pytest.raises(ValueError, match="FakeClock"):
        LocalFabric(campaign(), clock=__import__("time").monotonic,
                    fault=FaultInjection(hang_worker=0))


def test_local_fabric_fused_jit_identity():
    """The fused float32 sweep distributes bitwise too (same compiled fn,
    same padded tile shapes, order-independent merges)."""
    single = campaign(evaluator="jit").run()
    res = LocalFabric(campaign(evaluator="jit"), n_workers=3, seed=5,
                      fault=FaultInjection(kill_worker=2, kill_after_tiles=1,
                                           duplicate=True)).run()
    assert res.complete
    assert_identical_frontiers(single.frontiers, res.frontiers)


def test_local_fabric_overflow_normalization_identity():
    """A workload whose screened set overflows max_survivors ships the
    host-reduced exact skyline instead; the fold still matches the
    single-process overflow fallback bitwise."""
    single = campaign(evaluator="jit", max_survivors=1).run()
    res = LocalFabric(campaign(evaluator="jit", max_survivors=1),
                      n_workers=2, seed=0).run()
    assert res.complete
    assert_identical_frontiers(single.frontiers, res.frontiers)


# --- distributed checkpoints -------------------------------------------------


def test_fabric_checkpoint_resume_matches_fresh(tmp_path,
                                                single_process_result):
    ckpt = str(tmp_path / "fabric.ckpt.json")
    fab = LocalFabric(campaign(), n_workers=3, seed=2)
    partial = fab.run(max_completions=3, checkpoint_path=ckpt)
    assert not partial.complete

    state = store.load_checkpoint(ckpt)
    assert state["version"] == 1                  # schema unchanged
    done = _expand_intervals(state["fabric"]["done"])
    assert len(done) == 3
    prefix = 0
    while prefix in done:
        prefix += 1
    assert state["next_tile"] == prefix  # contiguous done prefix

    # resume on a DIFFERENT worker count; done tiles are not re-evaluated
    coord = FabricCoordinator.from_checkpoint(ckpt, lease_timeout_s=1e9,
                                              clock=FakeClock())
    assert coord.board.done_tiles == done
    res = LocalFabric(coord, n_workers=2, seed=9).run()
    assert res.complete
    assert_identical_frontiers(single_process_result.frontiers, res.frontiers)
    assert res.candidates_evaluated == len(small_spec()) * len(WLS)


def test_plain_campaign_resumes_fabric_checkpoint(tmp_path,
                                                  single_process_result):
    """A fabric checkpoint is a valid single-process checkpoint: next_tile
    is the contiguous done prefix and any out-of-prefix tiles the fabric
    already folded re-merge as exact no-ops."""
    ckpt = str(tmp_path / "fabric.ckpt.json")
    LocalFabric(campaign(), n_workers=3, seed=4).run(max_completions=4,
                                                     checkpoint_path=ckpt)
    resumed = Campaign.from_checkpoint(ckpt)
    res = resumed.run()
    assert res.complete
    assert_identical_frontiers(single_process_result.frontiers, res.frontiers)


# --- MultiprocessFabric: real spawn workers ----------------------------------


def test_multiprocess_fabric_death_duplicate_identity(tmp_path,
                                                      single_process_result):
    """One real-process run exercising the whole failure matrix: a worker
    crashes mid-tile (exits without delivering), the first payload is
    delivered twice, a checkpoint is written — and the frontier still
    equals the single-process run bitwise."""
    ckpt = str(tmp_path / "mp.ckpt.json")
    fab = MultiprocessFabric(
        campaign(), n_workers=2, checkpoint_every=2,
        fault=FaultInjection(kill_worker=1, kill_after_tiles=1,
                             duplicate=True))
    res = fab.run(checkpoint_path=ckpt)
    assert res.complete
    assert fab.stats["lost_workers"] == [1]
    assert fab.stats["duplicates"] == 1
    assert fab.stats["reissued_tiles"] >= 1
    assert_identical_frontiers(single_process_result.frontiers, res.frontiers)
    assert res.candidates_evaluated == len(small_spec()) * len(WLS)
    # the final checkpoint records every tile done
    state = store.load_checkpoint(ckpt)
    assert _expand_intervals(state["fabric"]["done"]) == list(
        range(small_spec().n_tiles()))


def test_multiprocess_fabric_rejects_hang_injection():
    with pytest.raises(ValueError, match="LocalFabric"):
        MultiprocessFabric(campaign(), fault=FaultInjection(hang_worker=0))
