"""Topology-aware collective model tests: per-axis link/wraparound/hop
semantics, factorization signal (same-count meshes -> distinct t_collective),
scalar/batch/jit parity across tile sizes, pod-axis plumbing, and the
removed ``links_used`` knob (fixed mesh-less approximation + checkpoint
upgrade error)."""

import itertools
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on bare installs
    from _hypothesis_stub import given, settings, st

from repro.core import costmodel, dse
from repro.dse_campaign import (CampaignConfig, SpaceSpec, StreamingFrontier,
                                frontiers_identical)
from repro.hw import (CHIPS, axis_link_counts, get_chip, mesh_factorizations,
                      normalize_mesh, topology_for)

# collective-heavy census: wire bytes dominate so the factorization axis
# carries a visible latency/energy signal
COLL_HEAVY = {"flops": 1e13, "hbm_bytes": 1e12, "collective_bytes": 5e12,
              "wire_bytes": 7e12}
BASE_CHIPS = 256
WL = dse.Workload("qwen3_14b", "train_4k", COLL_HEAVY, BASE_CHIPS, 0.1)
CONS = dse.Constraint(max_power_w=60_000, min_hbm_fit=False)


def scalar_sim(cand: dse.Candidate) -> costmodel.SimResult:
    return costmodel.simulate(
        dse._scale_analysis(COLL_HEAVY, BASE_CHIPS, cand),
        get_chip(cand.chip), cand.n_chips, freq_mhz=cand.freq_mhz,
        mesh=cand.mesh)


# --- hw.Topology: link counts, wraparound, hops -------------------------------


def test_topology_v5e_2d_full_links():
    t = topology_for(get_chip("tpu-v5e"), (8, 8))
    assert t.mesh == (1, 8, 8)
    assert t.links == (0, 2, 2)          # 4 links / 2 active axes = 2 each
    assert t.wraparound == (False, True, True)
    assert t.hops == (0, 4, 4)           # torus diameter k//2


def test_topology_extent2_axis_is_a_line():
    t = topology_for(get_chip("tpu-v5e"), (2, 32))
    assert t.links[1] == 1               # no wrap on a 2-chip axis
    assert t.wraparound[1] is False
    assert t.hops[1] == 1
    assert t.links[2] == 2 and t.wraparound[2] is True


def test_topology_link_budget_degrades_3d_on_v5e():
    """v5e has 4 links: a 3D mesh (3 active axes) degrades to 1 link/axis,
    while 6-link v4/v5p keep 2 on the non-line axes."""
    v5e = topology_for(get_chip("tpu-v5e"), (4, 4, 4))
    assert v5e.links == (1, 1, 1)
    v5p = topology_for(get_chip("tpu-v5p"), (4, 4, 4))
    assert v5p.links == (2, 2, 2)


def test_topology_edge_chip_has_no_links():
    t = topology_for(get_chip("tpu-edge"), (1, 1))
    assert t.links == (0, 0, 0)
    assert t.hops == (0, 0, 0)


def test_normalize_mesh():
    assert normalize_mesh((16,)) == (1, 1, 16)
    assert normalize_mesh((4, 8)) == (1, 4, 8)
    assert normalize_mesh((2, 4, 8)) == (2, 4, 8)
    assert normalize_mesh((2, 2, 4, 8)) == (4, 4, 8)   # leading axes collapse
    with pytest.raises(ValueError):
        normalize_mesh((0, 4))
    with pytest.raises(ValueError):
        normalize_mesh(())


def test_axis_link_counts_vectorized_matches_scalar():
    chips = [CHIPS[n] for n in ("tpu-v5e", "tpu-v5p", "tpu-v4", "tpu-edge")]
    meshes = [(1, 1, 16), (1, 2, 8), (1, 4, 4), (2, 2, 4), (2, 4, 8)]
    cases = list(itertools.product(chips, meshes))
    lp, ld, lm = axis_link_counts(
        np.asarray([m[0] for _, m in cases]),
        np.asarray([m[1] for _, m in cases]),
        np.asarray([m[2] for _, m in cases]),
        np.asarray([c.ici_links for c, _ in cases], np.float64),
        np.asarray([c.ici_links_per_axis for c, _ in cases], np.float64))
    for i, (chip, mesh) in enumerate(cases):
        t = topology_for(chip, mesh)
        assert (int(lp[i]), int(ld[i]), int(lm[i])) == t.links, (chip.name, mesh)


# --- factorization signal: same chip count, distinct t_collective -------------


@settings(max_examples=30, deadline=None)
@given(st.sampled_from([16, 32, 64, 128, 256, 1024]),
       st.sampled_from(["tpu-v5e", "tpu-v5p", "tpu-v4"]),
       st.sampled_from([2, 3]))
def test_same_count_factorizations_distinct_t_coll(n_chips, chip, dims):
    """Every mesh factorization of the same chip count prices differently on
    the collective-heavy workload — the axis the mesh-agnostic model zeroed."""
    meshes = mesh_factorizations(n_chips, dims)
    if len(meshes) < 2:
        return
    t_colls = {}
    for mesh in meshes:
        cand = dse.Candidate(chip, n_chips, mesh, CHIPS[chip].max_freq_mhz)
        t_colls[mesh] = scalar_sim(cand).t_collective
    assert all(t > 0 for t in t_colls.values())
    vals = list(t_colls.values())
    assert len(set(vals)) == len(vals), t_colls    # pairwise distinct
    # ...and the signal reaches the ranking objective, not just the term
    energies = {m: scalar_sim(
        dse.Candidate(chip, n_chips, m, CHIPS[chip].max_freq_mhz)).energy_j
        for m in meshes}
    assert len(set(energies.values())) == len(meshes), energies


def test_legacy_model_tied_where_topology_differentiates():
    """The before/after of the refactor: mesh-less simulate ties all
    factorizations of 64 chips; the topology model separates them."""
    legacy, topo = set(), set()
    for mesh in mesh_factorizations(64, 2):
        cand = dse.Candidate("tpu-v5e", 64, mesh, 1600.0)
        ana = dse._scale_analysis(COLL_HEAVY, BASE_CHIPS, cand)
        chip = get_chip("tpu-v5e")
        legacy.add(costmodel.simulate(ana, chip, 64, 1600.0).t_collective)
        topo.add(costmodel.simulate(ana, chip, 64, 1600.0,
                                    mesh=cand.mesh).t_collective)
    assert len(legacy) == 1                      # the old tie
    assert len(topo) == len(mesh_factorizations(64, 2))


# --- scalar == batch == jit across chunk sizes --------------------------------


def space_3d(**kw):
    kw.setdefault("chips", ("tpu-v5e", "tpu-v5p", "tpu-edge"))
    kw.setdefault("chip_counts", (16, 64))
    kw.setdefault("freq_points", 5)
    kw.setdefault("mesh_dims", 3)
    return SpaceSpec(**kw)


@pytest.mark.parametrize("chunk", [1, 7, 4096])
def test_batch_scalar_topology_parity_across_chunks(chunk):
    """Tile-streamed simulate_batch must equal the scalar oracle bitwise for
    every candidate, for any chunk size, pod axes included."""
    spec = space_3d()
    for t, lo, batch in spec.tiles(chunk_size=chunk):
        sim, _ = dse.evaluate_workload_tile(WL, batch, CONS)
        for i, cand in enumerate(batch.candidates):
            ref = scalar_sim(cand)
            # collective term and latency are the same float64 expressions ->
            # bitwise; energy keeps the documented <=1-ulp pow()-vs-**3
            # residual of the power model
            assert float(sim.t_collective[i]) == ref.t_collective, cand
            assert float(sim.latency_s[i]) == ref.latency_s, cand
            assert abs(float(sim.energy_j[i]) - ref.energy_j) <= (
                4e-16 * abs(ref.energy_j)), cand


def test_jit_topology_parity():
    spec = space_3d()
    batch = spec.slice(0, len(spec))
    ref, _ = dse.evaluate_workload_tile(WL, batch, CONS)
    jit, _ = dse.evaluate_workload_tile(WL, batch, CONS, engine="jit")
    np.testing.assert_allclose(np.asarray(jit.t_collective),
                               np.asarray(ref.t_collective), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(jit.energy_j),
                               np.asarray(ref.energy_j), rtol=1e-5)


def test_pod_axis_flows_through_spacespec():
    """mesh_dims=3 rows carry their leading (pod) factor into the batch and
    the simulator prices it (satellite fix: the pod axis used to be dropped)."""
    spec = space_3d(chips=("tpu-v5p",), chip_counts=(64,))
    batch = spec.slice(0, len(spec))
    assert batch.mesh_pod is not None
    for i, cand in enumerate(batch.candidates):
        pod = int(np.prod(cand.mesh[:-2])) if len(cand.mesh) > 2 else 1
        assert int(batch.mesh_pod[i]) == pod
        assert pod * batch.mesh_data[i] * batch.mesh_model[i] == cand.n_chips
    # a 3D mesh must not collapse onto its pod-dropped sibling: the same
    # scaled census priced at (2, 4, 8) vs (1, 4, 8) must differ — this is
    # the exact regression (leading pod factor silently ignored) being fixed
    c3 = dse.Candidate("tpu-v5p", 64, (2, 4, 8), 1750.0)
    ana = dse._scale_analysis(COLL_HEAVY, BASE_CHIPS, c3)
    chip = get_chip("tpu-v5p")
    with_pod = costmodel.simulate(ana, chip, 64, 1750.0, mesh=(2, 4, 8))
    pod_dropped = costmodel.simulate(ana, chip, 64, 1750.0, mesh=(1, 4, 8))
    assert with_pod.t_collective != pod_dropped.t_collective
    assert scalar_sim(c3).t_collective == with_pod.t_collective


def test_streamed_equals_oneshot_under_topology_model():
    """Frontier identity (the campaign acceptance gate) holds with the
    topology model on a 3D-mesh space."""
    spec = space_3d()
    fr = StreamingFrontier()
    for t, lo, batch in spec.tiles(chunk_size=48):
        sim, feas = dse.evaluate_workload_tile(WL, batch, CONS)
        fr.merge(batch.candidates, sim.energy_j, sim.latency_s, feas,
                 indices=np.arange(lo, lo + len(batch)), tile=t)
    oneshot = dse.pareto_search(WL, spec.slice(0, len(spec)), CONS)[
        ("qwen3_14b", "train_4k")]
    assert frontiers_identical(fr.as_pareto_frontier(WL), oneshot)


def test_campaign_frontier_contains_mesh_differentiated_points():
    """With the topology model the frontier resolves mesh ties: frontier
    members carry definite meshes and same-(chip, count) duplicates with
    equal scores are gone for collective-heavy workloads."""
    spec = space_3d(chips=("tpu-v5e", "tpu-v5p"))
    front = dse.pareto_search(WL, spec.slice(0, len(spec)), CONS)[
        ("qwen3_14b", "train_4k")]
    assert len(front) >= 1
    seen = {}
    for c, e, l in zip(front.candidates, front.energy_j, front.latency_s):
        key = (c.chip, c.n_chips, c.freq_mhz, float(e), float(l))
        assert key not in seen or seen[key] == c.mesh, (
            "same-count mesh factorizations still tie on the frontier", key)
        seen[key] = c.mesh


# --- removed links_used knob (SIM_MODEL_VERSION 3) ----------------------------


def test_links_used_field_removed():
    """The deprecated knob is gone for good: constructing a SimConfig with
    it is a hard TypeError, not a warning."""
    with pytest.raises(TypeError):
        costmodel.SimConfig(links_used=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # defaults stay silent
        costmodel.SimConfig()


def test_meshless_fallback_is_fixed_approximation():
    """Mesh-less simulation uses the fixed MESHLESS_LINKS approximation —
    bitwise-identical to the old links_used default — and the topology path
    is untouched by the removal."""
    ana = {"flops": 1e12, "hbm_bytes": 1e10, "wire_bytes": 4e11,
           "collective_bytes": 3e11}
    chip = get_chip("tpu-v5e")
    assert costmodel.MESHLESS_LINKS == 2
    r = costmodel.simulate(ana, chip, 16)
    assert r.t_collective == 4e11 / (chip.ici_bw * costmodel.MESHLESS_LINKS)
    t = costmodel.simulate(ana, chip, 16, mesh=(4, 4))
    assert t.t_collective != r.t_collective      # topology model, not fallback


def test_links_used_checkpoint_gets_upgrade_error(tmp_path):
    """A checkpoint whose sim dict still carries links_used was written
    under cost-model version <= 2, so the version gate fires FIRST with the
    explicit upgrade message — the stale sim key never reaches
    SimConfig(**...)."""
    import json

    from repro.dse_campaign import Campaign
    from repro.dse_campaign.space import SpaceSpec

    spec = SpaceSpec(chips=("tpu-v5e",), chip_counts=(16,), freq_points=3,
                     chunk_size=16)
    camp = Campaign([WL], CampaignConfig(space=spec))
    camp.run(max_tiles=1)
    state = camp.state_dict()
    state["sim_model_version"] = 2
    state["sim"]["links_used"] = 2               # the v2 on-disk shape
    path = tmp_path / "old.json"
    path.write_text(json.dumps(state))
    with pytest.raises(ValueError, match="re-run the campaign"):
        Campaign.from_checkpoint(str(path))
    # the raw dict itself no longer reconstructs — the knob is really gone
    with pytest.raises(TypeError):
        costmodel.SimConfig(**state["sim"])


def test_cross_model_checkpoint_resume_refused(tmp_path):
    """Resuming a checkpoint written under a different cost-model version
    would splice incomparable frontiers — ``from_checkpoint`` must refuse
    (pre-topology checkpoints carry no ``sim_model_version`` at all)."""
    import json

    from repro.dse_campaign import Campaign
    from repro.dse_campaign.space import SpaceSpec

    spec = SpaceSpec(chips=("tpu-v5e",), chip_counts=(16,), freq_points=3,
                     chunk_size=16)
    camp = Campaign([WL], spec)
    camp.run(max_tiles=1)
    state = camp.state_dict()
    assert state["sim_model_version"] == costmodel.SIM_MODEL_VERSION

    path = tmp_path / "ckpt.json"
    path.write_text(json.dumps(state))
    resumed = Campaign.from_checkpoint(str(path))   # same version: fine
    assert resumed.next_tile == 1

    state["sim_model_version"] = costmodel.SIM_MODEL_VERSION - 1
    path.write_text(json.dumps(state))
    with pytest.raises(ValueError, match="cost-model version"):
        Campaign.from_checkpoint(str(path))
    del state["sim_model_version"]                  # pre-topology checkpoint
    path.write_text(json.dumps(state))
    with pytest.raises(ValueError, match="cost-model version"):
        Campaign.from_checkpoint(str(path))
