"""No-op stand-ins for hypothesis so @given property tests SKIP individually
(instead of the whole module failing to import / being skipped) when
hypothesis isn't installed.  Plain unit tests in the same module still run.
"""

import pytest


def given(*_args, **_kwargs):
    return pytest.mark.skip(reason="needs hypothesis (pip install -e .[test])")


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategy:
    """Absorbs any st.<strategy>(...) expression used in @given arguments."""

    def __call__(self, *_a, **_k):
        return self

    def __getattr__(self, _name):
        return self


st = _Strategy()
