"""Telemetry subsystem tests: metrics registry, span tracer, and the one
rule that keeps observability safe — instrumentation is a reading, never an
input.  The headline identity: a fully instrumented campaign's frontier is
BITWISE-equal to an uninstrumented one (``NullTelemetry`` default), so the
registry/tracer can ride every hot path without touching results."""

import json
import threading

import numpy as np
import pytest

from repro.core import dse
from repro.dse_campaign import (Campaign, FakeClock, LocalFabric,
                                MultiprocessFabric, SliceVariant, SpaceSpec,
                                frontiers_identical)
from repro.telemetry import (MetricsRegistry, NullTelemetry, SpanTracer,
                             Telemetry, coerce_telemetry, metric_value)
from repro.telemetry.trace import NULL_SPAN
from tools import trace_report

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}
WLS = [dse.Workload("qwen3_14b", "train_4k", BASE, 256, 0.5),
       dse.Workload("stablelm_1_6b", "serve_2k",
                    {k: v * 0.3 for k, v in BASE.items()}, 64, 0.2)]
CONS = dse.Constraint(max_power_w=50_000)


def small_spec(**kw):
    kw.setdefault("chips", ("tpu-v5e", "tpu-v4", "tpu-edge"))
    kw.setdefault("chip_counts", (16, 64))
    kw.setdefault("freq_points", 7)
    kw.setdefault("variants", (SliceVariant(), SliceVariant("bin85", 0.85)))
    kw.setdefault("chunk_size", 32)
    return SpaceSpec(**kw)


# ---------------------------------------------------------------- metrics --


class TestMetricsRegistry:
    def test_counter_monotonic(self):
        reg = MetricsRegistry(clock=FakeClock(5.0))
        c = reg.counter("tiles_total")
        c.inc()
        c.inc(3)
        assert c.value == 4
        assert c.updated_at == 5.0
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_labeled_series_are_distinct(self):
        reg = MetricsRegistry()
        a = reg.counter("queries_total", path="index_exact")
        b = reg.counter("queries_total", path="mini_campaign")
        a.inc(2)
        b.inc(5)
        assert a is not b and a.value == 2 and b.value == 5
        # same (name, labels) -> the SAME series object (held-series idiom)
        assert reg.counter("queries_total", path="index_exact") is a

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("busy_s")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("busy_s")

    def test_gauge_set_and_add(self):
        reg = MetricsRegistry()
        g = reg.gauge("worker_busy_s", worker=0)
        assert g.value is None
        g.add(1.5)
        g.add(0.5)
        assert g.value == 2.0
        g.set(7.0)
        assert g.value == 7.0

    def test_histogram_quantile_matches_numpy(self):
        rng = np.random.default_rng(7)
        samples = rng.lognormal(mean=-6.0, sigma=1.3, size=513)
        reg = MetricsRegistry()
        h = reg.histogram("latency_s")
        for s in samples:
            h.observe(float(s))
        for q in (0.0, 0.1, 0.5, 0.9, 0.99, 1.0):
            np.testing.assert_allclose(
                h.quantile(q), np.percentile(samples, q * 100),
                rtol=1e-12, err_msg=f"q={q}")
        assert h.count == samples.size
        np.testing.assert_allclose(h.sum, samples.sum())
        assert h.min == samples.min() and h.max == samples.max()

    def test_histogram_ring_bounds_memory_but_totals_exact(self):
        reg = MetricsRegistry()
        h = reg.histogram("latency_s", max_samples=64)
        for i in range(1000):
            h.observe(float(i))
        assert len(h.samples) == 64
        assert h.samples == [float(i) for i in range(936, 1000)]
        assert h.count == 1000 and h.sum == sum(range(1000))
        assert h.min == 0.0 and h.max == 999.0

    def test_histogram_empty_and_bad_q(self):
        h = MetricsRegistry().histogram("x_s")
        assert h.quantile(0.5) is None
        h.observe(1.0)
        with pytest.raises(ValueError):
            h.quantile(1.5)

    def test_snapshot_roundtrips_through_json(self):
        tel = Telemetry(clock=FakeClock(1.0))
        tel.counter("tiles_total").inc(3)
        tel.gauge("ema_s").set(0.25)
        tel.histogram("lat_s", path="exact").observe(0.5)
        snap = json.loads(json.dumps(tel.snapshot()))
        assert metric_value(snap, "tiles_total") == 3
        assert metric_value(snap, "ema_s", kind="gauges") == 0.25
        row = metric_value(snap, "lat_s", kind="histograms", path="exact")
        assert row["count"] == 1 and row["p50"] == 0.5
        assert metric_value(snap, "absent_total", default=-1) == -1

    def test_fakeclock_snapshots_deterministic(self):
        def activity():
            tel = Telemetry(clock=FakeClock(10.0))
            c = tel.counter("tiles_total")
            for _ in range(5):
                tel.clock.advance(0.125)
                c.inc()
                tel.histogram("tile_wall_s").observe(0.125)
            return tel.snapshot()

        a, b = activity(), activity()
        assert a == b                       # identical activity, identical snap
        assert a["clock_s"] == 10.625
        assert metric_value(a, "tiles_total") == 5


# ----------------------------------------------------------------- tracer --


class TestSpanTracer:
    def test_nesting_parent_depth(self):
        tr = SpanTracer(clock=FakeClock(0.0))
        with tr.span("tile_eval", tile=3) as outer:
            tr.clock.advance(0.5)
            with tr.span("launch") as inner:
                tr.clock.advance(0.25)
        outer_r, inner_r = {r.name: r for r in tr.records}["tile_eval"], \
            {r.name: r for r in tr.records}["launch"]
        assert outer_r.parent == -1 and outer_r.depth == 0
        assert inner_r.parent == outer_r.sid and inner_r.depth == 1
        assert outer_r.dur == 0.75 and inner_r.dur == 0.25
        assert outer_r.attrs == {"tile": 3}
        assert inner_r.sid == outer.sid + 1 == inner.sid

    def test_ring_evicts_oldest(self):
        tr = SpanTracer(capacity=8)
        for i in range(20):
            with tr.span("s", i=i):
                pass
        recs = tr.records
        assert len(recs) == 8
        assert [r.attrs["i"] for r in recs] == list(range(12, 20))

    def test_threads_nest_independently(self):
        tr = SpanTracer()
        done = threading.Event()

        def other():
            with tr.span("worker_root"):
                done.wait(5.0)

        t = threading.Thread(target=other)
        with tr.span("main_root"):
            t.start()
            done.set()
            t.join()
        by_name = {r.name: r for r in tr.records}
        # the worker's span is a root on ITS thread, not a child of main
        assert by_name["worker_root"].parent == -1
        assert by_name["worker_root"].depth == 0
        assert by_name["worker_root"].thread_id != \
            by_name["main_root"].thread_id

    def test_chrome_trace_schema(self, tmp_path):
        tel = Telemetry(clock=FakeClock(100.0))
        with tel.span("tile_eval", tile=0):
            tel.clock.advance(0.010)
            with tel.span("launch"):
                tel.clock.advance(0.002)
        path = tel.export_trace(str(tmp_path / "trace.json"))
        with open(path) as f:
            doc = json.load(f)
        assert doc["displayTimeUnit"] == "ms"
        meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
        assert meta and meta[0]["args"]["name"] == "repro-campaign"
        xs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
        te, la = xs["tile_eval"], xs["launch"]
        assert te["ts"] == 0.0 and te["dur"] == pytest.approx(12_000)
        assert la["ts"] == pytest.approx(10_000)
        assert la["dur"] == pytest.approx(2_000)
        assert la["args"]["parent"] == te["args"]["sid"]
        assert la["args"]["depth"] == te["args"]["depth"] + 1
        assert te["args"]["tile"] == 0

    def test_trace_report_check_passes_and_catches_violations(self, tmp_path):
        tel = Telemetry()
        with tel.span("tile_eval"):
            with tel.span("launch"):
                pass
        path = tel.export_trace(str(tmp_path / "t.json"))
        events = trace_report.load_events(path)
        assert trace_report.check(events, ["tile_eval"]) == []
        assert trace_report.check(events, ["lease"]) != []  # missing name
        bad = [dict(e) for e in events]
        for e in bad:
            if e["name"] == "launch":
                e["args"] = dict(e["args"], depth=5)
        assert any("depth" in err for err in trace_report.check(bad, []))

    def test_null_span_is_shared_noop(self):
        tel = NullTelemetry()
        assert tel.span("anything", tile=1) is NULL_SPAN
        with tel.span("x"):
            pass
        assert tel.tracer.records == []
        assert tel.tracer.chrome_trace()["traceEvents"] == []

    def test_coerce_telemetry_fresh_per_owner(self):
        a, b = coerce_telemetry(None), coerce_telemetry(None)
        assert a is not b                   # per-owner registries: no aliasing
        t = Telemetry()
        assert coerce_telemetry(t) is t


# --------------------------------------------------- instrumented == plain --


class TestInstrumentationIsAReading:
    def test_instrumented_frontier_bitwise_equals_uninstrumented(self):
        spec = small_spec()
        plain = Campaign(WLS, spec, constraint=CONS, evaluator="numpy").run()
        tel = Telemetry()
        traced = Campaign(WLS, spec, constraint=CONS, evaluator="numpy",
                          telemetry=tel).run()
        for key in plain.frontiers:
            assert frontiers_identical(plain.frontiers[key],
                                       traced.frontiers[key])
        # and the instrumented run actually observed itself
        assert metric_value(tel.snapshot(), "campaign_tiles_total") == \
            traced.tiles_done
        assert any(r.name == "tile_eval" for r in tel.tracer.records)

    def test_nulltelemetry_metrics_still_count(self):
        # the disabled path keeps REAL counters: fused_launches (back-compat
        # surface, tests/test_selection.py reads it) must count as before
        campaign = Campaign(WLS, small_spec(), constraint=CONS,
                            evaluator="numpy")
        campaign.run()
        ev = campaign.engine
        assert isinstance(ev.telemetry, NullTelemetry)
        assert metric_value(ev.telemetry.snapshot(),
                            "evaluator_candidates_total") == \
            len(WLS) * len(small_spec())

    def test_local_fabric_trace_has_fabric_spans(self, tmp_path):
        tel = Telemetry()
        campaign = Campaign(WLS, small_spec(), constraint=CONS,
                            evaluator="numpy", telemetry=tel)
        LocalFabric(campaign, n_workers=2, seed=0).run(
            checkpoint_path=str(tmp_path / "ckpt.json"))
        names = {r.name for r in tel.tracer.records}
        assert {"tile_eval", "lease", "deliver", "checkpoint_write"} <= names
        errors = trace_report.check(
            trace_report.load_events(
                tel.export_trace(str(tmp_path / "trace.json"))),
            trace_report.DEFAULT_REQUIRED)
        assert errors == []

    def test_multiprocess_workers_ship_metrics_snapshots(self, tmp_path):
        campaign = Campaign(WLS, small_spec(), constraint=CONS,
                            evaluator="numpy")
        fabric = MultiprocessFabric(campaign, n_workers=2)
        result = fabric.run(checkpoint_path=str(tmp_path / "ckpt.json"))
        assert result.complete
        wm = fabric.stats["worker_metrics"]
        assert set(wm) == {0, 1}
        total_tiles = sum(
            metric_value(snap, "worker_tiles_total", default=0)
            for snap in wm.values())
        assert total_tiles == campaign.space.n_tiles()
        for w, snap in wm.items():
            busy = metric_value(snap, "worker_busy_s_total")
            assert busy is not None and busy >= 0.0
            # stats' busy ledger uses the worker-shipped totals
            assert fabric.stats["worker_busy_s"][w] == busy
