"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("S,H,KV,hd", [
    (128, 2, 2, 32),
    (256, 4, 2, 64),
    (256, 4, 1, 64),      # MQA
    (384, 2, 2, 128),     # non-power-of-two block count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(S, H, KV, hd, dtype):
    B = 2
    q = _rand((B, S, H, hd), dtype)
    k = _rand((B, S, KV, hd), dtype)
    v = _rand((B, S, KV, hd), dtype)
    o = ops.flash_attention(q, k, v, block_q=128, block_k=128)
    kk, vv = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
    o_ref = ref.attention_ref(q, kk, vv)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_non_causal():
    B, S, H, hd = 1, 128, 2, 32
    q, k, v = (_rand((B, S, H, hd), jnp.float32) for _ in range(3))
    o = ops.flash_attention(q, k, v, causal=False)
    o_ref = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-6)


@pytest.mark.parametrize("S,nh,hp,ds,chunk", [
    (128, 2, 16, 16, 32),
    (256, 3, 16, 32, 64),
    (128, 4, 32, 16, 128),   # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_sequential_ref(S, nh, hp, ds, chunk, dtype):
    b = 2
    x = _rand((b, S, nh, hp), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, S, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, nh), jnp.float32)
    B = _rand((b, S, 1, ds), dtype)
    C = _rand((b, S, 1, ds), dtype)
    y = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_ref = ref.ssd_ref(x, dt, A, B, C)
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-6
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref))) / scale < tol


@pytest.mark.parametrize("HW,cin,cout,kh", [
    (16, 8, 16, 3),
    (16, 4, 8, 1),
    (24, 8, 8, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_matches_ref(HW, cin, cout, kh, dtype):
    x = _rand((2, HW, HW, cin), dtype)
    w = _rand((kh, kh, cin, cout), dtype) * 0.1
    o = ops.conv2d(x, w, padding="SAME")
    xp = jnp.pad(x, ((0, 0), (kh // 2, (kh - 1) // 2),
                     (kh // 2, (kh - 1) // 2), (0, 0)))
    o_ref = ref.conv2d_ref(xp, w)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=tol * 10, rtol=tol)


def test_kernels_match_model_layers():
    """The XLA model path and the Pallas kernel agree (same math)."""
    from repro.models import layers as L
    B, S, H, hd = 1, 256, 2, 32
    q, k, v = (_rand((B, S, H, hd), jnp.float32) for _ in range(3))
    o_model = L.flash_attention(q, k, v, scale=hd ** -0.5, chunk=128)
    o_kernel = ops.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               atol=1e-5, rtol=1e-5)
