"""Batched DSE engine tests: batch-vs-scalar agreement, fast-path ranking,
Pareto frontier / constraint-mask contracts."""

import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config
from repro.core import costmodel, dse, features, offload, predictors
from repro.hw import CHIP_TABLE, CHIPS, chip_index, get_chip

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}
BASE_CHIPS = 256
STATE_GB = 0.5


def _rel(a, b):
    return abs(a - b) / max(abs(b), 1e-300)


# --- (a) simulate_batch == scalar simulate over the whole default space -------


def test_simulate_batch_matches_scalar_over_default_space():
    batch = dse.default_space_batch()
    res = dse.evaluate_space(BASE, BASE_CHIPS, batch)
    for i, cand in enumerate(batch.candidates):
        ref = costmodel.simulate(
            dse._scale_analysis(BASE, BASE_CHIPS, cand), get_chip(cand.chip),
            cand.n_chips, freq_mhz=cand.freq_mhz, mesh=cand.mesh)
        got = res.result(i)
        for field in ("t_compute", "t_memory", "t_collective", "latency_s",
                      "cycles", "utilization", "power_w", "energy_j"):
            assert _rel(getattr(got, field), getattr(ref, field)) <= 1e-6, \
                (cand, field)
        assert got.bottleneck == ref.bottleneck, cand


def test_simulate_batch_default_frequency_and_scalar_broadcast():
    idx = np.asarray([chip_index("tpu-v5e"), chip_index("tpu-edge")])
    res = costmodel.simulate_batch(
        {"flops": 1e12, "hbm_bytes": 1e10, "collective_bytes": 0.0,
         "wire_bytes": 0.0}, idx, np.asarray([16, 1]))
    for i, name in enumerate(("tpu-v5e", "tpu-edge")):
        ref = costmodel.simulate(
            {"flops": 1e12, "hbm_bytes": 1e10, "collective_bytes": 0.0,
             "wire_bytes": 0.0}, get_chip(name), [16, 1][i])
        assert _rel(res.result(i).energy_j, ref.energy_j) <= 1e-6


def test_extract_batch_matches_scalar_extract():
    cfg = get_config("qwen3_14b")
    shape = SHAPES["train_4k"]
    batch = dse.default_space_batch(freq_points=4)
    X = features.extract_batch(cfg, shape, batch.chip_idx, batch.n_chips,
                               batch.mesh_data, batch.mesh_model,
                               batch.freq_mhz)
    assert X.shape == (len(batch), len(features.FEATURE_NAMES))
    for i, c in enumerate(batch.candidates):
        row = features.extract(cfg, shape, get_chip(c.chip), c.n_chips,
                               mesh_shape=c.mesh, freq_mhz=c.freq_mhz)
        np.testing.assert_allclose(X[i], np.asarray(row, np.float32),
                                   rtol=1e-6)


def test_slow_path_batched_matches_scalar_loop():
    cons = dse.Constraint(max_power_w=50_000, min_hbm_fit=True)
    space = dse.default_space(freq_points=4)
    b_new, r_new, _ = dse.slow_path_search(
        "qwen3_14b", "train_4k", BASE, BASE_CHIPS, STATE_GB, space, cons)
    b_old, r_old, _ = dse.slow_path_search_scalar(
        "qwen3_14b", "train_4k", BASE, BASE_CHIPS, STATE_GB, space, cons)
    assert b_new == b_old
    assert len(r_new) == len(r_old) == len(space)
    for c in space:
        assert r_new[c]["feasible"] == r_old[c]["feasible"], c
        assert _rel(r_new[c]["sim"].energy_j, r_old[c]["sim"].energy_j) <= 1e-6


def test_evaluate_workload_tile_matches_evaluate_space():
    """Tile-wise evaluation (the campaign entry point) concatenates to the
    same SimBatch + feasibility as one evaluate_space pass."""
    wl = dse.Workload("qwen3_14b", "train_4k", BASE, BASE_CHIPS, STATE_GB)
    cons = dse.Constraint(max_power_w=50_000)
    space = dse.default_space(freq_points=4)
    full = dse.CandidateBatch.from_candidates(space)
    ref = dse.evaluate_space(BASE, BASE_CHIPS, full)
    ref_feas = dse.feasibility_mask(full, ref, cons, STATE_GB, BASE_CHIPS)
    chunk = 17
    e, l, f = [], [], []
    for lo in range(0, len(space), chunk):
        tile = dse.CandidateBatch.from_candidates(space[lo:lo + chunk])
        sim, feas = dse.evaluate_workload_tile(wl, tile, cons)
        e.append(sim.energy_j), l.append(sim.latency_s), f.append(feas)
    np.testing.assert_array_equal(np.concatenate(e), np.asarray(ref.energy_j))
    np.testing.assert_array_equal(np.concatenate(l), np.asarray(ref.latency_s))
    np.testing.assert_array_equal(np.concatenate(f), ref_feas)


# --- (b) fast-path top-1 lands in the slow-path top-k -------------------------


def test_fast_path_top1_within_slow_path_topk():
    cfg_name, shape_name = "qwen3_14b", "train_4k"
    cfg = get_config(cfg_name)
    shape = SHAPES[shape_name]
    batch = dse.default_space_batch(freq_points=4)
    cons = dse.Constraint(max_power_w=50_000, min_hbm_fit=False)

    # train predictors on the space itself (fixed seed, deterministic)
    X = features.extract_batch(cfg, shape, batch.chip_idx, batch.n_chips,
                               batch.mesh_data, batch.mesh_model,
                               batch.freq_mhz)
    sim = dse.evaluate_space(BASE, BASE_CHIPS, batch)
    rf = predictors.RandomForestRegressor(n_trees=20).fit(
        X, np.asarray(sim.power_w), seed=0)
    knn = predictors.KNNRegressor().fit(X, np.asarray(sim.cycles))

    best_fast, _, _ = dse.fast_path_search(
        cfg_name, shape_name, rf, knn, batch, cons, verify_top_k=1)
    _, results, _ = dse.slow_path_search(
        cfg_name, shape_name, BASE, BASE_CHIPS, STATE_GB, batch, cons)
    feasible = results.feasible
    energy = np.where(feasible, np.asarray(results.sim.energy_j), np.inf)
    k = 5
    topk = {batch.candidates[i] for i in np.argsort(energy)[:k]}
    assert best_fast in topk, (best_fast, topk)


# --- (c) Pareto frontier + constraint masks -----------------------------------


def _dominates(e1, l1, e2, l2):
    return e1 <= e2 and l1 <= l2 and (e1 < e2 or l1 < l2)


def test_pareto_frontier_mutually_non_dominated():
    batch = dse.default_space_batch()
    wls = [dse.Workload("qwen3_14b", "train_4k", BASE, BASE_CHIPS, STATE_GB),
           dse.Workload("qwen2_72b", "train_4k",
                        {k: v * 3 for k, v in BASE.items()}, BASE_CHIPS, 2.0)]
    cons = dse.Constraint(max_power_w=50_000)
    fronts = dse.pareto_search(wls, batch, cons)
    assert set(fronts) == {("qwen3_14b", "train_4k"), ("qwen2_72b", "train_4k")}
    for front in fronts.values():
        assert len(front) >= 1
        e, l = front.energy_j, front.latency_s
        for i in range(len(front)):
            for j in range(len(front)):
                if i != j:
                    assert not _dominates(e[j], l[j], e[i], l[i]), (i, j)


def test_pareto_frontier_beats_all_feasible_points():
    """Every feasible non-frontier point is dominated by some frontier point."""
    batch = dse.default_space_batch(freq_points=4)
    wl = dse.Workload("qwen3_14b", "train_4k", BASE, BASE_CHIPS, STATE_GB)
    cons = dse.Constraint(max_power_w=50_000)
    front = dse.pareto_search(wl, batch, cons)[("qwen3_14b", "train_4k")]
    sim = dse.evaluate_space(BASE, BASE_CHIPS, batch)
    feasible = dse.feasibility_mask(batch, sim, cons, STATE_GB, BASE_CHIPS)
    on_front = set(front.indices.tolist())
    for i in np.flatnonzero(feasible):
        if i in on_front:
            continue
        assert any(_dominates(front.energy_j[j], front.latency_s[j],
                              sim.energy_j[i], sim.latency_s[i])
                   for j in range(len(front))), i


def test_constraint_masks_respected():
    batch = dse.default_space_batch(freq_points=4)
    sim = dse.evaluate_space(BASE, BASE_CHIPS, batch)
    cons = dse.Constraint(max_power_w=20_000, max_latency_s=1.0,
                          min_hbm_fit=True)
    ok = dse.feasibility_mask(batch, sim, cons, STATE_GB, BASE_CHIPS)
    slice_power = np.asarray(sim.power_w) * batch.n_chips
    state_bytes = STATE_GB * BASE_CHIPS / batch.n_chips * 1e9
    hbm = CHIP_TABLE.hbm_bytes[batch.chip_idx]
    for i in range(len(batch)):
        expect = (slice_power[i] <= 20_000
                  and sim.latency_s[i] <= 1.0
                  and state_bytes[i] <= hbm[i] * 0.9)
        assert bool(ok[i]) == expect, batch.candidates[i]
    # frontier members must all be feasible
    wl = dse.Workload("qwen3_14b", "train_4k", BASE, BASE_CHIPS, STATE_GB)
    front = dse.pareto_search(wl, batch, cons)[("qwen3_14b", "train_4k")]
    assert all(ok[i] for i in front.indices)
    assert front.feasible_count == int(ok.sum())


# --- supporting contracts -----------------------------------------------------


def test_candidate_batch_roundtrip():
    space = dse.default_space(freq_points=3)
    batch = dse.CandidateBatch.from_candidates(space)
    assert len(batch) == len(space)
    for i, c in enumerate(space):
        assert batch[i] == c
        assert CHIP_TABLE.names[batch.chip_idx[i]] == c.chip
        assert batch.n_chips[i] == c.n_chips
        assert batch.freq_mhz[i] == c.freq_mhz


def test_chip_table_consistent_with_registry():
    for name, spec in CHIPS.items():
        i = chip_index(name)
        assert CHIP_TABLE.names[i] == name
        assert CHIP_TABLE.peak_flops_bf16[i] == spec.peak_flops_bf16
        assert CHIP_TABLE.hbm_bytes[i] == spec.hbm_bytes
        assert CHIP_TABLE.tdp_watts[i] == spec.tdp_watts


def test_simulate_batch_jit_close_to_numpy():
    batch = dse.default_space_batch(freq_points=3)
    ana = dse._scale_analysis_batch(BASE, BASE_CHIPS, batch.n_chips)
    ref = costmodel.simulate_batch(ana, batch.chip_idx, batch.n_chips,
                                   batch.freq_mhz)
    jit = costmodel.simulate_batch_jit(ana, batch.chip_idx,
                                       batch.n_chips.astype(np.float32),
                                       batch.freq_mhz)
    np.testing.assert_allclose(np.asarray(jit.latency_s),
                               np.asarray(ref.latency_s), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(jit.power_w),
                               np.asarray(ref.power_w), rtol=1e-5)


def test_offload_sweep_matches_analyze():
    local = {"flops": 2e12, "hbm_bytes": 2e10, "collective_bytes": 0.0,
             "wire_bytes": 0.0}
    remote = {"flops": 1.2e11, "hbm_bytes": 1.5e9, "collective_bytes": 2e7,
              "wire_bytes": 2e7}
    bws = np.array([1e6, 5e7, 1e9])
    sweep = offload.sweep_bandwidth(local, remote, 1.2e7, 3.2e4, bws)
    for i, bw in enumerate(bws):
        ref = offload.analyze(local, remote, 1.2e7, 3.2e4,
                              offload.NetworkSpec(bandwidth_bps=bw))
        for f in ("local_latency_s", "remote_latency_s", "local_energy_j",
                  "remote_edge_energy_j", "remote_total_energy_j"):
            assert _rel(float(sweep[f][i]), getattr(ref, f)) <= 1e-9, f
        assert bool(sweep["choose_remote_latency"][i]) == ref.choose_remote_latency
        assert bool(sweep["choose_remote_battery"][i]) == ref.choose_remote_battery
