"""Accelerator-selection serving tests: index-hit identity with offline
campaign picks, novel-workload fallback parity, deadline degradation,
batched-vs-sequential equality with the one-fused-launch assertion, the
FrontierIndex version gates, and the four-entry-points-one-CampaignConfig
API contract."""

import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.core import costmodel, dse
from repro.dse_campaign import (Campaign, CampaignConfig, SliceVariant,
                                SpaceSpec, TileEvaluator, frontiers_identical,
                                run_distributed, store)
from repro.dse_campaign.frontier import StreamingFrontier
from repro.serving.engine import PROVENANCES, SelectionEngine
from repro.serving.frontier_index import (INDEX_SCHEMA_VERSION, FrontierIndex,
                                          family_key)

BASE = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
        "wire_bytes": 7e11}


def wl(arch="qwen3_14b", shape="train_4k", scale=1.0, chips=256, gb=0.5):
    return dse.Workload(arch, shape,
                        {k: v * scale for k, v in BASE.items()}, chips, gb)


CACHED = [wl(),
          wl("stablelm_1_6b", scale=0.3, chips=64, gb=0.2),
          wl("mamba2_130m", scale=0.05, chips=16, gb=0.05)]
NOVEL = wl(scale=1.07)                     # same (arch, shape), new census


def small_spec(**kw):
    kw.setdefault("chips", ("tpu-v5e", "tpu-v4"))
    kw.setdefault("chip_counts", (16, 64))
    kw.setdefault("freq_points", 5)
    kw.setdefault("variants", (SliceVariant(),))
    kw.setdefault("chunk_size", 64)
    return SpaceSpec(**kw)


def serving_config(**kw):
    kw.setdefault("space", small_spec())
    kw.setdefault("evaluator", "jit")
    kw.setdefault("constraint", dse.Constraint(max_power_w=50_000))
    return CampaignConfig(**kw)


class StubModel:
    """Deterministic ``.predict(X)`` stand-in for a fitted predictor."""

    def __init__(self, scale):
        self.scale = scale

    def predict(self, X):
        X = np.asarray(X, np.float64)
        return self.scale * (1.0 + np.abs(X).sum(axis=1)
                             / (1.0 + np.abs(X).max() * X.shape[1]))


@pytest.fixture(scope="module")
def offline():
    """One completed campaign + its index, shared by the module's tests."""
    camp = Campaign(CACHED, serving_config())
    result = camp.run()
    assert result.complete
    return camp, result, FrontierIndex.from_campaign(camp)


# --- FrontierIndex ------------------------------------------------------------


def test_index_roundtrip_and_lookup(tmp_path, offline):
    camp, result, index = offline
    path = index.save(str(tmp_path / "index.json"))
    loaded = FrontierIndex.load(path)
    assert len(loaded) == len(CACHED)
    assert set(loaded.keys) == {(w.arch, w.shape) for w in CACHED}
    for w in CACHED:
        entry = loaded.lookup(w)
        assert entry is not None and entry.arch == w.arch
        assert frontiers_identical(entry.frontier(),
                                   result.frontiers[(w.arch, w.shape)])
    assert loaded.lookup(NOVEL) is None    # perturbed census: not a hit
    near, dist = loaded.nearest(CACHED[0])
    assert near.arch == CACHED[0].arch and dist == 0.0
    near, dist = loaded.nearest(NOVEL)
    assert near.arch == NOVEL.arch and dist > 0.0


def test_index_version_and_completeness_gates(tmp_path, offline):
    _, _, index = offline
    path = index.save(str(tmp_path / "index.json"))
    with open(path) as f:
        payload = json.load(f)
    stale = dict(payload, sim_model_version=costmodel.SIM_MODEL_VERSION - 1)
    stale_path = tmp_path / "stale.json"
    stale_path.write_text(json.dumps(stale))
    with pytest.raises(ValueError, match="cost-model version"):
        FrontierIndex.load(str(stale_path))
    bad = dict(payload, index_schema_version=INDEX_SCHEMA_VERSION + 1)
    stale_path.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="schema version"):
        FrontierIndex.load(str(stale_path))
    partial = Campaign(CACHED, serving_config())
    partial.run(max_tiles=1)
    with pytest.raises(ValueError, match="incomplete"):
        FrontierIndex.from_campaign(partial)


def test_index_from_checkpoint_inherits_version_gate(tmp_path, offline):
    camp, result, _ = offline
    ckpt = str(tmp_path / "ckpt.json")
    store.save_checkpoint(camp.state_dict(), ckpt)
    index = FrontierIndex.from_checkpoint(ckpt)
    for w in CACHED:
        assert frontiers_identical(index.lookup(w).frontier(),
                                   result.frontiers[(w.arch, w.shape)])
    state = camp.state_dict()
    state["sim_model_version"] = costmodel.SIM_MODEL_VERSION - 1
    (tmp_path / "old.json").write_text(json.dumps(state))
    with pytest.raises(ValueError, match="rebuild any FrontierIndex"):
        FrontierIndex.from_checkpoint(str(tmp_path / "old.json"))


def test_family_key_is_wl_cols_order():
    key = family_key(CACHED[0])
    np.testing.assert_array_equal(
        key, [BASE["flops"], BASE["hbm_bytes"], BASE["collective_bytes"],
              BASE["wire_bytes"], 256, 0.5])


# --- SelectionEngine: the three provenances -----------------------------------


def test_index_hit_identity_on_all_cached_cells(tmp_path, offline):
    """The acceptance gate: served answers == offline campaign picks, exact
    candidate identity, for every cached workload cell — through a full
    index save/load round trip."""
    camp, result, index = offline
    loaded = FrontierIndex.load(index.save(str(tmp_path / "index.json")))
    engine = SelectionEngine(loaded)
    for w in CACHED:
        answer = engine.select(w)
        assert answer.provenance == "index_exact"
        assert frontiers_identical(answer.frontier(),
                                   result.frontiers[(w.arch, w.shape)])
        best = answer.choices[0]
        assert best.exact and best.energy_j == float(
            min(result.frontiers[(w.arch, w.shape)].energy_j))
    assert engine.fused_launches == 0      # no sweep ran
    assert engine.stats["index_exact"] == len(CACHED)


def test_novel_workload_fallback_parity(offline):
    """A novel family's mini-campaign answer equals a standalone campaign
    on the same slice (here: the full serving space, swept independently
    through the tile loop)."""
    _, _, index = offline
    engine = SelectionEngine(index)
    answer = engine.select(NOVEL)
    assert answer.provenance == "mini_campaign"
    assert answer.verified_gidx.size == len(engine.space)
    standalone = Campaign([NOVEL], engine.config).run()
    assert frontiers_identical(answer.frontier(),
                               standalone.frontiers[(NOVEL.arch, NOVEL.shape)])


def test_constraint_override_forces_exact_path(offline):
    """A known family under a non-index constraint cannot be served from
    the index — the engine re-evaluates under the queried constraint."""
    _, _, index = offline
    engine = SelectionEngine(index)
    tight = dse.Constraint(max_power_w=20_000)
    answer = engine.select(CACHED[0], constraint=tight)
    assert answer.provenance == "mini_campaign"
    standalone = Campaign(
        [CACHED[0]], engine.config.replace(constraint=tight)).run()
    assert frontiers_identical(
        answer.frontier(),
        standalone.frontiers[(CACHED[0].arch, CACHED[0].shape)])


def test_deadline_exceeded_degrades_to_predictor_only(offline):
    _, _, index = offline
    cfg = SelectionEngine._config_from_index(index).replace(
        power_model=StubModel(40.0), cycles_model=StubModel(1e9))
    engine = SelectionEngine(index, cfg)
    answer = engine.select(NOVEL, deadline_s=0.0)
    assert answer.provenance == "predictor_only"
    assert answer.choices and all(not c.exact for c in answer.choices)
    assert engine.fused_launches == 0
    # same query, no deadline: the exact path answers
    assert engine.select(NOVEL).provenance == "mini_campaign"
    # without predictors a deadline cannot degrade — exact is the only path
    bare = SelectionEngine(index)
    assert bare.select(NOVEL, deadline_s=0.0).provenance == "mini_campaign"
    assert set(engine.stats) >= set(PROVENANCES)


def test_predictor_pruned_slice_is_verified_exactly(offline):
    """With predictors, the fallback verifies a pruned slice; the served
    frontier equals a direct exact evaluation of that same slice."""
    _, _, index = offline
    cfg = SelectionEngine._config_from_index(index).replace(
        power_model=StubModel(40.0), cycles_model=StubModel(1e9))
    engine = SelectionEngine(index, cfg, verify_top=16)
    answer = engine.select(NOVEL)
    assert answer.provenance == "mini_campaign"
    gidx = answer.verified_gidx
    assert 0 < gidx.size < len(engine.space)
    ev = TileEvaluator([NOVEL], engine.config)
    batch = dse.CandidateBatch.from_candidates(
        engine.space.candidates_at(gidx))
    tr = ev.reduce_tile(batch, 0)
    fr = StreamingFrontier()
    loc = tr.surv_gidx[0]
    fr.merge_reduced(engine.space.candidates_at(gidx[loc]),
                     tr.surv_energy[0], tr.surv_latency[0], loc,
                     span=(0, int(gidx.size)), n_feasible=tr.n_feasible[0],
                     ref_energy_j=tr.ref_energy_j[0],
                     ref_latency_s=tr.ref_latency_s[0])
    direct = fr.as_pareto_frontier(NOVEL)
    direct = dse.ParetoFrontier(
        workload=NOVEL, candidates=direct.candidates,
        energy_j=direct.energy_j, latency_s=direct.latency_s,
        indices=gidx[direct.indices], feasible_count=direct.feasible_count)
    assert frontiers_identical(answer.frontier(), direct)


def test_batched_queries_one_launch_and_equal_to_sequential(offline):
    """All novel queries of one flush ride ONE fused sweep launch
    (measured via ``fused_launches``, not assumed), and batched answers are
    bitwise identical to sequential single-query answers."""
    _, _, index = offline
    novel = [wl(scale=1.07), wl("stablelm_1_6b", scale=0.41, chips=64,
                                gb=0.2), wl("mamba2_130m", scale=0.06,
                                            chips=16, gb=0.05)]
    batched = SelectionEngine(index)
    for w in novel:
        batched.submit(w)
    batched.submit(CACHED[0])              # index hit rides along for free
    before = batched.fused_launches
    answers = batched.flush()
    assert batched.fused_launches - before == 1
    assert [a.provenance for a in answers] == ["mini_campaign"] * 3 + [
        "index_exact"]
    sequential = SelectionEngine(index)
    for w, got in zip(novel, answers):
        solo = sequential.select(w)
        assert frontiers_identical(got.frontier(), solo.frontier())
    assert sequential.fused_launches == 3  # one launch per lone query


# --- the one-CampaignConfig API contract --------------------------------------


def test_all_entry_points_construct_from_one_config(tmp_path, offline):
    """Campaign, TileEvaluator, run_distributed and SelectionEngine all
    take the same frozen CampaignConfig."""
    _, _, index = offline
    cfg = serving_config(
        space=small_spec(chip_counts=(16,), freq_points=3, chunk_size=32),
        n_workers=1, checkpoint_path=str(tmp_path / "fab.json"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        camp = Campaign(CACHED, cfg)
        ev = TileEvaluator(CACHED, cfg)
        eng = SelectionEngine(index, cfg)
        dist, stats = run_distributed(CACHED, cfg)
    assert camp.config is ev.config is eng.config is cfg
    assert dist.complete and stats["deliveries"] >= 1
    single = camp.run()
    for key in single.frontiers:
        assert frontiers_identical(single.frontiers[key],
                                   dist.frontiers[key])


def test_legacy_keyword_construction_warns_but_works():
    spec = small_spec(chip_counts=(16,), freq_points=3, chunk_size=32)
    cons = dse.Constraint(max_power_w=50_000)
    with pytest.warns(DeprecationWarning, match="CampaignConfig"):
        camp = Campaign(CACHED[:1], spec, evaluator="jit", constraint=cons)
    with pytest.warns(DeprecationWarning, match="CampaignConfig"):
        ev = TileEvaluator(CACHED[:1], spec, evaluator="jit", constraint=cons)
    assert camp.space == ev.space == spec
    assert camp.evaluator == ev.evaluator == "jit"
    legacy = camp.run()
    fresh = Campaign(
        CACHED[:1], CampaignConfig(space=spec, evaluator="jit",
                                   constraint=cons)).run()
    for key in fresh.frontiers:
        assert frontiers_identical(legacy.frontiers[key],
                                   fresh.frontiers[key])
    with pytest.warns(DeprecationWarning, match="CampaignConfig"):
        _, _ = run_distributed(
            Campaign(CACHED[:1],
                     CampaignConfig(space=spec, evaluator="jit",
                                    constraint=cons)), n_workers=1)
    with pytest.raises(TypeError):        # config AND legacy kwargs: refused
        Campaign(CACHED[:1], CampaignConfig(space=spec), evaluator="jit")
    with pytest.raises(TypeError):        # unknown kwarg: refused
        Campaign(CACHED[:1], spec, evaluatr="jit")


def test_config_chunk_size_override_and_validation():
    spec = small_spec(chunk_size=64)
    cfg = CampaignConfig(space=spec, chunk_size=32)
    assert cfg.resolved_space.chunk_size == 32
    assert cfg.resolved_space == dataclasses.replace(spec, chunk_size=32)
    assert CampaignConfig(space=spec).resolved_space is spec
    with pytest.raises(ValueError, match="evaluator"):
        CampaignConfig(space=spec, evaluator="warp")
    with pytest.raises(ValueError, match="power_model"):
        CampaignConfig(space=spec, evaluator="fast")
    with pytest.raises(TypeError, match="SpaceSpec"):
        CampaignConfig(space="not-a-space")


# --- launch CLI + store durability --------------------------------------------


def test_serve_cli_build_index_and_select(tmp_path, offline, capsys):
    from repro.launch.serve import build_index, select_queries
    from repro.dse_campaign.runner import workload_to_dict

    camp, result, _ = offline
    ckpt = str(tmp_path / "ckpt.json")
    store.save_checkpoint(camp.state_dict(), ckpt)
    idx_path = build_index(ckpt, str(tmp_path / "index.json"))
    answers = select_queries(idx_path)     # self-check: all families
    assert [a.provenance for a in answers] == ["index_exact"] * len(CACHED)
    queries = [{"workload": workload_to_dict(CACHED[0])},
               {"workload": workload_to_dict(NOVEL), "deadline_s": 60.0}]
    qpath = tmp_path / "queries.json"
    qpath.write_text(json.dumps(queries))
    answers = select_queries(idx_path, str(qpath))
    assert [a.provenance for a in answers] == ["index_exact",
                                               "mini_campaign"]
    assert "fused launches" in capsys.readouterr().out


def test_atomic_write_json_durable_path(tmp_path):
    """The checkpoint writer leaves no temp file behind and the renamed
    file is complete, well-formed JSON (fsync-before-rename path)."""
    path = str(tmp_path / "nested" / "out.json")
    store.atomic_write_json({"a": [1, 2, 3]}, path)
    assert json.load(open(path)) == {"a": [1, 2, 3]}
    assert not (tmp_path / "nested" / "out.json.tmp").exists()
