"""End-to-end behaviour tests: train loop with checkpoint/restart, DSE round
trip, cost model + roofline consistency, input-spec contracts."""

import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import ARCH_NAMES, LM_SHAPES, SHAPES, get_config
from repro.core import costmodel, dse, features, predictors
from repro.hw import get_chip
from repro.launch.train import train


def test_train_loop_improves_and_restarts():
    with tempfile.TemporaryDirectory() as d:
        losses1, _ = train("stablelm-1.6b", steps=12, reduced=True, seq_len=32,
                           batch=4, ckpt_dir=d, ckpt_every=6,
                           install_signals=False, log_every=100)
        assert losses1[-1] < losses1[0]
        losses2, _ = train("stablelm-1.6b", steps=16, reduced=True, seq_len=32,
                           batch=4, ckpt_dir=d, restore=True, ckpt_every=100,
                           install_signals=False, log_every=100)
        assert len(losses2) == 4  # resumed from step 12


def test_cost_model_roofline_consistency():
    ana = {"flops": 1e12, "hbm_bytes": 1e11, "collective_bytes": 1e9,
           "wire_bytes": 1.5e9}
    chip = get_chip("tpu-v5e")
    terms = costmodel.roofline_terms(ana, chip, 256)
    assert terms["dominant"] == "memory_s"
    assert abs(terms["compute_s"] - 1e12 / 197e12) < 1e-9
    res = costmodel.simulate(ana, chip, 256)
    assert res.latency_s >= max(res.t_compute, res.t_memory, res.t_collective)
    assert chip.idle_watts <= res.power_w <= chip.tdp_watts


def test_dvfs_power_monotone_energy_tradeoff():
    """Higher frequency -> more power per chip, lower latency (paper Fig. 2)."""
    ana = {"flops": 5e13, "hbm_bytes": 1e10, "collective_bytes": 1e8,
           "wire_bytes": 1e8}
    chip = get_chip("tpu-v5e")
    r_lo = costmodel.simulate(ana, chip, 16, freq_mhz=500)
    r_hi = costmodel.simulate(ana, chip, 16, freq_mhz=1600)
    assert r_hi.power_w > r_lo.power_w
    assert r_hi.latency_s < r_lo.latency_s


def test_feature_vector_stable_and_finite():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        shapes = cfg.applicable_shapes() if arch != "resnet50" else []
        for shape in shapes:
            v = features.extract(cfg, shape, get_chip("tpu-v5e"), 256)
            assert len(v) == len(features.FEATURE_NAMES)
            assert np.isfinite(v).all(), (arch, shape.name)


def test_dse_fast_path_agrees_with_slow_path():
    """Predictors trained on the simulator let the fast path find a candidate
    within 10% of the slow-path optimum (the paper's core claim in miniature)."""
    cfg = get_config("qwen3_14b")
    shape = SHAPES["train_4k"]
    base = {"flops": 3.2e14, "hbm_bytes": 4.5e13, "collective_bytes": 5e11,
            "wire_bytes": 7e11}
    space = [c for c in dse.default_space(freq_points=4) if c.n_chips >= 16]

    X, yp, yc = [], [], []
    for c in space:
        chip = get_chip(c.chip)
        ana = dse._scale_analysis(base, 256, c)
        r = costmodel.simulate(ana, chip, c.n_chips, freq_mhz=c.freq_mhz,
                               mesh=c.mesh)
        X.append(features.extract(cfg, shape, chip, c.n_chips, c.mesh, c.freq_mhz))
        yp.append(r.power_w)
        yc.append(r.cycles)
    rf = predictors.RandomForestRegressor(n_trees=20).fit(np.asarray(X), np.asarray(yp))
    knn = predictors.KNNRegressor().fit(np.asarray(X), np.asarray(yc))

    cons = dse.Constraint(max_power_w=50_000, min_hbm_fit=False)
    best_slow, results, _ = dse.slow_path_search(
        "qwen3_14b", "train_4k", base, 256, 0.5, space, cons)
    best_fast, _, _ = dse.fast_path_search(
        "qwen3_14b", "train_4k", rf, knn, space, cons, verify_top_k=5,
        slow_verify=lambda c: costmodel.simulate(
            dse._scale_analysis(base, 256, c), get_chip(c.chip), c.n_chips,
            freq_mhz=c.freq_mhz, mesh=c.mesh))
    e_slow = results[best_slow]["sim"].energy_j
    e_fast = results[best_fast]["sim"].energy_j
    assert e_fast <= e_slow * 1.10, (e_slow, e_fast)


def test_applicable_shapes_contract():
    """long_500k only for sub-quadratic archs; 32 compiled LM cells total."""
    cells = 0
    for arch in ARCH_NAMES:
        if arch == "resnet50":
            continue
        cfg = get_config(arch)
        shapes = {s.name for s in cfg.applicable_shapes()}
        if cfg.sub_quadratic:
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        cells += len(shapes)
    assert cells == 32


def test_param_counts_match_billing_names():
    """Config param counts are in the ballpark their names advertise."""
    expect = {"deepseek_v3_671b": 671e9, "deepseek_v2_236b": 236e9,
              "qwen2_72b": 72e9, "qwen3_14b": 14e9, "granite_20b": 20e9,
              "stablelm_1_6b": 1.6e9, "mamba2_130m": 130e6,
              "zamba2_1_2b": 1.2e9, "paligemma_3b": 2.6e9}
    for name, n in expect.items():
        got = get_config(name).param_count()
        assert 0.6 * n < got < 1.45 * n, (name, got / 1e9)


def test_offload_decision_flips_with_bandwidth():
    # LLM-prefill-class request: heavy enough that the cloud slice beats the
    # edge chip once the uplink clears (paper's Jetson-vs-cloud example)
    from repro.core import offload
    local = {"flops": 2e12, "hbm_bytes": 2e10, "collective_bytes": 0.0,
             "wire_bytes": 0.0}
    remote = {"flops": 1.2e11, "hbm_bytes": 1.5e9, "collective_bytes": 2e7,
              "wire_bytes": 2e7}
    slow = offload.analyze(local, remote, 1.2e7, 3.2e4,
                           offload.NetworkSpec(bandwidth_bps=1e6))
    fast = offload.analyze(local, remote, 1.2e7, 3.2e4,
                           offload.NetworkSpec(bandwidth_bps=1e9))
    assert not slow.choose_remote_latency
    assert fast.choose_remote_latency
