"""Optimizer + compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; plain unit tests still run
    from _hypothesis_stub import given, settings, st

from repro import optim
from repro.optim import compression
from repro.optim.adamw import quantize_i8, dequantize_i8


def _quadratic_losses(opt_name, steps=60):
    target = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)), jnp.float32)
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    opt = optim.make_optimizer(opt_name, lr=0.05, total_steps=steps)
    state = opt.init(params)
    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        params, state, _ = opt.apply(params, grads, state)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("name", ["adamw", "adamw_bf16", "adamw8bit", "adafactor"])
def test_optimizers_descend_quadratic(name):
    losses = _quadratic_losses(name)
    # int8-quantized moments add noise: looser bound, still clearly descending
    bound = 0.40 if name == "adamw8bit" else 0.15
    assert losses[-1] < losses[0] * bound, f"{name}: {losses[0]} -> {losses[-1]}"


def test_adafactor_factored_state_is_small():
    params = {"w": jnp.zeros((512, 1024), jnp.float32)}
    opt = optim.make_optimizer("adafactor")
    st_ = opt.init(params)
    v = st_.v["w"]
    assert hasattr(v, "r") and v.r.shape == (512,) and v.c.shape == (1024,)


def test_adafactor_small_params_not_factored():
    params = {"b": jnp.zeros((64,), jnp.float32)}
    st_ = optim.make_optimizer("adafactor").init(params)
    assert st_.v["b"].shape == (64,)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 2000))
def test_int8_quant_roundtrip_bounded_error(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=n) * rng.uniform(0.01, 100), jnp.float32)
    q = quantize_i8(x)
    err = jnp.abs(dequantize_i8(q) - x)
    # error bounded by scale/2 per block
    max_scale = float(jnp.max(q["scale"]))
    assert float(jnp.max(err)) <= max_scale * 0.5 + 1e-7


def test_compression_error_feedback_recovers_signal():
    """With error feedback, the MEAN of sent gradients converges to the true
    gradient (bias-free): classic EF-SGD property."""
    g = jnp.asarray(np.random.default_rng(1).normal(size=(333,)), jnp.float32) * 0.01
    grads = {"g": g}
    resid = compression.init_residual(grads)
    total = jnp.zeros_like(g)
    n = 20
    for _ in range(n):
        sent, resid = compression.compressed_grads_with_feedback(grads, resid)
        total = total + sent["g"]
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g),
                               atol=5e-4, rtol=0.05)


def test_global_norm_clipping_applies():
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = optim.make_optimizer("adamw", lr=0.0)
    state = opt.init(params)
    big = {"w": jnp.full((4,), 1e6, jnp.float32)}
    _, _, metrics = opt.apply(params, big, state)
    assert float(metrics["grad_norm"]) > 1e5  # reported raw
