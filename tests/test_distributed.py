"""Multi-device behaviour, run in SUBPROCESSES with 8 fake host devices so the
main pytest process keeps seeing exactly one device."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout=900):
    code = "import os\n" \
           "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n" \
           + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_moe_ep_matches_dense():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs.base import get_config
    from repro.models import moe as MOE
    from repro.models.dist import Dist

    cfg = get_config('deepseek_v2_236b').reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops -> exact
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    dist = Dist(mesh=mesh, dp_axes=('data',))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.1
         ).astype(jnp.bfloat16)
    y_dense, aux_d = MOE.moe_dense(p, cfg, x)
    y_ep, aux_e = jax.jit(lambda pp, xx: MOE.moe_block(pp, cfg, xx, dist))(p, x)
    err = float(jnp.max(jnp.abs(y_ep.astype(jnp.float32) - y_dense.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(y_dense.astype(jnp.float32)))) + 1e-6
    assert err / scale < 0.05, (err, scale)
    print('moe ep vs dense OK', err / scale)
    """)


def test_train_step_on_mesh_and_elastic_restore():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile, functools
    from jax.sharding import NamedSharding
    from repro.configs.base import get_config, ShapeConfig
    from repro.models import api
    from repro.models.dist import make_dist
    from repro.models.sharding import param_shardings
    from repro import optim
    from repro.checkpoint import store

    cfg = get_config('qwen3_14b').reduced()
    model = api.build_model(cfg)
    opt = optim.make_optimizer(cfg.optimizer, total_steps=10)

    # --- train 2 steps on a 2x4 mesh
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    dist = make_dist(mesh)
    params = model.init(jax.random.PRNGKey(0), max_seq=32)
    params = jax.device_put(params, param_shardings(params, dist))
    state = api.TrainState(params, opt.init(params))
    step = jax.jit(api.make_train_step(model, opt, dist))
    batch = {'tokens': jnp.ones((8, 32), jnp.int32),
             'labels': jnp.ones((8, 32), jnp.int32)}
    state, m = step(state, batch)
    state, m = step(state, batch)
    assert jnp.isfinite(m['loss'])
    print('mesh train OK', float(m['loss']))

    # --- checkpoint, restore onto a DIFFERENT mesh (4x2): elastic
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 2, state.params)
        mesh2 = jax.make_mesh((4, 2), ('data', 'model'))
        dist2 = make_dist(mesh2)
        shardings2 = param_shardings(state.params, dist2)
        _, params2, _ = store.restore(d, shardings=shardings2)
        # value-identical across the re-shard
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(params2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and trainable on the new mesh
        state2 = api.TrainState(params2, opt.init(params2))
        step2 = jax.jit(api.make_train_step(model, opt, dist2))
        state2, m2 = step2(state2, batch)
        assert jnp.isfinite(m2['loss'])
        print('elastic restore OK', float(m2['loss']))
    """)


def test_losses_match_across_mesh_shapes():
    """Same model, same data: 1-device loss == 2x4-mesh loss (SPMD correctness)."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.models import api
    from repro.models.dist import make_dist

    cfg = get_config('granite_20b').reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=32)
    batch = {'tokens': jnp.arange(8 * 32).reshape(8, 32).astype(jnp.int32) % 64,
             'labels': jnp.arange(8 * 32).reshape(8, 32).astype(jnp.int32) % 64}
    loss_1dev, _ = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    dist = make_dist(mesh)
    loss_mesh, _ = jax.jit(lambda p, b: model.loss(p, b, dist))(params, batch)
    assert abs(float(loss_1dev) - float(loss_mesh)) < 5e-2, \
        (float(loss_1dev), float(loss_mesh))
    print('spmd loss match OK', float(loss_1dev), float(loss_mesh))
    """)


def test_compressed_crosspod_psum():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.optim import compression

    mesh = jax.make_mesh((2, 4), ('pod', 'data'))
    g = {'w': jnp.ones((64, 8), jnp.float32) * 0.01}
    e = compression.init_residual(g)
    summed, new_e = compression.crosspod_compressed_psum(g, e, mesh, 'pod')
    np.testing.assert_allclose(np.asarray(summed['w']), 0.02, rtol=0.02)
    print('compressed psum OK')
    """)


def test_pipeline_parallel_stage_axis():
    """GPipe-style pipeline over a dedicated stage axis via shard_map +
    collective_permute; equivalence vs the unpipelined stack."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.runtime.pipeline import pipeline_apply

    S, D, n_stage, micro = 4, 16, 4, 8
    ws = jax.random.normal(jax.random.PRNGKey(0), (n_stage, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (16, D))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    # reference: sequential stages
    h = x
    for i in range(n_stage):
        h = stage_fn(ws[i], h)

    mesh = jax.make_mesh((4,), ('stage',))
    out = pipeline_apply(stage_fn, ws, x, mesh, n_micro=micro)
    np.testing.assert_allclose(np.asarray(out), np.asarray(h), atol=1e-5)
    print('pipeline OK')
    """)
