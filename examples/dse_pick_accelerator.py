"""The paper's headline workflow: pick the right accelerator via ML-aided DSE.

Trains the paper's predictor suite (KNN / Decision Tree / Random Forest) on
cached dry-run design points, then explores the accelerator space (TPU
generation x slice size x DVFS frequency) for a target workload under a power
budget — fast path (predictors) vs slow path (one batched simulator pass),
with the speedup the paper motivates, plus the energy/latency Pareto
frontier the single-objective search hides.

  PYTHONPATH=src python examples/dse_pick_accelerator.py
"""

import os

import numpy as np

from repro.core import dataset, dse, predictors

ART = os.path.join(os.getcwd(), "experiments", "dryrun")

if __name__ == "__main__":
    X, y_power, y_cycles, meta = dataset.build_dataset(ART)
    if len(X) < 40:
        raise SystemExit(f"need cached dry-run artifacts in {ART} "
                         "(run python -m repro.launch.dryrun --all first)")
    print(f"design points: {len(X)}")
    rf = predictors.RandomForestRegressor().fit(X, y_power)
    knn = predictors.KNNRegressor().fit(X, y_cycles)

    arts = dataset.load_dryrun_artifacts(ART)
    key = ("qwen3_14b", "train_4k", "pod1")
    if key not in arts:
        key = sorted(arts)[0]
    art = arts[key]
    base = {k: art["hxa"][k] for k in
            ("flops", "hbm_bytes", "collective_bytes", "wire_bytes")}
    space = dse.default_space_batch()      # packed once, swept many times
    cons = dse.Constraint(max_power_w=30_000)   # 30 kW budget

    best_slow, _, t_slow = dse.slow_path_search(
        key[0], key[1], base, art["roofline"]["n_chips"],
        art["memory"]["state_gb_per_device"], space, cons)
    dse.fast_path_search(key[0], key[1], rf, knn, space, cons)  # warm the jit
    best_fast, _, t_fast = dse.fast_path_search(
        key[0], key[1], rf, knn, space, cons)
    _, _, t_scalar = dse.slow_path_search_scalar(
        key[0], key[1], base, art["roofline"]["n_chips"],
        art["memory"]["state_gb_per_device"], space.candidates, cons)
    print(f"workload: {key[0]} x {key[1]}")
    print(f"slow path (batched): {best_slow.chip} x{best_slow.n_chips} @ "
          f"{best_slow.freq_mhz:.0f} MHz   ({t_slow * 1e3:.1f} ms; "
          f"scalar loop took {t_scalar * 1e3:.1f} ms)")
    print(f"fast path:           {best_fast.chip} x{best_fast.n_chips} @ "
          f"{best_fast.freq_mhz:.0f} MHz   ({t_fast * 1e3:.1f} ms)")
    print(f"batched sweep speedup vs seed scalar loop: "
          f"{t_scalar / max(t_slow, 1e-9):.1f}x over {len(space)} candidates "
          "(and either path avoids a compile per candidate)")

    # multi-objective view: the energy/latency frontier under the same budget
    wl = dse.Workload(key[0], key[1], base, art["roofline"]["n_chips"],
                      art["memory"]["state_gb_per_device"])
    front = dse.pareto_search(wl, space, cons)[(key[0], key[1])]
    print(f"\nenergy/latency Pareto frontier ({len(front)} of "
          f"{front.feasible_count} feasible candidates):")
    for cand, e, lat in zip(front.candidates, front.energy_j, front.latency_s):
        print(f"  {cand.chip:>8} x{cand.n_chips:<4} @ {cand.freq_mhz:6.0f} MHz"
              f"   {lat * 1e3:8.2f} ms   {e / 1e3:8.2f} kJ")
