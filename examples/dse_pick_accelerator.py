"""The paper's headline workflow: pick the right accelerator via ML-aided DSE.

Trains the paper's predictor suite (KNN / Decision Tree / Random Forest) on
cached dry-run design points, then explores the accelerator space (TPU
generation x slice size x DVFS frequency) for a target workload under a power
budget — fast path (predictors) vs slow path (simulator), with the speedup
the paper motivates.

  PYTHONPATH=src python examples/dse_pick_accelerator.py
"""

import os

import numpy as np

from repro.core import dataset, dse, predictors

ART = os.path.join(os.getcwd(), "experiments", "dryrun")

if __name__ == "__main__":
    X, y_power, y_cycles, meta = dataset.build_dataset(ART)
    if len(X) < 40:
        raise SystemExit(f"need cached dry-run artifacts in {ART} "
                         "(run python -m repro.launch.dryrun --all first)")
    print(f"design points: {len(X)}")
    rf = predictors.RandomForestRegressor().fit(X, y_power)
    knn = predictors.KNNRegressor().fit(X, y_cycles)

    arts = dataset.load_dryrun_artifacts(ART)
    key = ("qwen3_14b", "train_4k", "pod1")
    if key not in arts:
        key = sorted(arts)[0]
    art = arts[key]
    base = {k: art["hxa"][k] for k in
            ("flops", "hbm_bytes", "collective_bytes", "wire_bytes")}
    space = dse.default_space()
    cons = dse.Constraint(max_power_w=30_000)   # 30 kW budget

    best_slow, _, t_slow = dse.slow_path_search(
        key[0], key[1], base, art["roofline"]["n_chips"],
        art["memory"]["state_gb_per_device"], space, cons)
    best_fast, _, t_fast = dse.fast_path_search(
        key[0], key[1], rf, knn, space, cons)
    print(f"workload: {key[0]} x {key[1]}")
    print(f"slow path: {best_slow.chip} x{best_slow.n_chips} @ "
          f"{best_slow.freq_mhz:.0f} MHz   ({t_slow * 1e3:.1f} ms)")
    print(f"fast path: {best_fast.chip} x{best_fast.n_chips} @ "
          f"{best_fast.freq_mhz:.0f} MHz   ({t_fast * 1e3:.1f} ms)")
    print(f"DSE speedup (per evaluated point): "
          f"{t_slow / max(t_fast, 1e-9):.1f}x over {len(space)} candidates")
