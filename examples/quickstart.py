"""Quickstart: train a reduced model end-to-end, checkpoint, restore, resume.

  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.launch.train import train

if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as ckpt_dir:
        print("== phase 1: train 40 steps with checkpoints ==")
        losses1, _ = train("stablelm-1.6b", steps=40, reduced=True,
                           seq_len=128, batch=8, ckpt_dir=ckpt_dir,
                           ckpt_every=20, install_signals=False)
        print("== phase 2: simulate restart, restore, train 20 more ==")
        losses2, _ = train("stablelm-1.6b", steps=60, reduced=True,
                           seq_len=128, batch=8, ckpt_dir=ckpt_dir,
                           restore=True, ckpt_every=20, install_signals=False)
        assert losses2[-1] < losses1[0], "loss should improve across restart"
        print(f"quickstart OK: {losses1[0]:.3f} -> {losses2[-1]:.3f} "
              f"(through a checkpoint/restore cycle)")
