"""Serve a small model with batched requests (continuous batching engine).

  PYTHONPATH=src python examples/serve_batch.py
"""

from repro.launch.serve import serve

if __name__ == "__main__":
    reqs, stats = serve("stablelm-1.6b", n_requests=6, slots=3,
                        max_len=96, max_new=12)
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt {len(r.prompt)} toks -> {r.tokens_out}")
    print(f"serve_batch OK: {stats['completed']}/{len(reqs)} requests, "
          f"{stats['tok_per_s']:.1f} tok/s")
    assert stats["completed"] == len(reqs)
