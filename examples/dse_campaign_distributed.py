"""Distributed DSE campaigns: many workers, one frontier, same answer.

The campaign fabric shards a tile-streamed sweep across real worker
processes: a coordinator leases tile indices, ``spawn`` workers evaluate
them with the standard ``TileEvaluator`` engines and ship O(survivors)
``TileReduction`` payloads back, and idempotent/commutative frontier merges
make the result independent of worker count, delivery order, worker loss
and duplicated deliveries.  This demo runs the same campaign single-process
and on a 2-worker fabric — WITH an injected worker crash mid-tile and a
duplicated payload delivery — and shows the two frontiers are IDENTICAL.

  python examples/dse_campaign_distributed.py [--workers 2]
      [--evaluator numpy] [--no-faults]

CI runs this (2 workers, tiny space, faults on) in its gating matrix as the
fabric smoke.  See docs/campaigns.md for the operator runbook.
"""

import argparse
import os

from repro.core import dse
from repro.dse_campaign import (Campaign, CampaignConfig, FaultInjection,
                                MultiprocessFabric, frontiers_identical,
                                tiny_campaign_space)

ART = os.path.join(os.getcwd(), "experiments", "dryrun")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--evaluator", default="numpy",
                    choices=("numpy", "jit", "pallas"))
    ap.add_argument("--no-faults", action="store_true",
                    help="skip the injected worker crash + duplicate delivery")
    args = ap.parse_args()

    spec = tiny_campaign_space(chunk_size=64)
    cfg = CampaignConfig(
        space=spec, evaluator=args.evaluator, n_workers=args.workers,
        constraint=dse.Constraint(max_power_w=40_000, min_hbm_fit=False))
    print(f"evaluator: {args.evaluator}; space: {len(spec)} candidates in "
          f"{spec.n_tiles()} tiles of {spec.chunk_size}")

    single = Campaign.from_artifacts(ART, cfg).run()
    print(f"single process: {single.candidates_evaluated} evaluations, "
          f"{sum(len(f) for f in single.frontiers.values())} frontier points")

    fault = None
    if not args.no_faults:
        # worker (n-1) completes one tile, then crashes mid-tile without
        # delivering; the coordinator re-issues its lease.  the first
        # delivered payload is also folded twice (at-least-once delivery).
        fault = FaultInjection(kill_worker=args.workers - 1,
                               kill_after_tiles=1, duplicate=True)
    campaign = Campaign.from_artifacts(ART, cfg)
    fabric = MultiprocessFabric(campaign, n_workers=args.workers, fault=fault)
    result = fabric.run()
    assert result.complete

    stats = fabric.stats
    print(f"\n{args.workers}-worker fabric: {stats['deliveries']} deliveries "
          f"({stats['duplicates']} duplicate), "
          f"{len(stats['lost_workers'])} worker(s) lost, "
          f"{stats['reissued_tiles']} tile(s) re-issued")
    for w, busy in sorted(stats["worker_busy_s"].items()):
        print(f"  worker {w}: {busy * 1e3:8.1f} ms busy CPU")

    identical = all(
        frontiers_identical(single.frontiers[k], result.frontiers[k])
        for k in single.frontiers)
    print(f"\ndistributed frontier == single-process frontier: {identical}")
    assert identical, "distributed run diverged from single-process run"
    if fault is not None:
        assert stats["lost_workers"], "injected worker crash never fired"
        assert stats["duplicates"] >= 1, "duplicate delivery never folded"

    key = sorted(single.frontiers)[0]
    front = result.frontiers[key]
    print(f"\n{key[0]} x {key[1]} frontier ({len(front)} points; "
          "first 5 by latency):")
    for cand, e, lat in list(zip(front.candidates, front.energy_j,
                                 front.latency_s))[:5]:
        mesh = "x".join(map(str, cand.mesh))
        print(f"  {cand.chip:>8} x{cand.n_chips:<4} mesh {mesh:>8} @ "
              f"{cand.freq_mhz:7.1f} MHz   {lat * 1e3:9.2f} ms   "
              f"{e / 1e3:9.2f} kJ")
