"""Resumable DSE campaigns: interrupt a mega-space sweep, resume, same answer.

A campaign streams the design space in fixed-size tiles and checkpoints its
state (frontiers + next tile) after every tile, so a preempted sweep —
spot-VM eviction, CI timeout, ctrl-C — continues from where it stopped
instead of restarting.  This demo runs a campaign over all cached dry-run
workloads, kills it mid-sweep, resumes from the checkpoint, and shows the
final frontier is IDENTICAL to an uninterrupted fresh run.

  python examples/dse_campaign_resume.py [--evaluator pallas]

``--evaluator`` selects the tile engine (numpy / jit / pallas); CI runs the
pallas-interpret variant in its gating matrix as the fused-kernel smoke.
"""

import argparse
import os
import tempfile

from repro.core import dse
from repro.dse_campaign import (Campaign, CampaignConfig,
                                frontiers_identical, tiny_campaign_space)

ART = os.path.join(os.getcwd(), "experiments", "dryrun")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--evaluator", default="numpy",
                    choices=("numpy", "jit", "pallas"))
    args = ap.parse_args()
    spec = tiny_campaign_space(chunk_size=128)
    cfg = CampaignConfig(
        space=spec, evaluator=args.evaluator,
        constraint=dse.Constraint(max_power_w=40_000, min_hbm_fit=False))
    ckpt = os.path.join(tempfile.mkdtemp(prefix="dse_campaign_"), "ckpt.json")

    campaign = Campaign.from_artifacts(ART, cfg)
    print(f"evaluator: {args.evaluator}")
    n_tiles = spec.n_tiles()
    cut = n_tiles // 2
    print(f"space: {len(spec)} candidates in {n_tiles} tiles of "
          f"{spec.chunk_size}; workloads: "
          f"{[f'{w.arch} x {w.shape}' for w in campaign.workloads]}")

    partial = campaign.run(checkpoint_path=ckpt, max_tiles=cut)
    print(f"\n-- interrupted after tile {partial.tiles_done - 1} "
          f"({partial.tiles_done}/{n_tiles} tiles, "
          f"{partial.candidates_evaluated} evaluations) --")
    print(f"checkpoint: {ckpt} ({os.path.getsize(ckpt)} bytes)")

    resumed = Campaign.from_checkpoint(ckpt)
    print(f"resumed at tile {resumed.next_tile}")
    final = resumed.run(checkpoint_path=ckpt)
    assert final.complete

    fresh = Campaign.from_artifacts(ART, cfg).run()
    identical = all(frontiers_identical(final.frontiers[k], fresh.frontiers[k])
                    for k in fresh.frontiers)
    print(f"\nresumed final frontier == uninterrupted fresh run: {identical}")
    assert identical, "resume diverged from fresh run"

    key = sorted(fresh.frontiers)[0]
    front = final.frontiers[key]
    print(f"\n{key[0]} x {key[1]} energy/latency frontier "
          f"({len(front)} points, {front.feasible_count} feasible; "
          "first 10 by latency):")
    for cand, e, lat in list(zip(front.candidates, front.energy_j,
                                 front.latency_s))[:10]:
        mesh = "x".join(map(str, cand.mesh))
        print(f"  {cand.chip:>8} x{cand.n_chips:<4} mesh {mesh:>8} @ "
              f"{cand.freq_mhz:7.1f} MHz   {lat * 1e3:9.2f} ms   "
              f"{e / 1e3:9.2f} kJ")
    traj = final.trajectories[key]
    print(f"\ntrajectory: {len(traj)} snapshots; frontier growth "
          f"{[s.frontier_size for s in traj[:: max(len(traj) // 8, 1)]]}")
