"""Surrogate-guided adaptive campaign: near-exact frontiers, a fraction of
the evaluations.

``AdaptiveCampaign`` evaluates a small evenly-spaced seed slice of the space
exactly, fits random-forest surrogates (energy + latency, log-target) on
per-tile training samples, ranks every unevaluated tile by expected frontier
hypervolume gain (optimistic lower-confidence-bound predictions against the
pinned acquisition reference points), evaluates the best tiles exactly,
refits and repeats until the hypervolume plateaus or the evaluation budget
(default 10% of the space) runs out.  The frontier only ever contains
exactly-evaluated candidates — the surrogates steer, they never score.

This demo runs the adaptive loop on the tiny campaign space over all cached
dry-run workloads, compares its frontier hypervolume against the exact
sweep, shows the budget=100% degenerate case is bitwise-identical to the
exact sweep, and checkpoints/resumes the loop mid-search.

  python examples/dse_campaign_adaptive.py [--evaluator jit]
"""

import argparse
import os
import tempfile

from repro.core import dse
from repro.dse_campaign import (AdaptiveCampaign, AdaptiveConfig, Campaign,
                                CampaignConfig, frontiers_identical,
                                hypervolume_2d, tiny_campaign_space)

ART = os.path.join(os.getcwd(), "experiments", "dryrun")


def build(cfg):
    camp = Campaign.from_artifacts(ART, cfg)
    return AdaptiveCampaign(camp.workloads, cfg)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--evaluator", default="jit",
                    choices=("numpy", "jit", "pallas"))
    args = ap.parse_args()
    spec = tiny_campaign_space(chunk_size=64)
    cons = dse.Constraint(max_power_w=40_000, min_hbm_fit=False)
    # tiny-space knobs: the default 10% budget assumes >=100k candidates;
    # at 800 candidates / 13 tiles a workable search needs a larger slice
    # and a tighter plateau (benchmarks/dse_campaign.py runs the defaults
    # on the full space)
    acfg = AdaptiveConfig(budget_fraction=0.5, seed_fraction=0.25,
                          round_fraction=0.08, train_sample=48,
                          plateau_rounds=3, plateau_tol=1e-5)
    cfg = CampaignConfig(space=spec, evaluator=args.evaluator,
                         constraint=cons, adaptive=acfg)

    exact = Campaign.from_artifacts(
        ART, CampaignConfig(space=spec, evaluator=args.evaluator,
                            constraint=cons))
    er = exact.run()
    refs = {k: (fr.ref_energy_j, fr.ref_latency_s)
            for k, fr in exact.frontiers.items()}

    adaptive = build(cfg)
    res = adaptive.run()
    print(f"evaluator: {args.evaluator}")
    print(f"space: {res.space_size} candidates in {res.n_tiles} tiles of "
          f"{spec.chunk_size}; workloads: {len(adaptive.workloads)}")
    print(f"adaptive: {len(res.rounds)} rounds "
          f"(tiles per round: {[len(r) for r in res.rounds]}), "
          f"stopped on {res.stopped_on}")
    print(f"evaluated {res.candidates_evaluated}/{res.space_size} candidates "
          f"= {res.fraction_evaluated:.1%} of the space "
          f"(exact sweep: {er.candidates_evaluated})")

    print("\nfrontier hypervolume vs exact sweep (shared ref points):")
    worst = 1.0
    for k in sorted(refs):
        hv_e = hypervolume_2d(exact.frontiers[k].energy_j,
                              exact.frontiers[k].latency_s, *refs[k])
        hv_a = hypervolume_2d(adaptive.frontiers[k].energy_j,
                              adaptive.frontiers[k].latency_s, *refs[k])
        ratio = hv_a / hv_e if hv_e else 1.0
        worst = min(worst, ratio)
        print(f"  {k[0]:>14} x {k[1]:<12} {ratio:.5f}")
    print(f"worst cell: {worst:.5f}")

    # degenerate contract: budget=100% IS the exact sweep, bitwise
    full = build(CampaignConfig(space=spec, evaluator=args.evaluator,
                                constraint=cons,
                                adaptive=AdaptiveConfig(budget_fraction=1.0)))
    full.run()
    identical = all(frontiers_identical(full.frontiers[k], exact.frontiers[k])
                    for k in exact.frontiers)
    print(f"\nbudget=100% frontier bitwise == exact sweep: {identical}")
    assert identical, "budget=100% diverged from the exact sweep"

    # interrupt after one round, resume from the checkpoint, same answer
    ckpt = os.path.join(tempfile.mkdtemp(prefix="dse_adaptive_"), "ckpt.json")
    part = build(cfg)
    part.run(checkpoint_path=ckpt, max_rounds=1)
    resumed = AdaptiveCampaign.from_checkpoint(ckpt)
    rres = resumed.run(checkpoint_path=ckpt)
    same = (rres.rounds == res.rounds
            and all(frontiers_identical(resumed.frontiers[k],
                                        adaptive.frontiers[k])
                    for k in adaptive.frontiers))
    print(f"interrupted-after-1-round resume == uninterrupted run: {same}")
    assert same, "adaptive resume diverged from the uninterrupted run"
