"""Edge-vs-cloud offloading analysis (the paper's §IV future work).

Sweeps network bandwidth and reports where inference should run — locally on
an edge TPU or offloaded to a cloud v5e slice — for latency and for battery.
Mirrors the paper's Jetson-vs-cloud motivating example (7 W local vs 2 W
offloaded).

  PYTHONPATH=src python examples/offload_decision.py
"""

from repro.core import offload

if __name__ == "__main__":
    # HxA censuses of a LLM-prefill-class inference, per device (analytic stand-in
    # numbers of the right magnitude; the benchmark suite derives them from
    # compiled artifacts).
    local = {"flops": 2.0e12, "hbm_bytes": 2.0e10, "collective_bytes": 0.0,
             "wire_bytes": 0.0}
    remote = {"flops": 1.2e11, "hbm_bytes": 1.5e9, "collective_bytes": 0.02e9,
              "wire_bytes": 0.02e9}
    req, resp = 1.5e6 * 8, 4e3 * 8     # 1.5 MB payload up, 4 KB logits down

    print(f"{'bw (Mbps)':>10} {'local (ms)':>11} {'remote (ms)':>12} "
          f"{'latency says':>13} {'battery says':>13}")
    for bw_mbps in (2, 10, 50, 200, 1000):
        net = offload.NetworkSpec(bandwidth_bps=bw_mbps * 1e6)
        d = offload.analyze(local, remote, req, resp, net)
        print(f"{bw_mbps:>10} {d.local_latency_s * 1e3:>11.2f} "
              f"{d.remote_latency_s * 1e3:>12.2f} "
              f"{'offload' if d.choose_remote_latency else 'local':>13} "
              f"{'offload' if d.choose_remote_battery else 'local':>13}")
