"""Edge-vs-cloud offloading analysis (the paper's §IV future work).

Sweeps network bandwidth and reports where inference should run — locally on
an edge TPU or offloaded to a cloud v5e slice — for latency and for battery.
Mirrors the paper's Jetson-vs-cloud motivating example (7 W local vs 2 W
offloaded).  The whole bandwidth sweep is one batched ``sweep_bandwidth``
call: both censuses are simulated once, the network leg is array math.

  PYTHONPATH=src python examples/offload_decision.py
"""

import numpy as np

from repro.core import offload

if __name__ == "__main__":
    # HxA censuses of a LLM-prefill-class inference, per device (analytic stand-in
    # numbers of the right magnitude; the benchmark suite derives them from
    # compiled artifacts).
    local = {"flops": 2.0e12, "hbm_bytes": 2.0e10, "collective_bytes": 0.0,
             "wire_bytes": 0.0}
    remote = {"flops": 1.2e11, "hbm_bytes": 1.5e9, "collective_bytes": 0.02e9,
              "wire_bytes": 0.02e9}
    req, resp = 1.5e6 * 8, 4e3 * 8     # 1.5 MB payload up, 4 KB logits down

    bw_mbps = np.array([2, 10, 50, 200, 1000], np.float64)
    sweep = offload.sweep_bandwidth(local, remote, req, resp, bw_mbps * 1e6)

    print(f"{'bw (Mbps)':>10} {'local (ms)':>11} {'remote (ms)':>12} "
          f"{'latency says':>13} {'battery says':>13}")
    for i, bw in enumerate(bw_mbps):
        print(f"{bw:>10.0f} {sweep['local_latency_s'][i] * 1e3:>11.2f} "
              f"{sweep['remote_latency_s'][i] * 1e3:>12.2f} "
              f"{'offload' if sweep['choose_remote_latency'][i] else 'local':>13} "
              f"{'offload' if sweep['choose_remote_battery'][i] else 'local':>13}")
