"""Accelerator selection end to end: campaign -> frontier index -> queries.

An offline campaign sweeps the design space for every cached dry-run
workload; its Pareto frontiers are frozen into a versioned ``FrontierIndex``
artifact (real save/load round trip).  A ``SelectionEngine`` then answers
``select(workload, constraints)`` queries three ways, and this demo proves
each path:

  * cached workloads  -> ``index_exact``: the served frontier is IDENTICAL
    to the offline campaign pick, with zero sweep launches;
  * a novel workload  -> ``mini_campaign``: the fused exact fallback, with
    answers identical to a standalone campaign over the same space;
  * expired deadline  -> ``predictor_only``: ranked by the learned power /
    performance predictors, stamped inexact.

  python examples/select_accelerator.py

CI runs this as a gating step right after the campaign smoke; it shares the
same dry-run artifacts.  See docs/serving.md for the serving runbook.
"""

import os
import tempfile

from repro.core import dataset, dse, predictors
from repro.dse_campaign import (Campaign, CampaignConfig,
                                frontiers_identical, tiny_campaign_space)
from repro.select import FrontierIndex, SelectionEngine

ART = os.path.join(os.getcwd(), "experiments", "dryrun")


def perturbed(wl: dse.Workload, scale: float) -> dse.Workload:
    """A novel workload family: same arch/shape, census uniformly scaled."""
    return dse.Workload(wl.arch, wl.shape,
                        {k: v * scale for k, v in wl.base_analysis.items()},
                        wl.base_chips, wl.state_gb_per_device)


if __name__ == "__main__":
    cfg = CampaignConfig(
        space=tiny_campaign_space(chunk_size=128), evaluator="jit",
        constraint=dse.Constraint(max_power_w=40_000, min_hbm_fit=False))
    campaign = Campaign.from_artifacts(ART, cfg)
    print(f"offline campaign: {len(cfg.space)} candidates x "
          f"{len(campaign.workloads)} workloads")
    offline = campaign.run()
    assert offline.complete

    index_path = os.path.join(
        tempfile.mkdtemp(prefix="frontier_index_"), "frontier_index.json")
    FrontierIndex.from_campaign(campaign).save(index_path)
    index = FrontierIndex.load(index_path)
    print(f"frontier index: {len(index)} workload families -> {index_path} "
          f"({os.path.getsize(index_path)} bytes)")

    # -- cached workloads: index hits, identical to the offline picks -------
    engine = SelectionEngine(index)
    print("\ncached workloads (index_exact):")
    for wl in campaign.workloads:
        answer = engine.select(wl)
        identical = frontiers_identical(
            answer.frontier(), offline.frontiers[(wl.arch, wl.shape)])
        best = answer.choices[0]
        mesh = "x".join(map(str, best.candidate.mesh))
        print(f"  {wl.arch:>14} x {wl.shape}: [{answer.provenance}] "
              f"{best.candidate.chip} x{best.candidate.n_chips} mesh {mesh} "
              f"({answer.wall_s * 1e3:.2f} ms)  == offline: {identical}")
        assert answer.provenance == "index_exact", answer.provenance
        assert identical, "served answer diverged from offline campaign pick"
    assert engine.fused_launches == 0, "an index hit triggered a sweep"

    # -- a novel family: exact mini-campaign fallback -----------------------
    novel = perturbed(campaign.workloads[0], 1.07)
    answer = engine.select(novel)
    standalone = Campaign([novel], engine.config).run()
    parity = frontiers_identical(
        answer.frontier(), standalone.frontiers[(novel.arch, novel.shape)])
    best = answer.choices[0]
    print(f"\nnovel workload (census x1.07): [{answer.provenance}] "
          f"{best.candidate.chip} x{best.candidate.n_chips} "
          f"({answer.wall_s * 1e3:.2f} ms)  == standalone campaign: {parity}")
    assert answer.provenance == "mini_campaign", answer.provenance
    assert parity, "mini-campaign fallback diverged from standalone campaign"

    # -- expired deadline + fitted predictors: degraded answers -------------
    X, y_power, y_cycles, _ = dataset.build_dataset(ART)
    deg = SelectionEngine(index, engine.config.replace(
        power_model=predictors.RandomForestRegressor().fit(X, y_power),
        cycles_model=predictors.KNNRegressor().fit(X, y_cycles)))
    answer = deg.select(perturbed(campaign.workloads[0], 1.11), deadline_s=0.0)
    best = answer.choices[0]
    print(f"deadline expired:              [{answer.provenance}] "
          f"{best.candidate.chip} x{best.candidate.n_chips} "
          f"(exact={best.exact}, {answer.wall_s * 1e3:.2f} ms)")
    assert answer.provenance == "predictor_only", answer.provenance
    assert not best.exact

    print(f"\nengine stats: {dict(engine.stats)}; "
          f"fused launches: {engine.fused_launches}")
    print("all selection paths verified OK")
