"""paligemma-3b [vlm] — SigLIP frontend (STUB) + gemma-2b backbone.

18L d_model=2048, 8 heads (head_dim 256), MQA kv=1, d_ff=16384, vocab 257216.
The SigLIP vision tower is a stub per assignment: ``input_specs()`` provides
256 precomputed patch embeddings which form a bidirectional prefix.
[arXiv:2407.07726]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    act_fn="gelu",
    num_patches=256,
    tie_embeddings=True,
    remat="dots",
)
