"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload shape a
``ShapeConfig``.  A (arch, shape, mesh, chip, freq) tuple is one *design point*
— the unit the paper's DSE sweeps over.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in LM_SHAPES}

# Reduced shapes for smoke tests (same kinds, tiny extents).
SMOKE_TRAIN = ShapeConfig("smoke_train", 64, 2, "train")
SMOKE_PREFILL = ShapeConfig("smoke_prefill", 64, 2, "prefill")
SMOKE_DECODE = ShapeConfig("smoke_decode", 64, 2, "decode")


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Unified model description covering all assigned families."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio | cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- attention variant ---------------------------------------------------
    attn_type: str = "gqa"          # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True           # False -> learned positional embeddings

    # --- MLA (DeepSeek) -------------------------------------------------------
    q_lora_rank: int = 0            # 0 -> full-rank Q projection
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ------------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0          # leading dense layers (DeepSeek)
    router_fn: str = "softmax"      # softmax | sigmoid (v3 aux-free bias routing)
    capacity_factor: float = 1.25
    moe_fsdp: str = "gather"        # gather weights | "partial" contraction
                                    # (psum activations) | "auto" by bytes
    moe_combine_dtype: str = "float32"   # psum dtype for the combine ("bfloat16"
                                         # halves the dominant MoE collective)
    mtp_depth: int = 0              # multi-token-prediction extra heads (v3)

    # --- SSM (Mamba2 / SSD) -----------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # --- hybrid (Zamba2) -------------------------------------------------------
    attn_every: int = 0             # shared attention block every N ssm blocks

    # --- enc-dec / multimodal ---------------------------------------------------
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    num_frames: int = 0             # audio stub: precomputed frame embeddings
    num_patches: int = 0            # vlm stub: precomputed patch embeddings

    # --- cnn (paper's own domain) ------------------------------------------------
    cnn_stages: Tuple[int, ...] = ()
    cnn_width: int = 64
    image_size: int = 224

    # --- numerics / training ----------------------------------------------------
    norm_eps: float = 1e-6
    act_fn: str = "silu"            # silu (swiglu) | gelu (whisper / gemma)
    gated_mlp: bool = True          # False -> plain 2-matrix MLP (whisper)
    attn_impl: str = "xla"          # xla | pallas (fused flash kernel: scores
                                    # stay in VMEM; see kernels/flash_attention)
    ssm_impl: str = "xla"           # xla | pallas (fused SSD chunk kernel)
    cache_layout: str = "seq_major"  # seq_major [L,B,S,KV,hd] | head_major
                                     # [L,B,KV,S,hd] (decode-dot-friendly: no
                                     # per-layer cache transpose; §Perf)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"             # none | dots | full (activation ckpt policy)
    optimizer: str = "adamw"        # adamw | adamw8bit
    sub_quadratic: bool = False     # supports long_500k decode

    # ---------------------------------------------------------------------------

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # --- derived quantities used by features.py / roofline -----------------------

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    def is_moe_layer(self, i: int) -> bool:
        return self.num_experts > 0 and i >= self.first_k_dense

    def attn_params_per_layer(self) -> int:
        d = self.d_model
        if self.attn_type == "mla":
            nope, rope_d, vd = self.qk_nope_head_dim, self.qk_rope_head_dim, self.v_head_dim
            h = self.num_heads
            p = 0
            if self.q_lora_rank:
                p += d * self.q_lora_rank + self.q_lora_rank * h * (nope + rope_d)
            else:
                p += d * h * (nope + rope_d)
            p += d * (self.kv_lora_rank + rope_d)                   # down-proj + k_rope
            p += self.kv_lora_rank * h * (nope + vd)                # up-proj
            p += h * vd * d                                         # o-proj
            return p
        if self.attn_type == "none":
            return 0
        hd = self.head_dim
        return d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d

    def ssm_params_per_layer(self) -> int:
        if not self.ssm_state:
            return 0
        d, di = self.d_model, self.d_inner
        ng, ds, nh = self.ssm_ngroups, self.ssm_state, self.ssm_nheads
        in_proj = d * (2 * di + 2 * ng * ds + nh)       # z, x, B, C, dt
        conv = self.ssm_conv_width * (di + 2 * ng * ds)
        out = di * d
        return in_proj + conv + out + 2 * nh            # A_log, D

    def ffn_params(self, i: int) -> int:
        d = self.d_model
        if self.is_moe_layer(i):
            e = self.num_experts * 3 * d * self.moe_d_ff
            e += self.num_shared_experts * 3 * d * self.moe_d_ff
            e += d * self.num_experts                   # router
            return e
        return 3 * d * self.d_ff if self.act_fn == "silu" else 2 * d * self.d_ff

    def ffn_active_params(self, i: int) -> int:
        d = self.d_model
        if self.is_moe_layer(i):
            return (self.experts_per_token + self.num_shared_experts) * 3 * d * self.moe_d_ff
        return self.ffn_params(i)

    def _body_params(self, active: bool) -> int:
        total = 0
        n_dec = self.num_layers
        for i in range(n_dec):
            if self.family in ("ssm",):
                total += self.ssm_params_per_layer() + self.ffn_params(i) * 0
                # mamba2 has no separate FFN; block = ssm only
            elif self.family == "hybrid":
                total += self.ssm_params_per_layer()
            else:
                total += self.attn_params_per_layer()
                total += self.ffn_active_params(i) if active else self.ffn_params(i)
        if self.family == "hybrid" and self.attn_every:
            # one SHARED attention+mlp block (weights shared across call sites)
            hd = self.head_dim
            shared = self.d_model * self.num_heads * hd * 2 + 2 * self.d_model * self.num_kv_heads * hd
            shared += 3 * self.d_model * self.d_ff
            total += shared
        if self.is_encoder_decoder:
            for _ in range(self.encoder_layers):
                total += self.attn_params_per_layer()
                total += 2 * self.d_model * self.d_ff
            # decoder cross-attention
            total += self.num_layers * self.attn_params_per_layer()
        return total

    def param_count(self, active: bool = False) -> int:
        """Total (or active, for MoE) parameter count, embeddings included."""
        emb = self.vocab_size * self.d_model
        out = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        return emb + out + self._body_params(active)

    def model_flops(self, shape: ShapeConfig) -> float:
        """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); 2*N*D for fwd-only."""
        n = self.param_count(active=True)
        if shape.kind == "train":
            per_tok = 6.0 * n
            toks = shape.tokens
        elif shape.kind == "prefill":
            per_tok = 2.0 * n
            toks = shape.tokens
        else:  # decode: one new token per sequence
            per_tok = 2.0 * n
            toks = shape.global_batch
        return per_tok * toks

    def applicable_shapes(self) -> Tuple[ShapeConfig, ...]:
        out = []
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.sub_quadratic:
                continue  # needs sub-quadratic attention; skip for full-attn archs
            out.append(s)
        return tuple(out)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) or 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
        )
        if self.attn_type == "mla":
            kw.update(q_lora_rank=32 if self.q_lora_rank else 0, kv_lora_rank=32,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        if self.num_experts:
            kw.update(num_experts=8, experts_per_token=2, moe_d_ff=32,
                      first_k_dense=min(self.first_k_dense, 1),
                      num_shared_experts=min(self.num_shared_experts, 1))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
        if self.attn_every:
            kw.update(attn_every=2)
        if self.is_encoder_decoder:
            kw.update(encoder_layers=2, num_frames=8)
        if self.num_patches:
            kw.update(num_patches=8)
        if self.mtp_depth:
            kw.update(mtp_depth=1)
        if self.cnn_stages:
            kw.update(cnn_stages=(1, 1), cnn_width=8, image_size=32)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------

ARCH_NAMES = (
    "mamba2_130m",
    "deepseek_v3_671b",
    "deepseek_v2_236b",
    "qwen3_14b",
    "qwen2_72b",
    "granite_20b",
    "stablelm_1_6b",
    "paligemma_3b",
    "whisper_small",
    "zamba2_1_2b",
    "resnet50",  # the paper's own CNN domain
)

def get_config(name: str) -> ArchConfig:
    key = name.lower().replace("-", "_").replace(".", "_")
    if key not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs() -> dict:
    return {n: get_config(n) for n in ARCH_NAMES}
