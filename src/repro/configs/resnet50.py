"""resnet50 [cnn] — the paper's OWN workload domain (CNN inferencing).

Bottleneck ResNet-50 (stages 3-4-6-3), 224x224x3 inputs, 1000 classes.
Used by the paper-reproduction benchmarks (power/perf prediction of CNN
inference) and by the conv2d Pallas kernel.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="resnet50",
    family="cnn",
    num_layers=16,              # bottleneck blocks
    d_model=2048,               # final feature width
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=1000,            # classes
    attn_type="none",
    use_rope=False,
    cnn_stages=(3, 4, 6, 3),
    cnn_width=64,
    image_size=224,
    remat="none",
)
