"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 + MTP.

61L d_model=7168, 128 heads, MLA (q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128), first 3 layers dense (d_ff=18432), MoE d_ff=2048, vocab 129280,
sigmoid aux-loss-free routing.  [arXiv:2412.19437]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # MLA: effectively full heads from latent
    d_ff=18432,                # dense layers' FFN width
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    head_dim=192,              # nope + rope
    num_experts=256,
    num_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    first_k_dense=3,
    router_fn="sigmoid",
    mtp_depth=1,
    rope_theta=10000.0,
    optimizer="adafactor",     # factored 2nd moment: 671B state fits 16GB/chip
    remat="full",
)
