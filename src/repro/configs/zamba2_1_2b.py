"""zamba2-1.2b [hybrid] — Mamba2 backbone + one SHARED attention block.

38 mamba2 layers d_model=2048 (d_state 64), a shared full-attention+MLP block
(32 heads, d_ff=8192) invoked every 6 ssm layers with tied weights.
[arXiv:2411.15242]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    attn_every=6,
    tie_embeddings=True,
    sub_quadratic=True,
    remat="dots",
)
