"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.

60L d_model=5120, 128 heads, first 1 layer dense (d_ff=12288), MoE d_ff=1536,
vocab 102400, softmax routing.  [arXiv:2405.04434]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,
    vocab_size=102400,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    head_dim=192,
    num_experts=160,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1536,
    first_k_dense=1,
    router_fn="softmax",
    optimizer="adafactor",
    remat="full",
)
