"""qwen3-14b [dense] — GQA kv=8, qk_norm.

40L d_model=5120, 40 heads (head_dim 128), d_ff=17408, vocab 151936.
[hf:Qwen/Qwen3-14B family]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    remat="dots",
)
