"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768, d_state=128, expand=2 (d_inner=1536), headdim=64 -> 24 ssm
heads, conv width 4.  [arXiv:2405.21060]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    use_rope=False,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
    sub_quadratic=True,
    remat="dots",
)
