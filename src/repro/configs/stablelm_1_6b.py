"""stablelm-1.6b [dense] — full MHA (kv=32).

24L d_model=2048, 32 heads (head_dim 64), d_ff=5632, vocab 100352.
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    remat="none",
)
