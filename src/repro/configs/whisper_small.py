"""whisper-small [audio] — enc-dec backbone, conv frontend STUB.

12+12L d_model=768, 12 heads, d_ff=3072, vocab 51865, learned positions,
GELU MLP.  The conv1d/log-mel frontend is a stub per assignment:
``input_specs()`` provides 1500 precomputed frame embeddings.
[arXiv:2212.04356]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    use_rope=False,
    act_fn="gelu",
    gated_mlp=False,
    is_encoder_decoder=True,
    encoder_layers=12,
    num_frames=1500,
    remat="none",
)
