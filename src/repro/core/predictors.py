"""The paper's ML predictor suite: KNN, Decision Tree (CART), Random Forest.

The paper trains "multiple machine learning models (e.g., K-Nearest Neighbor,
Decision Tree, Random Forest Tree) for each specific task (i.e., power or
performance prediction)" and picks the best per task.  Reported: Random Forest
power MAPE 5.03% / R^2 0.9561; KNN cycles MAPE 5.94%.

Implementation notes:
  * Tree FITTING is plain numpy (recursive CART, variance-reduction splits) —
    fitting is host-side and tiny.
  * Tree INFERENCE is vectorized: flattened (feature, threshold, child, leaf)
    arrays walked level-by-level in jnp — jit-able so DSE sweeps can evaluate
    thousands of design points per millisecond (the paper's "fast" claim).
  * KNN is pure jnp (z-scored features, inverse-distance-weighted top-k).
  * Targets are trained in log space: power and especially cycles span orders
    of magnitude across the design space; MAPE is computed in linear space.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --- metrics -------------------------------------------------------------------------

def mape(y_true, y_pred) -> float:
    y_true, y_pred = np.asarray(y_true, np.float64), np.asarray(y_pred, np.float64)
    return float(np.mean(np.abs((y_pred - y_true) / np.maximum(np.abs(y_true), 1e-12))) * 100)


def r2_score(y_true, y_pred) -> float:
    y_true, y_pred = np.asarray(y_true, np.float64), np.asarray(y_pred, np.float64)
    ss_res = np.sum((y_true - y_pred) ** 2)
    ss_tot = np.sum((y_true - y_true.mean()) ** 2)
    return float(1.0 - ss_res / max(ss_tot, 1e-12))


# --- KNN -------------------------------------------------------------------------------

@dataclasses.dataclass
class KNNRegressor:
    k: int = 5
    log_target: bool = True
    _x: Optional[jnp.ndarray] = None
    _y: Optional[jnp.ndarray] = None
    _mu: Optional[jnp.ndarray] = None
    _sd: Optional[jnp.ndarray] = None

    def fit(self, X, y):
        # features span orders of magnitude (tokens, flops): distance in
        # log1p space, then z-scored
        X = jnp.log1p(jnp.abs(jnp.asarray(X, jnp.float32)))
        y = jnp.asarray(y, jnp.float32)
        self._mu = X.mean(0)
        self._sd = jnp.maximum(X.std(0), 1e-6)
        self._x = (X - self._mu) / self._sd
        self._y = jnp.log(jnp.maximum(y, 1e-12)) if self.log_target else y
        return self

    def predict(self, X):
        X = jnp.log1p(jnp.abs(jnp.asarray(X, jnp.float32)))
        X = (X - self._mu) / self._sd
        d2 = jnp.sum((X[:, None, :] - self._x[None, :, :]) ** 2, axis=-1)
        k = min(self.k, self._x.shape[0])
        neg_d2, idx = jax.lax.top_k(-d2, k)
        w = 1.0 / (jnp.sqrt(-neg_d2) + 1e-6)
        w = w / jnp.sum(w, axis=1, keepdims=True)
        pred = jnp.sum(w * self._y[idx], axis=1)
        return np.asarray(jnp.exp(pred) if self.log_target else pred)


# --- CART decision tree ------------------------------------------------------------------

@dataclasses.dataclass
class _TreeArrays:
    feature: np.ndarray      # int32 [n_nodes]; -1 => leaf
    threshold: np.ndarray    # float32
    left: np.ndarray         # int32 child indices
    right: np.ndarray
    value: np.ndarray        # float32 leaf predictions


def _build_cart(X: np.ndarray, y: np.ndarray, max_depth: int, min_leaf: int,
                rng: np.random.Generator, feature_frac: float) -> _TreeArrays:
    nodes: List[dict] = []

    def grow(idx: np.ndarray, depth: int) -> int:
        node_id = len(nodes)
        nodes.append({})
        yi = y[idx]
        if depth >= max_depth or idx.size < 2 * min_leaf or np.ptp(yi) < 1e-12:
            nodes[node_id] = {"leaf": float(yi.mean())}
            return node_id
        n_feat = X.shape[1]
        feats = rng.choice(n_feat, max(1, int(n_feat * feature_frac)), replace=False)
        best = None
        parent_var = yi.var() * idx.size
        for f in feats:
            xs = X[idx, f]
            order = np.argsort(xs, kind="stable")
            xs_s, ys_s = xs[order], yi[order]
            csum = np.cumsum(ys_s)
            csq = np.cumsum(ys_s ** 2)
            n = idx.size
            split_pts = np.nonzero(np.diff(xs_s) > 1e-12)[0] + 1
            split_pts = split_pts[(split_pts >= min_leaf) & (split_pts <= n - min_leaf)]
            if split_pts.size == 0:
                continue
            nl = split_pts.astype(np.float64)
            sl, sq_l = csum[split_pts - 1], csq[split_pts - 1]
            var_l = sq_l - sl ** 2 / nl
            sr, sq_r = csum[-1] - sl, csq[-1] - sq_l
            var_r = sq_r - sr ** 2 / (n - nl)
            score = var_l + var_r
            j = int(np.argmin(score))
            if best is None or score[j] < best[0]:
                thr = 0.5 * (xs_s[split_pts[j] - 1] + xs_s[split_pts[j]])
                best = (float(score[j]), int(f), float(thr))
        if best is None or best[0] >= parent_var - 1e-12:
            nodes[node_id] = {"leaf": float(yi.mean())}
            return node_id
        _, f, thr = best
        mask = X[idx, f] <= thr
        li = grow(idx[mask], depth + 1)
        ri = grow(idx[~mask], depth + 1)
        nodes[node_id] = {"feature": f, "threshold": thr, "left": li, "right": ri}
        return node_id

    grow(np.arange(X.shape[0]), 0)
    n = len(nodes)
    arr = _TreeArrays(
        feature=np.full(n, -1, np.int32), threshold=np.zeros(n, np.float32),
        left=np.zeros(n, np.int32), right=np.zeros(n, np.int32),
        value=np.zeros(n, np.float32))
    for i, nd in enumerate(nodes):
        if "leaf" in nd:
            arr.value[i] = nd["leaf"]
        else:
            arr.feature[i] = nd["feature"]
            arr.threshold[i] = nd["threshold"]
            arr.left[i] = nd["left"]
            arr.right[i] = nd["right"]
    return arr


@functools.partial(jax.jit, static_argnames=("max_depth",))
def _forest_predict_jnp(feat, thr, left, right, val, X, *, max_depth: int):
    """Level-synchronous walk of T stacked trees over N samples, jitted.

    feat/thr/left/right/val: [T, n_nodes] padded per-tree arrays; X: [N, F].
    Returns [T, N] leaf values.  One compiled kernel evaluates the whole
    forest x design-space batch — the paper's "microseconds per point" path.
    """
    T, N = feat.shape[0], X.shape[0]
    node = jnp.zeros((T, N), jnp.int32)
    sample = jnp.arange(N)[None, :]

    def step(node, _):
        f = jnp.take_along_axis(feat, node, axis=1)          # [T, N]
        is_leaf = f < 0
        x = X[sample, jnp.maximum(f, 0)]                     # [T, N]
        nxt = jnp.where(x <= jnp.take_along_axis(thr, node, axis=1),
                        jnp.take_along_axis(left, node, axis=1),
                        jnp.take_along_axis(right, node, axis=1))
        return jnp.where(is_leaf, node, nxt), None

    node, _ = jax.lax.scan(step, node, None, length=max_depth + 1)
    return jnp.take_along_axis(val, node, axis=1)


def _stack_trees(trees: List[_TreeArrays]) -> tuple:
    """Pad every tree to the forest's max node count and stack [T, n_nodes]."""
    m = max(t.feature.shape[0] for t in trees)
    pad = lambda a, fill: np.stack(
        [np.concatenate([x, np.full(m - x.shape[0], fill, x.dtype)])
         for x in a])
    return (pad([t.feature for t in trees], -1),
            pad([t.threshold for t in trees], 0.0),
            pad([t.left for t in trees], 0),
            pad([t.right for t in trees], 0),
            pad([t.value for t in trees], 0.0))


def _tree_predict_jnp(arr: _TreeArrays, X: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    out = _forest_predict_jnp(arr.feature[None], arr.threshold[None],
                              arr.left[None], arr.right[None], arr.value[None],
                              X, max_depth=max_depth)
    return out[0]


@dataclasses.dataclass
class DecisionTreeRegressor:
    max_depth: int = 12
    min_leaf: int = 2
    log_target: bool = True
    _tree: Optional[_TreeArrays] = None

    def fit(self, X, y, seed: int = 0):
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float64)
        yt = np.log(np.maximum(y, 1e-12)) if self.log_target else y
        self._tree = _build_cart(X, yt, self.max_depth, self.min_leaf,
                                 np.random.default_rng(seed), 1.0)
        return self

    def predict(self, X):
        p = _tree_predict_jnp(self._tree, jnp.asarray(X, jnp.float32), self.max_depth)
        p = np.asarray(p, np.float64)
        return np.exp(p) if self.log_target else p


@dataclasses.dataclass
class RandomForestRegressor:
    """The paper's random forest, extended with a warm-start surface for
    active-learning loops (``repro.dse_campaign.adaptive``):

    * ``partial_fit`` appends new rows and rebuilds only ``refresh_trees``
      tree slots per call (cycling through the forest), so per-round refits
      cost a fraction of a full ``fit`` while every tree eventually sees the
      accumulated data;
    * ``predict_log_stats`` exposes the per-tree prediction spread — the
      forest-variance exploration term of the acquisition function.

    Both are seeded-deterministic: tree slot ``t`` rebuilt on the ``c``-th
    ``partial_fit`` call draws its bootstrap from ``default_rng((seed, c,
    t))``, so replaying the same call sequence (same data, same seeds)
    reproduces the forest bitwise — the property that makes adaptive
    checkpoint/resume able to reconstruct the surrogate state exactly.
    """

    n_trees: int = 40
    max_depth: int = 12
    min_leaf: int = 2
    feature_frac: float = 0.7
    log_target: bool = True
    refresh_trees: Optional[int] = None      # per-partial_fit rebuild budget
    _trees: Optional[List[_TreeArrays]] = None
    _stacked: Optional[tuple] = None
    _X: Optional[np.ndarray] = None          # accumulated warm-start rows
    _y: Optional[np.ndarray] = None          # (transformed target space)
    _fit_calls: int = 0
    _next_slot: int = 0

    def _transform_y(self, y: np.ndarray) -> np.ndarray:
        return np.log(np.maximum(y, 1e-12)) if self.log_target else y

    def fit(self, X, y, seed: int = 0):
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float64)
        yt = self._transform_y(y)
        rng = np.random.default_rng(seed)
        self._trees = []
        n = X.shape[0]
        for _ in range(self.n_trees):
            boot = rng.integers(0, n, n)                    # bootstrap sample
            self._trees.append(_build_cart(X[boot], yt[boot], self.max_depth,
                                           self.min_leaf, rng, self.feature_frac))
        self._stacked = _stack_trees(self._trees)
        # a full fit resets the warm-start state (the incremental history is
        # superseded by the from-scratch forest)
        self._X, self._y = X, yt
        self._fit_calls, self._next_slot = 1, 0
        return self

    @property
    def n_rows(self) -> int:
        """Accumulated training rows (warm-start surface)."""
        return 0 if self._X is None else int(self._X.shape[0])

    def partial_fit(self, X, y, seed: int = 0):
        """Warm-start incremental refit: append ``(X, y)`` to the accumulated
        training set, then rebuild only ``refresh_trees`` tree slots
        (cyclically; ``None`` rebuilds all) on the FULL accumulated data.

        The first call builds the whole forest.  Each rebuilt slot's
        bootstrap is drawn from ``default_rng((seed, call_index, slot))`` —
        independent of which slots any other call rebuilt — so a replayed
        call sequence reproduces the forest bitwise (tested in
        ``tests/test_predictors.py``).  Untouched slots keep their exact
        tree arrays: they were fitted on less data, which is the
        staleness-for-speed trade the adaptive campaign's per-round refit
        makes.
        """
        X = np.asarray(X, np.float32)
        yt = self._transform_y(np.asarray(y, np.float64))
        if X.ndim != 2 or X.shape[0] != yt.shape[0]:
            raise ValueError(f"partial_fit shapes: X {X.shape} vs y {yt.shape}")
        if self._X is None:
            self._X, self._y = X, yt
        else:
            if X.shape[1] != self._X.shape[1]:
                raise ValueError(
                    f"partial_fit feature width {X.shape[1]} != accumulated "
                    f"{self._X.shape[1]}")
            self._X = np.concatenate([self._X, X])
            self._y = np.concatenate([self._y, yt])
        n = self._X.shape[0]
        if self._trees is None:
            self._trees = [None] * self.n_trees
            slots = list(range(self.n_trees))               # cold: build all
        else:
            k = self.n_trees if self.refresh_trees is None else min(
                max(int(self.refresh_trees), 1), self.n_trees)
            slots = [(self._next_slot + i) % self.n_trees for i in range(k)]
            self._next_slot = (slots[-1] + 1) % self.n_trees
        for t in slots:
            rng = np.random.default_rng((seed, self._fit_calls, t))
            boot = rng.integers(0, n, n)
            self._trees[t] = _build_cart(self._X[boot], self._y[boot],
                                         self.max_depth, self.min_leaf, rng,
                                         self.feature_frac)
        self._fit_calls += 1
        self._stacked = _stack_trees(self._trees)
        return self

    def _tree_preds(self, X) -> jnp.ndarray:
        if self._stacked is None:           # fitted by an older pickle/caller
            self._stacked = _stack_trees(self._trees)
        return _forest_predict_jnp(*self._stacked,
                                   jnp.asarray(X, jnp.float32),
                                   max_depth=self.max_depth)

    def predict(self, X):
        p = np.asarray(jnp.mean(self._tree_preds(X), axis=0), np.float64)
        return np.exp(p) if self.log_target else p

    def predict_log_stats(self, X) -> Tuple[np.ndarray, np.ndarray]:
        """Per-sample (mean, std) over the per-tree predictions, in the
        model's TRAINING target space (log space when ``log_target``) — the
        spread is the epistemic-uncertainty reading the adaptive campaign's
        exploration term consumes.  ``exp(mean)`` equals ``predict``."""
        preds = np.asarray(self._tree_preds(X), np.float64)   # [T, N]
        return preds.mean(axis=0), preds.std(axis=0)


MODELS = {
    "knn": lambda: KNNRegressor(k=5),
    "decision_tree": lambda: DecisionTreeRegressor(),
    "random_forest": lambda: RandomForestRegressor(),
}


def kfold_evaluate(model_name: str, X, y, k: int = 5, seed: int = 0) -> dict:
    """K-fold CV -> mean MAPE / R^2 (the paper's model-selection metric)."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float64)
    n = X.shape[0]
    idx = np.random.default_rng(seed).permutation(n)
    folds = np.array_split(idx, k)
    mapes, r2s = [], []
    for i in range(k):
        test = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        m = MODELS[model_name]()
        m.fit(X[train], y[train])
        pred = m.predict(X[test])
        mapes.append(mape(y[test], pred))
        r2s.append(r2_score(y[test], pred))
    return {"model": model_name, "mape": float(np.mean(mapes)),
            "r2": float(np.mean(r2s)), "mape_std": float(np.std(mapes))}
