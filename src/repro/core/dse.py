"""Design Space Exploration — the paper's end goal.

"identify the most appropriate GPGPU for CNN inferencing systems" ->
identify the most appropriate TPU slice (generation, chip count, mesh shape,
DVFS frequency) for a given (arch, shape) workload, under power / latency /
capacity constraints.

Two exploration modes mirror the paper's comparison:
  * slow path  — run the calibrated simulator on every candidate (stands in
    for "simulate / prototype each design"; requires a compiled census).
  * fast path  — rank ALL candidates with the trained ML predictors in one
    vectorized call (microseconds/point), then verify only the top-k with the
    slow path.  The speedup of fast vs slow is a paper deliverable.

Both paths run on struct-of-arrays batch primitives: a ``CandidateBatch``
packs the space into index/extent/frequency arrays, chip properties come from
``hw.CHIP_TABLE`` gathers, and ``costmodel.simulate_batch`` /
``features.extract_batch`` evaluate the whole space in single vector passes.
``slow_path_search_scalar`` preserves the per-candidate Python loop as the
agreement oracle (and the benchmark's "before" measurement).
"""

from __future__ import annotations

import dataclasses
import time
from collections.abc import Mapping
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import SHAPES, get_config
from repro.core import costmodel, features
from repro.hw import (CHIP_TABLE, CHIPS, ChipTable, get_chip, frequency_sweep,
                      normalize_mesh)


@dataclasses.dataclass(frozen=True)
class Candidate:
    chip: str
    n_chips: int
    mesh: Tuple[int, ...]
    freq_mhz: float


@dataclasses.dataclass
class Constraint:
    max_power_w: Optional[float] = None      # whole-slice power budget
    max_latency_s: Optional[float] = None
    min_hbm_fit: bool = True                 # state must fit HBM


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: ndarray fields
class CandidateBatch:
    """The design space packed struct-of-arrays for batch evaluation.

    ``candidates`` keeps the scalar view (report/API compatibility); the
    arrays are what the vectorized paths consume.  ``mesh_data``/``mesh_model``
    are the trailing two mesh extents (1 for unmeshed edge parts), matching
    ``features.extract``'s reading of ``mesh_shape``.  Array-only batches
    (``candidates=None``, e.g. ``SpaceSpec.slice(with_candidates=False)``)
    serve the zero-copy campaign paths that materialize ``Candidate``
    objects lazily for frontier survivors only.
    """

    candidates: Optional[Tuple[Candidate, ...]]
    chip_idx: np.ndarray                     # int32 [N] -> CHIP_TABLE row
    n_chips: np.ndarray                      # int64 [N]
    mesh_data: np.ndarray                    # int64 [N], mesh[-2] or 1
    mesh_model: np.ndarray                   # int64 [N], mesh[-1]
    freq_mhz: np.ndarray                     # float64 [N]
    mesh_pod: Optional[np.ndarray] = None    # int64 [N], prod(mesh[:-2]) or 1
    chip_cols: Optional[Dict[str, np.ndarray]] = None  # CHIP_TABLE.gather cache

    @classmethod
    def from_candidates(cls, space: Sequence[Candidate],
                        table: ChipTable = CHIP_TABLE) -> "CandidateBatch":
        space = tuple(space)
        chip_idx = table.indices([c.chip for c in space])
        axes = [normalize_mesh(c.mesh) for c in space]   # (pod, data, model)
        return cls(
            candidates=space,
            chip_idx=chip_idx,
            n_chips=np.asarray([c.n_chips for c in space], np.int64),
            mesh_data=np.asarray([a[1] for a in axes], np.int64),
            mesh_model=np.asarray([a[2] for a in axes], np.int64),
            freq_mhz=np.asarray([c.freq_mhz for c in space], np.float64),
            mesh_pod=np.asarray([a[0] for a in axes], np.int64),
            chip_cols=table.gather(chip_idx))

    def __len__(self) -> int:
        return int(np.shape(self.chip_idx)[0])

    def __getitem__(self, i: int) -> Candidate:
        if self.candidates is None:
            raise TypeError("array-only CandidateBatch (candidates=None); "
                            "materialize candidates from the owning SpaceSpec")
        return self.candidates[i]

    def pod_axis(self) -> np.ndarray:
        """The leading (pod) mesh extents; all-ones for batches built before
        the topology model (external constructors without ``mesh_pod``)."""
        if self.mesh_pod is not None:
            return self.mesh_pod
        return np.ones(len(self), np.int64)

    def hbm_bytes(self, table: ChipTable = CHIP_TABLE) -> np.ndarray:
        """Per-candidate HBM capacity, from the gather cache when present."""
        if self.chip_cols is not None:
            return self.chip_cols["hbm_bytes"]
        return table.hbm_bytes[self.chip_idx]


SpaceLike = Union[Sequence[Candidate], CandidateBatch]


def as_batch(space: SpaceLike) -> CandidateBatch:
    if isinstance(space, CandidateBatch):
        return space
    return CandidateBatch.from_candidates(space)


def default_space(freq_points: int = 12) -> List[Candidate]:
    """The accelerator design space: generation x slice size x DVFS point.

    The DVFS resolution matches ``hw.frequency_sweep``'s default 12 points
    (the paper's fine-grained 397-1590 MHz V100S sweep); batch evaluation
    made the denser default free.
    """
    out = []
    meshes = [(4, 4), (8, 8), (8, 16), (16, 16), (2, 16, 16)]
    for chip_name, chip in CHIPS.items():
        if chip.ici_bw == 0:
            meshes_c = [(1, 1)]
        else:
            meshes_c = meshes
        for mesh in meshes_c:
            n = int(np.prod(mesh))
            for f in frequency_sweep(chip_name, freq_points):
                out.append(Candidate(chip_name, n, mesh, f))
    return out


def default_space_batch(freq_points: int = 12) -> CandidateBatch:
    """``default_space`` packed as a ``CandidateBatch`` (list rides along in
    ``.candidates``)."""
    return CandidateBatch.from_candidates(default_space(freq_points))


def _scale_analysis(base_analysis: Dict, base_chips: int, cand: Candidate) -> Dict:
    """First-order rescale of a compiled census to a different slice size.

    flops/bytes scale ~1/chips (data/model parallel split); collective bytes
    grow with ring size: x (n-1)/n relative to base ring.  Also emits
    ``coll_payload_bytes`` — the payload with the base census's global ring
    factor un-applied — which the topology-aware simulator splits across
    mesh axes by its ``SimConfig.coll_model_frac``.
    """
    r = base_chips / cand.n_chips
    nb, nc = base_chips, cand.n_chips
    ring = ((nc - 1) / nc) / max((nb - 1) / nb, 1e-9) if nc > 1 else 0.0
    return {
        "flops": base_analysis["flops"] * r,
        "hbm_bytes": base_analysis["hbm_bytes"] * r,
        "collective_bytes": base_analysis["collective_bytes"] * r * ring,
        "wire_bytes": base_analysis["wire_bytes"] * r * ring,
        "coll_payload_bytes":
            base_analysis["wire_bytes"] * r / max((nb - 1) / nb, 1e-9),
    }


def _scale_analysis_batch(base_analysis: Dict, base_chips,
                          n_chips: np.ndarray, xp=np) -> Dict[str, np.ndarray]:
    """``_scale_analysis`` over a whole candidate array at once.

    ``base_analysis`` values and ``base_chips`` may themselves be arrays
    (broadcast against ``n_chips``) — that is how multi-workload sweeps tile
    W workloads x N candidates into one flat batch.  Thin alias of
    ``costmodel.scale_census`` (the single home of the scaling arithmetic,
    shared with the fused sweep paths), so the scalar oracle matches the
    default numpy float64 variant bitwise.
    """
    return costmodel.scale_census(base_analysis, base_chips, n_chips, xp=xp)


def feasibility_mask(batch: CandidateBatch, sim: costmodel.SimBatch,
                     constraint: Constraint, state_gb_per_device: float,
                     base_chips: int,
                     table: ChipTable = CHIP_TABLE) -> np.ndarray:
    """Vectorized constraint check: HBM fit, slice power budget, latency."""
    ok = np.ones(len(batch), bool)
    if constraint.min_hbm_fit:
        state_pd = state_gb_per_device * base_chips / batch.n_chips
        ok &= state_pd * 1e9 <= batch.hbm_bytes(table) * 0.9
    if constraint.max_power_w is not None:
        ok &= sim.power_w * batch.n_chips <= constraint.max_power_w
    if constraint.max_latency_s is not None:
        ok &= sim.latency_s <= constraint.max_latency_s
    return ok


# Feature layout the adaptive-campaign surrogates train on.  Candidate
# geometry first, then the chip-table columns the cost model actually
# consumes — every column is a pure function of the candidate index, so
# features computed from ``SpaceSpec.slice`` on any host/process are
# bitwise identical (the property adaptive resume and the distributed
# adaptive path rely on).
SURROGATE_FEATURES: Tuple[str, ...] = (
    "n_chips", "freq_mhz", "mesh_pod", "mesh_data", "mesh_model",
    "peak_flops_bf16", "hbm_bw", "hbm_bytes", "ici_bw",
    "tdp_watts", "idle_watts", "ici_hop_s",
)

_CHIP_FEATURES = SURROGATE_FEATURES[5:]


def surrogate_features(batch: CandidateBatch,
                       table: ChipTable = CHIP_TABLE) -> np.ndarray:
    """Pack a candidate batch into the ``[N, F]`` float32 feature matrix the
    adaptive campaign's forests consume (column order =
    ``SURROGATE_FEATURES``)."""
    cols = batch.chip_cols if batch.chip_cols is not None \
        else table.gather(batch.chip_idx)
    feats = [np.asarray(batch.n_chips, np.float64),
             np.asarray(batch.freq_mhz, np.float64),
             np.asarray(batch.pod_axis(), np.float64),
             np.asarray(batch.mesh_data, np.float64),
             np.asarray(batch.mesh_model, np.float64)]
    feats += [np.asarray(cols[f], np.float64) for f in _CHIP_FEATURES]
    return np.stack(feats, axis=1).astype(np.float32)


def predict_tile_scores(energy_model, latency_model, batch: CandidateBatch,
                        table: ChipTable = CHIP_TABLE
                        ) -> Tuple[np.ndarray, np.ndarray,
                                   np.ndarray, np.ndarray]:
    """Tile-level surrogate scoring entry point: one batched forest inference
    per model over the whole tile.  Returns ``(e_mu, e_sd, l_mu, l_sd)`` in
    LOG space (the forests train on log targets).  Models without a
    ``predict_log_stats`` surface degrade to ``log(predict)`` with zero
    spread, so point predictors still work (no exploration term)."""
    X = surrogate_features(batch, table)
    out = []
    for model in (energy_model, latency_model):
        stats = getattr(model, "predict_log_stats", None)
        if stats is not None:
            mu, sd = stats(X)
        else:
            mu = np.log(np.maximum(np.asarray(model.predict(X), np.float64),
                                   1e-300))
            sd = np.zeros_like(mu)
        out += [np.asarray(mu, np.float64), np.asarray(sd, np.float64)]
    return out[0], out[1], out[2], out[3]


class BatchSearchResults(Mapping):
    """Per-candidate results of a batched sweep, API-compatible with the old
    ``{cand: {"sim": SimResult, "feasible": bool}}`` dict.

    Rows are materialized into scalar ``SimResult`` objects lazily on access,
    so the batched search never pays a per-candidate Python cost for
    candidates nobody inspects.  The underlying arrays stay available as
    ``.sim`` / ``.feasible`` for array-native consumers.
    """

    def __init__(self, batch: CandidateBatch, sim: costmodel.SimBatch,
                 feasible: np.ndarray):
        self.batch = batch
        self.sim = sim
        self.feasible = feasible
        self._index: Optional[Dict[Candidate, int]] = None
        self._cache: Dict[int, Dict] = {}

    def __getitem__(self, cand: Candidate) -> Dict:
        if self._index is None:
            self._index = {c: i for i, c in enumerate(self.batch.candidates)}
        i = self._index[cand]
        if i not in self._cache:
            self._cache[i] = {"sim": self.sim.result(i),
                              "feasible": bool(self.feasible[i])}
        return self._cache[i]

    def __iter__(self):
        return iter(self.batch.candidates)

    def __len__(self) -> int:
        return len(self.batch)


def evaluate_space(base_analysis: Dict, base_chips: int, batch: CandidateBatch,
                   sim: costmodel.SimConfig = costmodel.SimConfig()
                   ) -> costmodel.SimBatch:
    """Scale the base census to every candidate and simulate the whole space
    in one vector pass.  The batch's mesh axes feed the topology-aware
    collective model, so same-chip-count factorizations score differently."""
    ana = _scale_analysis_batch(base_analysis, base_chips, batch.n_chips)
    return costmodel.simulate_batch(ana, batch.chip_idx, batch.n_chips,
                                    batch.freq_mhz, sim=sim,
                                    gathered=batch.chip_cols,
                                    mesh_pod=batch.pod_axis(),
                                    mesh_data=batch.mesh_data,
                                    mesh_model=batch.mesh_model)


def evaluate_workload_tile(workload: "Workload", batch: CandidateBatch,
                           constraint: "Constraint" = None,
                           sim: costmodel.SimConfig = costmodel.SimConfig(),
                           engine: str = "numpy"
                           ) -> Tuple[costmodel.SimBatch, np.ndarray]:
    """Evaluate one candidate tile for one workload: (SimBatch, feasible).

    The tile-friendly composition of ``evaluate_space`` + ``feasibility_mask``
    that streaming campaigns (``repro.dse_campaign``) call per chunk —
    evaluating a space tile by tile through this function is exactly
    equivalent to one big ``evaluate_space`` call on the concatenated batch.
    ``engine="jit"`` routes the simulate through ``simulate_batch_jit``
    (float32 on the default config; use the numpy engine when bitwise
    agreement with ``pareto_search`` matters).
    """
    if constraint is None:
        constraint = Constraint()
    if engine not in ("numpy", "jit"):
        raise ValueError(f"unknown engine {engine!r}; expected 'numpy' or "
                         "'jit' (the predictor fast path lives in "
                         "Campaign(evaluator='fast'))")
    if engine == "jit":
        ana = _scale_analysis_batch(workload.base_analysis, workload.base_chips,
                                    batch.n_chips)
        res = costmodel.simulate_batch_jit(ana, batch.chip_idx, batch.n_chips,
                                           batch.freq_mhz, sim=sim,
                                           mesh_pod=batch.pod_axis(),
                                           mesh_data=batch.mesh_data,
                                           mesh_model=batch.mesh_model)
    else:
        res = evaluate_space(workload.base_analysis, workload.base_chips,
                             batch, sim=sim)
    feasible = feasibility_mask(batch, res, constraint,
                                workload.state_gb_per_device,
                                workload.base_chips)
    return res, feasible


def slow_path_search(arch: str, shape_name: str, base_analysis: Dict,
                     base_chips: int, state_gb_per_device: float,
                     space: SpaceLike,
                     constraint: Constraint = Constraint(),
                     objective: str = "energy") -> Tuple[Candidate, Mapping, float]:
    """Exhaustive simulator sweep (the paper's 'slow' baseline), evaluated as
    ONE batched pass.  Returns (best, per-candidate results, wall_seconds)."""
    t0 = time.perf_counter()
    batch = as_batch(space)
    if not len(batch):
        return None, {}, time.perf_counter() - t0
    res = evaluate_space(base_analysis, base_chips, batch)
    feasible = feasibility_mask(batch, res, constraint, state_gb_per_device,
                                base_chips)
    score = res.energy_j if objective == "energy" else res.latency_s
    score = np.where(feasible, score, np.inf)
    i = int(np.argmin(score))
    best = batch.candidates[i] if np.isfinite(score[i]) else None
    results = BatchSearchResults(batch, res, feasible)
    return best, results, time.perf_counter() - t0


def slow_path_search_scalar(arch: str, shape_name: str, base_analysis: Dict,
                            base_chips: int, state_gb_per_device: float,
                            space: SpaceLike,
                            constraint: Constraint = Constraint(),
                            objective: str = "energy") -> Tuple[Candidate, Dict, float]:
    """The seed per-candidate Python loop, kept as the agreement oracle for
    ``slow_path_search`` and the benchmark's scalar baseline.  Each candidate
    passes its ``mesh`` into the scalar simulator, mirroring the batched
    path's topology threading — scalar stays the ground truth."""
    if isinstance(space, CandidateBatch):
        space = space.candidates
    t0 = time.perf_counter()
    best, best_score, results = None, float("inf"), {}
    for cand in space:
        chip = get_chip(cand.chip)
        ana = _scale_analysis(base_analysis, base_chips, cand)
        res = costmodel.simulate(ana, chip, cand.n_chips,
                                 freq_mhz=cand.freq_mhz, mesh=cand.mesh)
        state_pd = state_gb_per_device * base_chips / cand.n_chips
        fits = state_pd * 1e9 <= chip.hbm_bytes * 0.9
        ok = ((not constraint.min_hbm_fit or fits)
              and (constraint.max_power_w is None
                   or res.power_w * cand.n_chips <= constraint.max_power_w)
              and (constraint.max_latency_s is None
                   or res.latency_s <= constraint.max_latency_s))
        score = (res.energy_j if objective == "energy" else res.latency_s)
        results[cand] = {"sim": res, "feasible": ok}
        if ok and score < best_score:
            best, best_score = cand, score
    return best, results, time.perf_counter() - t0


def predict_space(cfg, shape, power_model, cycles_model, batch: CandidateBatch,
                  constraint: Constraint = Constraint()
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                             np.ndarray, np.ndarray]:
    """The fast path's shared scoring core: predictor-based
    (energy_j, latency_s, feasible, power_w_per_chip, cycles) for a batch.

    Single home for the prediction arithmetic and constraint masks so
    ``fast_path_search`` and campaign fast-path tiles cannot diverge.
    """
    X = features.extract_batch(cfg, shape, batch.chip_idx, batch.n_chips,
                               batch.mesh_data, batch.mesh_model,
                               batch.freq_mhz)
    p_watts = np.asarray(power_model.predict(X))     # per chip
    p_cycles = np.asarray(cycles_model.predict(X))
    n = batch.n_chips.astype(np.float64)
    lat = p_cycles / (batch.freq_mhz * 1e6)
    energy = p_watts * n * lat
    feasible = np.ones(len(batch), bool)
    if constraint.max_power_w is not None:
        feasible &= (p_watts * n) <= constraint.max_power_w
    if constraint.max_latency_s is not None:
        feasible &= lat <= constraint.max_latency_s
    if constraint.min_hbm_fit:
        need = cfg.param_count() * 2 * (3.0 if shape.kind == "train" else 1.0)
        feasible &= need / n <= batch.hbm_bytes() * 0.9
    return energy, lat, feasible, p_watts, p_cycles


def fast_path_search(arch: str, shape_name: str, power_model, cycles_model,
                     space: SpaceLike,
                     constraint: Constraint = Constraint(),
                     objective: str = "energy",
                     verify_top_k: int = 5,
                     slow_verify=None) -> Tuple[Candidate, Dict, float]:
    """Predictor-ranked search (the paper's fast path).

    The design matrix comes from ``features.extract_batch`` (one vector pass,
    no per-candidate Python), predictions and constraint masks are array ops,
    and only the top-k survivors are optionally re-verified with the
    simulator (callable ``slow_verify(cand) -> SimResult``)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t0 = time.perf_counter()
    batch = as_batch(space)
    energy, lat, feasible, p_watts, p_cycles = predict_space(
        cfg, shape, power_model, cycles_model, batch, constraint)
    score = energy if objective == "energy" else lat
    score = np.where(feasible, score, np.inf)
    order = np.argsort(score)
    elapsed = time.perf_counter() - t0
    top = [batch.candidates[i] for i in order[:verify_top_k]
           if np.isfinite(score[i])]
    if not top:
        return None, {}, elapsed
    best = top[0]
    if slow_verify is not None:
        verified = [(slow_verify(c), c) for c in top]
        key = ((lambda rc: rc[0].energy_j) if objective == "energy"
               else (lambda rc: rc[0].latency_s))
        best = min(verified, key=key)[1]
    details = {"predicted_power_w": p_watts, "predicted_cycles": p_cycles,
               "order": order[:verify_top_k]}
    return best, details, elapsed


# --- Multi-objective / multi-workload sweep -----------------------------------


@dataclasses.dataclass(frozen=True)
class Workload:
    """One (arch, shape) cell to sweep: its compiled census + footprint."""

    arch: str
    shape: str
    base_analysis: Dict
    base_chips: int
    state_gb_per_device: float = 0.0


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: ndarray fields
class ParetoFrontier:
    """Energy/latency frontier of one workload over the candidate space."""

    workload: Workload
    candidates: Tuple[Candidate, ...]        # frontier members
    energy_j: np.ndarray                     # [F], aligned with candidates
    latency_s: np.ndarray                    # [F]
    indices: np.ndarray                      # [F] rows into the swept batch
    feasible_count: int

    def __len__(self) -> int:
        return len(self.candidates)


def pareto_mask(energy: np.ndarray, latency: np.ndarray,
                feasible: np.ndarray) -> np.ndarray:
    """Non-dominated feasible points of the (energy, latency) minimization,
    as a boolean mask.

    Skyline sweep — sort by (latency, energy) and keep the running energy
    minimum — O(N log N) time, O(N) memory, so it survives the
    orders-of-magnitude space scaling the batched engine is built for.
    j dominates i iff j is feasible, <= on both axes, strictly better on
    one; equal (energy, latency) duplicates do not dominate each other.
    """
    e = np.asarray(energy, np.float64)
    l = np.asarray(latency, np.float64)
    feas = np.asarray(feasible, bool)
    mask = np.zeros(e.shape, bool)
    idx = np.flatnonzero(feas)
    if idx.size == 0:
        return mask
    order = np.lexsort((e[idx], l[idx]))
    es, ls = e[idx][order], l[idx][order]
    # min energy over all strictly-smaller latencies (inf for the first group)
    first = np.searchsorted(ls, ls, side="left")
    prefix_min = np.minimum.accumulate(es)
    best_before = np.where(first > 0, prefix_min[np.maximum(first - 1, 0)],
                           np.inf)
    # survive: not beaten by a faster point (strict latency, <= energy) and
    # tied-latency points only if they hold the group's energy minimum
    nondom = (es < best_before) & (es <= es[first])
    mask[idx[order[nondom]]] = True
    return mask


def pareto_search(workloads: Union[Workload, Sequence[Workload]],
                  space: SpaceLike,
                  constraint: Constraint = Constraint()
                  ) -> Dict[Tuple[str, str], ParetoFrontier]:
    """Multi-objective DSE: the energy/latency Pareto frontier per workload.

    All W workloads x N candidates are evaluated in ONE ``simulate_batch``
    call by tiling the candidate arrays and broadcasting each workload's
    census across its tile — sweeping another workload costs no extra Python.
    Returns ``{(arch, shape): ParetoFrontier}``.
    """
    if isinstance(workloads, Workload):
        workloads = [workloads]
    keys = [(wl.arch, wl.shape) for wl in workloads]
    if len(set(keys)) != len(keys):
        raise ValueError(f"duplicate (arch, shape) workload keys in {keys}; "
                         "disambiguate (e.g. suffix the shape with the pod "
                         "tag) — results are keyed by (arch, shape)")
    batch = as_batch(space)
    n = len(batch)
    w = len(workloads)
    if w == 0:
        return {}
    tile = lambda a: np.tile(np.asarray(a), w)
    rep = lambda vals: np.repeat(np.asarray(vals, np.float64), n)
    base = {k: rep([wl.base_analysis[k] for wl in workloads])
            for k in ("flops", "hbm_bytes", "collective_bytes", "wire_bytes")}
    base_chips = rep([wl.base_chips for wl in workloads])
    ana = _scale_analysis_batch(base, base_chips, tile(batch.n_chips))
    gathered = ({k: tile(batch.chip_cols[k])
                 for k in costmodel.SIM_GATHER_FIELDS}
                if batch.chip_cols is not None else None)
    sim = costmodel.simulate_batch(ana, tile(batch.chip_idx),
                                   tile(batch.n_chips), tile(batch.freq_mhz),
                                   gathered=gathered,
                                   mesh_pod=tile(batch.pod_axis()),
                                   mesh_data=tile(batch.mesh_data),
                                   mesh_model=tile(batch.mesh_model))
    out = {}
    for wi, wl in enumerate(workloads):
        sl = slice(wi * n, (wi + 1) * n)
        row = costmodel.SimBatch(**{
            f.name: getattr(sim, f.name)[sl]
            for f in dataclasses.fields(costmodel.SimBatch)})
        feasible = feasibility_mask(batch, row, constraint,
                                    wl.state_gb_per_device, wl.base_chips)
        mask = pareto_mask(row.energy_j, row.latency_s, feasible)
        idx = np.flatnonzero(mask)
        order = idx[np.argsort(row.latency_s[idx])]
        out[(wl.arch, wl.shape)] = ParetoFrontier(
            workload=wl,
            candidates=tuple(batch.candidates[i] for i in order),
            energy_j=row.energy_j[order],
            latency_s=row.latency_s[order],
            indices=order,
            feasible_count=int(feasible.sum()))
    return out
