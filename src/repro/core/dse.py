"""Design Space Exploration — the paper's end goal.

"identify the most appropriate GPGPU for CNN inferencing systems" ->
identify the most appropriate TPU slice (generation, chip count, mesh shape,
DVFS frequency) for a given (arch, shape) workload, under power / latency /
capacity constraints.

Two exploration modes mirror the paper's comparison:
  * slow path  — run the calibrated simulator on every candidate (stands in
    for "simulate / prototype each design"; requires a compiled census).
  * fast path  — rank ALL candidates with the trained ML predictors in one
    vectorized call (microseconds/point), then verify only the top-k with the
    slow path.  The speedup of fast vs slow is a paper deliverable.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import SHAPES, get_config
from repro.core import costmodel, features
from repro.hw import CHIPS, get_chip, frequency_sweep


@dataclasses.dataclass(frozen=True)
class Candidate:
    chip: str
    n_chips: int
    mesh: Tuple[int, ...]
    freq_mhz: float


@dataclasses.dataclass
class Constraint:
    max_power_w: Optional[float] = None      # whole-slice power budget
    max_latency_s: Optional[float] = None
    min_hbm_fit: bool = True                 # state must fit HBM


def default_space(freq_points: int = 6) -> List[Candidate]:
    """The accelerator design space: generation x slice size x DVFS point."""
    out = []
    meshes = [(4, 4), (8, 8), (8, 16), (16, 16), (2, 16, 16)]
    for chip_name, chip in CHIPS.items():
        if chip.ici_bw == 0:
            meshes_c = [(1, 1)]
        else:
            meshes_c = meshes
        for mesh in meshes_c:
            n = int(np.prod(mesh))
            for f in frequency_sweep(chip_name, freq_points):
                out.append(Candidate(chip_name, n, mesh, f))
    return out


def _scale_analysis(base_analysis: Dict, base_chips: int, cand: Candidate) -> Dict:
    """First-order rescale of a compiled census to a different slice size.

    flops/bytes scale ~1/chips (data/model parallel split); collective bytes
    grow with ring size: x (n-1)/n relative to base ring.
    """
    r = base_chips / cand.n_chips
    nb, nc = base_chips, cand.n_chips
    ring = ((nc - 1) / nc) / max((nb - 1) / nb, 1e-9) if nc > 1 else 0.0
    return {
        "flops": base_analysis["flops"] * r,
        "hbm_bytes": base_analysis["hbm_bytes"] * r,
        "collective_bytes": base_analysis["collective_bytes"] * r * ring,
        "wire_bytes": base_analysis["wire_bytes"] * r * ring,
    }


def slow_path_search(arch: str, shape_name: str, base_analysis: Dict,
                     base_chips: int, state_gb_per_device: float,
                     space: List[Candidate],
                     constraint: Constraint = Constraint(),
                     objective: str = "energy") -> Tuple[Candidate, Dict, float]:
    """Exhaustive simulator sweep (the paper's 'slow' baseline). Returns
    (best, per-candidate results, wall_seconds)."""
    t0 = time.perf_counter()
    best, best_score, results = None, float("inf"), {}
    for cand in space:
        chip = get_chip(cand.chip)
        ana = _scale_analysis(base_analysis, base_chips, cand)
        res = costmodel.simulate(ana, chip, cand.n_chips, freq_mhz=cand.freq_mhz)
        state_pd = state_gb_per_device * base_chips / cand.n_chips
        fits = state_pd * 1e9 <= chip.hbm_bytes * 0.9
        ok = ((not constraint.min_hbm_fit or fits)
              and (constraint.max_power_w is None
                   or res.power_w * cand.n_chips <= constraint.max_power_w)
              and (constraint.max_latency_s is None
                   or res.latency_s <= constraint.max_latency_s))
        score = (res.energy_j if objective == "energy" else res.latency_s)
        results[cand] = {"sim": res, "feasible": ok}
        if ok and score < best_score:
            best, best_score = cand, score
    return best, results, time.perf_counter() - t0


def fast_path_search(arch: str, shape_name: str, power_model, cycles_model,
                     space: List[Candidate],
                     constraint: Constraint = Constraint(),
                     objective: str = "energy",
                     verify_top_k: int = 5,
                     slow_verify=None) -> Tuple[Candidate, Dict, float]:
    """Predictor-ranked search (the paper's fast path).

    One vectorized predict over the whole space, rank by predicted objective,
    optionally re-verify the top-k with the simulator (callable
    ``slow_verify(cand) -> SimResult``)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t0 = time.perf_counter()
    X = np.asarray([features.extract(cfg, shape, get_chip(c.chip), c.n_chips,
                                     mesh_shape=c.mesh, freq_mhz=c.freq_mhz)
                    for c in space], np.float32)
    p_watts = power_model.predict(X)                 # per chip
    p_cycles = cycles_model.predict(X)
    freqs = np.asarray([c.freq_mhz for c in space]) * 1e6
    n = np.asarray([c.n_chips for c in space], np.float64)
    lat = p_cycles / freqs
    energy = p_watts * n * lat
    feasible = np.ones(len(space), bool)
    if constraint.max_power_w is not None:
        feasible &= (p_watts * n) <= constraint.max_power_w
    if constraint.max_latency_s is not None:
        feasible &= lat <= constraint.max_latency_s
    if constraint.min_hbm_fit:
        for i, c in enumerate(space):
            chip = get_chip(c.chip)
            need = cfg.param_count() * 2 * (3.0 if shape.kind == "train" else 1.0)
            feasible[i] &= need / c.n_chips <= chip.hbm_bytes * 0.9
    score = energy if objective == "energy" else lat
    score = np.where(feasible, score, np.inf)
    order = np.argsort(score)
    elapsed = time.perf_counter() - t0
    top = [space[i] for i in order[:verify_top_k] if np.isfinite(score[i])]
    if not top:
        return None, {}, elapsed
    best = top[0]
    if slow_verify is not None:
        verified = [(slow_verify(c), c) for c in top]
        key = ((lambda rc: rc[0].energy_j) if objective == "energy"
               else (lambda rc: rc[0].latency_s))
        best = min(verified, key=key)[1]
    details = {"predicted_power_w": p_watts, "predicted_cycles": p_cycles,
               "order": order[:verify_top_k]}
    return best, details, elapsed
