"""Offloading analyzer — the paper's §IV future work, implemented.

"devise approaches to discern whether offloading would adhere to the
constraints or if executing locally would be more advantageous" — given an
edge device, a cloud slice, and a network (bandwidth, RTT), decide where an
inference request should run, for latency or energy.

Energy accounting on the edge device includes radio transmit/receive power;
cloud energy is booked separately (operator view) so both the
battery-centric and the total-energy decisions are reported.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core import costmodel
from repro.hw import chip_index, get_chip


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    bandwidth_bps: float = 100e6       # uplink
    downlink_bps: float = 300e6
    rtt_s: float = 0.04
    tx_power_w: float = 1.2            # radio while transmitting
    rx_power_w: float = 0.8


@dataclasses.dataclass(frozen=True)
class OffloadDecision:
    local_latency_s: float
    remote_latency_s: float
    local_energy_j: float              # edge-battery energy
    remote_edge_energy_j: float        # edge-battery energy when offloading
    remote_total_energy_j: float       # + cloud slice energy
    choose_remote_latency: bool
    choose_remote_battery: bool

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(local_analysis: Dict, remote_analysis: Dict,
            request_bytes: float, response_bytes: float,
            net: NetworkSpec = NetworkSpec(),
            local_chip: str = "tpu-edge", remote_chip: str = "tpu-v5e",
            remote_chips: int = 4) -> OffloadDecision:
    """local/remote_analysis: HxA censuses of the SAME workload compiled for
    each target (per-device)."""
    local = costmodel.simulate(local_analysis, get_chip(local_chip), 1)
    remote = costmodel.simulate(remote_analysis, get_chip(remote_chip), remote_chips)

    t_net = (request_bytes / net.bandwidth_bps
             + response_bytes / net.downlink_bps + net.rtt_s)
    remote_latency = remote.latency_s + t_net
    e_radio = (request_bytes / net.bandwidth_bps) * net.tx_power_w \
        + (response_bytes / net.downlink_bps) * net.rx_power_w
    idle_during_wait = get_chip(local_chip).idle_watts * remote_latency
    remote_edge_energy = e_radio + idle_during_wait
    return OffloadDecision(
        local_latency_s=local.latency_s,
        remote_latency_s=remote_latency,
        local_energy_j=local.energy_j,
        remote_edge_energy_j=remote_edge_energy,
        remote_total_energy_j=remote_edge_energy + remote.energy_j,
        choose_remote_latency=remote_latency < local.latency_s,
        choose_remote_battery=remote_edge_energy < local.energy_j,
    )


def sweep_bandwidth(local_analysis: Dict, remote_analysis: Dict,
                    request_bytes: float, response_bytes: float,
                    bandwidths_bps, net: NetworkSpec = NetworkSpec(),
                    local_chip: str = "tpu-edge", remote_chip: str = "tpu-v5e",
                    remote_chips: int = 4) -> Dict[str, np.ndarray]:
    """``analyze`` over a whole uplink-bandwidth array in one batched pass.

    Both compute censuses are simulated once via ``simulate_batch`` (a
    two-row batch); the network leg is elementwise over ``bandwidths_bps``.
    Returns arrays keyed like ``OffloadDecision`` fields plus
    ``bandwidth_bps``.
    """
    bw = np.asarray(bandwidths_bps, np.float64)
    wire = costmodel.wire_bytes
    sim = costmodel.simulate_batch(
        {"flops": np.asarray([local_analysis["flops"],
                              remote_analysis["flops"]]),
         "hbm_bytes": np.asarray([local_analysis["hbm_bytes"],
                                  remote_analysis["hbm_bytes"]]),
         "wire_bytes": np.asarray([wire(local_analysis),
                                   wire(remote_analysis)])},
        np.asarray([chip_index(local_chip), chip_index(remote_chip)]),
        np.asarray([1, remote_chips]))
    t_up = request_bytes / bw
    t_down = response_bytes / net.downlink_bps
    remote_latency = sim.latency_s[1] + t_up + t_down + net.rtt_s
    e_radio = t_up * net.tx_power_w + t_down * net.rx_power_w
    remote_edge_energy = e_radio + get_chip(local_chip).idle_watts * remote_latency
    ones = np.ones_like(bw)
    return {
        "bandwidth_bps": bw,
        "local_latency_s": sim.latency_s[0] * ones,
        "remote_latency_s": remote_latency,
        "local_energy_j": sim.energy_j[0] * ones,
        "remote_edge_energy_j": remote_edge_energy,
        "remote_total_energy_j": remote_edge_energy + sim.energy_j[1],
        "choose_remote_latency": remote_latency < sim.latency_s[0],
        "choose_remote_battery": remote_edge_energy < sim.energy_j[0],
    }
