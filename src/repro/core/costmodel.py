"""Analytical ground-truth simulator: latency (cycles) + power + energy.

Role in the reproduction: the paper trains its predictors against POWER and
CYCLES measured on a real V100S.  This container is CPU-only, so the measured
target is replaced by a deterministic, calibrated analytical model over the
compiled artifact (the "slow-accurate path"): HxA census -> three roofline
terms -> partial-overlap latency -> CMOS power.  The ML predictors (fast path)
never see any of this — they predict from static early-design features only,
exactly like the paper.

Latency model:
  t_comp = flops / (peak * mxu_derate)        t_mem = hbm_bytes / hbm_bw
  t_coll = wire_bytes / (ici_bw * links_used)
  latency = max(t) + (1 - overlap) * (sum(t) - max(t))
    -- overlap=0.8: XLA latency-hiding overlaps most, not all, of the
       non-dominant terms.

Power model (per chip):
  P = P_idle + (TDP - P_idle) * (w_mxu*u_mxu + w_hbm*u_hbm + w_ici*u_ici)
      * (f/f_max)^3            [DVFS cubic, paper ref [5]]
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.hw import ChipSpec, get_chip


@dataclasses.dataclass(frozen=True)
class SimConfig:
    overlap: float = 0.8
    w_mxu: float = 0.55
    w_hbm: float = 0.30
    w_ici: float = 0.15
    links_used: int = 2          # links concurrently busy per collective step


@dataclasses.dataclass(frozen=True)
class SimResult:
    t_compute: float
    t_memory: float
    t_collective: float
    latency_s: float
    cycles: float
    utilization: float
    power_w: float               # per chip
    energy_j: float              # whole slice
    bottleneck: str

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(analysis: Dict, chip: ChipSpec, n_chips: int) -> Dict:
    """The §Roofline contract.  ``analysis`` holds PER-DEVICE HxA numbers, so
    term = per_device_quantity / per_chip_rate == global / (chips * rate)."""
    t_comp = analysis["flops"] / chip.peak_flops_bf16
    t_mem = analysis["hbm_bytes"] / chip.hbm_bw
    t_coll = (analysis["collective_bytes"] / chip.ici_bw
              if chip.ici_bw else 0.0)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return {**terms, "dominant": dom,
            "hlo_flops_per_device": analysis["flops"],
            "hlo_bytes_per_device": analysis["hbm_bytes"],
            "collective_bytes_per_device": analysis["collective_bytes"],
            "n_chips": n_chips}


def simulate(analysis: Dict, chip: ChipSpec, n_chips: int,
             freq_mhz: Optional[float] = None,
             sim: SimConfig = SimConfig()) -> SimResult:
    """Slow-accurate path: deterministic latency/power from a compiled cell."""
    if freq_mhz is None:
        freq_mhz = chip.nominal_freq_mhz
    chip_f = chip.at_frequency(freq_mhz)
    t_comp = analysis["flops"] / chip_f.peak_flops_bf16
    t_mem = analysis["hbm_bytes"] / chip_f.hbm_bw
    wire = analysis.get("wire_bytes", analysis.get("collective_bytes", 0.0))
    t_coll = wire / (chip_f.ici_bw * max(sim.links_used, 1)) if chip_f.ici_bw else 0.0

    ts = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(ts, key=ts.get)
    t_max = ts[dom]
    latency = t_max + (1.0 - sim.overlap) * (sum(ts.values()) - t_max)
    latency = max(latency, 1e-9)

    u_mxu = t_comp / latency
    u_hbm = t_mem / latency
    u_ici = t_coll / latency
    util = sim.w_mxu * u_mxu + sim.w_hbm * u_hbm + sim.w_ici * u_ici
    power = chip.dynamic_power(freq_mhz, util)
    cycles = latency * freq_mhz * 1e6
    return SimResult(
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        latency_s=latency, cycles=cycles, utilization=u_mxu,
        power_w=power, energy_j=power * latency * n_chips,
        bottleneck=dom)


def simulate_by_name(analysis: Dict, chip_name: str, n_chips: int,
                     freq_mhz: Optional[float] = None) -> SimResult:
    return simulate(analysis, get_chip(chip_name), n_chips, freq_mhz)
