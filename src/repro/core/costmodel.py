"""Analytical ground-truth simulator: latency (cycles) + power + energy.

Role in the reproduction: the paper trains its predictors against POWER and
CYCLES measured on a real V100S.  This container is CPU-only, so the measured
target is replaced by a deterministic, calibrated analytical model over the
compiled artifact (the "slow-accurate path"): HxA census -> three roofline
terms -> partial-overlap latency -> CMOS power.  The ML predictors (fast path)
never see any of this — they predict from static early-design features only,
exactly like the paper.

Latency model:
  t_comp = flops / (peak * mxu_derate)        t_mem = hbm_bytes / hbm_bw
  t_coll = wire_bytes / (ici_bw * links_used)
  latency = max(t) + (1 - overlap) * (sum(t) - max(t))
    -- overlap=0.8: XLA latency-hiding overlaps most, not all, of the
       non-dominant terms.

Power model (per chip):
  P = P_idle + (TDP - P_idle) * (w_mxu*u_mxu + w_hbm*u_hbm + w_ici*u_ici)
      * (f/f_max)^3            [DVFS cubic, paper ref [5]]
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import numpy as np

from repro.hw import CHIP_TABLE, ChipSpec, ChipTable, get_chip


@dataclasses.dataclass(frozen=True)
class SimConfig:
    overlap: float = 0.8
    w_mxu: float = 0.55
    w_hbm: float = 0.30
    w_ici: float = 0.15
    links_used: int = 2          # links concurrently busy per collective step


@dataclasses.dataclass(frozen=True)
class SimResult:
    t_compute: float
    t_memory: float
    t_collective: float
    latency_s: float
    cycles: float
    utilization: float
    power_w: float               # per chip
    energy_j: float              # whole slice
    bottleneck: str

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def wire_bytes(analysis: Dict):
    """Collective wire-bytes of a census, with the documented fallback chain
    (wire_bytes -> collective_bytes -> 0) shared by every simulate variant."""
    return analysis.get("wire_bytes", analysis.get("collective_bytes", 0.0))


def roofline_terms(analysis: Dict, chip: ChipSpec, n_chips: int) -> Dict:
    """The §Roofline contract.  ``analysis`` holds PER-DEVICE HxA numbers, so
    term = per_device_quantity / per_chip_rate == global / (chips * rate)."""
    t_comp = analysis["flops"] / chip.peak_flops_bf16
    t_mem = analysis["hbm_bytes"] / chip.hbm_bw
    t_coll = (analysis["collective_bytes"] / chip.ici_bw
              if chip.ici_bw else 0.0)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return {**terms, "dominant": dom,
            "hlo_flops_per_device": analysis["flops"],
            "hlo_bytes_per_device": analysis["hbm_bytes"],
            "collective_bytes_per_device": analysis["collective_bytes"],
            "n_chips": n_chips}


def simulate(analysis: Dict, chip: ChipSpec, n_chips: int,
             freq_mhz: Optional[float] = None,
             sim: SimConfig = SimConfig()) -> SimResult:
    """Slow-accurate path: deterministic latency/power from a compiled cell."""
    if freq_mhz is None:
        freq_mhz = chip.nominal_freq_mhz
    chip_f = chip.at_frequency(freq_mhz)
    t_comp = analysis["flops"] / chip_f.peak_flops_bf16
    t_mem = analysis["hbm_bytes"] / chip_f.hbm_bw
    wire = wire_bytes(analysis)
    t_coll = wire / (chip_f.ici_bw * max(sim.links_used, 1)) if chip_f.ici_bw else 0.0

    ts = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(ts, key=ts.get)
    t_max = ts[dom]
    latency = t_max + (1.0 - sim.overlap) * (sum(ts.values()) - t_max)
    latency = max(latency, 1e-9)

    u_mxu = t_comp / latency
    u_hbm = t_mem / latency
    u_ici = t_coll / latency
    util = sim.w_mxu * u_mxu + sim.w_hbm * u_hbm + sim.w_ici * u_ici
    power = chip.dynamic_power(freq_mhz, util)
    cycles = latency * freq_mhz * 1e6
    return SimResult(
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        latency_s=latency, cycles=cycles, utilization=u_mxu,
        power_w=power, energy_j=power * latency * n_chips,
        bottleneck=dom)


def simulate_by_name(analysis: Dict, chip_name: str, n_chips: int,
                     freq_mhz: Optional[float] = None) -> SimResult:
    return simulate(analysis, get_chip(chip_name), n_chips, freq_mhz)


# --- Batched (struct-of-arrays) path ------------------------------------------
# Same arithmetic as ``simulate`` applied to whole candidate arrays at once:
# chip properties are gathered from CHIP_TABLE by index, every step is an
# elementwise array op, so a full DSE space is one pass of vector code instead
# of a Python loop.  numpy float64 by default (bitwise-matches the scalar
# path); pass ``xp=jax.numpy`` for a jit-able accelerator variant.

BOTTLENECKS = ("compute", "memory", "collective")

# the chip-table columns simulate_batch actually gathers; pre-gathered
# ``gathered`` dicts only need (and multi-workload tiling only tiles) these
SIM_GATHER_FIELDS = ("nominal_freq_mhz", "min_freq_mhz", "max_freq_mhz",
                     "peak_flops_bf16", "hbm_bw", "ici_bw", "tdp_watts",
                     "idle_watts")


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: ndarray fields
class SimBatch:
    """``SimResult`` over N candidates, field-per-array."""

    t_compute: np.ndarray
    t_memory: np.ndarray
    t_collective: np.ndarray
    latency_s: np.ndarray
    cycles: np.ndarray
    utilization: np.ndarray
    power_w: np.ndarray              # per chip
    energy_j: np.ndarray             # whole slice
    bottleneck_idx: np.ndarray       # index into BOTTLENECKS

    def __len__(self) -> int:
        return int(np.shape(self.latency_s)[0])

    def bottleneck(self, i: int) -> str:
        return BOTTLENECKS[int(self.bottleneck_idx[i])]

    def result(self, i: int) -> SimResult:
        """Materialize one row as the scalar dataclass."""
        return SimResult(
            t_compute=float(self.t_compute[i]),
            t_memory=float(self.t_memory[i]),
            t_collective=float(self.t_collective[i]),
            latency_s=float(self.latency_s[i]),
            cycles=float(self.cycles[i]),
            utilization=float(self.utilization[i]),
            power_w=float(self.power_w[i]),
            energy_j=float(self.energy_j[i]),
            bottleneck=self.bottleneck(i))


def simulate_batch(analysis: Dict, chip_idx, n_chips,
                   freq_mhz=None, sim: SimConfig = SimConfig(),
                   table: ChipTable = CHIP_TABLE, xp=np,
                   gathered: Optional[Dict] = None) -> SimBatch:
    """Vectorized ``simulate`` over arrays of candidates.

    ``analysis`` holds per-device arrays (or scalars, broadcast) of flops /
    hbm_bytes / collective_bytes / wire_bytes; ``chip_idx`` indexes
    ``table``; ``n_chips`` / ``freq_mhz`` are per-candidate arrays.  With the
    default ``xp=np`` the arithmetic is float64 and agrees with the scalar
    path to machine precision; any array namespace with the numpy API (e.g.
    ``jax.numpy``) works, making the body jit-able.  ``gathered`` (from
    ``table.gather(chip_idx)``) skips the per-call column gathers when the
    same candidate batch is swept repeatedly.
    """
    n_chips = xp.asarray(n_chips)
    if gathered is None:
        gathered = {f: xp.asarray(getattr(table, f))[xp.asarray(chip_idx)]
                    for f in SIM_GATHER_FIELDS}
    nominal = gathered["nominal_freq_mhz"]
    f_min = gathered["min_freq_mhz"]
    f_max = gathered["max_freq_mhz"]
    if freq_mhz is None:
        freq_mhz = nominal
    freq = xp.clip(xp.asarray(freq_mhz), f_min, f_max)

    peak = gathered["peak_flops_bf16"] * (freq / nominal)
    hbm_bw = gathered["hbm_bw"]
    ici_bw = gathered["ici_bw"]

    flops = xp.asarray(analysis["flops"])
    hbm_bytes = xp.asarray(analysis["hbm_bytes"])
    wire = xp.asarray(wire_bytes(analysis))

    t_comp = flops / peak
    t_mem = hbm_bytes / hbm_bw
    has_ici = ici_bw > 0
    t_coll = xp.where(
        has_ici, wire / (xp.where(has_ici, ici_bw, 1.0) * max(sim.links_used, 1)),
        0.0)

    ts = xp.stack([t_comp, t_mem, t_coll])         # BOTTLENECKS order
    dom = xp.argmax(ts, axis=0)
    t_max = xp.max(ts, axis=0)
    latency = t_max + (1.0 - sim.overlap) * (xp.sum(ts, axis=0) - t_max)
    latency = xp.maximum(latency, 1e-9)

    # same association as the scalar path (w * (t/latency), summed in the
    # same order); residual disagreement is 1 ulp from pow() vs array **3
    util = (sim.w_mxu * (t_comp / latency) + sim.w_hbm * (t_mem / latency)
            + sim.w_ici * (t_coll / latency))
    util = xp.clip(util, 0.0, 1.0)
    tdp = gathered["tdp_watts"]
    idle = gathered["idle_watts"]
    power = idle + (tdp - idle) * util * (freq / f_max) ** 3
    power = xp.minimum(power, tdp)

    # cycles use the caller's (unclamped) frequency, matching ``simulate``;
    # freq_mhz was defaulted to nominal above if the caller passed None
    cycles = latency * xp.asarray(freq_mhz) * 1e6
    return SimBatch(
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        latency_s=latency, cycles=cycles, utilization=t_comp / latency,
        power_w=power, energy_j=power * latency * n_chips,
        bottleneck_idx=dom)


@functools.lru_cache(maxsize=None)
def _jit_simulate_batch(sim: SimConfig):
    import jax
    import jax.numpy as jnp

    def run(flops, hbm_bytes, wire_bytes, chip_idx, n_chips, freq_mhz):
        batch = simulate_batch(
            {"flops": flops, "hbm_bytes": hbm_bytes, "wire_bytes": wire_bytes},
            chip_idx, n_chips, freq_mhz, sim=sim, xp=jnp)
        return dataclasses.asdict(batch)

    return jax.jit(run)


def simulate_batch_jit(analysis: Dict, chip_idx, n_chips, freq_mhz,
                       sim: SimConfig = SimConfig()) -> SimBatch:
    """jit-compiled ``simulate_batch`` on the default JAX backend.

    Accelerator path for very large spaces; float32 under the repo's default
    x64-disabled config, so expect ~1e-6 relative agreement rather than the
    numpy path's exact match.
    """
    out = _jit_simulate_batch(sim)(
        analysis["flops"], analysis["hbm_bytes"], wire_bytes(analysis),
        np.asarray(chip_idx, np.int32), n_chips, freq_mhz)
    return SimBatch(**{k: np.asarray(v) for k, v in out.items()})
