"""Analytical ground-truth simulator: latency (cycles) + power + energy.

Role in the reproduction: the paper trains its predictors against POWER and
CYCLES measured on a real V100S.  This container is CPU-only, so the measured
target is replaced by a deterministic, calibrated analytical model over the
compiled artifact (the "slow-accurate path"): HxA census -> three roofline
terms -> partial-overlap latency -> CMOS power.  The ML predictors (fast path)
never see any of this — they predict from static early-design features only,
exactly like the paper.

Latency model:
  t_comp = flops / (peak * mxu_derate)        t_mem = hbm_bytes / hbm_bw
  t_coll -- topology-aware when the candidate's mesh is known: the collective
  payload splits into a data-parallel share (hierarchical ring all-reduce over
  the pod x data axes) and a model-parallel share (all-gather/reduce-scatter
  on the model axis), each axis costing

      t_axis = bytes_axis * (k - 1)/k / (ici_bw * links_axis)
               + 2 * (k - 1) * hop_s

  with per-axis link counts from ``hw.axis_link_counts`` (ring vs. torus
  wraparound, chip link-budget degradation).  Without a mesh the legacy
  scalar fallback ``wire_bytes / (ici_bw * links_used)`` applies
  (``SimConfig.links_used`` is deprecated and only feeds this fallback).
  latency = max(t) + (1 - overlap) * (sum(t) - max(t))
    -- overlap=0.8: XLA latency-hiding overlaps most, not all, of the
       non-dominant terms.

Power model (per chip):
  P = P_idle + (TDP - P_idle) * (w_mxu*u_mxu + w_hbm*u_hbm + w_ici*u_ici)
      * (f/f_max)^3            [DVFS cubic, paper ref [5]]
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, Optional

import numpy as np

from repro.hw import (CHIP_TABLE, ChipSpec, ChipTable, axis_link_counts,
                      get_chip, normalize_mesh)

# default fraction of the collective payload attributed to model-parallel
# collectives (activation all-gather/reduce-scatter on the model axis); the
# remainder is the data-parallel all-reduce share.  The split happens in ONE
# place (``collective_payload``), always from the simulating ``SimConfig``'s
# ``coll_model_frac`` — analyses carry only the un-split payload.
COLL_MODEL_FRAC = 0.5

# bump when the cost model's arithmetic changes on purpose: the CI frontier
# compare (benchmarks/compare_campaign.py) only gates hypervolume regressions
# between artifacts produced by the SAME model version
SIM_MODEL_VERSION = 2   # 1 = mesh-agnostic links_used; 2 = topology-aware


@dataclasses.dataclass(frozen=True)
class SimConfig:
    overlap: float = 0.8
    w_mxu: float = 0.55
    w_hbm: float = 0.30
    w_ici: float = 0.15
    links_used: int = 2          # DEPRECATED: only the mesh-less fallback
                                 # path reads this; topology-aware simulation
                                 # derives links from hw.axis_link_counts
    coll_model_frac: float = COLL_MODEL_FRAC

    def __post_init__(self):
        if self.links_used != 2:
            warnings.warn(
                "SimConfig.links_used is deprecated: the collective model is "
                "topology-aware (pass the candidate mesh to simulate / "
                "simulate_batch); links_used only affects the mesh-less "
                "fallback path", DeprecationWarning, stacklevel=2)


@dataclasses.dataclass(frozen=True)
class SimResult:
    t_compute: float
    t_memory: float
    t_collective: float
    latency_s: float
    cycles: float
    utilization: float
    power_w: float               # per chip
    energy_j: float              # whole slice
    bottleneck: str

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def wire_bytes(analysis: Dict):
    """Collective wire-bytes of a census, with the documented fallback chain
    (wire_bytes -> collective_bytes -> 0) shared by every simulate variant."""
    return analysis.get("wire_bytes", analysis.get("collective_bytes", 0.0))


def _raw_payload(analysis: Dict, n_chips, xp):
    """Un-ring-factored collective payload bytes per device.

    Prefers the ``coll_payload_bytes`` key that ``dse._scale_analysis``
    emits; otherwise derives it from ``wire_bytes`` by un-applying the
    whole-slice ring factor (n-1)/n that first-order scaling applied."""
    if "coll_payload_bytes" in analysis:
        return xp.asarray(analysis["coll_payload_bytes"])
    wire = xp.asarray(wire_bytes(analysis))
    n = xp.asarray(n_chips) * 1.0
    ring = xp.where(n > 1, (n - 1.0) / xp.maximum(n, 1.0), 1.0)
    return wire / ring


def collective_payload(analysis: Dict, n_chips, frac: float, xp=np):
    """(data_bytes, model_bytes) collective payload split for a candidate.

    The ONLY place the data/model split happens, so the simulating
    ``SimConfig.coll_model_frac`` is always honored.  Identical IEEE
    expressions in scalar and array form, so every simulate variant splits
    bitwise the same."""
    payload = _raw_payload(analysis, n_chips, xp)
    return payload * (1.0 - frac), payload * frac


def _axis_collective_time(payload, extent, links, ici_bw, hop_s, xp):
    """Ring time of one mesh axis: bandwidth term + per-step hop latency.

    t = payload * (k-1)/k / (ici_bw * links) + 2*(k-1)*hop_s
    (reduce-scatter + all-gather, k-1 ring steps each).  Inactive axes
    (k <= 1), axes moving zero bytes, linkless chips, and zero-bandwidth
    chips contribute 0."""
    k = xp.asarray(extent) * 1.0
    links = xp.asarray(links) * 1.0
    bw = xp.asarray(ici_bw) * 1.0
    live = (k > 1) & (links > 0) & (bw > 0) & (xp.asarray(payload) > 0)
    denom = xp.where(live, bw * xp.where(links > 0, links, 1.0), 1.0)
    t_bw = payload * (k - 1.0) / xp.maximum(k, 1.0) / denom
    t_hop = 2.0 * (k - 1.0) * hop_s
    return xp.where(live, t_bw + t_hop, 0.0)


def topology_collective_time(p_data, p_model, mesh_pod, mesh_data, mesh_model,
                             ici_bw, ici_links, links_per_axis, hop_s, xp=np):
    """Topology-aware collective time over the (pod, data, model) mesh axes.

    The model-parallel payload rides the model axis; the data-parallel
    payload does a hierarchical ring all-reduce: a full ring over the data
    axis, then the pod axis on the 1/k_data shard that survives the first
    reduce-scatter stage.  Per-axis link counts come from
    ``hw.axis_link_counts`` (torus wraparound, link-budget degradation)."""
    lp, ld, lm = axis_link_counts(mesh_pod, mesh_data, mesh_model,
                                  ici_links, links_per_axis, xp=xp)
    kd = xp.asarray(mesh_data) * 1.0
    return (_axis_collective_time(p_data, mesh_data, ld, ici_bw, hop_s, xp)
            + _axis_collective_time(p_data / xp.maximum(kd, 1.0), mesh_pod,
                                    lp, ici_bw, hop_s, xp)
            + _axis_collective_time(p_model, mesh_model, lm, ici_bw, hop_s,
                                    xp))


def roofline_terms(analysis: Dict, chip: ChipSpec, n_chips: int) -> Dict:
    """The §Roofline contract.  ``analysis`` holds PER-DEVICE HxA numbers, so
    term = per_device_quantity / per_chip_rate == global / (chips * rate)."""
    t_comp = analysis["flops"] / chip.peak_flops_bf16
    t_mem = analysis["hbm_bytes"] / chip.hbm_bw
    t_coll = (analysis["collective_bytes"] / chip.ici_bw
              if chip.ici_bw else 0.0)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return {**terms, "dominant": dom,
            "hlo_flops_per_device": analysis["flops"],
            "hlo_bytes_per_device": analysis["hbm_bytes"],
            "collective_bytes_per_device": analysis["collective_bytes"],
            "n_chips": n_chips}


def simulate(analysis: Dict, chip: ChipSpec, n_chips: int,
             freq_mhz: Optional[float] = None,
             sim: SimConfig = SimConfig(), mesh=None) -> SimResult:
    """Slow-accurate path: deterministic latency/power from a compiled cell.

    With ``mesh`` (the candidate's mesh tuple) the collective term is the
    topology-aware per-axis model; without it the deprecated mesh-agnostic
    ``links_used`` fallback applies.  The topology arithmetic runs through
    the same xp-generic helpers as ``simulate_batch``, so scalar and batch
    agree bitwise."""
    if freq_mhz is None:
        freq_mhz = chip.nominal_freq_mhz
    chip_f = chip.at_frequency(freq_mhz)
    t_comp = analysis["flops"] / chip_f.peak_flops_bf16
    t_mem = analysis["hbm_bytes"] / chip_f.hbm_bw
    wire = wire_bytes(analysis)
    if mesh is not None:
        pod, data, model = normalize_mesh(mesh)
        p_d, p_m = collective_payload(analysis, n_chips, sim.coll_model_frac)
        t_coll = float(topology_collective_time(
            p_d, p_m, pod, data, model, chip_f.ici_bw, chip_f.ici_links,
            chip_f.ici_links_per_axis, chip_f.ici_hop_s))
    else:
        t_coll = (wire / (chip_f.ici_bw * max(sim.links_used, 1))
                  if chip_f.ici_bw else 0.0)

    ts = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(ts, key=ts.get)
    t_max = ts[dom]
    latency = t_max + (1.0 - sim.overlap) * (sum(ts.values()) - t_max)
    latency = max(latency, 1e-9)

    u_mxu = t_comp / latency
    u_hbm = t_mem / latency
    u_ici = t_coll / latency
    util = sim.w_mxu * u_mxu + sim.w_hbm * u_hbm + sim.w_ici * u_ici
    power = chip.dynamic_power(freq_mhz, util)
    cycles = latency * freq_mhz * 1e6
    return SimResult(
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        latency_s=latency, cycles=cycles, utilization=u_mxu,
        power_w=power, energy_j=power * latency * n_chips,
        bottleneck=dom)


def simulate_by_name(analysis: Dict, chip_name: str, n_chips: int,
                     freq_mhz: Optional[float] = None, mesh=None) -> SimResult:
    return simulate(analysis, get_chip(chip_name), n_chips, freq_mhz,
                    mesh=mesh)


# --- Batched (struct-of-arrays) path ------------------------------------------
# Same arithmetic as ``simulate`` applied to whole candidate arrays at once:
# chip properties are gathered from CHIP_TABLE by index, every step is an
# elementwise array op, so a full DSE space is one pass of vector code instead
# of a Python loop.  numpy float64 by default (bitwise-matches the scalar
# path); pass ``xp=jax.numpy`` for a jit-able accelerator variant.

BOTTLENECKS = ("compute", "memory", "collective")

# the chip-table columns simulate_batch actually gathers; pre-gathered
# ``gathered`` dicts only need (and multi-workload tiling only tiles) these
SIM_GATHER_FIELDS = ("nominal_freq_mhz", "min_freq_mhz", "max_freq_mhz",
                     "peak_flops_bf16", "hbm_bw", "ici_bw", "tdp_watts",
                     "idle_watts", "ici_links", "ici_links_per_axis",
                     "ici_hop_s")


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: ndarray fields
class SimBatch:
    """``SimResult`` over N candidates, field-per-array."""

    t_compute: np.ndarray
    t_memory: np.ndarray
    t_collective: np.ndarray
    latency_s: np.ndarray
    cycles: np.ndarray
    utilization: np.ndarray
    power_w: np.ndarray              # per chip
    energy_j: np.ndarray             # whole slice
    bottleneck_idx: np.ndarray       # index into BOTTLENECKS

    def __len__(self) -> int:
        return int(np.shape(self.latency_s)[0])

    def bottleneck(self, i: int) -> str:
        return BOTTLENECKS[int(self.bottleneck_idx[i])]

    def result(self, i: int) -> SimResult:
        """Materialize one row as the scalar dataclass."""
        return SimResult(
            t_compute=float(self.t_compute[i]),
            t_memory=float(self.t_memory[i]),
            t_collective=float(self.t_collective[i]),
            latency_s=float(self.latency_s[i]),
            cycles=float(self.cycles[i]),
            utilization=float(self.utilization[i]),
            power_w=float(self.power_w[i]),
            energy_j=float(self.energy_j[i]),
            bottleneck=self.bottleneck(i))


def simulate_batch(analysis: Dict, chip_idx, n_chips,
                   freq_mhz=None, sim: SimConfig = SimConfig(),
                   table: ChipTable = CHIP_TABLE, xp=np,
                   gathered: Optional[Dict] = None,
                   mesh_pod=None, mesh_data=None, mesh_model=None) -> SimBatch:
    """Vectorized ``simulate`` over arrays of candidates.

    ``analysis`` holds per-device arrays (or scalars, broadcast) of flops /
    hbm_bytes / collective_bytes / wire_bytes (plus the optional
    ``coll_payload_bytes`` un-split collective payload); ``chip_idx``
    indexes ``table``; ``n_chips`` / ``freq_mhz`` are per-candidate arrays.
    With ``mesh_data``/``mesh_model`` (and optionally ``mesh_pod``) the
    collective term is the topology-aware per-axis model; without them the
    deprecated ``links_used`` fallback applies.  With the default ``xp=np``
    the arithmetic is float64 and agrees with the scalar path to machine
    precision; any array namespace with the numpy API (e.g. ``jax.numpy``)
    works, making the body jit-able.  ``gathered`` (from
    ``table.gather(chip_idx)``) skips the per-call column gathers when the
    same candidate batch is swept repeatedly.
    """
    n_chips = xp.asarray(n_chips)
    if gathered is None:
        gathered = {f: xp.asarray(getattr(table, f))[xp.asarray(chip_idx)]
                    for f in SIM_GATHER_FIELDS}
    nominal = gathered["nominal_freq_mhz"]
    f_min = gathered["min_freq_mhz"]
    f_max = gathered["max_freq_mhz"]
    if freq_mhz is None:
        freq_mhz = nominal
    freq = xp.clip(xp.asarray(freq_mhz), f_min, f_max)

    peak = gathered["peak_flops_bf16"] * (freq / nominal)
    hbm_bw = gathered["hbm_bw"]
    ici_bw = gathered["ici_bw"]

    flops = xp.asarray(analysis["flops"])
    hbm_bytes = xp.asarray(analysis["hbm_bytes"])
    wire = xp.asarray(wire_bytes(analysis))

    t_comp = flops / peak
    t_mem = hbm_bytes / hbm_bw
    if mesh_model is not None:
        if mesh_data is None:
            raise ValueError("mesh_model without mesh_data; pass both "
                             "trailing mesh axes (mesh_pod is optional)")
        if mesh_pod is None:
            mesh_pod = xp.ones(xp.shape(xp.asarray(mesh_model)), xp.asarray(
                mesh_model).dtype)
        p_d, p_m = collective_payload(analysis, n_chips,
                                      sim.coll_model_frac, xp=xp)
        t_coll = topology_collective_time(
            p_d, p_m, mesh_pod, mesh_data, mesh_model, ici_bw,
            gathered["ici_links"], gathered["ici_links_per_axis"],
            gathered["ici_hop_s"], xp=xp)
    else:
        has_ici = ici_bw > 0
        t_coll = xp.where(
            has_ici,
            wire / (xp.where(has_ici, ici_bw, 1.0) * max(sim.links_used, 1)),
            0.0)

    ts = xp.stack([t_comp, t_mem, t_coll])         # BOTTLENECKS order
    dom = xp.argmax(ts, axis=0)
    t_max = xp.max(ts, axis=0)
    latency = t_max + (1.0 - sim.overlap) * (xp.sum(ts, axis=0) - t_max)
    latency = xp.maximum(latency, 1e-9)

    # same association as the scalar path (w * (t/latency), summed in the
    # same order); residual disagreement is 1 ulp from pow() vs array **3
    util = (sim.w_mxu * (t_comp / latency) + sim.w_hbm * (t_mem / latency)
            + sim.w_ici * (t_coll / latency))
    util = xp.clip(util, 0.0, 1.0)
    tdp = gathered["tdp_watts"]
    idle = gathered["idle_watts"]
    power = idle + (tdp - idle) * util * (freq / f_max) ** 3
    power = xp.minimum(power, tdp)

    # cycles use the caller's (unclamped) frequency, matching ``simulate``;
    # freq_mhz was defaulted to nominal above if the caller passed None
    cycles = latency * xp.asarray(freq_mhz) * 1e6
    return SimBatch(
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        latency_s=latency, cycles=cycles, utilization=t_comp / latency,
        power_w=power, energy_j=power * latency * n_chips,
        bottleneck_idx=dom)


@functools.lru_cache(maxsize=None)
def _jit_simulate_batch(sim: SimConfig, with_mesh: bool):
    import jax
    import jax.numpy as jnp

    if with_mesh:
        def run(flops, hbm_bytes, payload, chip_idx, n_chips,
                freq_mhz, mesh_pod, mesh_data, mesh_model):
            batch = simulate_batch(
                {"flops": flops, "hbm_bytes": hbm_bytes,
                 "coll_payload_bytes": payload, "wire_bytes": payload},
                chip_idx, n_chips, freq_mhz, sim=sim, xp=jnp,
                mesh_pod=mesh_pod, mesh_data=mesh_data, mesh_model=mesh_model)
            return dataclasses.asdict(batch)
    else:
        def run(flops, hbm_bytes, wire_bytes, chip_idx, n_chips, freq_mhz):
            batch = simulate_batch(
                {"flops": flops, "hbm_bytes": hbm_bytes,
                 "wire_bytes": wire_bytes},
                chip_idx, n_chips, freq_mhz, sim=sim, xp=jnp)
            return dataclasses.asdict(batch)

    return jax.jit(run)


def simulate_batch_jit(analysis: Dict, chip_idx, n_chips, freq_mhz,
                       sim: SimConfig = SimConfig(),
                       mesh_pod=None, mesh_data=None,
                       mesh_model=None) -> SimBatch:
    """jit-compiled ``simulate_batch`` on the default JAX backend.

    Accelerator path for very large spaces; float32 under the repo's default
    x64-disabled config, so expect ~1e-6 relative agreement rather than the
    numpy path's exact match.  Passing ``mesh_data``/``mesh_model`` (and
    optionally ``mesh_pod``) selects the topology-aware collective model;
    the un-split payload is derived in float64 numpy BEFORE entering the
    jit, then split in-trace by ``sim.coll_model_frac`` like every other
    path.
    """
    if mesh_model is not None:
        mesh_model = np.asarray(mesh_model, np.int32)
        mesh_data = np.asarray(mesh_data, np.int32)
        mesh_pod = (np.ones_like(mesh_model) if mesh_pod is None
                    else np.asarray(mesh_pod, np.int32))
        payload = _raw_payload(analysis, n_chips, np)
        out = _jit_simulate_batch(sim, True)(
            analysis["flops"], analysis["hbm_bytes"], payload,
            np.asarray(chip_idx, np.int32), n_chips, freq_mhz,
            mesh_pod, mesh_data, mesh_model)
    else:
        out = _jit_simulate_batch(sim, False)(
            analysis["flops"], analysis["hbm_bytes"], wire_bytes(analysis),
            np.asarray(chip_idx, np.int32), n_chips, freq_mhz)
    return SimBatch(**{k: np.asarray(v) for k, v in out.items()})
