"""Analytical ground-truth simulator: latency (cycles) + power + energy.

Role in the reproduction: the paper trains its predictors against POWER and
CYCLES measured on a real V100S.  This container is CPU-only, so the measured
target is replaced by a deterministic, calibrated analytical model over the
compiled artifact (the "slow-accurate path"): HxA census -> three roofline
terms -> partial-overlap latency -> CMOS power.  The ML predictors (fast path)
never see any of this — they predict from static early-design features only,
exactly like the paper.

Latency model:
  t_comp = flops / (peak * mxu_derate)        t_mem = hbm_bytes / hbm_bw
  t_coll -- topology-aware when the candidate's mesh is known: the collective
  payload splits into a data-parallel share (hierarchical ring all-reduce over
  the pod x data axes) and a model-parallel share (all-gather/reduce-scatter
  on the model axis), each axis costing

      t_axis = bytes_axis * (k - 1)/k / (ici_bw * links_axis)
               + 2 * (k - 1) * hop_s

  with per-axis link counts from ``hw.axis_link_counts`` (ring vs. torus
  wraparound, chip link-budget degradation).  Without a mesh the fixed
  mesh-less approximation ``wire_bytes / (ici_bw * MESHLESS_LINKS)``
  applies (the former ``SimConfig.links_used`` knob is gone; see
  ``SIM_MODEL_VERSION``).
  latency = max(t) + (1 - overlap) * (sum(t) - max(t))
    -- overlap=0.8: XLA latency-hiding overlaps most, not all, of the
       non-dominant terms.

Power model (per chip):
  P = P_idle + (TDP - P_idle) * (w_mxu*u_mxu + w_hbm*u_hbm + w_ici*u_ici)
      * (f/f_max)^3            [DVFS cubic, paper ref [5]]
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import numpy as np

from repro.hw import (CHIP_TABLE, ChipSpec, ChipTable, axis_link_counts,
                      get_chip, normalize_mesh)

# default fraction of the collective payload attributed to model-parallel
# collectives (activation all-gather/reduce-scatter on the model axis); the
# remainder is the data-parallel all-reduce share.  The split happens in ONE
# place (``collective_payload``), always from the simulating ``SimConfig``'s
# ``coll_model_frac`` — analyses carry only the un-split payload.
COLL_MODEL_FRAC = 0.5

# bump when the cost model's arithmetic changes on purpose: the CI frontier
# compare (benchmarks/compare_campaign.py) only gates hypervolume regressions
# between artifacts produced by the SAME model version.  Checkpoints,
# fabric worker configs and FrontierIndex artifacts all stamp this number
# and refuse to load across a mismatch.
# 1 = mesh-agnostic links_used; 2 = topology-aware collectives;
# 3 = SimConfig.links_used removed (mesh-less simulation is the fixed
#     MESHLESS_LINKS approximation, no longer a config knob)
SIM_MODEL_VERSION = 3

# link count of the fixed mesh-less approximation: censuses simulated
# without a candidate mesh (dry-run base pods, offload slices, rooflines)
# price collectives as ``wire_bytes / (ici_bw * MESHLESS_LINKS)``.  This is
# the old ``links_used`` default frozen in place — candidate sweeps always
# carry a mesh and never touch it.
MESHLESS_LINKS = 2


@dataclasses.dataclass(frozen=True)
class SimConfig:
    overlap: float = 0.8
    w_mxu: float = 0.55
    w_hbm: float = 0.30
    w_ici: float = 0.15
    coll_model_frac: float = COLL_MODEL_FRAC


@dataclasses.dataclass(frozen=True)
class SimResult:
    t_compute: float
    t_memory: float
    t_collective: float
    latency_s: float
    cycles: float
    utilization: float
    power_w: float               # per chip
    energy_j: float              # whole slice
    bottleneck: str

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def wire_bytes(analysis: Dict):
    """Collective wire-bytes of a census, with the documented fallback chain
    (wire_bytes -> collective_bytes -> 0) shared by every simulate variant."""
    return analysis.get("wire_bytes", analysis.get("collective_bytes", 0.0))


def _raw_payload(analysis: Dict, n_chips, xp):
    """Un-ring-factored collective payload bytes per device.

    Prefers the ``coll_payload_bytes`` key that ``dse._scale_analysis``
    emits; otherwise derives it from ``wire_bytes`` by un-applying the
    whole-slice ring factor (n-1)/n that first-order scaling applied."""
    if "coll_payload_bytes" in analysis:
        return xp.asarray(analysis["coll_payload_bytes"])
    wire = xp.asarray(wire_bytes(analysis))
    n = xp.asarray(n_chips) * 1.0
    ring = xp.where(n > 1, (n - 1.0) / xp.maximum(n, 1.0), 1.0)
    return wire / ring


def collective_payload(analysis: Dict, n_chips, frac: float, xp=np):
    """(data_bytes, model_bytes) collective payload split for a candidate.

    The ONLY place the data/model split happens, so the simulating
    ``SimConfig.coll_model_frac`` is always honored.  Identical IEEE
    expressions in scalar and array form, so every simulate variant splits
    bitwise the same."""
    payload = _raw_payload(analysis, n_chips, xp)
    return payload * (1.0 - frac), payload * frac


def _axis_collective_time(payload, extent, links, ici_bw, hop_s, xp):
    """Ring time of one mesh axis: bandwidth term + per-step hop latency.

    t = payload * (k-1)/k / (ici_bw * links) + 2*(k-1)*hop_s
    (reduce-scatter + all-gather, k-1 ring steps each).  Inactive axes
    (k <= 1), axes moving zero bytes, linkless chips, and zero-bandwidth
    chips contribute 0."""
    k = xp.asarray(extent) * 1.0
    links = xp.asarray(links) * 1.0
    bw = xp.asarray(ici_bw) * 1.0
    live = (k > 1) & (links > 0) & (bw > 0) & (xp.asarray(payload) > 0)
    denom = xp.where(live, bw * xp.where(links > 0, links, 1.0), 1.0)
    t_bw = payload * (k - 1.0) / xp.maximum(k, 1.0) / denom
    t_hop = 2.0 * (k - 1.0) * hop_s
    return xp.where(live, t_bw + t_hop, 0.0)


def topology_collective_time(p_data, p_model, mesh_pod, mesh_data, mesh_model,
                             ici_bw, ici_links, links_per_axis, hop_s, xp=np):
    """Topology-aware collective time over the (pod, data, model) mesh axes.

    The model-parallel payload rides the model axis; the data-parallel
    payload does a hierarchical ring all-reduce: a full ring over the data
    axis, then the pod axis on the 1/k_data shard that survives the first
    reduce-scatter stage.  Per-axis link counts come from
    ``hw.axis_link_counts`` (torus wraparound, link-budget degradation)."""
    lp, ld, lm = axis_link_counts(mesh_pod, mesh_data, mesh_model,
                                  ici_links, links_per_axis, xp=xp)
    kd = xp.asarray(mesh_data) * 1.0
    return (_axis_collective_time(p_data, mesh_data, ld, ici_bw, hop_s, xp)
            + _axis_collective_time(p_data / xp.maximum(kd, 1.0), mesh_pod,
                                    lp, ici_bw, hop_s, xp)
            + _axis_collective_time(p_model, mesh_model, lm, ici_bw, hop_s,
                                    xp))


def roofline_terms(analysis: Dict, chip: ChipSpec, n_chips: int) -> Dict:
    """The §Roofline contract.  ``analysis`` holds PER-DEVICE HxA numbers, so
    term = per_device_quantity / per_chip_rate == global / (chips * rate)."""
    t_comp = analysis["flops"] / chip.peak_flops_bf16
    t_mem = analysis["hbm_bytes"] / chip.hbm_bw
    t_coll = (analysis["collective_bytes"] / chip.ici_bw
              if chip.ici_bw else 0.0)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return {**terms, "dominant": dom,
            "hlo_flops_per_device": analysis["flops"],
            "hlo_bytes_per_device": analysis["hbm_bytes"],
            "collective_bytes_per_device": analysis["collective_bytes"],
            "n_chips": n_chips}


def simulate(analysis: Dict, chip: ChipSpec, n_chips: int,
             freq_mhz: Optional[float] = None,
             sim: SimConfig = SimConfig(), mesh=None) -> SimResult:
    """Slow-accurate path: deterministic latency/power from a compiled cell.

    With ``mesh`` (the candidate's mesh tuple) the collective term is the
    topology-aware per-axis model; without it the fixed mesh-less
    ``MESHLESS_LINKS`` approximation applies.  The topology arithmetic runs
    through the same xp-generic helpers as ``simulate_batch``, so scalar
    and batch agree bitwise."""
    if freq_mhz is None:
        freq_mhz = chip.nominal_freq_mhz
    chip_f = chip.at_frequency(freq_mhz)
    t_comp = analysis["flops"] / chip_f.peak_flops_bf16
    t_mem = analysis["hbm_bytes"] / chip_f.hbm_bw
    wire = wire_bytes(analysis)
    if mesh is not None:
        pod, data, model = normalize_mesh(mesh)
        p_d, p_m = collective_payload(analysis, n_chips, sim.coll_model_frac)
        t_coll = float(topology_collective_time(
            p_d, p_m, pod, data, model, chip_f.ici_bw, chip_f.ici_links,
            chip_f.ici_links_per_axis, chip_f.ici_hop_s))
    else:
        t_coll = (wire / (chip_f.ici_bw * MESHLESS_LINKS)
                  if chip_f.ici_bw else 0.0)

    ts = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(ts, key=ts.get)
    t_max = ts[dom]
    latency = t_max + (1.0 - sim.overlap) * (sum(ts.values()) - t_max)
    latency = max(latency, 1e-9)

    u_mxu = t_comp / latency
    u_hbm = t_mem / latency
    u_ici = t_coll / latency
    util = sim.w_mxu * u_mxu + sim.w_hbm * u_hbm + sim.w_ici * u_ici
    power = chip.dynamic_power(freq_mhz, util)
    cycles = latency * freq_mhz * 1e6
    return SimResult(
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        latency_s=latency, cycles=cycles, utilization=u_mxu,
        power_w=power, energy_j=power * latency * n_chips,
        bottleneck=dom)


def simulate_by_name(analysis: Dict, chip_name: str, n_chips: int,
                     freq_mhz: Optional[float] = None, mesh=None) -> SimResult:
    return simulate(analysis, get_chip(chip_name), n_chips, freq_mhz,
                    mesh=mesh)


# --- Batched (struct-of-arrays) path ------------------------------------------
# Same arithmetic as ``simulate`` applied to whole candidate arrays at once:
# chip properties are gathered from CHIP_TABLE by index, every step is an
# elementwise array op, so a full DSE space is one pass of vector code instead
# of a Python loop.  numpy float64 by default (bitwise-matches the scalar
# path); pass ``xp=jax.numpy`` for a jit-able accelerator variant.

BOTTLENECKS = ("compute", "memory", "collective")

# the chip-table columns simulate_batch actually gathers; pre-gathered
# ``gathered`` dicts only need (and multi-workload tiling only tiles) these
SIM_GATHER_FIELDS = ("nominal_freq_mhz", "min_freq_mhz", "max_freq_mhz",
                     "peak_flops_bf16", "hbm_bw", "ici_bw", "tdp_watts",
                     "idle_watts", "ici_links", "ici_links_per_axis",
                     "ici_hop_s")


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: ndarray fields
class SimBatch:
    """``SimResult`` over N candidates, field-per-array."""

    t_compute: np.ndarray
    t_memory: np.ndarray
    t_collective: np.ndarray
    latency_s: np.ndarray
    cycles: np.ndarray
    utilization: np.ndarray
    power_w: np.ndarray              # per chip
    energy_j: np.ndarray             # whole slice
    bottleneck_idx: np.ndarray       # index into BOTTLENECKS

    def __len__(self) -> int:
        return int(np.shape(self.latency_s)[0])

    def bottleneck(self, i: int) -> str:
        return BOTTLENECKS[int(self.bottleneck_idx[i])]

    def result(self, i: int) -> SimResult:
        """Materialize one row as the scalar dataclass."""
        return SimResult(
            t_compute=float(self.t_compute[i]),
            t_memory=float(self.t_memory[i]),
            t_collective=float(self.t_collective[i]),
            latency_s=float(self.latency_s[i]),
            cycles=float(self.cycles[i]),
            utilization=float(self.utilization[i]),
            power_w=float(self.power_w[i]),
            energy_j=float(self.energy_j[i]),
            bottleneck=self.bottleneck(i))


def simulate_batch(analysis: Dict, chip_idx, n_chips,
                   freq_mhz=None, sim: SimConfig = SimConfig(),
                   table: ChipTable = CHIP_TABLE, xp=np,
                   gathered: Optional[Dict] = None,
                   mesh_pod=None, mesh_data=None, mesh_model=None) -> SimBatch:
    """Vectorized ``simulate`` over arrays of candidates.

    ``analysis`` holds per-device arrays (or scalars, broadcast) of flops /
    hbm_bytes / collective_bytes / wire_bytes (plus the optional
    ``coll_payload_bytes`` un-split collective payload); ``chip_idx``
    indexes ``table``; ``n_chips`` / ``freq_mhz`` are per-candidate arrays.
    With ``mesh_data``/``mesh_model`` (and optionally ``mesh_pod``) the
    collective term is the topology-aware per-axis model; without them the
    fixed mesh-less ``MESHLESS_LINKS`` approximation applies.  With the
    default ``xp=np``
    the arithmetic is float64 and agrees with the scalar path to machine
    precision; any array namespace with the numpy API (e.g. ``jax.numpy``)
    works, making the body jit-able.  ``gathered`` (from
    ``table.gather(chip_idx)``) skips the per-call column gathers when the
    same candidate batch is swept repeatedly.
    """
    n_chips = xp.asarray(n_chips)
    if gathered is None:
        gathered = {f: xp.asarray(getattr(table, f))[xp.asarray(chip_idx)]
                    for f in SIM_GATHER_FIELDS}
    nominal = gathered["nominal_freq_mhz"]
    f_min = gathered["min_freq_mhz"]
    f_max = gathered["max_freq_mhz"]
    if freq_mhz is None:
        freq_mhz = nominal
    freq = xp.clip(xp.asarray(freq_mhz), f_min, f_max)

    peak = gathered["peak_flops_bf16"] * (freq / nominal)
    hbm_bw = gathered["hbm_bw"]
    ici_bw = gathered["ici_bw"]

    flops = xp.asarray(analysis["flops"])
    hbm_bytes = xp.asarray(analysis["hbm_bytes"])
    wire = xp.asarray(wire_bytes(analysis))

    t_comp = flops / peak
    t_mem = hbm_bytes / hbm_bw
    if mesh_model is not None:
        if mesh_data is None:
            raise ValueError("mesh_model without mesh_data; pass both "
                             "trailing mesh axes (mesh_pod is optional)")
        if mesh_pod is None:
            mesh_pod = xp.ones(xp.shape(xp.asarray(mesh_model)), xp.asarray(
                mesh_model).dtype)
        p_d, p_m = collective_payload(analysis, n_chips,
                                      sim.coll_model_frac, xp=xp)
        t_coll = topology_collective_time(
            p_d, p_m, mesh_pod, mesh_data, mesh_model, ici_bw,
            gathered["ici_links"], gathered["ici_links_per_axis"],
            gathered["ici_hop_s"], xp=xp)
    else:
        has_ici = ici_bw > 0
        t_coll = xp.where(
            has_ici,
            wire / (xp.where(has_ici, ici_bw, 1.0) * MESHLESS_LINKS),
            0.0)

    ts = xp.stack([t_comp, t_mem, t_coll])         # BOTTLENECKS order
    dom = xp.argmax(ts, axis=0)
    t_max = xp.max(ts, axis=0)
    latency = t_max + (1.0 - sim.overlap) * (xp.sum(ts, axis=0) - t_max)
    latency = xp.maximum(latency, 1e-9)

    # same association as the scalar path (w * (t/latency), summed in the
    # same order); residual disagreement is 1 ulp from pow() vs array **3
    util = (sim.w_mxu * (t_comp / latency) + sim.w_hbm * (t_mem / latency)
            + sim.w_ici * (t_coll / latency))
    util = xp.clip(util, 0.0, 1.0)
    tdp = gathered["tdp_watts"]
    idle = gathered["idle_watts"]
    power = idle + (tdp - idle) * util * (freq / f_max) ** 3
    power = xp.minimum(power, tdp)

    # cycles use the caller's (unclamped) frequency, matching ``simulate``;
    # freq_mhz was defaulted to nominal above if the caller passed None
    cycles = latency * xp.asarray(freq_mhz) * 1e6
    return SimBatch(
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        latency_s=latency, cycles=cycles, utilization=t_comp / latency,
        power_w=power, energy_j=power * latency * n_chips,
        bottleneck_idx=dom)


def scale_census(base_analysis: Dict, base_chips, n_chips, xp=np) -> Dict:
    """First-order rescale of a compiled census to other slice sizes, xp-generic.

    The single home of the scaling arithmetic shared by
    ``dse._scale_analysis_batch`` (numpy float64), the fused jit sweep below,
    and the Pallas DSE-sweep kernel — identical IEEE expressions in every
    path, so the float64 variants agree bitwise with the scalar oracle.
    flops/bytes scale ~1/chips; collective bytes ride the ring factor; the
    emitted ``coll_payload_bytes`` un-applies the base census's global ring
    factor so the topology-aware simulator can split it per mesh axis.
    """
    bc = xp.asarray(base_chips) * 1.0
    nc = xp.asarray(n_chips) * 1.0
    r = bc / nc
    ring_base = xp.maximum((bc - 1.0) / bc, 1e-9)
    ring = xp.where(nc > 1, ((nc - 1.0) / nc) / ring_base, 0.0)
    return {
        "flops": xp.asarray(base_analysis["flops"]) * r,
        "hbm_bytes": xp.asarray(base_analysis["hbm_bytes"]) * r,
        "collective_bytes":
            xp.asarray(base_analysis["collective_bytes"]) * r * ring,
        "wire_bytes": xp.asarray(base_analysis["wire_bytes"]) * r * ring,
        "coll_payload_bytes":
            xp.asarray(base_analysis["wire_bytes"]) * r / ring_base,
    }


# --- Fused sweep reduction (per-tile skyline pre-reduction) -------------------
# A campaign tile's full energy/latency arrays exist only so the streaming
# frontier can discard >99% of them.  The helpers below move that discard on
# device: the constraint-feasible Pareto survivors of the tile plus the scalar
# aggregates the frontier accounting needs (feasible count, feasible maxima
# for the hypervolume reference point) are everything the host has to see —
# O(survivors) transfer instead of O(tile).  ``skyline_reduce`` is xp-generic
# so the numpy reference, the jit reference path and the Pallas kernel all
# reduce with the same arithmetic, and the surviving mask provably equals
# ``dse.pareto_mask`` on the feasible subset (same sort keys, same strict /
# group-minimum survival rule, infeasible rows pushed to +inf keys).

# chip-table columns the fused sweep gathers: the simulate set plus the HBM
# capacity the feasibility check reads
SWEEP_GATHER_FIELDS = SIM_GATHER_FIELDS + ("hbm_bytes",)

# per-workload scalar column order of the packed [W, 6] workload matrix
WL_COLS = ("flops", "hbm_bytes", "collective_bytes", "wire_bytes",
           "base_chips", "state_gb_per_device")


def _cummin(x, xp):
    if xp is np:
        return np.minimum.accumulate(x)
    import jax.lax
    return jax.lax.cummin(x)


def skyline_reduce(energy, latency, feasible, xp=np):
    """(keep, n_feasible, ref_energy, ref_latency) of one evaluated tile.

    ``keep`` marks the feasible Pareto survivors of the (energy, latency)
    minimization — the same set ``dse.pareto_mask`` selects, computed with
    static shapes so it jits: infeasible rows are mapped to +inf sort keys
    instead of being compacted away.  ``ref_*`` are the feasible maxima
    (-inf when the tile has no feasible point) that pin the streaming
    frontier's hypervolume reference point.
    """
    e = xp.asarray(energy)
    l = xp.asarray(latency)
    feas = xp.asarray(feasible, bool)
    e_key = xp.where(feas, e, xp.inf)
    l_key = xp.where(feas, l, xp.inf)
    order = xp.lexsort((e_key, l_key))
    es, ls = e_key[order], l_key[order]
    first = xp.searchsorted(ls, ls, side="left")
    prefix = _cummin(es, xp)
    best_before = xp.where(first > 0, prefix[xp.maximum(first - 1, 0)], xp.inf)
    # survive: strictly faster points all cost more energy, and tied-latency
    # points only if they hold the group's energy minimum (equal duplicates
    # never dominate each other — both stay, matching dse.pareto_mask)
    nondom = (es < best_before) & (es <= es[first]) & feas[order]
    if xp is np:
        keep = np.zeros(e.shape, bool)
        keep[order] = nondom
    else:
        keep = xp.zeros(e.shape, bool).at[order].set(nondom)
    n_feasible = xp.sum(feas)
    ref_e = xp.max(xp.where(feas, e, -xp.inf))
    ref_l = xp.max(xp.where(feas, l, -xp.inf))
    return keep, n_feasible, ref_e, ref_l


def sweep_feasibility(power_w, latency_s, n_chips, hbm_bytes, base_chips,
                      state_gb_per_device, valid, max_power_w, max_latency_s,
                      min_hbm_fit: bool, xp=np):
    """``dse.feasibility_mask`` arithmetic in xp-generic, padding-aware form.

    ``valid`` masks tile padding lanes (always infeasible); ``max_power_w`` /
    ``max_latency_s`` of ``None`` skip their comparison exactly like the
    numpy constraint path, so the float64 variants agree bitwise."""
    ok = xp.asarray(valid) > 0
    nc = xp.asarray(n_chips) * 1.0
    if min_hbm_fit:
        state_pd = state_gb_per_device * (xp.asarray(base_chips) * 1.0) / nc
        ok = ok & (state_pd * 1e9 <= hbm_bytes * 0.9)
    if max_power_w is not None:
        ok = ok & (power_w * nc <= max_power_w)
    if max_latency_s is not None:
        ok = ok & (latency_s <= max_latency_s)
    return ok


# convex-weight probe spread of the on-device dominance screen: each weight
# w picks the feasible argmin of w*(e/e_min) + (l/l_min) — a point ON the
# tile skyline — and everything strictly dominated by a probe is screened
# out.  Geometric spread covers frontier slopes across four decades.
_PROBE_WEIGHTS = np.geomspace(1e-2, 1e2, 8)


def _screen_rows(energy, latency, feasible):
    """jnp screen shared by the jit reference path and the Pallas wrapper:
    per-workload-row conservative dominance screen of [W, N] sweeps.
    Returns (keep, n_surv, n_feas, ref_e, ref_l) with ``keep`` the [W, N]
    survivor mask.

    The screen is CONSERVATIVE: probes are real feasible points (argmins of
    convex (energy, latency) weightings, i.e. skyline members), and a
    skyline point is dominated by nothing — so the surviving set is always
    a superset of the exact ``skyline_reduce`` set, and the frontier fold
    (``StreamingFrontier.merge_reduced`` -> ``dse.pareto_mask``) recovers
    the exact skyline from it.  Everything here is elementwise / reduction
    work — no sort, no prefix scan: XLA's comparator sort costs more than
    the whole simulation on [W, 32k] tiles, while the probe screen leaves
    only a few percent of slack over the exact skyline on real campaign
    tiles.  All dominance comparisons run in the sweep dtype against probe
    values gathered from the same arrays, so screening decisions are exact
    in any precision."""
    import jax
    import jax.numpy as jnp
    wts = jnp.asarray(_PROBE_WEIGHTS, energy.dtype)

    def row(e, l, feas):
        e_lo = jnp.min(jnp.where(feas, e, jnp.inf))
        l_lo = jnp.min(jnp.where(feas, l, jnp.inf))
        score = wts[:, None] * (e / e_lo)[None, :] + (l / l_lo)[None, :]
        pi = jnp.argmin(jnp.where(feas[None, :], score, jnp.inf), axis=1)
        ep, lp = e[pi][:, None], l[pi][:, None]             # [P, 1] probes
        dom = ((e[None, :] >= ep) & (l[None, :] >= lp)
               & ((e[None, :] > ep) | (l[None, :] > lp)))
        keep = feas & ~jnp.any(dom, axis=0)
        return (keep, jnp.sum(keep), jnp.sum(feas),
                jnp.max(jnp.where(feas, e, -jnp.inf)),
                jnp.max(jnp.where(feas, l, -jnp.inf)))

    return jax.vmap(row)(energy, latency, feasible)


def _compact_rows_host(keep, energy, latency, max_survivors: int):
    """numpy survivor compaction of screened [W, N] rows: (surv_idx, surv_e,
    surv_l) as [W, K] with ascending lanes, rows past the row's survivor
    count zero-filled.  The host side of the reduction on backends where
    device arrays are host memory anyway (CPU interpret); compiled
    accelerator paths compact on device (``_compact_rows_device``) so only
    O(K) crosses the link."""
    w_count, n = keep.shape
    k = min(int(max_survivors), n)
    surv_idx = np.zeros((w_count, k), np.int64)
    surv_e = np.zeros((w_count, k), energy.dtype)
    surv_l = np.zeros((w_count, k), latency.dtype)
    for w in range(w_count):
        pos = np.flatnonzero(keep[w])[:k]
        surv_idx[w, :pos.size] = pos
        surv_e[w, :pos.size] = energy[w, pos]
        surv_l[w, :pos.size] = latency[w, pos]
    return surv_idx, surv_e, surv_l


def _compact_rows_device(keep, energy, latency, max_survivors: int):
    """jnp survivor compaction (cumsum-rank scatter) for compiled backends,
    same contract as ``_compact_rows_host``."""
    import jax
    import jax.numpy as jnp
    n = keep.shape[1]
    k = min(int(max_survivors), n)
    lane = jnp.arange(n, dtype=jnp.int32)

    def row(kp, e, l):
        tgt = jnp.where(kp, jnp.cumsum(kp) - 1, k)
        pos = jnp.zeros(k, jnp.int32).at[tgt].set(lane, mode="drop")
        filled = jnp.arange(k) < jnp.sum(kp)
        return (jnp.where(filled, pos, 0),
                jnp.where(filled, e[pos], 0.0),
                jnp.where(filled, l[pos], 0.0))

    return jax.vmap(row)(keep, energy, latency)


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: ndarray fields
class SweepReduced:
    """Reduced result of one fused (all-workloads x tile) sweep launch.

    ``surv_*`` are the screened tile survivors (a feasible superset of the
    tile's Pareto skyline) — all a frontier merge needs; ``*_full`` back
    the (rare) overflow fallback when a workload's screened set exceeds
    ``max_survivors``."""

    surv_idx: np.ndarray         # int [W, K] lane indices into the tile
    surv_energy: np.ndarray      # [W, K], rows past n_survivors are fill
    surv_latency: np.ndarray     # [W, K]
    n_survivors: np.ndarray      # int [W] (may exceed K: overflow)
    n_feasible: np.ndarray       # int [W]
    ref_energy: np.ndarray       # [W] feasible max (-inf if none)
    ref_latency: np.ndarray      # [W]
    max_survivors: int
    energy_full: object          # [W, N] — read only on overflow fallback
    latency_full: object
    feasible_full: object

    def overflowed(self, w: int) -> bool:
        return int(self.n_survivors[w]) > self.max_survivors


@functools.lru_cache(maxsize=None)
def _jit_sweep_reduced(sim: SimConfig, max_power_w, max_latency_s,
                       min_hbm_fit: bool):
    import jax
    import jax.numpy as jnp

    def run(wl_cols, chip_cols, n_chips, freq_mhz, mesh_pod, mesh_data,
            mesh_model, valid):
        # workloads broadcast as a leading DATA axis ([W, 1] x [1, N] ->
        # [W, N]) rather than a Python loop, so the traced graph — and the
        # compile time — is independent of the workload count
        row = lambda a: jnp.asarray(a)[None, :]
        wl = {k: wl_cols[:, i:i + 1] for i, k in enumerate(WL_COLS)}
        cols = {k: row(v) for k, v in chip_cols.items()}
        ana = scale_census(wl, wl["base_chips"], row(n_chips), xp=jnp)
        b = simulate_batch(ana, None, row(n_chips), row(freq_mhz), sim=sim,
                           xp=jnp, gathered=cols,
                           mesh_pod=row(mesh_pod), mesh_data=row(mesh_data),
                           mesh_model=row(mesh_model))
        feas = sweep_feasibility(
            b.power_w, b.latency_s, row(n_chips), cols["hbm_bytes"],
            wl["base_chips"], wl["state_gb_per_device"], row(valid),
            max_power_w, max_latency_s, min_hbm_fit, xp=jnp)
        e = jnp.broadcast_to(b.energy_j, feas.shape)
        l = jnp.broadcast_to(b.latency_s, feas.shape)
        return _screen_rows(e, l, feas) + (e, l, feas)

    return jax.jit(run)


def sweep_workloads_reduced_jit(wl_cols, chip_cols: Dict, n_chips, freq_mhz,
                                mesh_pod, mesh_data, mesh_model, valid,
                                sim: SimConfig = SimConfig(),
                                max_power_w=None, max_latency_s=None,
                                min_hbm_fit: bool = True,
                                max_survivors: int = 2048) -> SweepReduced:
    """The jit reference path of the fused on-device campaign evaluator.

    One launch evaluates ALL ``W`` workloads on one (padded) candidate tile —
    census scaling, topology-aware simulation, constraint masking and the
    per-tile skyline pre-reduction (a conservative dominance screen whose
    survivors are a guaranteed superset of the tile's feasible Pareto set)
    all happen in-trace — so the host only handles O(survivors) per tile.
    float32 under the repo's default x64-disabled config (the ``"jit"``
    precision tier); the Pallas kernel path (``repro.kernels.dse_sweep``)
    shares every helper and runs float64 in interpret mode.  ``chip_cols``
    needs the ``SWEEP_GATHER_FIELDS`` columns; ``wl_cols`` is the packed
    [W, 6] ``WL_COLS`` matrix.
    """
    w_count, n_wl_cols = np.shape(wl_cols)
    if n_wl_cols != len(WL_COLS):
        raise ValueError(f"wl_cols must be [W, {len(WL_COLS)}] ({WL_COLS})")
    cols = {k: chip_cols[k] for k in SWEEP_GATHER_FIELDS}
    out = _jit_sweep_reduced(
        sim, max_power_w, max_latency_s, bool(min_hbm_fit))(
            np.asarray(wl_cols, np.float64), cols, n_chips, freq_mhz,
            mesh_pod, mesh_data, mesh_model, valid)
    return build_sweep_reduced(out, int(max_survivors))


def build_sweep_reduced(out, max_survivors: int) -> SweepReduced:
    """Assemble the host-side ``SweepReduced`` from a fused launch's output
    tuple (keep, n_surv, n_feas, ref_e, ref_l, e_full, l_full, feas_full).

    Compaction runs in numpy: on CPU (this container, and interpret-mode
    CI) device arrays ARE host memory, so the mask + gathers here cost a
    memcpy — far less than an XLA prefix-scan compaction.  A compiled
    accelerator deployment would swap in ``_compact_rows_device`` before
    the transfer; the contract is identical.
    """
    keep = np.asarray(out[0])
    e_full, l_full = np.asarray(out[5]), np.asarray(out[6])
    surv_idx, surv_e, surv_l = _compact_rows_host(
        keep, e_full, l_full, max_survivors)
    return SweepReduced(
        surv_idx=surv_idx, surv_energy=surv_e, surv_latency=surv_l,
        n_survivors=np.asarray(out[1]), n_feasible=np.asarray(out[2]),
        ref_energy=np.asarray(out[3]), ref_latency=np.asarray(out[4]),
        max_survivors=int(max_survivors),
        energy_full=e_full, latency_full=l_full,
        feasible_full=np.asarray(out[7]))


@functools.lru_cache(maxsize=None)
def _jit_simulate_batch(sim: SimConfig, with_mesh: bool):
    import jax
    import jax.numpy as jnp

    if with_mesh:
        def run(flops, hbm_bytes, payload, chip_idx, n_chips,
                freq_mhz, mesh_pod, mesh_data, mesh_model):
            batch = simulate_batch(
                {"flops": flops, "hbm_bytes": hbm_bytes,
                 "coll_payload_bytes": payload, "wire_bytes": payload},
                chip_idx, n_chips, freq_mhz, sim=sim, xp=jnp,
                mesh_pod=mesh_pod, mesh_data=mesh_data, mesh_model=mesh_model)
            return dataclasses.asdict(batch)
    else:
        def run(flops, hbm_bytes, wire_bytes, chip_idx, n_chips, freq_mhz):
            batch = simulate_batch(
                {"flops": flops, "hbm_bytes": hbm_bytes,
                 "wire_bytes": wire_bytes},
                chip_idx, n_chips, freq_mhz, sim=sim, xp=jnp)
            return dataclasses.asdict(batch)

    return jax.jit(run)


def simulate_batch_jit(analysis: Dict, chip_idx, n_chips, freq_mhz,
                       sim: SimConfig = SimConfig(),
                       mesh_pod=None, mesh_data=None,
                       mesh_model=None) -> SimBatch:
    """jit-compiled ``simulate_batch`` on the default JAX backend.

    Accelerator path for very large spaces; float32 under the repo's default
    x64-disabled config, so expect ~1e-6 relative agreement rather than the
    numpy path's exact match.  Passing ``mesh_data``/``mesh_model`` (and
    optionally ``mesh_pod``) selects the topology-aware collective model;
    the un-split payload is derived in float64 numpy BEFORE entering the
    jit, then split in-trace by ``sim.coll_model_frac`` like every other
    path.
    """
    if mesh_model is not None:
        mesh_model = np.asarray(mesh_model, np.int32)
        mesh_data = np.asarray(mesh_data, np.int32)
        mesh_pod = (np.ones_like(mesh_model) if mesh_pod is None
                    else np.asarray(mesh_pod, np.int32))
        payload = _raw_payload(analysis, n_chips, np)
        out = _jit_simulate_batch(sim, True)(
            analysis["flops"], analysis["hbm_bytes"], payload,
            np.asarray(chip_idx, np.int32), n_chips, freq_mhz,
            mesh_pod, mesh_data, mesh_model)
    else:
        out = _jit_simulate_batch(sim, False)(
            analysis["flops"], analysis["hbm_bytes"], wire_bytes(analysis),
            np.asarray(chip_idx, np.int32), n_chips, freq_mhz)
    return SimBatch(**{k: np.asarray(v) for k, v in out.items()})
