"""HxA — Hybrid HLO Analyzer (the paper's HyPA, adapted PTX -> HLO).

The paper's HyPA statically analyzes compiled PTX and micro-simulates control
flow (loops, branches) to recover the number of instructions that actually
EXECUTE, because a static census alone undercounts loop bodies.  The exact
same gap exists in XLA: ``compiled.cost_analysis()`` counts a ``while`` body
(every ``lax.scan`` — i.e. every scanned transformer stack) ONCE, not
trip-count times (verified empirically; see EXPERIMENTS.md §Dry-run).

HxA closes the gap the HyPA way:
  1. parse the compiled (post-SPMD, post-fusion) HLO module text,
  2. statically census FLOPs / HBM-traffic bytes / collective bytes per op,
  3. "simulate" control flow: recover each while loop's trip count from its
     condition computation (the compare-against-constant pattern) and multiply
     the body's census through — nested loops compose multiplicatively.

Everything here is per-device (post-SPMD shapes are per-device shards).

Cost conventions (documented knobs, not truth claims):
  * dot:           2 * prod(result) * K   (K = contracted extent)
  * convolution:   2 * prod(result) * prod(kernel) / out_features
  * elementwise:   1 flop / output element (transcendentals too)
  * reduce:        1 flop / input element
  * HBM bytes:     operand + result bytes of materializing ops only (fusion
                   interiors are free — they never round-trip to HBM)
  * collectives:   operand bytes (the §Roofline contract), plus a modeled
                   "wire bytes" using ring formulas for reporting.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_elems(shape_str: str) -> int:
    if not shape_str:
        return 1
    n = 1
    for d in shape_str.split(","):
        n *= int(d)
    return n


def _parse_types(segment: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _TYPE_RE.finditer(segment):
        dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _bytes_of(types: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in types:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_types: List[Tuple[str, List[int]]]
    operand_names: List[str]
    args: str
    attrs: str
    calls: List[str]
    operand_types: List[Tuple[str, List[int]]] = dataclasses.field(default_factory=list)


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_module(text: str) -> Dict[str, List[Op]]:
    """Split an HLO module into computations -> op lists.

    Optimized HLO prints operands as bare %names — types are resolved through
    a per-computation symbol table (operands always live in their computation).
    """
    comps: Dict[str, List[Op]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_RE.match(line.strip()) if line.rstrip().endswith("{") else None
            if m and ("->" in line or line.lstrip().startswith(("ENTRY", "%"))):
                current = m.group(1)
                comps[current] = []
            continue
        if line.startswith("}") or line.strip() == "}":
            current = None
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, rest0 = m.groups()
        # the opcode is the first `token(` after the (possibly tuple) type —
        # type strings never contain '(' directly after an identifier.
        om = _OPCODE_RE.search(rest0)
        if not om:
            continue
        rtype, opcode, rest = rest0[: om.start()], om.group(1), rest0[om.end():]
        # split args segment from attributes (first unmatched ')')
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        args, attrs = rest[:idx], rest[idx + 1:]
        comps[current].append(Op(
            name=name, opcode=opcode,
            result_types=_parse_types(rtype),
            operand_names=_OPERAND_RE.findall(args),
            args=args,
            attrs=attrs,
            calls=_CALL_ATTR_RE.findall(attrs)))
    # resolve operand types
    for ops in comps.values():
        table = {op.name: op.result_types for op in ops}
        for op in ops:
            inline = _parse_types(op.args)
            if inline:
                op.operand_types = inline
            else:
                op.operand_types = [t for nm in op.operand_names
                                    for t in table.get(nm, [])]
    return comps


# --- per-op flop model ------------------------------------------------------------

_ELEMENTWISE_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "reshape", "transpose", "copy", "broadcast", "iota", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad", "reverse",
    "gather", "scatter", "convert", "after-all", "custom-call",
    "rng-bit-generator", "partition-id", "replica-id", "optimization-barrier",
    "while", "conditional", "call", "fusion", "select-and-scatter", "bitcast-convert",
} | set(COLLECTIVE_OPS)


def _op_flops(op: Op) -> float:
    out_elems = sum(_shape_elems(",".join(map(str, dims))) if dims else 1
                    for _, dims in op.result_types)
    if op.opcode == "dot":
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        k = 1
        if m and op.operand_types:
            lhs_dims = op.operand_types[0][1]
            for ci in (int(c) for c in m.group(1).split(",") if c):
                if ci < len(lhs_dims):
                    k *= lhs_dims[ci]
        return 2.0 * out_elems * k
    if op.opcode == "convolution":
        if len(op.operand_types) >= 2:
            kdims = op.operand_types[1][1]
            kelems = 1
            for d in kdims:
                kelems *= d
            out_feat = kdims[-1] if kdims else 1
            return 2.0 * out_elems * (kelems / max(out_feat, 1))
        return 2.0 * out_elems
    if op.opcode in ("reduce", "reduce-window"):
        in_elems = sum(_shape_elems(",".join(map(str, d))) if d else 1
                       for _, d in op.operand_types)
        return float(in_elems)
    if op.opcode in _ELEMENTWISE_FREE:
        return 0.0
    return float(out_elems)          # elementwise / transcendental: 1/elt


def _trip_count(cond_ops: List[Op]) -> int:
    """HyPA-style control-flow resolution: largest integer constant in the
    loop condition (scan conditions compare the counter to the trip bound)."""
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.match(r"\s*(\d+)\s*$", op.args)
            if m:
                best = max(best, int(m.group(1)))
        for m in _CONST_RE.finditer(op.attrs):
            best = max(best, int(m.group(1)))
    return best


_MATERIALIZING = {"fusion", "dot", "convolution", "copy", "concatenate",
                  "scatter", "sort", "reduce", "transpose",
                  "pad", "custom-call"} | set(COLLECTIVE_OPS)
# broadcasts/iotas fuse into consumers on TPU: no HBM round-trip.
# window-ops: traffic = the data actually touched, not the whole base buffer
_WINDOW_READ = {"dynamic-slice", "slice", "gather"}
_WINDOW_WRITE = {"dynamic-update-slice"}


@dataclasses.dataclass
class Census:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0           # operand bytes (§Roofline contract)
    wire_bytes: float = 0.0                 # ring-modeled bytes on the ICI
    op_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    hbm_by_opcode: Dict[str, float] = dataclasses.field(default_factory=dict)
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    loops: List[Dict] = dataclasses.field(default_factory=list)

    def _hbm(self, opcode: str, nbytes: float):
        self.hbm_bytes += nbytes
        self.hbm_by_opcode[opcode] = self.hbm_by_opcode.get(opcode, 0.0) + nbytes

    def add(self, other: "Census", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        for k, v in other.op_counts.items():
            self.op_counts[k] = self.op_counts.get(k, 0) + v * mult
        for k, v in other.hbm_by_opcode.items():
            self.hbm_by_opcode[k] = self.hbm_by_opcode.get(k, 0.0) + v * mult
        for k, v in other.collectives.items():
            slot = self.collectives.setdefault(k, {"count": 0.0, "bytes": 0.0,
                                                   "wire_bytes": 0.0})
            for kk in slot:
                slot[kk] += v.get(kk, 0.0) * mult
        self.loops.extend(other.loops)


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 2


def _wire_factor(opcode: str, n: int) -> float:
    """Ring-algorithm bytes-on-wire multiplier per device."""
    if n <= 1:
        return 0.0
    if opcode == "all-reduce":
        return 2.0 * (n - 1) / n
    if opcode in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


def _passes_through_bf16(src: Op, comps: Dict[str, List[Op]]) -> bool:
    """True when `src` produces f32 values that semantically went through
    bf16 (XLA:CPU's promotion of bf16 math; TPU keeps bf16)."""
    if not src.result_types or src.result_types[0][0] != "f32":
        return False
    if src.opcode == "convert":
        return any(dt == "bf16" for dt, _ in src.operand_types)
    if src.opcode == "fusion" and "convert" in src.name and src.calls:
        callee = comps.get(src.calls[0], [])
        return any(o.opcode == "convert" and o.result_types
                   and o.result_types[0][0] == "bf16" for o in callee)
    return False


def census_computation(name: str, comps: Dict[str, List[Op]],
                       _memo: Optional[dict] = None,
                       trips_ctx: int = 1) -> Census:
    """trips_ctx: trip count of the IMMEDIATELY enclosing while loop.  A
    fusion that dynamic-slices a stacked buffer inside a T-trip loop touches
    a 1/T window of it per iteration — the HyPA-style control-flow-aware
    traffic attribution."""
    memo = _memo if _memo is not None else {}
    key = (name, trips_ctx)
    if key in memo:
        return memo[key]
    c = Census()
    producers = {o.name: o for o in comps.get(name, [])}
    for op in comps.get(name, []):
        c.op_counts[op.opcode] = c.op_counts.get(op.opcode, 0) + 1
        c.flops += _op_flops(op)
        if op.opcode in COLLECTIVE_OPS:
            b = _bytes_of(op.operand_types)
            if op.opcode == "all-gather":                  # result is the moved unit
                b = max(b, _bytes_of(op.result_types))
            # XLA:CPU promotes bf16 reductions to f32 (no native bf16 adds);
            # TPU reduces in bf16.  If the operand passes through bf16 (a
            # bf16->f32 convert, or a fusion with an interior bf16 roundtrip),
            # charge the collective at bf16 width.
            if op.operand_names:
                src = producers.get(op.operand_names[0])
                if src is not None and _passes_through_bf16(src, comps):
                    b *= 0.5
            n = _group_size(op.attrs)
            wire = b * _wire_factor(op.opcode, n)
            c.collective_bytes += b
            c.wire_bytes += wire
            slot = c.collectives.setdefault(op.opcode,
                                            {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0})
            slot["count"] += 1
            slot["bytes"] += b
            slot["wire_bytes"] += wire
            c._hbm(op.opcode, _bytes_of(op.operand_types) + _bytes_of(op.result_types))
        elif op.opcode == "while":
            body, cond = None, None
            m = re.search(r"body=%?([\w.\-]+)", op.attrs)
            if m:
                body = m.group(1)
            m = re.search(r"condition=%?([\w.\-]+)", op.attrs)
            if m:
                cond = m.group(1)
            trips = _trip_count(comps.get(cond, [])) if cond else 1
            if body:
                sub = census_computation(body, comps, memo, trips_ctx=trips)
                c.add(sub, mult=trips)
                c.loops.append({"body": body, "trips": trips,
                                "body_flops": sub.flops})
        elif op.opcode in ("fusion", "call", "conditional"):
            sub_counts = Census()
            for callee in op.calls:
                sub = census_computation(callee, comps, memo, trips_ctx=trips_ctx)
                c.add(sub)
                sub_counts.add(sub)
            if op.opcode == "fusion":
                ob = [_bytes_of([t]) for t in op.operand_types]
                rb = _bytes_of(op.result_types)
                has_ds = sub_counts.op_counts.get("dynamic-slice", 0) > 0
                has_reduce = any(k.startswith("reduce")
                                 for k in sub_counts.op_counts)
                # XLA:CPU widens bf16 while-carries to f32 (wrapped_convert at
                # entry; converts inside every carry-touching fusion).  TPU has
                # native bf16 — charge such fusions at bf16 width.  Signature:
                # interior converts with both f32 and bf16 params present.
                widened = (
                    sub_counts.op_counts.get("convert", 0) >= 2
                    and any(dt == "f32" for dt, _ in op.operand_types)
                    and trips_ctx > 1
                    and (sub_counts.op_counts.get("dynamic-update-slice")
                         or sub_counts.op_counts.get("select")))
                width_corr = 0.5 if widened else 1.0
                if sub_counts.op_counts.get("dynamic-update-slice"):
                    # in-place window write (scan ys / cache update): the base
                    # buffer is aliased through; true traffic is the window,
                    # read + write — approximated by the non-base operands,
                    # themselves window-capped when sliced inside a loop.
                    base = max((x for x in ob if x <= rb), default=0)
                    rest = 0.0
                    for x in ob:
                        if x == base:
                            base = -1          # consume base exactly once
                            continue
                        if trips_ctx > 1:
                            # per-iteration window of stacked buffers: no
                            # operand moves more than biggest-buffer/trips
                            rest += min(x, max(rb, x) / trips_ctx)
                        else:
                            rest += x
                    b = 2.0 * max(rest, 1.0)
                else:
                    b = rb
                    for x in ob:
                        if has_ds and trips_ctx > 1 and x > 4 * rb:
                            # sliced stacked buffer inside a T-trip loop:
                            # per-iteration window = 1/T of the base
                            b += max(rb, x / trips_ctx)
                        elif has_reduce:
                            b += x          # reductions truly read it all
                        elif x > 4 * rb:
                            # windowed read of a big buffer outside loops
                            b += rb if has_ds else x
                        else:
                            b += min(x, rb) if not has_reduce else x
                c._hbm("fusion", b * width_corr)
        elif op.opcode == "copy":
            # loop-carry copies are aliased away by TPU buffer assignment;
            # charge the write side only.
            c._hbm(op.opcode, _bytes_of(op.result_types))
        elif op.opcode in _WINDOW_READ:
            c._hbm(op.opcode, 2.0 * _bytes_of(op.result_types))
        elif op.opcode in _WINDOW_WRITE:
            upd = (_bytes_of(op.operand_types[1:2])
                   if len(op.operand_types) > 1 else _bytes_of(op.result_types))
            c._hbm(op.opcode, 2.0 * upd)
        else:
            if op.opcode in _MATERIALIZING:
                c._hbm(op.opcode, _bytes_of(op.operand_types) + _bytes_of(op.result_types))
    memo[name] = c
    return c


def _entry_name(comps: Dict[str, List[Op]], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation with the most ops
    return max(comps, key=lambda k: len(comps[k]))


def analyze_hlo_text(text: str) -> dict:
    """Full HxA analysis of one compiled HLO module (per-device numbers)."""
    comps = parse_module(text)
    entry = _entry_name(comps, text)
    # fusions called inside while bodies are memoized once; the recursion in
    # census_computation handles nesting, so we only walk from the entry.
    census = census_computation(entry, comps, {})
    return {
        "entry": entry,
        "flops": census.flops,
        "hbm_bytes": census.hbm_bytes,
        "collective_bytes": census.collective_bytes,
        "wire_bytes": census.wire_bytes,
        "op_counts": dict(sorted(census.op_counts.items(),
                                 key=lambda kv: -kv[1])[:40]),
        "hbm_by_opcode": dict(sorted(census.hbm_by_opcode.items(),
                                     key=lambda kv: -kv[1])[:15]),
        "collectives": census.collectives,
        "loops": census.loops[:20],
        "n_computations": len(comps),
    }
