"""Design-point dataset builder for predictor training.

A design point = (arch, shape, chip, freq, mesh).  Ground-truth labels come
from the slow-accurate path (compiled dry-run -> HxA -> cost model); to keep
the sweep tractable on one CPU the HxA census of a compiled (arch, shape,
mesh) cell is CACHED and re-simulated across the DVFS/chip sweep — exactly
how the paper reuses one profiled workload across frequencies (Fig. 2: the
same three CNNs at 397-1590 MHz).

The resulting (X, y_power, y_cycles) arrays feed predictors.kfold_evaluate —
the paper's Figs. 2-3 experiment.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ARCH_NAMES, SHAPES, get_config
from repro.core import costmodel, features
from repro.hw import CHIPS, get_chip, frequency_sweep


@dataclasses.dataclass
class DesignPoint:
    arch: str
    shape: str
    chip: str
    freq_mhz: float
    mesh: Tuple[int, ...] = (16, 16)

    @property
    def n_chips(self) -> int:
        n = 1
        for d in self.mesh:
            n *= d
        return n


def load_dryrun_artifacts(art_dir: str) -> Dict[Tuple[str, str, str], dict]:
    """(arch, shape, pod-tag) -> artifact json."""
    out = {}
    if not os.path.isdir(art_dir):
        return out
    for fn in os.listdir(art_dir):
        if not fn.endswith(".json") or "__" not in fn:
            continue
        parts = fn[:-5].split("__")
        if len(parts) != 3:
            continue  # hillclimb variants carry a 4th tag; baselines only
        arch, shape, pod = parts
        try:
            with open(os.path.join(art_dir, fn)) as f:
                out[(arch, shape, pod)] = json.load(f)
        except (json.JSONDecodeError, OSError):
            continue
    return out


def build_dataset(art_dir: str, chips: Optional[List[str]] = None,
                  freq_points: int = 8, pod: str = "pod1"):
    """Sweep cached cells x chips x frequencies -> (X, y_power, y_cycles, meta).

    Labels: the calibrated simulator on the REAL compiled census (slow path).
    Features: static config/hardware numerics only (fast path inputs).
    """
    chips = chips or [c for c in CHIPS if CHIPS[c].ici_bw > 0]
    arts = load_dryrun_artifacts(art_dir)
    X, y_power, y_cycles, meta = [], [], [], []
    for (arch, shape_name, pod_tag), art in sorted(arts.items()):
        if pod_tag != pod:
            continue
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        n_chips = art["roofline"]["n_chips"]
        analysis = {"flops": art["hxa"]["flops"],
                    "hbm_bytes": art["hxa"]["hbm_bytes"],
                    "collective_bytes": art["hxa"]["collective_bytes"],
                    "wire_bytes": art["hxa"]["wire_bytes"]}
        mesh_shape = (2, 16, 16) if pod == "pod2" else (16, 16)
        for chip_name in chips:
            chip = get_chip(chip_name)
            for f in frequency_sweep(chip_name, freq_points):
                res = costmodel.simulate(analysis, chip, n_chips, freq_mhz=f)
                X.append(features.extract(cfg, shape, chip, n_chips,
                                          mesh_shape=mesh_shape, freq_mhz=f))
                y_power.append(res.power_w)
                y_cycles.append(res.cycles)
                meta.append(DesignPoint(arch, shape_name, chip_name, f, mesh_shape))
    return (np.asarray(X, np.float32), np.asarray(y_power, np.float64),
            np.asarray(y_cycles, np.float64), meta)
