"""Design-point dataset builder for predictor training.

A design point = (arch, shape, chip, freq, mesh).  Ground-truth labels come
from the slow-accurate path (compiled dry-run -> HxA -> cost model); to keep
the sweep tractable on one CPU the HxA census of a compiled (arch, shape,
mesh) cell is CACHED and re-simulated across the DVFS/chip sweep — exactly
how the paper reuses one profiled workload across frequencies (Fig. 2: the
same three CNNs at 397-1590 MHz).

The resulting (X, y_power, y_cycles) arrays feed predictors.kfold_evaluate —
the paper's Figs. 2-3 experiment.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ARCH_NAMES, SHAPES, get_config
from repro.core import costmodel, features
from repro.hw import CHIPS, get_chip, frequency_sweep


@dataclasses.dataclass
class DesignPoint:
    arch: str
    shape: str
    chip: str
    freq_mhz: float
    mesh: Tuple[int, ...] = (16, 16)

    @property
    def n_chips(self) -> int:
        n = 1
        for d in self.mesh:
            n *= d
        return n


def load_dryrun_artifacts(art_dir: str) -> Dict[Tuple[str, str, str], dict]:
    """(arch, shape, pod-tag) -> artifact json."""
    out = {}
    if not os.path.isdir(art_dir):
        return out
    for fn in os.listdir(art_dir):
        if not fn.endswith(".json") or "__" not in fn:
            continue
        parts = fn[:-5].split("__")
        if len(parts) != 3:
            continue  # hillclimb variants carry a 4th tag; baselines only
        arch, shape, pod = parts
        try:
            with open(os.path.join(art_dir, fn)) as f:
                out[(arch, shape, pod)] = json.load(f)
        except (json.JSONDecodeError, OSError):
            continue
    return out


def build_dataset(art_dir: str, chips: Optional[List[str]] = None,
                  freq_points: int = 8, pod: str = "pod1",
                  mesh_counts: Tuple[int, ...] = (16, 64, 256),
                  mesh_freq_points: int = 4):
    """Sweep cached cells x chips x frequencies x meshes ->
    (X, y_power, y_cycles, meta).

    Labels: the calibrated simulator on the REAL compiled census (slow path),
    topology-aware — each design point's mesh prices its own collective
    time.  Features: static config/hardware numerics only (fast path inputs).
    Beyond the base-mesh DVFS sweep, ``mesh_counts`` adds a coarser
    (``mesh_freq_points``) sweep over every 2D mesh factorization of each
    count, rescaling the census first-order (``dse._scale_analysis``) — the
    coverage the predictors need now that the factorization axis carries
    signal in the DSE space.  Edge-class chips (``ici_bw == 0``) are swept
    at their only valid design point (1 chip, 1x1 mesh) instead of the base
    mesh, so the fast path stops extrapolating blindly into the edge region
    of the space.  Pass ``mesh_counts=()`` for a base-mesh-only dataset.
    """
    from repro.core import dse  # local import: dse imports this module's deps
    from repro.hw import mesh_factorizations

    chips = chips if chips is not None else list(CHIPS)
    arts = load_dryrun_artifacts(art_dir)
    X, y_power, y_cycles, meta = [], [], [], []

    def add_point(cfg, shape, names, chip, count, mesh, f, ana):
        res = costmodel.simulate(ana, chip, count, freq_mhz=f, mesh=mesh)
        X.append(features.extract(cfg, shape, chip, count,
                                  mesh_shape=mesh, freq_mhz=f))
        y_power.append(res.power_w)
        y_cycles.append(res.cycles)
        meta.append(DesignPoint(names[0], names[1], chip.name, f, mesh))

    for (arch, shape_name, pod_tag), art in sorted(arts.items()):
        if pod_tag != pod:
            continue
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        n_chips = art["roofline"]["n_chips"]
        analysis = {"flops": art["hxa"]["flops"],
                    "hbm_bytes": art["hxa"]["hbm_bytes"],
                    "collective_bytes": art["hxa"]["collective_bytes"],
                    "wire_bytes": art["hxa"]["wire_bytes"]}
        mesh_shape = (2, 16, 16) if pod == "pod2" else (16, 16)
        for chip_name in chips:
            chip = get_chip(chip_name)
            if chip.ici_bw == 0:
                ana1 = dse._scale_analysis(
                    analysis, n_chips, dse.Candidate(chip_name, 1, (1, 1), 0.0))
                for f in frequency_sweep(chip_name, freq_points):
                    add_point(cfg, shape, (arch, shape_name), chip, 1,
                              (1, 1), f, ana1)
                continue
            for f in frequency_sweep(chip_name, freq_points):
                add_point(cfg, shape, (arch, shape_name), chip, n_chips,
                          mesh_shape, f, analysis)
            for count in mesh_counts:
                for mesh in mesh_factorizations(count, 2):
                    cand0 = dse.Candidate(chip_name, count, mesh, 0.0)
                    ana = dse._scale_analysis(analysis, n_chips, cand0)
                    for f in frequency_sweep(chip_name, mesh_freq_points):
                        add_point(cfg, shape, (arch, shape_name), chip,
                                  count, mesh, f, ana)
    return (np.asarray(X, np.float32), np.asarray(y_power, np.float64),
            np.asarray(y_cycles, np.float64), meta)
