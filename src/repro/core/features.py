"""Early-design-time static features — the paper's predictor inputs.

The paper uses (a) hardware specs ("size and factor of the GPGPU, the number
of cores, the frequency, the available memory") and (b) NN descriptors
("varying layers and neurons"), plus (c) HyPA-derived executed-instruction
counts.  TPU adaptation, same three groups:

  (a) chip spec: peak FLOP/s, HBM BW/capacity, ICI BW, frequency, #chips,
      mesh shape;
  (b) arch descriptors: layers, d_model, heads, kv-heads, d_ff, vocab,
      experts/top-k, ssm dims, param counts, shape (seq, batch, kind);
  (c) ANALYTIC op counts (flops/bytes/collective estimates computed from the
      config alone with pencil-and-paper formulas — NO compilation, the whole
      point of the fast path).  These mirror what HyPA recovers from PTX, but
      from the model description instead of the artifact.

Everything here must stay cheap: called per design point inside DSE sweeps.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.hw import CHIP_TABLE, ChipSpec, ChipTable

FEATURE_NAMES: List[str] = [
    # hardware (a)
    "peak_tflops", "hbm_gbps", "hbm_gb", "ici_gbps", "freq_ghz", "n_chips",
    "mesh_data", "mesh_model", "tdp_w", "idle_w",
    # arch (b)
    "layers", "d_model", "heads", "kv_heads", "d_ff", "vocab_k", "params_b",
    "active_params_b", "experts", "topk", "ssm_state", "is_train", "is_decode",
    "seq_k", "batch", "tokens_m",
    # analytic counts (c) — the HyPA-analogue, from formulas not compilation
    "an_flops_pd_t", "an_hbm_gb_pd", "an_coll_gb_pd", "an_intensity",
    # analytic roofline-term estimates (still pencil-and-paper: counts / specs)
    "an_t_comp_ms", "an_t_mem_ms", "an_t_coll_ms", "an_t_max_ms",
]


def analytic_counts_batch(cfg: ArchConfig, shape: ShapeConfig, n_chips,
                          mesh_model) -> Dict[str, np.ndarray]:
    """Pencil-and-paper per-device flops/bytes/collective estimates,
    vectorized over candidate arrays ``n_chips`` / ``mesh_model`` (scalars
    broadcast)."""
    n_chips = np.asarray(n_chips)
    mesh_model = np.asarray(mesh_model)
    n_active = cfg.param_count(active=True)
    n_total = cfg.param_count(active=False)
    if shape.kind == "train":
        flops_global = 6.0 * n_active * shape.tokens
        # attention quadratic term (causal): 12 * L * H * hd * S^2 * B / 2 fwd+bwd
        if cfg.num_heads and cfg.attn_type != "none":
            hd = cfg.head_dim
            flops_global += 6.0 * cfg.num_layers * cfg.num_heads * hd * \
                shape.seq_len * shape.seq_len * shape.global_batch
        tokens = shape.tokens
    elif shape.kind == "prefill":
        flops_global = 2.0 * n_active * shape.tokens
        if cfg.num_heads and cfg.attn_type != "none":
            hd = cfg.head_dim
            flops_global += 2.0 * cfg.num_layers * cfg.num_heads * hd * \
                shape.seq_len * shape.seq_len * shape.global_batch
        tokens = shape.tokens
    else:  # decode: weights-bound
        flops_global = 2.0 * n_active * shape.global_batch
        if cfg.num_heads and cfg.attn_type != "none":
            hd = cfg.head_dim
            flops_global += 4.0 * cfg.num_layers * cfg.num_heads * hd * \
                shape.seq_len * shape.global_batch
        tokens = shape.global_batch
    flops_pd = flops_global / n_chips

    # HBM traffic: weights (decode: all of them, every step; train: ~3x for
    # fwd/bwd/update) + activations (~12 bytes/token/layer/d_model)
    bpp = 2.0
    if shape.kind == "train":
        w_bytes = 3.0 * n_total * (bpp + 4.0) / n_chips
        act_bytes = 14.0 * cfg.num_layers * cfg.d_model * tokens * bpp / n_chips
    elif shape.kind == "prefill":
        w_bytes = n_total * bpp / np.maximum(
            n_chips.astype(np.int64) // 8, 1) / 8
        act_bytes = 8.0 * cfg.num_layers * cfg.d_model * tokens * bpp / n_chips
    else:
        w_bytes = n_total * bpp / n_chips * mesh_model  # weights re-read per token
        kv = _kv_bytes_per_token(cfg)
        act_bytes = kv * shape.seq_len * shape.global_batch / n_chips
    hbm = w_bytes + act_bytes

    # collectives: TP all-reduces (2/layer of the activation block) + FSDP
    # weight gathers (params/device per step) + MoE dispatch
    act_block = tokens / n_chips * cfg.d_model * bpp
    coll = 4.0 * cfg.num_layers * act_block * (mesh_model - 1) / np.maximum(mesh_model, 1)
    coll = coll + n_total * bpp / n_chips * (2.0 if shape.kind == "train" else 1.0)
    if cfg.num_experts:
        coll = coll + 2.0 * cfg.experts_per_token * act_block
    intensity = flops_pd / np.maximum(hbm, 1.0)
    return {"an_flops_pd_t": flops_pd / 1e12, "an_hbm_gb_pd": hbm / 1e9,
            "an_coll_gb_pd": coll / 1e9, "an_intensity": intensity}


def analytic_counts(cfg: ArchConfig, shape: ShapeConfig, n_chips: int,
                    mesh_model: int) -> Dict[str, float]:
    """Scalar view of ``analytic_counts_batch`` (kept for per-point callers)."""
    an = analytic_counts_batch(cfg, shape, n_chips, mesh_model)
    return {k: float(v) for k, v in an.items()}


def _kv_bytes_per_token(cfg: ArchConfig) -> float:
    if cfg.attn_type == "mla":
        return 2.0 * cfg.num_layers * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
    if cfg.attn_type == "none":
        return 0.0
    return 2.0 * cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim


def _feature_columns(cfg: ArchConfig, shape: ShapeConfig, *, peak, hbm_bw,
                     hbm_bytes, ici_bw, freq_mhz, tdp, idle, n_chips,
                     mesh_data, mesh_model) -> Dict[str, np.ndarray]:
    """FEATURE_NAMES -> column, vectorized over candidates (scalars broadcast).

    Hardware args are the already-derated (frequency-clamped/scaled) chip
    numbers except ``freq_mhz``, which is the caller's raw DVFS point.
    """
    an = analytic_counts_batch(cfg, shape, n_chips, mesh_model)
    t_comp = an["an_flops_pd_t"] * 1e12 / peak * 1e3
    t_mem = an["an_hbm_gb_pd"] * 1e9 / hbm_bw * 1e3
    has_ici = np.asarray(ici_bw) > 0
    t_coll = np.where(has_ici,
                      an["an_coll_gb_pd"] * 1e9 / np.where(has_ici, ici_bw, 1.0) * 1e3,
                      0.0)
    an = {**an, "an_t_comp_ms": t_comp, "an_t_mem_ms": t_mem,
          "an_t_coll_ms": t_coll,
          "an_t_max_ms": np.maximum(np.maximum(t_comp, t_mem), t_coll)}
    return {
        "peak_tflops": np.asarray(peak) / 1e12,
        "hbm_gbps": np.asarray(hbm_bw) / 1e9,
        "hbm_gb": np.asarray(hbm_bytes) / 1e9,
        "ici_gbps": np.asarray(ici_bw) / 1e9,
        "freq_ghz": np.asarray(freq_mhz) / 1e3,
        "n_chips": np.asarray(n_chips, np.float64),
        "mesh_data": np.asarray(mesh_data, np.float64),
        "mesh_model": np.asarray(mesh_model, np.float64),
        "tdp_w": np.asarray(tdp, np.float64),
        "idle_w": np.asarray(idle, np.float64),
        "layers": float(cfg.num_layers + cfg.encoder_layers),
        "d_model": float(cfg.d_model),
        "heads": float(cfg.num_heads),
        "kv_heads": float(cfg.num_kv_heads),
        "d_ff": float(max(cfg.d_ff, cfg.moe_d_ff)),
        "vocab_k": cfg.vocab_size / 1e3,
        "params_b": cfg.param_count() / 1e9,
        "active_params_b": cfg.param_count(active=True) / 1e9,
        "experts": float(cfg.num_experts),
        "topk": float(cfg.experts_per_token),
        "ssm_state": float(cfg.ssm_state),
        "is_train": 1.0 if shape.kind == "train" else 0.0,
        "is_decode": 1.0 if shape.kind == "decode" else 0.0,
        "seq_k": shape.seq_len / 1e3,
        "batch": float(shape.global_batch),
        "tokens_m": shape.tokens / 1e6,
        **an,
    }


def extract(cfg: ArchConfig, shape: ShapeConfig, chip: ChipSpec, n_chips: int,
            mesh_shape=(16, 16), freq_mhz: float | None = None) -> List[float]:
    """One design point -> fixed-order feature vector (floats)."""
    freq = freq_mhz if freq_mhz is not None else chip.nominal_freq_mhz
    chip_f = chip.at_frequency(freq)
    mesh_data = mesh_shape[-2] if len(mesh_shape) >= 2 else 1
    mesh_model = mesh_shape[-1]
    vals = _feature_columns(
        cfg, shape, peak=chip_f.peak_flops_bf16, hbm_bw=chip_f.hbm_bw,
        hbm_bytes=chip_f.hbm_bytes, ici_bw=chip_f.ici_bw, freq_mhz=freq,
        tdp=chip_f.tdp_watts, idle=chip_f.idle_watts, n_chips=n_chips,
        mesh_data=mesh_data, mesh_model=mesh_model)
    return [float(vals[k]) for k in FEATURE_NAMES]


def extract_batch(cfg: ArchConfig, shape: ShapeConfig, chip_idx, n_chips,
                  mesh_data, mesh_model, freq_mhz,
                  table: ChipTable = CHIP_TABLE) -> np.ndarray:
    """Whole candidate arrays -> [N, n_features] float32 matrix in one pass.

    Chip properties are gathered from ``table`` by ``chip_idx``; no Python
    per-candidate loop, so building the fast-path design matrix scales to
    arbitrarily large spaces.  Row i equals ``extract`` for candidate i.
    """
    chip_idx = np.asarray(chip_idx)
    freq_raw = (table.nominal_freq_mhz[chip_idx] if freq_mhz is None
                else np.asarray(freq_mhz, np.float64))
    freq = np.clip(freq_raw, table.min_freq_mhz[chip_idx],
                   table.max_freq_mhz[chip_idx])
    peak = table.peak_flops_bf16[chip_idx] * (freq / table.nominal_freq_mhz[chip_idx])
    vals = _feature_columns(
        cfg, shape, peak=peak, hbm_bw=table.hbm_bw[chip_idx],
        hbm_bytes=table.hbm_bytes[chip_idx], ici_bw=table.ici_bw[chip_idx],
        freq_mhz=freq_raw, tdp=table.tdp_watts[chip_idx],
        idle=table.idle_watts[chip_idx], n_chips=n_chips,
        mesh_data=mesh_data, mesh_model=mesh_model)
    n = np.shape(chip_idx)[0]
    cols = [np.broadcast_to(np.asarray(vals[k], np.float64), (n,))
            for k in FEATURE_NAMES]
    return np.stack(cols, axis=1).astype(np.float32)
