"""Distributed campaign fabric: many workers, one frontier.

A campaign's unit of work is the tile index, and ``StreamingFrontier``
merges are idempotent and commutative by global candidate index — so
distribution is a ledger problem, not a numerics problem.  This module
supplies the ledger:

  * ``LeaseBoard`` — tile ownership: pending tiles are leased to workers,
    completed tiles are retired, and a lost worker's leases return to the
    pending pool for re-issue.
  * ``FabricCoordinator`` — owns the ``Campaign`` state (frontiers, tile
    stats, checkpoints); folds every delivered ``TileReduction`` via
    ``Campaign.merge_reduction`` and drives the board plus a
    ``HeartbeatMonitor`` (``repro.runtime.fault_tolerance``) for
    lease-timeout expiry.  Pure bookkeeping — it never evaluates a tile —
    and clock-injectable, so every failure path is deterministic in tests.
  * ``LocalFabric`` — N simulated workers in one process with seeded
    interleaving and scripted fault injection (kill / hang / duplicate):
    the exhaustive-identity test harness.
  * ``MultiprocessFabric`` — real ``spawn`` worker processes running
    ``TileEvaluator`` loops, shipping ``TileReduction`` payloads
    (O(survivors), cheap to pickle) over queues.  The transport is two
    queue ends per worker; a multi-host fabric only needs to replace those
    ends with sockets — the coordinator protocol is transport-agnostic.

Delivery is at-least-once by design: the coordinator folds EVERY payload it
receives, and span idempotence in ``StreamingFrontier.merge_reduced`` makes
re-folds exact no-ops — a re-issued tile that was secretly completed, or a
duplicated delivery, cannot perturb the frontier.  ``LeaseBoard.complete``
is first-write-wins for the stats ledger only.

THE invariant, gated in tests and ``benchmarks/dse_campaign.py``: for any
worker count, any interleaving, any injected worker death or duplicated
payload, the distributed frontier is bitwise-identical to the
single-process ``Campaign.run`` frontier on the same (space, workloads,
constraint, sim, evaluator).

Worker processes use the ``spawn`` start method unconditionally: JAX
runtimes are not fork-safe, and spawn children re-import ``repro`` cleanly
from the parent's ``sys.path``.
"""

from __future__ import annotations

import dataclasses
import heapq
import multiprocessing as mp
import os
import queue as queue_mod
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import costmodel, dse
from repro.dse_campaign import store
from repro.dse_campaign.config import AdaptiveConfig, CampaignConfig
from repro.dse_campaign.runner import (Campaign, CampaignResult, TileEvaluator,
                                       TileReduction, TileStat,
                                       workload_from_dict, workload_to_dict)
from repro.dse_campaign.space import SpaceSpec
from repro.runtime.fault_tolerance import HeartbeatMonitor, RetryPolicy
from repro.telemetry import metric_value

WorkerId = Union[int, str]


class FakeClock:
    """Deterministic stand-in for ``time.monotonic``: time moves only when
    the test calls ``advance``.  Injected into ``FabricCoordinator`` /
    ``HeartbeatMonitor`` so lease expiry fires at an exact, repeatable
    instant instead of depending on scheduler timing."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        """Move time forward ``dt`` seconds (time never moves on its own)."""
        self.t += float(dt)


def tile_span(space: SpaceSpec, tile: int) -> Tuple[int, int]:
    """The flat candidate span [lo, hi) of ``tile`` — the same arithmetic
    ``SpaceSpec.tiles`` uses, exposed for random tile access by workers."""
    n_tiles = space.n_tiles()
    if not 0 <= tile < n_tiles:
        raise IndexError(f"tile {tile} outside [0, {n_tiles})")
    lo = tile * space.chunk_size
    return lo, min(lo + space.chunk_size, len(space))


# ---------------------------------------------------------------------------
# worker config: the picklable description of "what to evaluate"
# ---------------------------------------------------------------------------

def campaign_config(campaign: Union[Campaign, TileEvaluator]) -> Dict:
    """The JSON/pickle-safe evaluator config shipped to fabric workers.

    Stamps ``costmodel.SIM_MODEL_VERSION`` so a mixed-version fleet (one
    worker built against a different cost model) is refused at worker
    startup instead of silently splicing incomparable scores into one
    frontier.  ``evaluator="fast"`` is refused here: fitted predictor
    models do not serialize, so the fast path stays single-process.
    """
    eng = campaign.engine if isinstance(campaign, Campaign) else campaign
    if eng.evaluator == "fast":
        raise ValueError(
            "evaluator='fast' cannot run on the fabric: fitted predictor "
            "models are not serializable to workers — use 'numpy', 'jit' or "
            "'pallas'")
    return {
        "sim_model_version": costmodel.SIM_MODEL_VERSION,
        "space": eng.space.to_dict(),
        "workloads": [workload_to_dict(wl) for wl in eng.workloads],
        "constraint": dataclasses.asdict(eng.constraint),
        "sim": dataclasses.asdict(eng.sim),
        "evaluator": eng.evaluator,
        "pipeline": eng.pipeline,
        "max_survivors": eng.max_survivors,
        # adaptive campaigns need workers to attach the seeded training
        # subsample to every reduction; exact campaigns ship None
        "adaptive": eng.adaptive.to_dict() if eng.adaptive else None,
    }


def evaluator_from_config(cfg: Dict, telemetry=None) -> TileEvaluator:
    """Rebuild a worker-side ``TileEvaluator`` from ``campaign_config``.

    Refuses a config whose ``sim_model_version`` differs from this
    process's ``costmodel.SIM_MODEL_VERSION`` — the distributed analogue of
    the checkpoint-resume version gate.  ``telemetry`` is the worker's own
    observability bundle (a telemetry object never crosses the process
    boundary; only its ``snapshot()`` dict ships back).
    """
    version = cfg.get("sim_model_version")
    if version != costmodel.SIM_MODEL_VERSION:
        raise ValueError(
            f"fabric config carries cost-model version {version!r} but this "
            f"worker is built against {costmodel.SIM_MODEL_VERSION}; a "
            "mixed-version fleet would fold incomparable scores into one "
            "frontier")
    return TileEvaluator(
        [workload_from_dict(w) for w in cfg["workloads"]],
        CampaignConfig(
            space=SpaceSpec.from_dict(cfg["space"]),
            constraint=dse.Constraint(**cfg["constraint"]),
            evaluator=cfg["evaluator"],
            sim=costmodel.SimConfig(**cfg["sim"]),
            pipeline=cfg["pipeline"],
            max_survivors=cfg["max_survivors"],
            adaptive=(AdaptiveConfig.from_dict(cfg["adaptive"])
                      if cfg.get("adaptive") else None)),
        telemetry=telemetry)


# ---------------------------------------------------------------------------
# lease ledger
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Lease:
    """One outstanding tile lease: ``worker`` owes the coordinator tile
    ``tile``, issued at coordinator-clock time ``issued_at``."""

    tile: int
    worker: WorkerId
    issued_at: float


class LeaseBoard:
    """Tile-ownership ledger for one campaign: every tile is exactly one of
    *pending* (needs a worker), *leased* (a worker owes its reduction) or
    *done* (folded into the frontier and retired).

    Invariants:

    * ``next_tile`` issues pending tiles smallest-first and never issues a
      done tile, so the board converges even when a revoked tile is
      completed by its original (presumed-dead) worker before re-issue;
    * ``complete`` is first-write-wins: the first delivery of a tile
      retires it, later duplicates report ``False`` (the caller still folds
      them — frontier idempotence, not the board, is the dedup authority);
    * ``revoke_worker`` returns a lost worker's leases to the pending pool;
      nothing is ever lost, so ``all_done`` eventually holds as long as one
      worker survives.

    ``set_priority`` overrides the default smallest-index issue order with
    an explicit ranking — the adaptive campaign's hook for leasing tiles in
    acquisition order while keeping every other board invariant (re-pended
    tiles return at their assigned rank, done tiles never re-issue).
    """

    def __init__(self, n_tiles: int, done: Sequence[int] = ()):
        if n_tiles < 1:
            raise ValueError("n_tiles must be >= 1")
        self.n_tiles = int(n_tiles)
        self._done = {int(t) for t in done if 0 <= int(t) < n_tiles}
        self._rank: Dict[int, int] = {}
        self._pending = [(t, t) for t in
                         sorted(set(range(self.n_tiles)) - self._done)]
        heapq.heapify(self._pending)
        self._leases: Dict[int, Lease] = {}
        self._parked: set = set()
        self._prefix = 0

    def _rank_of(self, tile: int) -> int:
        """Issue rank of ``tile``: its ``set_priority`` position when
        ranked, else after every ranked tile, in index order (the default
        board — no ranking — degenerates to rank == index)."""
        if not self._rank:
            return tile
        return self._rank.get(tile, len(self._rank) + tile)

    def set_priority(self, order: Sequence[int]) -> None:
        """Lease tiles in ``order`` (first element first) ahead of any tile
        not listed; unlisted tiles keep their relative index order after
        the listed ones.  Re-heapifies the pending pool; done/leased tiles
        are unaffected."""
        self._rank = {int(t): i for i, t in enumerate(order)}
        if len(self._rank) != len(order):
            raise ValueError("set_priority order contains duplicate tiles")
        pending = {t for _, t in self._pending
                   if t not in self._done and t not in self._leases}
        self._pending = [(self._rank_of(t), t) for t in pending]
        heapq.heapify(self._pending)

    def next_tile(self, worker: WorkerId, now: float = 0.0) -> Optional[int]:
        """Lease the lowest-rank pending tile to ``worker`` — smallest index
        by default, acquisition order after ``set_priority`` (``None`` when
        no tile is pending — outstanding leases may still re-pend later)."""
        while self._pending:
            _, tile = heapq.heappop(self._pending)
            if (tile in self._done or tile in self._leases
                    or tile in self._parked):
                continue
            self._leases[tile] = Lease(tile, worker, now)
            return tile
        return None

    def complete(self, tile: int) -> bool:
        """Retire ``tile``; ``True`` only for the first completion.  A late
        delivery of a parked (poison-quarantined) tile also completes it —
        the evidence of poison is worker death, and a delivered reduction is
        proof the tile evaluated after all."""
        if not 0 <= tile < self.n_tiles:
            raise IndexError(f"tile {tile} outside [0, {self.n_tiles})")
        if tile in self._done:
            return False
        self._done.add(tile)
        self._leases.pop(tile, None)
        self._parked.discard(tile)
        return True

    def park(self, tile: int) -> bool:
        """Quarantine ``tile``: no longer issued by ``next_tile`` until
        ``unpark``.  Its lease (if any) is dropped.  Returns ``False`` for
        an already-done or already-parked tile."""
        if not 0 <= tile < self.n_tiles:
            raise IndexError(f"tile {tile} outside [0, {self.n_tiles})")
        if tile in self._done or tile in self._parked:
            return False
        self._leases.pop(tile, None)
        self._parked.add(tile)
        return True

    def unpark(self, tile: int) -> bool:
        """Return a parked tile to the pending pool (retry path)."""
        if tile not in self._parked:
            return False
        self._parked.discard(tile)
        heapq.heappush(self._pending, (self._rank_of(tile), tile))
        return True

    def revoke_worker(self, worker: WorkerId) -> List[int]:
        """Return all of ``worker``'s outstanding leases to the pending
        pool (the lost-worker path); returns the re-pended tiles."""
        tiles = sorted(t for t, l in self._leases.items() if l.worker == worker)
        for t in tiles:
            del self._leases[t]
            heapq.heappush(self._pending, (self._rank_of(t), t))
        return tiles

    @property
    def all_done(self) -> bool:
        """True once every tile has completed (leases outstanding or not)."""
        return len(self._done) == self.n_tiles

    @property
    def all_settled(self) -> bool:
        """True once every tile is either done or parked — the fabric loop's
        exit condition when poison tiles are quarantined (they are retried
        single-process afterwards, outside the worker fleet)."""
        return len(self._done) + len(self._parked) == self.n_tiles

    @property
    def parked_tiles(self) -> List[int]:
        """Sorted poison-quarantined tile indices."""
        return sorted(self._parked)

    @property
    def n_done(self) -> int:
        """Completed tile count."""
        return len(self._done)

    @property
    def done_tiles(self) -> List[int]:
        """Sorted completed tile indices (checkpoint / observability view)."""
        return sorted(self._done)

    @property
    def leases(self) -> Dict[int, Lease]:
        """Snapshot copy of outstanding leases, keyed by tile."""
        return dict(self._leases)

    @property
    def n_pending(self) -> int:
        """Tiles neither done, leased nor parked (the heap may hold stale
        entries for revoked-then-completed tiles; they are filtered here)."""
        return len([t for _, t in self._pending
                    if t not in self._done and t not in self._leases
                    and t not in self._parked])

    def contiguous_done_prefix(self) -> int:
        """First tile index NOT in the done set — the ``next_tile`` a plain
        single-process ``Campaign.from_checkpoint`` resume starts at."""
        while self._prefix in self._done:
            self._prefix += 1
        return self._prefix


def _tile_intervals(tiles: Sequence[int]) -> List[List[int]]:
    """Sorted tile indices -> half-open [lo, hi) interval list (compact
    checkpoint encoding of the done set)."""
    out: List[List[int]] = []
    for t in sorted(tiles):
        if out and t == out[-1][1]:
            out[-1][1] = t + 1
        else:
            out.append([t, t + 1])
    return out


def _expand_intervals(intervals: Sequence[Sequence[int]]) -> List[int]:
    """Inverse of ``_tile_intervals``."""
    return [t for lo, hi in intervals for t in range(lo, hi)]


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------

class FabricCoordinator:
    """The single owner of campaign state in a distributed run.

    Wraps a ``Campaign`` (whose frontiers/tile-stats/checkpoint it reuses
    unchanged) with a ``LeaseBoard`` and a ``HeartbeatMonitor``.  Workers
    interact through exactly three verbs:

      * ``lease(worker)`` — claim the next pending tile (also a heartbeat);
      * ``deliver(worker, tile, reduction)`` — ship a ``TileReduction``;
        ALWAYS folded into the frontiers (at-least-once delivery — span
        idempotence makes duplicates exact no-ops), first delivery retires
        the tile and records its ``TileStat``;
      * ``worker_lost(worker)`` / ``expire()`` — revoke a dead worker's
        leases back to pending (explicit death vs. lease-timeout on the
        injected clock).

    Checkpoints keep the single-process schema (version 1) and add an
    optional ``"fabric"`` key (done-tile intervals + outstanding leases);
    ``next_tile`` is maintained as the contiguous done prefix, so a plain
    ``Campaign.from_checkpoint`` resume of a fabric checkpoint is correct —
    any out-of-prefix tiles it replays re-merge as exact no-ops.
    """

    def __init__(self, campaign: Campaign, lease_timeout_s: float = 300.0,
                 clock=time.monotonic, done_tiles: Sequence[int] = (),
                 poison_threshold: int = 3,
                 parked_tiles: Sequence[int] = ()):
        self.campaign = campaign
        prefix_done = range(campaign.next_tile)
        self.board = LeaseBoard(campaign.space.n_tiles(),
                                done=[*prefix_done, *done_tiles])
        self.monitor = HeartbeatMonitor([], timeout_s=lease_timeout_s,
                                        clock=clock)
        if poison_threshold < 1:
            raise ValueError("poison_threshold must be >= 1")
        self.poison_threshold = int(poison_threshold)
        # tile -> distinct workers that died while holding it; at
        # poison_threshold the tile is quarantined instead of re-issued
        self._tile_crashes: Dict[int, set] = {}
        self.stats = {"deliveries": 0, "duplicates": 0, "reissued_tiles": 0,
                      "lost_workers": [], "worker_crashes": [],
                      "worker_clean_exits": [], "poison_tiles": [],
                      "poison_retried": [], "recovery": None}
        # the coordinator shares the campaign's telemetry: one trace file
        # holds the lease/deliver spans AND the evaluation spans
        self.telemetry = campaign.telemetry
        self._c_deliveries = self.telemetry.counter("fabric_deliveries_total")
        self._c_duplicates = self.telemetry.counter("fabric_duplicates_total")
        self._c_reissued = self.telemetry.counter(
            "fabric_reissued_tiles_total")
        self._c_lost = self.telemetry.counter("fabric_lost_workers_total")
        self._c_expiries = self.telemetry.counter(
            "fabric_lease_expiries_total")
        self._c_crashed = self.telemetry.counter("fabric_worker_crashed")
        self._c_clean = self.telemetry.counter("fabric_worker_done")
        self._c_poison = self.telemetry.counter("fabric_poison_tiles_total")
        for t in parked_tiles:
            if self.board.park(int(t)):
                self.stats["poison_tiles"].append(int(t))

    @classmethod
    def from_checkpoint(cls, path: str, lease_timeout_s: float = 300.0,
                        clock=time.monotonic, poison_threshold: int = 3,
                        **campaign_kwargs) -> "FabricCoordinator":
        """Resume a distributed campaign from a (fabric or single-process)
        checkpoint; out-of-prefix tiles recorded under the ``"fabric"`` key
        are marked done so they are not re-issued.  Leases recorded at
        checkpoint time are NOT restored — a coordinator restart implicitly
        revokes them, and the tiles simply re-pend (counted as
        ``reissued_tiles``).  Parked poison tiles stay parked across the
        restart.

        The load path is the recovering one: a corrupt checkpoint is
        quarantined to ``*.corrupt`` and the newest valid generation is used
        instead; the write-ahead journal is cross-checked, and the full
        recovery report (file used, quarantined files, journal generation)
        lands in ``stats["recovery"]``.
        """
        state, report = store.load_checkpoint_recovering(path)
        version = state.get("version")
        if version != 1:
            raise ValueError(f"unsupported campaign checkpoint version "
                             f"{version!r} in {path}")
        campaign = Campaign.from_state(state, source=path, **campaign_kwargs)
        fabric_state = state.get("fabric") or {}
        done = _expand_intervals(fabric_state.get("done", []))
        parked = fabric_state.get("parked", [])
        coord = cls(campaign, lease_timeout_s=lease_timeout_s, clock=clock,
                    done_tiles=done, poison_threshold=poison_threshold,
                    parked_tiles=parked)
        journal = store.CheckpointJournal(path)
        records, torn = journal.records()
        released = [t for t, _ in fabric_state.get("leases", [])]
        coord.stats["reissued_tiles"] += len(released)
        coord._c_reissued.inc(len(released))
        coord.stats["recovery"] = {
            "path": report["path"],
            "quarantined": report["quarantined"],
            "fallback_generation": report["fallback_generation"],
            "journal_generation": (int(records[-1]["generation"])
                                   if records else None),
            "journal_torn_lines": torn,
            "released_leases": released,
            "tiles_done_at_restart": coord.board.n_done,
        }
        coord.telemetry.counter("fabric_coordinator_recoveries_total").inc()
        if report["quarantined"]:
            coord.telemetry.counter(
                "fabric_checkpoints_quarantined_total").inc(
                    len(report["quarantined"]))
        return coord

    # -- the three worker verbs --------------------------------------------

    def register_worker(self, worker: WorkerId) -> None:
        """Admit ``worker`` to heartbeat monitoring."""
        self.monitor.register(worker)

    def lease(self, worker: WorkerId) -> Optional[int]:
        """Claim the next pending tile for ``worker`` (beats its heart)."""
        with self.telemetry.span("lease", worker=worker):
            self.monitor.beat(worker)
            return self.board.next_tile(worker, now=self.monitor.clock())

    def deliver(self, worker: WorkerId, tile: int, reduction: TileReduction,
                busy_s: float = 0.0) -> bool:
        """Fold one delivered ``TileReduction``; returns ``True`` iff this
        was the tile's FIRST delivery (stats recorded), ``False`` for a
        duplicate (still folded — provably a no-op)."""
        with self.telemetry.span("deliver", worker=worker, tile=tile):
            if worker in self.monitor.last_seen:
                self.monitor.beat(worker)
            self.campaign.merge_reduction(reduction, tile)
            self.stats["deliveries"] += 1
            self._c_deliveries.inc()
            self.telemetry.gauge("fabric_worker_busy_s",
                                 worker=worker).add(busy_s)
            newly_done = self.board.complete(tile)
            if newly_done:
                self.campaign.tile_stats.append(TileStat(
                    tile=tile,
                    candidates=(reduction.hi - reduction.lo)
                    * len(self.campaign.workloads),
                    wall_s=busy_s))
                self.campaign.next_tile = self.board.contiguous_done_prefix()
            else:
                self.stats["duplicates"] += 1
                self._c_duplicates.inc()
            return newly_done

    def worker_lost(self, worker: WorkerId,
                    crashed: bool = True) -> List[int]:
        """Declare ``worker`` dead: its leases re-pend for re-issue and it
        leaves heartbeat monitoring.  Late deliveries from it still fold.

        ``crashed=True`` (death by nonzero exit, chaos kill, or lease
        expiry) attributes the death to every tile the worker held: a tile
        that kills ``poison_threshold`` DISTINCT workers is quarantined
        (parked) instead of re-issued — one poisoned tile must not grind
        through the whole fleet.  ``crashed=False`` is a clean protocol
        exit; it re-pends leases without attribution and increments
        ``fabric_worker_done`` instead of ``fabric_worker_crashed``.
        """
        held = [t for t, l in self.board.leases.items() if l.worker == worker]
        tiles = self.board.revoke_worker(worker)
        self.monitor.forget(worker)
        self.stats["reissued_tiles"] += len(tiles)
        self.stats["lost_workers"].append(worker)
        self._c_reissued.inc(len(tiles))
        self._c_lost.inc()
        if crashed:
            self.stats["worker_crashes"].append(worker)
            self._c_crashed.inc()
            for t in held:
                culprits = self._tile_crashes.setdefault(t, set())
                culprits.add(worker)
                if len(culprits) >= self.poison_threshold:
                    self.quarantine_tile(t)
        else:
            self.stats["worker_clean_exits"].append(worker)
            self._c_clean.inc()
        return tiles

    def quarantine_tile(self, tile: int) -> bool:
        """Park a poison tile: no re-issue to the fleet; it is retried once
        single-process at campaign end (``retry_parked``)."""
        if not self.board.park(tile):
            return False
        self.stats["poison_tiles"].append(tile)
        self._c_poison.inc()
        return True

    def retry_parked(self) -> List[int]:
        """Evaluate every parked tile once, single-process, in the
        coordinator — the end-of-campaign retry that turns a poison
        quarantine into either a completed tile or a loud failure in THIS
        process (debuggable, not a silent frontier gap).  Returns the tiles
        retried."""
        engine = self.campaign.engine
        space = self.campaign.space
        clock = self.telemetry.clock
        retried = []
        for tile in list(self.board.parked_tiles):
            lo, hi = tile_span(space, tile)
            t0 = clock()
            with self.telemetry.span("poison_retry", tile=tile):
                batch = space.slice(lo, hi, with_candidates=not engine.fused)
                reduction = engine.reduce_tile(batch, lo)
            self.board.unpark(tile)
            self.deliver("__poison_retry__", tile, reduction,
                         busy_s=clock() - t0)
            self.stats["poison_retried"].append(tile)
            retried.append(tile)
        return retried

    def expire(self) -> Dict[WorkerId, List[int]]:
        """Lease-timeout sweep: every worker that has been silent for longer
        than ``timeout_s`` on the injected clock WHILE holding a lease is
        declared lost.  Idle workers owe the coordinator nothing, so silence
        alone never expels them (process death is the transport's job to
        detect)."""
        leased = {lease.worker for lease in self.board.leases.values()}
        expired = {w: self.worker_lost(w)
                   for w in self.monitor.dead_hosts() if w in leased}
        if expired:
            self._c_expiries.inc(len(expired))
        return expired

    # -- state --------------------------------------------------------------

    @property
    def all_done(self) -> bool:
        """True once the lease board has every tile completed."""
        return self.board.all_done

    def state_dict(self) -> Dict:
        """Campaign schema version 1 plus a ``"fabric"`` key (done-tile
        intervals + outstanding leases + parked poison tiles); ``next_tile``
        is the contiguous done prefix, so plain ``Campaign.from_checkpoint``
        also resumes this."""
        state = self.campaign.state_dict()
        state["fabric"] = {
            "done": _tile_intervals(self.board.done_tiles),
            "leases": [[l.tile, l.worker] for l in
                       sorted(self.board.leases.values(),
                              key=lambda l: l.tile)],
            "parked": self.board.parked_tiles,
        }
        return state

    def checkpoint(self, path: str) -> str:
        """Atomically persist ``state_dict`` to ``path``."""
        with self.telemetry.span("checkpoint_write",
                                 n_done=self.board.n_done):
            return store.save_checkpoint(self.state_dict(), path)

    def result(self, wall_s: float) -> CampaignResult:
        """Materialize the campaign result with the board's (possibly
        non-contiguous) completed-tile count."""
        return self.campaign._result(wall_s, tiles_done=self.board.n_done)


# ---------------------------------------------------------------------------
# fault injection (tests + benchmark gates)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultInjection:
    """Scripted failures for identity testing.

    ``kill_worker`` crashes that worker mid-tile after it has completed
    ``kill_after_tiles`` tiles (evaluation started, reduction never ships);
    ``duplicate`` redelivers the first completed payload a second time;
    ``hang_worker`` (``LocalFabric`` + ``FakeClock`` only) takes its lease
    and never finishes, so only lease-timeout expiry can recover the tile;
    ``poison_tile`` kills EVERY worker that receives that tile — the
    coordinator's poison quarantine (park at ``poison_threshold`` distinct
    deaths, retry single-process at campaign end) is the only way such a
    run completes.
    """

    kill_worker: Optional[int] = None
    kill_after_tiles: int = 1
    duplicate: bool = False
    hang_worker: Optional[int] = None
    poison_tile: Optional[int] = None


# ---------------------------------------------------------------------------
# in-process deterministic fabric (the identity-test harness)
# ---------------------------------------------------------------------------

class LocalFabric:
    """N simulated workers in one process, interleaved by a seeded RNG.

    All workers share the campaign's own ``TileEvaluator`` (evaluation is a
    pure function of config + span, so sharing changes nothing and avoids
    re-jitting per worker); what varies across seeds is WHICH worker
    completes next — i.e. the delivery order the coordinator observes.
    Faults from ``FaultInjection`` are replayed exactly.  With a
    ``FakeClock`` the virtual clock advances 1.0 per loop iteration, making
    hang-expiry deterministic.

    This is the harness behind the interleaving/fault identity tests: for
    every seed and fault script, ``run().frontiers`` must be bitwise-equal
    to the single-process ``Campaign.run`` frontiers.
    """

    def __init__(self, campaign_or_coord: Union[Campaign, FabricCoordinator],
                 n_workers: int = 2, seed: int = 0,
                 lease_timeout_s: float = 1e9, clock=None,
                 fault: Optional[FaultInjection] = None,
                 poison_threshold: int = 3,
                 retry: Optional[RetryPolicy] = None):
        if isinstance(campaign_or_coord, FabricCoordinator):
            self.coord = campaign_or_coord
        else:
            self.coord = FabricCoordinator(
                campaign_or_coord, lease_timeout_s=lease_timeout_s,
                clock=clock if clock is not None else FakeClock(),
                poison_threshold=poison_threshold)
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.n_workers = int(n_workers)
        self.seed = int(seed)
        self.fault = fault or FaultInjection()
        self.retry = retry or RetryPolicy()
        if (self.fault.hang_worker is not None
                and not hasattr(self.coord.monitor.clock, "advance")):
            raise ValueError("hang_worker injection needs a FakeClock — a "
                             "real clock would spin until wall-clock expiry")
        if (self.fault.poison_tile is not None
                and not hasattr(self.coord.monitor.clock, "advance")):
            raise ValueError("poison_tile injection needs a FakeClock — "
                             "respawn backoff is paced on the virtual clock")

    def run(self, max_completions: Optional[int] = None,
            checkpoint_path: Optional[str] = None) -> CampaignResult:
        """Drive the fabric to completion (or ``max_completions`` tile
        completions, the distributed-interrupt point for resume tests)."""
        coord, fault = self.coord, self.fault
        campaign = coord.campaign
        engine = campaign.engine
        space = campaign.space
        tel = campaign.telemetry
        clock = tel.clock
        rng = np.random.default_rng(self.seed)
        t_start = clock()

        alive = list(range(self.n_workers))
        for w in alive:
            coord.register_worker(w)
        holding: Dict[int, int] = {}
        completed = {w: 0 for w in alive}
        kill_pending = fault.kill_worker is not None
        duplicate_pending = fault.duplicate
        n_completions = 0
        mclock = coord.monitor.clock  # the virtual clock (FakeClock in tests)
        respawns: List[Tuple[float, int]] = []  # (due time, new worker id)
        next_wid = self.n_workers
        n_respawned = 0

        def issue_leases():
            for w in alive:
                if w not in holding:
                    tile = coord.lease(w)
                    if tile is not None:
                        holding[w] = tile

        issue_leases()
        while not coord.all_done:
            if max_completions is not None and n_completions >= max_completions:
                break
            if coord.board.all_settled and not respawns:
                break  # only parked poison tiles remain: retried below
            active = [w for w in holding if w != fault.hang_worker]
            if active:
                w = active[int(rng.integers(len(active)))]
                tile = holding.pop(w)
                if tile == fault.poison_tile:
                    # poison: whoever touches the tile dies mid-evaluation;
                    # a replacement spawns after the RetryPolicy backoff on
                    # the virtual clock (attribution eventually parks it)
                    alive.remove(w)
                    coord.worker_lost(w, crashed=True)
                    respawns.append(
                        (mclock() + self.retry.backoff_s(n_respawned),
                         next_wid))
                    n_respawned += 1
                    next_wid += 1
                elif (kill_pending and w == fault.kill_worker
                        and completed[w] >= fault.kill_after_tiles):
                    # dies mid-tile: evaluation started, nothing delivered
                    kill_pending = False
                    alive.remove(w)
                    coord.worker_lost(w)
                else:
                    lo, hi = tile_span(space, tile)
                    t0 = clock()
                    with tel.span("tile_eval", tile=tile, worker=w):
                        with tel.span("tile_slice", tile=tile):
                            batch = space.slice(
                                lo, hi, with_candidates=not engine.fused)
                        tr = engine.reduce_tile(batch, lo)
                    busy = clock() - t0
                    coord.deliver(w, tile, tr, busy_s=busy)
                    if duplicate_pending:
                        duplicate_pending = False
                        coord.deliver(w, tile, tr, busy_s=0.0)
                    completed[w] += 1
                    n_completions += 1
                    if checkpoint_path:
                        coord.checkpoint(checkpoint_path)
            if hasattr(coord.monitor.clock, "advance"):
                coord.monitor.clock.advance(1.0)
            for w in coord.expire():
                if w in alive:
                    alive.remove(w)
                holding.pop(w, None)
            for due, nw in [r for r in respawns if mclock() >= r[0]]:
                respawns.remove((due, nw))
                coord.register_worker(nw)
                alive.append(nw)
                completed[nw] = 0
            issue_leases()
            if not coord.all_done and not alive and not respawns:
                raise RuntimeError(
                    f"fabric stalled: all workers lost with "
                    f"{coord.board.n_pending} tiles pending")
        if coord.board.parked_tiles and max_completions is None:
            coord.retry_parked()
        if checkpoint_path:
            coord.checkpoint(checkpoint_path)
        return coord.result(clock() - t_start)


# ---------------------------------------------------------------------------
# multiprocess fabric (real workers, spawn)
# ---------------------------------------------------------------------------

def _worker_main(worker_id: int, cfg: Dict, worker_cfg: Dict,
                 task_q, result_q) -> None:
    """Fabric worker loop (runs in a ``spawn`` child).

    Protocol (all messages are 5-tuples ``(kind, wid, tile, payload,
    busy_s)``): emits ``("ready", ...)`` once warm, then for each leased
    tile received on ``task_q`` evaluates it with the shared
    ``TileEvaluator`` and emits ``("result", wid, tile, TileReduction,
    busy_s)``; ``None`` on ``task_q`` is shutdown, answered with a terminal
    ``("metrics", wid, None, snapshot, 0.0)`` carrying the worker's own
    telemetry snapshot (``worker_busy_s_total`` / ``worker_tiles_total``
    plus the evaluator counters) — per-worker busy time is now measured
    where the work happens instead of reconstructed from coordinator clock
    arithmetic.  ``busy_s`` is ``time.process_time`` (CPU actually burned
    on the tile), the machine-independent cost the scaling benchmark
    aggregates.  Fused evaluators warm up (trace + compile) on tile 0's
    shape before signalling ready, so per-tile busy excludes one-time
    compile cost.
    """
    try:
        evaluator = evaluator_from_config(cfg)
        tel = evaluator.telemetry
        c_busy = tel.counter("worker_busy_s_total")
        c_tiles = tel.counter("worker_tiles_total")
        space = evaluator.space
        if evaluator.fused:
            lo, hi = tile_span(space, 0)
            evaluator.reduce_tile(space.slice(lo, hi, with_candidates=False),
                                  lo)
        result_q.put(("ready", worker_id, None, None, 0.0))
        die_on_nth = (worker_cfg or {}).get("die_on_nth_tile")
        die_on_tile = (worker_cfg or {}).get("die_on_tile")
        n_received = 0
        while True:
            tile = task_q.get()
            if tile is None:
                result_q.put(("metrics", worker_id, None, tel.snapshot(),
                              0.0))
                return
            n_received += 1
            t0 = time.process_time()
            lo, hi = tile_span(space, tile)
            with tel.span("tile_eval", tile=tile, worker=worker_id):
                with tel.span("tile_slice", tile=tile):
                    batch = space.slice(lo, hi,
                                        with_candidates=not evaluator.fused)
                if die_on_nth is not None and n_received >= die_on_nth:
                    # Flush and retire the queue's feeder thread before
                    # dying: ``os._exit`` while the feeder holds the shared
                    # ``result_q`` write lock (it can lose the GIL between
                    # sending bytes and releasing the lock) would wedge
                    # every surviving worker's puts — the fabric stalls.
                    result_q.close()
                    result_q.join_thread()
                    os._exit(40)  # injected crash mid-tile: no result ships
                if die_on_tile is not None and tile == die_on_tile:
                    result_q.close()      # poison tile: every worker that
                    result_q.join_thread()  # receives it dies the same way
                    os._exit(41)
                reduction = evaluator.reduce_tile(batch, lo)
            busy = time.process_time() - t0
            c_busy.inc(busy)
            c_tiles.inc()
            result_q.put(("result", worker_id, tile, reduction, busy))
    except BaseException as exc:  # surface config/eval errors, then die
        result_q.put(("error", worker_id, None, repr(exc), 0.0))
        result_q.close()          # guarantee the error ships and the shared
        result_q.join_thread()    # write lock is released before exiting
        os._exit(1)


class MultiprocessFabric:
    """Coordinator + N real ``spawn`` worker processes on one machine.

    The coordinator thread never evaluates: it leases tiles, folds
    delivered ``TileReduction`` payloads, detects death two ways — process
    exit (``Process.is_alive``, immediate) and lease timeout
    (``HeartbeatMonitor``, catches hangs) — and re-issues revoked tiles to
    surviving workers.  ``run`` returns the standard ``CampaignResult``;
    ``self.stats`` additionally carries the per-worker busy-CPU ledger
    (``worker_busy_s``) and the measurement window (``window_s``, from
    all-workers-ready to last fold — imports and jit warm-up excluded) that
    ``benchmarks/dse_campaign.py`` turns into scaling rows.
    """

    def __init__(self, campaign: Campaign, n_workers: int = 2,
                 lease_timeout_s: float = 300.0,
                 fault: Optional[FaultInjection] = None,
                 checkpoint_every: int = 8,
                 retry: Optional[RetryPolicy] = None,
                 max_respawns: int = 0, poison_threshold: int = 3):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.campaign = campaign
        self.n_workers = int(n_workers)
        self.lease_timeout_s = float(lease_timeout_s)
        self.fault = fault or FaultInjection()
        if self.fault.hang_worker is not None:
            raise ValueError("hang_worker is a LocalFabric-only injection; "
                             "multiprocess hangs are recovered by the lease "
                             "timeout in real time")
        self.checkpoint_every = max(int(checkpoint_every), 1)
        # one RetryPolicy carries every time constant of the run: respawn
        # backoff schedule plus the transport poll/join/drain timeouts that
        # used to be hard-coded literals scattered through this loop
        self.retry = retry or RetryPolicy()
        self.max_respawns = int(max_respawns)
        self.poison_threshold = int(poison_threshold)
        self.stats: Dict = {}

    def run(self, checkpoint_path: Optional[str] = None) -> CampaignResult:
        """Run the campaign to completion across the worker fleet.

        Leases are issued only after every worker is ready (or declared
        lost), so tile distribution is fair regardless of per-worker warm-up
        time.  Worker death is detected via ``Process.is_alive`` and lease
        timeout; lost workers' tiles re-issue to survivors.  Raises if the
        whole fleet dies.  The returned frontier is bitwise-identical to the
        single-process run.
        """
        cfg = campaign_config(self.campaign)
        clock = self.campaign.telemetry.clock
        # clock audit (PR 10): the coordinator's lease clock IS the telemetry
        # clock — one injected time source for the whole run, so a FakeClock
        # drives lease expiry and spans alike
        coord = FabricCoordinator(self.campaign,
                                  lease_timeout_s=self.lease_timeout_s,
                                  clock=clock,
                                  poison_threshold=self.poison_threshold)
        ctx = mp.get_context("spawn")  # jax is not fork-safe
        result_q = ctx.Queue()
        procs: Dict[int, mp.Process] = {}
        task_qs: Dict[int, object] = {}
        busy_s: Dict[int, float] = {}
        worker_metrics: Dict[int, Dict] = {}
        idle: List[int] = []
        ready: set = set()
        lost: set = set()
        duplicate_pending = self.fault.duplicate
        window_t0: Optional[float] = None
        # worker respawn: (due time on the injected clock, new worker id);
        # backoff comes from the shared RetryPolicy, not an ad-hoc sleep
        pending_respawns: List[Tuple[float, int]] = []
        n_respawned = 0
        next_wid = self.n_workers

        def spawn_worker(w: int):
            worker_cfg = {}
            if self.fault.kill_worker == w:
                worker_cfg["die_on_nth_tile"] = self.fault.kill_after_tiles + 1
            if self.fault.poison_tile is not None:
                worker_cfg["die_on_tile"] = self.fault.poison_tile
            task_qs[w] = ctx.Queue()
            p = ctx.Process(target=_worker_main,
                            args=(w, cfg, worker_cfg, task_qs[w], result_q),
                            daemon=True)
            p.start()
            procs[w] = p
            busy_s[w] = 0.0

        for w in range(self.n_workers):
            spawn_worker(w)

        def issue_leases():
            # hold the first lease until every worker is warm (or lost):
            # issuing early would let the first-ready worker drain the board
            # before its peers even finish compiling, skewing both the
            # work split and the measurement window
            if len(ready | lost) < self.n_workers:
                return
            while idle:
                w = idle[0]
                tile = coord.lease(w)
                if tile is None:
                    return
                idle.pop(0)
                task_qs[w].put(tile)

        def mark_lost(w: int, crashed: bool = True):
            nonlocal window_t0, n_respawned, next_wid
            lost.add(w)
            if w in idle:
                idle.remove(w)
            coord.worker_lost(w, crashed=crashed)
            if window_t0 is None and len(ready | lost) >= self.n_workers:
                window_t0 = clock()  # peer died during warm-up
            if crashed and n_respawned < self.max_respawns:
                pending_respawns.append(
                    (clock() + self.retry.backoff_s(n_respawned), next_wid))
                n_respawned += 1
                next_wid += 1

        try:
            while not coord.all_done:
                if coord.board.all_settled and not pending_respawns:
                    break  # only parked poison tiles remain: retried below
                try:
                    kind, w, tile, payload, t = result_q.get(
                        timeout=self.retry.poll_s)
                except queue_mod.Empty:
                    kind = None
                if kind == "ready":
                    coord.register_worker(w)
                    idle.append(w)
                    ready.add(w)
                    if len(ready | lost) >= self.n_workers:
                        if window_t0 is None:
                            window_t0 = clock()
                        issue_leases()
                elif kind == "metrics":
                    worker_metrics[w] = payload
                elif kind == "result":
                    busy_s[w] += t
                    newly = coord.deliver(w, tile, payload, busy_s=t)
                    if duplicate_pending and newly:
                        duplicate_pending = False
                        coord.deliver(w, tile, payload, busy_s=0.0)
                    if w not in lost:
                        idle.append(w)
                    if (checkpoint_path and newly and
                            coord.board.n_done % self.checkpoint_every == 0):
                        coord.checkpoint(checkpoint_path)
                elif kind == "error":
                    raise RuntimeError(f"fabric worker {w} failed: {payload}")
                for w2, p in procs.items():
                    if w2 not in lost and not p.is_alive():
                        # the exit code tells crash (nonzero: chaos kill,
                        # poison tile, hard fault) from clean protocol exit
                        mark_lost(w2, crashed=(p.exitcode is None
                                               or p.exitcode != 0))
                for w2 in coord.expire():
                    if w2 not in lost:
                        mark_lost(w2)
                for due, nw in [r for r in pending_respawns
                                if clock() >= r[0]]:
                    pending_respawns.remove((due, nw))
                    spawn_worker(nw)
                    self.campaign.telemetry.counter(
                        "fabric_worker_respawns_total").inc()
                issue_leases()
                if (not coord.all_done and not coord.board.all_settled
                        and len(lost) == len(procs) and not pending_respawns):
                    raise RuntimeError(
                        f"fabric stalled: all {len(procs)} workers lost with "
                        f"{coord.board.n_pending} tiles pending")
        finally:
            for w, p in procs.items():
                if p.is_alive():
                    try:
                        task_qs[w].put(None)
                    except Exception:
                        pass
            for p in procs.values():
                p.join(timeout=self.retry.join_timeout_s)
                if p.is_alive():
                    p.terminate()
            # shutdown exit-code audit: workers that were never declared
            # lost mid-run still report how they ended — 0 is a clean
            # protocol exit (fabric_worker_done), anything else (including
            # a terminate() after a wedged join) counts as a crash
            for w, p in procs.items():
                if w in lost or p.exitcode is None:
                    continue
                if p.exitcode == 0:
                    coord.stats["worker_clean_exits"].append(w)
                    coord._c_clean.inc()
                else:
                    coord.stats["worker_crashes"].append(w)
                    coord._c_crashed.inc()
            # drain the terminal payloads: each clean-shutdown worker
            # answers its None with a ("metrics", ...) snapshot (a crashed
            # worker never does — its entry is simply absent)
            while True:
                try:
                    kind, w, tile, payload, t = result_q.get(
                        timeout=self.retry.drain_timeout_s)
                except queue_mod.Empty:
                    break
                if kind == "metrics":
                    worker_metrics[w] = payload
        if coord.board.parked_tiles:
            # poison tiles: one single-process retry in THIS process — a
            # genuinely broken tile now raises here with a real traceback
            coord.retry_parked()
        window_s = clock() - window_t0 if window_t0 is not None else 0.0
        if checkpoint_path:
            coord.checkpoint(checkpoint_path)
        # prefer the busy total the worker measured itself (shipped in its
        # metrics snapshot) over the coordinator-side per-result sum; the
        # per-result sum stays the fallback for crashed workers
        busy_final = {
            w: metric_value(worker_metrics[w], "worker_busy_s_total",
                            default=busy_s[w])
            if w in worker_metrics else busy_s[w]
            for w in busy_s}
        self.stats = {
            **coord.stats,
            "n_workers": self.n_workers,
            "worker_busy_s": busy_final,
            "max_worker_busy_s": (max(busy_final.values())
                                  if busy_final else 0.0),
            "total_busy_s": sum(busy_final.values()),
            "window_s": window_s,
            "worker_metrics": worker_metrics,
        }
        return coord.result(window_s)


def run_distributed(workloads_or_campaign, config: CampaignConfig = None,
                    fault: Optional[FaultInjection] = None,
                    retry: Optional[RetryPolicy] = None,
                    max_respawns: int = 0, poison_threshold: int = 3,
                    **legacy) -> Tuple[CampaignResult, Dict]:
    """One-call distributed sweep; returns ``(CampaignResult, fabric stats)``.

    The documented surface is ``run_distributed(workloads, config)``: the
    ``CampaignConfig`` supplies the space/evaluator AND the fabric options
    (``n_workers``, ``lease_timeout_s``, ``checkpoint_path``) — the same
    config object the ``Campaign`` / ``TileEvaluator`` / ``SelectionEngine``
    entry points construct from.  Passing an already-built ``Campaign``
    also works (its own config drives the fabric); the pre-config keyword
    form ``run_distributed(campaign, n_workers=..., lease_timeout_s=...,
    checkpoint_path=...)`` still works but emits a ``DeprecationWarning``.

    The result's frontiers are bitwise-identical to ``Campaign.run``
    single-process on the same config.
    """
    if isinstance(workloads_or_campaign, Campaign):
        campaign = workloads_or_campaign
        if config is not None:
            raise TypeError("run_distributed: pass either a Campaign (which "
                            "carries its config) or (workloads, config), "
                            "not both")
        cfg = campaign.config
        if legacy:
            unknown = set(legacy) - {"n_workers", "lease_timeout_s",
                                     "checkpoint_path"}
            if unknown:
                raise TypeError(f"run_distributed: unexpected keyword "
                                f"arguments {sorted(unknown)}")
            warnings.warn(
                "run_distributed(campaign, n_workers=..., ...) keyword "
                "options are deprecated: set n_workers / lease_timeout_s / "
                "checkpoint_path on the CampaignConfig instead",
                DeprecationWarning, stacklevel=2)
            cfg = cfg.replace(**legacy)
    else:
        if legacy:
            raise TypeError(f"run_distributed(workloads, config) takes no "
                            f"extra keyword arguments (got {sorted(legacy)})")
        if not isinstance(config, CampaignConfig):
            raise TypeError("run_distributed(workloads, config) needs a "
                            "CampaignConfig")
        campaign = Campaign(workloads_or_campaign, config)
        cfg = config
    fabric = MultiprocessFabric(campaign, n_workers=cfg.n_workers,
                                lease_timeout_s=cfg.lease_timeout_s,
                                fault=fault, retry=retry,
                                max_respawns=max_respawns,
                                poison_threshold=poison_threshold)
    result = fabric.run(checkpoint_path=cfg.checkpoint_path)
    return result, fabric.stats
