"""Incremental energy/latency Pareto frontiers for streaming campaigns.

``dse.pareto_search`` computes a frontier in one shot over a fully
materialized space.  ``StreamingFrontier`` maintains the same frontier
incrementally: each evaluated tile is merged into the running skyline via
``dse.pareto_mask`` on (current frontier) u (new feasible points).  Because
Pareto(Pareto(A) u B) == Pareto(A u B) — dominance is transitive, and the
repo's duplicate semantics (equal points never dominate each other) carry
through the union — the streamed result is *identical* to the one-shot
frontier on the concatenated space, while resident state stays
O(frontier + tile) instead of O(space).

Merges are idempotent and commutative: points are identified by their global
candidate index (re-merging an already-seen index is a no-op), and the final
frontier set does not depend on tile order.  Every merge appends a
``FrontierSnapshot`` to the trajectory — frontier size, a hypervolume proxy,
and the best-per-constraint extremes — which campaigns persist for
cross-PR regression tracking.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dse


@dataclasses.dataclass(frozen=True)
class FrontierSnapshot:
    """Trajectory point recorded after one merge."""

    tile: int
    evaluated: int               # cumulative candidates evaluated
    feasible: int                # cumulative feasible candidates seen
    frontier_size: int
    best_energy_j: float         # best-per-constraint extremes
    best_latency_s: float
    hypervolume: float           # proxy vs the frontier's fixed ref point

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class StreamingFrontier:
    """Running energy/latency skyline over a streamed candidate space.

    The reference point for the hypervolume proxy is pinned at the first
    merge that contains feasible points (max energy/latency of that merge),
    so trajectory values are comparable across snapshots — and across a
    checkpoint/resume boundary, since the ref point rides in ``state_dict``.
    """

    def __init__(self, ref_energy_j: Optional[float] = None,
                 ref_latency_s: Optional[float] = None):
        self.candidates: List[dse.Candidate] = []
        self.energy_j = np.empty(0, np.float64)
        self.latency_s = np.empty(0, np.float64)
        self.indices = np.empty(0, np.int64)     # global candidate indices
        self.evaluated = 0
        self.feasible_seen = 0
        self.ref_energy_j = ref_energy_j
        self.ref_latency_s = ref_latency_s
        self.trajectory: List[FrontierSnapshot] = []
        # seen global indices as merged [start, end) intervals — O(intervals)
        # not O(space), and a contiguous tile stream is ONE growing interval
        self._seen: List[Tuple[int, int]] = []

    def __len__(self) -> int:
        return len(self.candidates)

    def _claim_novel(self, indices: np.ndarray) -> np.ndarray:
        """Mask of indices not seen by any earlier merge; marks them seen.

        Keeps ``evaluated``/``feasible_seen`` exact under re-merged tiles
        (idempotence covers the accounting, not just the frontier set).
        """
        if not self._seen:
            novel = np.ones(indices.shape, bool)
        else:
            starts = np.asarray([s for s, _ in self._seen], np.int64)
            ends = np.asarray([e for _, e in self._seen], np.int64)
            pos = np.searchsorted(starts, indices, side="right") - 1
            novel = ~((pos >= 0) & (indices < ends[np.maximum(pos, 0)]))
        new_idx = np.unique(indices[novel])
        if new_idx.size:
            brk = np.flatnonzero(np.diff(new_idx) > 1)
            new_starts = new_idx[np.concatenate([[0], brk + 1])]
            new_ends = new_idx[np.concatenate([brk, [new_idx.size - 1]])] + 1
            merged: List[Tuple[int, int]] = []
            for s, e in sorted(self._seen + list(zip(new_starts.tolist(),
                                                     new_ends.tolist()))):
                if merged and s <= merged[-1][1]:
                    merged[-1] = (merged[-1][0], max(merged[-1][1], e))
                else:
                    merged.append((s, e))
            self._seen = merged
        return novel

    def merge(self, candidates: Sequence[dse.Candidate], energy_j, latency_s,
              feasible=None, indices=None, tile: int = -1) -> int:
        """Fold one evaluated tile into the skyline; returns the new size.

        ``indices`` are the candidates' global positions in the space (used
        for idempotent dedup and for reporting); when omitted they are
        assigned sequentially from the running ``evaluated`` counter.
        Re-merging already-seen indices is a full no-op: neither the frontier
        set nor the evaluated/feasible accounting changes.
        """
        energy_j = np.asarray(energy_j, np.float64)
        latency_s = np.asarray(latency_s, np.float64)
        n = len(candidates)
        if energy_j.shape != (n,) or latency_s.shape != (n,):
            raise ValueError(f"shape mismatch: {n} candidates vs "
                             f"{energy_j.shape}/{latency_s.shape} scores")
        feasible = (np.ones(n, bool) if feasible is None
                    else np.asarray(feasible, bool))
        indices = (np.arange(self.evaluated, self.evaluated + n, dtype=np.int64)
                   if indices is None else np.asarray(indices, np.int64))
        novel = self._claim_novel(indices)
        self.evaluated += int(novel.sum())
        keep = np.flatnonzero(feasible & novel)
        self.feasible_seen += int(keep.size)

        if self.ref_energy_j is None and keep.size:
            self.ref_energy_j = float(energy_j[keep].max())
            self.ref_latency_s = float(latency_s[keep].max())

        if keep.size:
            # union: current frontier first so dedup-by-index keeps it
            all_cands = self.candidates + [candidates[i] for i in keep]
            all_e = np.concatenate([self.energy_j, energy_j[keep]])
            all_l = np.concatenate([self.latency_s, latency_s[keep]])
            all_i = np.concatenate([self.indices, indices[keep]])
            _, first = np.unique(all_i, return_index=True)
            first.sort()
            all_e, all_l, all_i = all_e[first], all_l[first], all_i[first]
            all_cands = [all_cands[i] for i in first]
            mask = dse.pareto_mask(all_e, all_l, np.ones(len(all_i), bool))
            sel = np.flatnonzero(mask)
            # canonical order: latency, then energy, then global index —
            # identical regardless of the merge order that produced the set
            order = sel[np.lexsort((all_i[sel], all_e[sel], all_l[sel]))]
            self.candidates = [all_cands[i] for i in order]
            self.energy_j = all_e[order]
            self.latency_s = all_l[order]
            self.indices = all_i[order]

        self.trajectory.append(FrontierSnapshot(
            tile=tile, evaluated=self.evaluated, feasible=self.feasible_seen,
            frontier_size=len(self),
            best_energy_j=float(self.energy_j.min()) if len(self) else float("inf"),
            best_latency_s=float(self.latency_s.min()) if len(self) else float("inf"),
            hypervolume=self.hypervolume()))
        return len(self)

    def hypervolume(self) -> float:
        """Area dominated by the frontier up to the fixed reference point.

        Exact for the 2D minimization given the ref point; a *proxy* overall
        because the ref point is pinned from early data rather than the true
        nadir.  Points outside the ref box contribute zero.
        """
        if not len(self) or self.ref_energy_j is None:
            return 0.0
        e, l = self.energy_j, self.latency_s
        inside = (e < self.ref_energy_j) & (l < self.ref_latency_s)
        if not inside.any():
            return 0.0
        e, l = e[inside], l[inside]
        order = np.lexsort((e, l))             # latency asc (energy desc)
        e, l = e[order], l[order]
        right = np.append(l[1:], self.ref_latency_s)
        return float(np.sum((self.ref_energy_j - e) * (right - l)))

    def as_pareto_frontier(self, workload: dse.Workload) -> dse.ParetoFrontier:
        """The running skyline in ``dse.ParetoFrontier`` form (sorted by
        latency, like ``pareto_search`` output)."""
        return dse.ParetoFrontier(
            workload=workload,
            candidates=tuple(self.candidates),
            energy_j=self.energy_j.copy(),
            latency_s=self.latency_s.copy(),
            indices=self.indices.copy(),
            feasible_count=self.feasible_seen)

    # -- persistence --------------------------------------------------------

    def state_dict(self) -> Dict:
        return {
            "candidates": [candidate_to_dict(c) for c in self.candidates],
            "energy_j": self.energy_j.tolist(),
            "latency_s": self.latency_s.tolist(),
            "indices": self.indices.tolist(),
            "evaluated": self.evaluated,
            "feasible_seen": self.feasible_seen,
            "ref_energy_j": self.ref_energy_j,
            "ref_latency_s": self.ref_latency_s,
            "seen_intervals": [list(iv) for iv in self._seen],
            "trajectory": [s.as_dict() for s in self.trajectory],
        }

    @classmethod
    def from_state(cls, state: Dict) -> "StreamingFrontier":
        fr = cls(ref_energy_j=state["ref_energy_j"],
                 ref_latency_s=state["ref_latency_s"])
        fr.candidates = [candidate_from_dict(d) for d in state["candidates"]]
        fr.energy_j = np.asarray(state["energy_j"], np.float64)
        fr.latency_s = np.asarray(state["latency_s"], np.float64)
        fr.indices = np.asarray(state["indices"], np.int64)
        fr.evaluated = state["evaluated"]
        fr.feasible_seen = state["feasible_seen"]
        fr._seen = [(int(s), int(e)) for s, e in state["seen_intervals"]]
        fr.trajectory = [FrontierSnapshot(**s) for s in state["trajectory"]]
        return fr


def canonical_frontier(front: dse.ParetoFrontier):
    """(candidates, energy, latency, indices) in the canonical
    (latency, energy, index) order — the one total order both streamed and
    one-shot frontiers can be compared under."""
    order = np.lexsort((front.indices, front.energy_j, front.latency_s))
    return ([front.candidates[i] for i in order], front.energy_j[order],
            front.latency_s[order], front.indices[order])


def frontiers_identical(a: dse.ParetoFrontier, b: dse.ParetoFrontier) -> bool:
    """Exact (bitwise) frontier equality under the canonical order — the
    single definition the benchmark gate, the resume example, and the tests
    all compare with."""
    ca, ea, la, ia = canonical_frontier(a)
    cb, eb, lb, ib = canonical_frontier(b)
    return (ca == cb and np.array_equal(ea, eb) and np.array_equal(la, lb)
            and np.array_equal(ia, ib))


def candidate_to_dict(c: dse.Candidate) -> Dict:
    return {"chip": c.chip, "n_chips": int(c.n_chips),
            "mesh": list(c.mesh), "freq_mhz": float(c.freq_mhz)}


def candidate_from_dict(d: Dict) -> dse.Candidate:
    return dse.Candidate(d["chip"], d["n_chips"], tuple(d["mesh"]),
                         d["freq_mhz"])
