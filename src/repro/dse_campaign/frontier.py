"""Incremental energy/latency Pareto frontiers for streaming campaigns.

``dse.pareto_search`` computes a frontier in one shot over a fully
materialized space.  ``StreamingFrontier`` maintains the same frontier
incrementally: each evaluated tile is merged into the running skyline via
``dse.pareto_mask`` on (current frontier) u (new feasible points).  Because
Pareto(Pareto(A) u B) == Pareto(A u B) — dominance is transitive, and the
repo's duplicate semantics (equal points never dominate each other) carry
through the union — the streamed result is *identical* to the one-shot
frontier on the concatenated space, while resident state stays
O(frontier + tile) instead of O(space).

Merges are idempotent and commutative: points are identified by their global
candidate index (re-merging an already-seen index is a no-op), and the final
frontier set does not depend on tile order.  Every merge appends a
``FrontierSnapshot`` to the trajectory — frontier size, a hypervolume proxy,
and the best-per-constraint extremes — which campaigns persist for
cross-PR regression tracking.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dse


def hypervolume_2d(energy_j, latency_s, ref_energy_j, ref_latency_s) -> float:
    """Area dominated by an (energy, latency) point set up to a reference
    point — the 2D-minimization rectangle sweep.  The single definition the
    streaming frontier's trajectory proxy AND the benchmark's cross-evaluator
    comparison both compute with, so the two hypervolume gates cannot drift.
    Points outside the ref box contribute zero."""
    e = np.asarray(energy_j, np.float64)
    l = np.asarray(latency_s, np.float64)
    if ref_energy_j is None or not e.size:
        return 0.0
    inside = (e < ref_energy_j) & (l < ref_latency_s)
    if not inside.any():
        return 0.0
    e, l = e[inside], l[inside]
    order = np.lexsort((e, l))             # latency asc (energy desc)
    e, l = e[order], l[order]
    right = np.append(l[1:], ref_latency_s)
    return float(np.sum((ref_energy_j - e) * (right - l)))


def hypervolume_gain_2d(energy_j, latency_s, front_energy_j, front_latency_s,
                        ref_energy_j, ref_latency_s,
                        chunk: int = 8192) -> np.ndarray:
    """Per-candidate hypervolume gain: for each (energy, latency) point,
    ``hypervolume_2d(front u {p}) - hypervolume_2d(front)`` against the same
    ref point — the exact marginal contribution the adaptive campaign's
    acquisition function ranks by, vectorized over N candidates at once.

    gain(p) = area of p's dominated rectangle minus its overlap with the
    current frontier's staircase.  The overlap is computed by clipping each
    frontier step into p's rectangle: with the frontier sorted by latency
    ascending (energy strictly descending after dedup), the clipped corners
    ``ce = max(fe, e)`` stay non-increasing and ``cl = max(fl, l)``
    non-decreasing, so the overlap is a sum of disjoint vertical strips
    ``(ref_e - ce_j) * (cl_{j+1} - cl_j)`` (with ``cl_{K+1} = ref_l``),
    each term clipped at zero.  Candidates are processed in ``chunk``-sized
    blocks to bound the N x K intermediate.

    Oracle-tested against ``hypervolume_2d`` on the augmented set
    (``tests/test_adaptive.py``)."""
    e = np.asarray(energy_j, np.float64)
    l = np.asarray(latency_s, np.float64)
    gains = np.zeros(e.shape[0], np.float64)
    if ref_energy_j is None or not e.size:
        return gains
    inside = (e < ref_energy_j) & (l < ref_latency_s)
    if not inside.any():
        return gains
    # canonical staircase of the current frontier: inside-box, latency asc,
    # strict running-min energy dedup (ties/dominated steps add no area)
    fe = np.asarray(front_energy_j, np.float64)
    fl = np.asarray(front_latency_s, np.float64)
    fin = (fe < ref_energy_j) & (fl < ref_latency_s)
    fe, fl = fe[fin], fl[fin]
    if fe.size:
        order = np.lexsort((fe, fl))
        fe, fl = fe[order], fl[order]
        run_min = np.minimum.accumulate(fe)
        keep = np.concatenate([[True], fe[1:] < run_min[:-1]])
        fe, fl = fe[keep], fl[keep]
    idx = np.flatnonzero(inside)
    for s in range(0, idx.size, max(int(chunk), 1)):
        sel = idx[s:s + chunk]
        ce_full = (ref_energy_j - e[sel]) * (ref_latency_s - l[sel])
        if fe.size:
            ce = np.maximum(fe[None, :], e[sel, None])       # [n, K]
            cl = np.maximum(fl[None, :], l[sel, None])
            cl_next = np.concatenate(
                [cl[:, 1:], np.full((sel.size, 1), ref_latency_s)], axis=1)
            strips = (np.clip(ref_energy_j - ce, 0.0, None)
                      * np.clip(cl_next - cl, 0.0, None))
            overlap = strips.sum(axis=1)
        else:
            overlap = 0.0
        gains[sel] = np.maximum(ce_full - overlap, 0.0)
    return gains


def _merge_intervals(intervals: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Coalesce [start, end) intervals — the one implementation of the
    ``_seen`` invariant both merge entry points claim indices through."""
    merged: List[Tuple[int, int]] = []
    for s, e in sorted(intervals):
        if merged and s <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], e))
        else:
            merged.append((s, e))
    return merged


@dataclasses.dataclass(frozen=True)
class FrontierSnapshot:
    """Trajectory point recorded after one merge."""

    tile: int
    evaluated: int               # cumulative candidates evaluated
    feasible: int                # cumulative feasible candidates seen
    frontier_size: int
    best_energy_j: float         # best-per-constraint extremes
    best_latency_s: float
    hypervolume: float           # proxy vs the frontier's fixed ref point

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class StreamingFrontier:
    """Running energy/latency skyline over a streamed candidate space.

    The reference point for the hypervolume proxy is pinned at the first
    merge that contains feasible points (max energy/latency of that merge),
    so trajectory values are comparable across snapshots — and across a
    checkpoint/resume boundary, since the ref point rides in ``state_dict``.
    """

    def __init__(self, ref_energy_j: Optional[float] = None,
                 ref_latency_s: Optional[float] = None):
        self.candidates: List[dse.Candidate] = []
        self.energy_j = np.empty(0, np.float64)
        self.latency_s = np.empty(0, np.float64)
        self.indices = np.empty(0, np.int64)     # global candidate indices
        self.evaluated = 0
        self.feasible_seen = 0
        self.ref_energy_j = ref_energy_j
        self.ref_latency_s = ref_latency_s
        self.trajectory: List[FrontierSnapshot] = []
        # seen global indices as merged [start, end) intervals — O(intervals)
        # not O(space), and a contiguous tile stream is ONE growing interval
        self._seen: List[Tuple[int, int]] = []

    def __len__(self) -> int:
        return len(self.candidates)

    def _claim_novel(self, indices: np.ndarray) -> np.ndarray:
        """Mask of indices not seen by any earlier merge; marks them seen.

        Keeps ``evaluated``/``feasible_seen`` exact under re-merged tiles
        (idempotence covers the accounting, not just the frontier set).
        """
        if not self._seen:
            novel = np.ones(indices.shape, bool)
        else:
            starts = np.asarray([s for s, _ in self._seen], np.int64)
            ends = np.asarray([e for _, e in self._seen], np.int64)
            pos = np.searchsorted(starts, indices, side="right") - 1
            novel = ~((pos >= 0) & (indices < ends[np.maximum(pos, 0)]))
        new_idx = np.unique(indices[novel])
        if new_idx.size:
            brk = np.flatnonzero(np.diff(new_idx) > 1)
            new_starts = new_idx[np.concatenate([[0], brk + 1])]
            new_ends = new_idx[np.concatenate([brk, [new_idx.size - 1]])] + 1
            self._seen = _merge_intervals(
                self._seen + list(zip(new_starts.tolist(),
                                      new_ends.tolist())))
        return novel

    def _fold(self, new_cands: List[dse.Candidate], new_e: np.ndarray,
              new_l: np.ndarray, new_i: np.ndarray) -> None:
        """Fold already-feasible, already-novel points into the skyline —
        the union / dedup-by-index / pareto core shared by ``merge`` and
        ``merge_reduced`` so the two entry points cannot diverge."""
        # union: current frontier first so dedup-by-index keeps it
        all_cands = self.candidates + new_cands
        all_e = np.concatenate([self.energy_j, new_e])
        all_l = np.concatenate([self.latency_s, new_l])
        all_i = np.concatenate([self.indices, new_i])
        _, first = np.unique(all_i, return_index=True)
        first.sort()
        all_e, all_l, all_i = all_e[first], all_l[first], all_i[first]
        all_cands = [all_cands[i] for i in first]
        mask = dse.pareto_mask(all_e, all_l, np.ones(len(all_i), bool))
        sel = np.flatnonzero(mask)
        # canonical order: latency, then energy, then global index —
        # identical regardless of the merge order that produced the set
        order = sel[np.lexsort((all_i[sel], all_e[sel], all_l[sel]))]
        self.candidates = [all_cands[i] for i in order]
        self.energy_j = all_e[order]
        self.latency_s = all_l[order]
        self.indices = all_i[order]

    def _snapshot(self, tile: int) -> None:
        self.trajectory.append(FrontierSnapshot(
            tile=tile, evaluated=self.evaluated, feasible=self.feasible_seen,
            frontier_size=len(self),
            best_energy_j=float(self.energy_j.min()) if len(self) else float("inf"),
            best_latency_s=float(self.latency_s.min()) if len(self) else float("inf"),
            hypervolume=self.hypervolume()))

    def merge(self, candidates: Sequence[dse.Candidate], energy_j, latency_s,
              feasible=None, indices=None, tile: int = -1) -> int:
        """Fold one evaluated tile into the skyline; returns the new size.

        ``indices`` are the candidates' global positions in the space (used
        for idempotent dedup and for reporting); when omitted they are
        assigned sequentially from the running ``evaluated`` counter.
        Re-merging already-seen indices is a full no-op: neither the frontier
        set nor the evaluated/feasible accounting changes.
        """
        energy_j = np.asarray(energy_j, np.float64)
        latency_s = np.asarray(latency_s, np.float64)
        n = len(candidates)
        if energy_j.shape != (n,) or latency_s.shape != (n,):
            raise ValueError(f"shape mismatch: {n} candidates vs "
                             f"{energy_j.shape}/{latency_s.shape} scores")
        feasible = (np.ones(n, bool) if feasible is None
                    else np.asarray(feasible, bool))
        indices = (np.arange(self.evaluated, self.evaluated + n, dtype=np.int64)
                   if indices is None else np.asarray(indices, np.int64))
        novel = self._claim_novel(indices)
        self.evaluated += int(novel.sum())
        keep = np.flatnonzero(feasible & novel)
        self.feasible_seen += int(keep.size)

        if self.ref_energy_j is None and keep.size:
            self.ref_energy_j = float(energy_j[keep].max())
            self.ref_latency_s = float(latency_s[keep].max())

        if keep.size:
            self._fold([candidates[i] for i in keep], energy_j[keep],
                       latency_s[keep], indices[keep])
        self._snapshot(tile)
        return len(self)

    def _span_overlap(self, lo: int, hi: int) -> int:
        """How many indices of [lo, hi) an earlier merge already claimed."""
        return sum(max(0, min(hi, e) - max(lo, s)) for s, e in self._seen)

    def _claim_span(self, lo: int, hi: int) -> None:
        self._seen = _merge_intervals(self._seen + [(lo, hi)])

    def merge_reduced(self, candidates: Sequence[dse.Candidate], energy_j,
                      latency_s, indices, *, span: Tuple[int, int],
                      n_feasible: int, ref_energy_j: Optional[float] = None,
                      ref_latency_s: Optional[float] = None,
                      tile: int = -1) -> int:
        """Fold a pre-reduced tile — any FEASIBLE SUPERSET of its Pareto
        survivors plus the tile aggregates — into the skyline; identical
        outcome to ``merge`` on the raw tile arrays.

        The fused on-device evaluators (``costmodel.sweep_workloads_reduced_jit``
        and the Pallas DSE-sweep kernel) discard dominated points on device,
        so the host only sees the survivors (the exact skyline, or a
        conservative screen superset of it — extra dominated points are
        eliminated by the fold's own ``pareto_mask``).  Identity with the
        raw merge holds because (a) dominance is transitive — a tile point
        dominated inside its own tile can never enter the union skyline,
        whether or not it rides along in ``candidates`` — and (b) the
        aggregates reproduce the raw path's accounting exactly: ``span`` is
        the tile's global index interval [lo, hi) (claimed whole for
        idempotence), ``n_feasible`` the tile's feasible count, and
        ``ref_*`` the tile's feasible maxima that pin the hypervolume
        reference point on the first feasible merge.  Re-merging a fully
        seen span is a no-op (snapshot only, like ``merge``); partially
        seen spans are refused — tiles are the dedup unit of the reduced
        path.
        """
        lo, hi = int(span[0]), int(span[1])
        if hi <= lo:
            raise ValueError(f"empty span [{lo}, {hi})")
        energy_j = np.asarray(energy_j, np.float64)
        latency_s = np.asarray(latency_s, np.float64)
        indices = np.asarray(indices, np.int64)
        n = len(candidates)
        if energy_j.shape != (n,) or latency_s.shape != (n,) or \
                indices.shape != (n,):
            raise ValueError(f"shape mismatch: {n} survivors vs "
                             f"{energy_j.shape}/{latency_s.shape}/"
                             f"{indices.shape}")
        if n > hi - lo or int(n_feasible) > hi - lo:
            raise ValueError(f"{n} survivors / {n_feasible} feasible exceed "
                             f"span [{lo}, {hi})")
        if indices.size and (indices.min() < lo or indices.max() >= hi):
            raise ValueError(f"survivor indices outside span [{lo}, {hi})")
        overlap = self._span_overlap(lo, hi)
        if overlap == hi - lo:
            self._snapshot(tile)                 # re-merged tile: no-op
            return len(self)
        if overlap:
            raise ValueError(
                f"span [{lo}, {hi}) partially overlaps already-merged "
                "indices; reduced merges dedup whole tiles — re-merge the "
                "exact tile or use merge() with per-point indices")
        self._claim_span(lo, hi)
        self.evaluated += hi - lo
        self.feasible_seen += int(n_feasible)
        if self.ref_energy_j is None and int(n_feasible) > 0:
            self.ref_energy_j = float(ref_energy_j)
            self.ref_latency_s = float(ref_latency_s)
        if n:
            self._fold(list(candidates), energy_j, latency_s, indices)
        self._snapshot(tile)
        return len(self)

    def hypervolume(self) -> float:
        """Area dominated by the frontier up to the fixed reference point
        (``hypervolume_2d``).  Exact for the 2D minimization given the ref
        point; a *proxy* overall because the ref point is pinned from early
        data rather than the true nadir.
        """
        return hypervolume_2d(self.energy_j, self.latency_s,
                              self.ref_energy_j, self.ref_latency_s)

    def as_pareto_frontier(self, workload: dse.Workload) -> dse.ParetoFrontier:
        """The running skyline in ``dse.ParetoFrontier`` form (sorted by
        latency, like ``pareto_search`` output)."""
        return dse.ParetoFrontier(
            workload=workload,
            candidates=tuple(self.candidates),
            energy_j=self.energy_j.copy(),
            latency_s=self.latency_s.copy(),
            indices=self.indices.copy(),
            feasible_count=self.feasible_seen)

    # -- persistence --------------------------------------------------------

    def state_dict(self) -> Dict:
        """JSON-serializable full state (skyline, aggregates, claimed spans,
        trajectory); ``from_state`` inverts it exactly."""
        return {
            "candidates": [candidate_to_dict(c) for c in self.candidates],
            "energy_j": self.energy_j.tolist(),
            "latency_s": self.latency_s.tolist(),
            "indices": self.indices.tolist(),
            "evaluated": self.evaluated,
            "feasible_seen": self.feasible_seen,
            "ref_energy_j": self.ref_energy_j,
            "ref_latency_s": self.ref_latency_s,
            "seen_intervals": [list(iv) for iv in self._seen],
            "trajectory": [s.as_dict() for s in self.trajectory],
        }

    @classmethod
    def from_state(cls, state: Dict) -> "StreamingFrontier":
        """Rebuild a frontier from ``state_dict`` output; subsequent merges
        continue exactly as if the frontier had never been serialized."""
        fr = cls(ref_energy_j=state["ref_energy_j"],
                 ref_latency_s=state["ref_latency_s"])
        fr.candidates = [candidate_from_dict(d) for d in state["candidates"]]
        fr.energy_j = np.asarray(state["energy_j"], np.float64)
        fr.latency_s = np.asarray(state["latency_s"], np.float64)
        fr.indices = np.asarray(state["indices"], np.int64)
        fr.evaluated = state["evaluated"]
        fr.feasible_seen = state["feasible_seen"]
        fr._seen = [(int(s), int(e)) for s, e in state["seen_intervals"]]
        fr.trajectory = [FrontierSnapshot(**s) for s in state["trajectory"]]
        return fr


def canonical_frontier(front: dse.ParetoFrontier):
    """(candidates, energy, latency, indices) in the canonical
    (latency, energy, index) order — the one total order both streamed and
    one-shot frontiers can be compared under."""
    order = np.lexsort((front.indices, front.energy_j, front.latency_s))
    return ([front.candidates[i] for i in order], front.energy_j[order],
            front.latency_s[order], front.indices[order])


def frontiers_identical(a: dse.ParetoFrontier, b: dse.ParetoFrontier) -> bool:
    """Exact (bitwise) frontier equality under the canonical order — the
    single definition the benchmark gate, the resume example, and the tests
    all compare with."""
    ca, ea, la, ia = canonical_frontier(a)
    cb, eb, lb, ib = canonical_frontier(b)
    return (ca == cb and np.array_equal(ea, eb) and np.array_equal(la, lb)
            and np.array_equal(ia, ib))


def candidate_to_dict(c: dse.Candidate) -> Dict:
    """JSON-serializable form of a ``dse.Candidate`` (checkpoints, BENCH
    artifacts); ``candidate_from_dict`` inverts it."""
    return {"chip": c.chip, "n_chips": int(c.n_chips),
            "mesh": list(c.mesh), "freq_mhz": float(c.freq_mhz)}


def candidate_from_dict(d: Dict) -> dse.Candidate:
    """Inverse of ``candidate_to_dict``."""
    return dse.Candidate(d["chip"], d["n_chips"], tuple(d["mesh"]),
                         d["freq_mhz"])
