"""Campaign orchestrator: resumable, bounded-memory DSE over mega-spaces.

A ``Campaign`` sweeps every workload in a cached dry-run artifact set across
a ``SpaceSpec``, tile by tile: each ``chunk_size`` tile is materialized,
evaluated for all workloads (``dse.evaluate_workload_tile`` — the numpy
simulator, its jitted variant, or the trained fast-path predictors), masked
by the ``Constraint``, folded into each workload's ``StreamingFrontier``,
and released.  Peak candidate memory is one tile regardless of space size.
Tiles carry their mesh axes (pod/data/model) into the simulators, so the
factorization axis of the space differentiates the frontier on every
evaluator, not just the predictor fast path.

Checkpointing is by tile index: the campaign state (spec, workloads,
frontiers, trajectory, next tile) round-trips through JSON, so an
interrupted sweep resumes exactly where it stopped and converges to the
same frontier a fresh run produces.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import SHAPES, get_config
from repro.core import costmodel, dataset, dse
from repro.dse_campaign import store
from repro.dse_campaign.frontier import StreamingFrontier
from repro.dse_campaign.space import SpaceSpec

WorkloadKey = Tuple[str, str]


@dataclasses.dataclass
class TileStat:
    """Wall-clock accounting for one evaluated tile (all workloads)."""

    tile: int
    candidates: int
    wall_s: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CampaignResult:
    """Final (or interrupted) campaign state returned by ``Campaign.run``."""

    frontiers: Dict[WorkloadKey, dse.ParetoFrontier]
    trajectories: Dict[WorkloadKey, List]
    tile_stats: List[TileStat]
    space_size: int
    tiles_done: int
    n_tiles: int
    wall_s: float

    @property
    def complete(self) -> bool:
        return self.tiles_done >= self.n_tiles

    @property
    def candidates_evaluated(self) -> int:
        return sum(s.candidates for s in self.tile_stats)

    @property
    def sweep_wall_s(self) -> float:
        """Total tile-evaluation wall across ALL runs of this campaign —
        ``tile_stats`` survives checkpoint/resume, so unlike ``wall_s`` (this
        ``run`` call only) it stays consistent with ``candidates_evaluated``
        on a resumed campaign."""
        return sum(s.wall_s for s in self.tile_stats)

    @property
    def candidates_per_sec(self) -> float:
        """Per-workload candidate evaluations per second of sweep wall."""
        return self.candidates_evaluated / max(self.sweep_wall_s, 1e-9)


class Campaign:
    """Streaming multi-workload DSE campaign over a ``SpaceSpec``.

    ``evaluator`` selects the tile engine: ``"numpy"`` (float64 simulator,
    bitwise-identical to one-shot ``pareto_search``), ``"jit"``
    (``simulate_batch_jit``), or ``"fast"`` (trained predictors; pass
    fitted ``power_model``/``cycles_model``).
    """

    def __init__(self, workloads: Sequence[dse.Workload], space: SpaceSpec,
                 constraint: dse.Constraint = None,
                 evaluator: str = "numpy",
                 sim: costmodel.SimConfig = costmodel.SimConfig(),
                 power_model=None, cycles_model=None,
                 checkpoint_every: int = 1):
        if evaluator not in ("numpy", "jit", "fast"):
            raise ValueError(f"unknown evaluator {evaluator!r}")
        if evaluator == "fast" and (power_model is None or cycles_model is None):
            raise ValueError("evaluator='fast' needs fitted power_model and "
                             "cycles_model")
        keys = [(wl.arch, wl.shape) for wl in workloads]
        if len(set(keys)) != len(keys):
            raise ValueError(f"duplicate (arch, shape) workload keys: {keys}")
        self.workloads = list(workloads)
        self.space = space
        self.constraint = constraint if constraint is not None else dse.Constraint()
        self.evaluator = evaluator
        self.sim = sim
        self.power_model = power_model
        self.cycles_model = cycles_model
        self.checkpoint_every = max(int(checkpoint_every), 1)
        self.frontiers: Dict[WorkloadKey, StreamingFrontier] = {
            k: StreamingFrontier() for k in keys}
        self.tile_stats: List[TileStat] = []
        self.next_tile = 0

    # -- construction -------------------------------------------------------

    @classmethod
    def from_artifacts(cls, art_dir: str, space: SpaceSpec,
                       **kwargs) -> "Campaign":
        """Sweep ALL cached dry-run workloads under ``art_dir``.

        Each artifact's compiled census (``base_analysis``) is loaded ONCE
        per (arch, shape) cell and reused across every tile of the sweep.
        Colliding (arch, shape) cells from different pods are disambiguated
        by suffixing the shape with the pod tag.
        """
        arts = dataset.load_dryrun_artifacts(art_dir)
        if not arts:
            raise FileNotFoundError(f"no dry-run artifacts in {art_dir}")
        seen = {}
        for (arch, shape, pod), art in sorted(arts.items()):
            key = (arch, shape) if (arch, shape) not in seen else (
                arch, f"{shape}:{pod}")
            seen[key] = dse.Workload(
                arch=key[0], shape=key[1],
                base_analysis={k: art["hxa"][k] for k in
                               ("flops", "hbm_bytes", "collective_bytes",
                                "wire_bytes")},
                base_chips=art["roofline"]["n_chips"],
                state_gb_per_device=art["memory"]["state_gb_per_device"])
        return cls(list(seen.values()), space, **kwargs)

    @classmethod
    def from_checkpoint(cls, path: str, **kwargs) -> "Campaign":
        """Rebuild an interrupted campaign from its checkpoint file; the
        next ``run`` continues at the first unevaluated tile.

        Space, workloads, constraint, ``SimConfig`` and evaluator are all
        restored from the checkpoint.  Fitted predictor models cannot be
        serialized, so resuming an ``evaluator="fast"`` campaign requires
        re-passing the SAME ``power_model``/``cycles_model`` via kwargs
        (``__init__`` refuses to resume without them); supplying retrained
        models would splice two predictors into one frontier undetected.
        A checkpoint written under a different ``costmodel.SIM_MODEL_VERSION``
        is refused for the same reason: its folded-in tiles and the tiles a
        resume would evaluate come from incomparable cost models.
        """
        state = store.load_checkpoint(path)
        ckpt_model = state.get("sim_model_version")
        if ckpt_model != costmodel.SIM_MODEL_VERSION:
            raise ValueError(
                f"checkpoint {path} was written under cost-model version "
                f"{ckpt_model!r} but this build is "
                f"{costmodel.SIM_MODEL_VERSION}; resuming would splice two "
                "incomparable cost models into one frontier — re-run the "
                "campaign from scratch")
        workloads = [dse.Workload(arch=w["arch"], shape=w["shape"],
                                  base_analysis=w["base_analysis"],
                                  base_chips=w["base_chips"],
                                  state_gb_per_device=w["state_gb_per_device"])
                     for w in state["workloads"]]
        cons = dse.Constraint(**state["constraint"])
        kwargs.setdefault("sim", costmodel.SimConfig(**state["sim"]))
        camp = cls(workloads, SpaceSpec.from_dict(state["space"]),
                   constraint=cons, evaluator=state["evaluator"], **kwargs)
        camp.next_tile = state["next_tile"]
        camp.tile_stats = [TileStat(**s) for s in state["tile_stats"]]
        for key_str, fr_state in state["frontiers"].items():
            arch, shape = key_str.split("|", 1)
            camp.frontiers[(arch, shape)] = StreamingFrontier.from_state(fr_state)
        return camp

    # -- evaluation ---------------------------------------------------------

    def _evaluate_tile(self, wl: dse.Workload, batch: dse.CandidateBatch
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(energy_j, latency_s, feasible) for one workload on one tile."""
        if self.evaluator == "fast":
            return self._evaluate_tile_fast(wl, batch)
        res, feasible = dse.evaluate_workload_tile(
            wl, batch, self.constraint, sim=self.sim, engine=self.evaluator)
        return np.asarray(res.energy_j), np.asarray(res.latency_s), feasible

    def _evaluate_tile_fast(self, wl: dse.Workload, batch: dse.CandidateBatch):
        """Predictor fast path via ``dse.predict_space`` (same scoring as
        ``fast_path_search``).  Workload shapes suffixed with a pod tag
        resolve to their base shape."""
        cfg = get_config(wl.arch)
        shape = SHAPES[wl.shape.split(":", 1)[0]]
        energy, latency, feasible, _, _ = dse.predict_space(
            cfg, shape, self.power_model, self.cycles_model, batch,
            self.constraint)
        return energy, latency, feasible

    # -- the sweep ----------------------------------------------------------

    def run(self, checkpoint_path: Optional[str] = None,
            max_tiles: Optional[int] = None) -> CampaignResult:
        """Sweep tiles from ``next_tile`` on; returns the (possibly partial)
        campaign result.  ``max_tiles`` bounds THIS call (interruption point
        for resume demos/tests); with a ``checkpoint_path`` the state is
        persisted every ``checkpoint_every`` tiles and at the end."""
        t_start = time.perf_counter()
        done_this_call = 0
        for tile_no, lo, batch in self.space.tiles(start_tile=self.next_tile):
            if max_tiles is not None and done_this_call >= max_tiles:
                break
            t0 = time.perf_counter()
            indices = np.arange(lo, lo + len(batch), dtype=np.int64)
            for wl in self.workloads:
                energy, latency, feasible = self._evaluate_tile(wl, batch)
                self.frontiers[(wl.arch, wl.shape)].merge(
                    batch.candidates, energy, latency, feasible,
                    indices=indices, tile=tile_no)
            self.tile_stats.append(TileStat(
                tile=tile_no, candidates=len(batch) * len(self.workloads),
                wall_s=time.perf_counter() - t0))
            self.next_tile = tile_no + 1
            done_this_call += 1
            if checkpoint_path and (self.next_tile % self.checkpoint_every == 0):
                store.save_checkpoint(self.state_dict(), checkpoint_path)
        if checkpoint_path:
            store.save_checkpoint(self.state_dict(), checkpoint_path)
        return self._result(time.perf_counter() - t_start)

    def _result(self, wall_s: float) -> CampaignResult:
        wl_by_key = {(wl.arch, wl.shape): wl for wl in self.workloads}
        return CampaignResult(
            frontiers={k: fr.as_pareto_frontier(wl_by_key[k])
                       for k, fr in self.frontiers.items()},
            trajectories={k: list(fr.trajectory)
                          for k, fr in self.frontiers.items()},
            tile_stats=list(self.tile_stats),
            space_size=len(self.space),
            tiles_done=self.next_tile,
            n_tiles=self.space.n_tiles(),
            wall_s=wall_s)

    # -- persistence --------------------------------------------------------

    def state_dict(self) -> Dict:
        return {
            "version": 1,
            "sim_model_version": costmodel.SIM_MODEL_VERSION,
            "space": self.space.to_dict(),
            "workloads": [{
                "arch": wl.arch, "shape": wl.shape,
                "base_analysis": dict(wl.base_analysis),
                "base_chips": wl.base_chips,
                "state_gb_per_device": wl.state_gb_per_device,
            } for wl in self.workloads],
            "constraint": dataclasses.asdict(self.constraint),
            "sim": dataclasses.asdict(self.sim),
            "evaluator": self.evaluator,
            "next_tile": self.next_tile,
            "tile_stats": [s.as_dict() for s in self.tile_stats],
            "frontiers": {f"{arch}|{shape}": fr.state_dict()
                          for (arch, shape), fr in self.frontiers.items()},
        }
