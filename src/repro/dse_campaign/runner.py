"""Campaign orchestrator: resumable, bounded-memory DSE over mega-spaces.

A ``Campaign`` sweeps every workload in a cached dry-run artifact set across
a ``SpaceSpec``, tile by tile.  Two tile engines exist:

* the per-workload loop (``"numpy"`` float64 — bitwise-identical to one-shot
  ``pareto_search`` — and ``"fast"`` predictors): each tile is materialized,
  evaluated per workload, constraint-masked and raw-merged into that
  workload's ``StreamingFrontier``.

* the fused zero-copy pipeline (``"jit"`` and ``"pallas"``): tiles stream as
  array-only batches (no per-candidate Python objects), padded to
  ``chunk_size`` with a validity mask so the device function compiles ONCE
  for the whole sweep, and ALL workloads are evaluated in a single launch
  per tile (``costmodel.sweep_workloads_reduced_jit`` or the Pallas
  DSE-sweep kernel).  The launch also reduces each workload's tile to its
  feasible Pareto survivors on device, so the host transfers O(survivors)
  instead of O(tile) and merges via ``StreamingFrontier.merge_reduced``
  (proven identical to the raw merge); ``Candidate`` objects are
  materialized lazily for survivors only.  A prefetch thread stages the
  next tile's arrays while the device evaluates the current one
  (double-buffering), so candidate generation overlaps execution.

Peak candidate memory is one tile regardless of space size.  Tiles carry
their mesh axes (pod/data/model) into the simulators, so the factorization
axis of the space differentiates the frontier on every evaluator.

Checkpointing is by tile index: the campaign state (spec, workloads,
frontiers, trajectory, next tile) round-trips through JSON, so an
interrupted sweep resumes exactly where it stopped and converges to the
same frontier a fresh run produces — on the fused engines too, because the
reduced merge reproduces the raw merge's accounting exactly.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import SHAPES, get_config
from repro.core import costmodel, dataset, dse
from repro.dse_campaign import store
from repro.dse_campaign.frontier import StreamingFrontier
from repro.dse_campaign.space import SpaceSpec

WorkloadKey = Tuple[str, str]

EVALUATORS = ("numpy", "jit", "fast", "pallas")


@dataclasses.dataclass
class TileStat:
    """Wall-clock accounting for one evaluated tile (all workloads)."""

    tile: int
    candidates: int
    wall_s: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CampaignResult:
    """Final (or interrupted) campaign state returned by ``Campaign.run``."""

    frontiers: Dict[WorkloadKey, dse.ParetoFrontier]
    trajectories: Dict[WorkloadKey, List]
    tile_stats: List[TileStat]
    space_size: int
    tiles_done: int
    n_tiles: int
    wall_s: float

    @property
    def complete(self) -> bool:
        return self.tiles_done >= self.n_tiles

    @property
    def candidates_evaluated(self) -> int:
        return sum(s.candidates for s in self.tile_stats)

    @property
    def sweep_wall_s(self) -> float:
        """Total tile-evaluation wall across ALL runs of this campaign —
        ``tile_stats`` survives checkpoint/resume, so unlike ``wall_s`` (this
        ``run`` call only) it stays consistent with ``candidates_evaluated``
        on a resumed campaign."""
        return sum(s.wall_s for s in self.tile_stats)

    @property
    def candidates_per_sec(self) -> float:
        """Per-workload candidate evaluations per second of sweep wall."""
        return self.candidates_evaluated / max(self.sweep_wall_s, 1e-9)


class _TilePrefetcher:
    """Double-buffered tile staging: a worker thread materializes the next
    tile(s) of a ``SpaceSpec.tiles`` generator while the main thread drives
    the device on the current one.  The worker does numpy-only work (no JAX
    dispatch), so it is safe alongside the evaluating thread; ``close()``
    unblocks and retires it when iteration stops early (max_tiles)."""

    _END = object()

    def __init__(self, it, depth: int = 1):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(depth)))
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, args=(it,),
                                        daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _work(self, it):
        try:
            for item in it:
                if not self._put(item):
                    return
        except BaseException as exc:  # re-raised on the consuming thread
            self._err = exc
        self._put(self._END)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._END:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()


class Campaign:
    """Streaming multi-workload DSE campaign over a ``SpaceSpec``.

    ``evaluator`` selects the tile engine: ``"numpy"`` (float64 simulator,
    bitwise-identical to one-shot ``pareto_search``), ``"jit"``
    (float32 fused multi-workload sweep, ``costmodel.sweep_workloads_
    reduced_jit``), ``"pallas"`` (the fused Pallas DSE-sweep kernel —
    float64 in interpret mode on CPU, where its frontier holds the numpy
    evaluator's exact candidate set, float32 compiled on an accelerator),
    or ``"fast"``
    (trained predictors; pass fitted ``power_model``/``cycles_model``).

    ``pipeline=False`` disables the fused path for ``"jit"`` and falls back
    to the original per-workload loop on unpadded tiles (one launch per
    workload per tile, full-tile host transfer, raw merges) — kept as the
    measured baseline for the evaluator-speedup benchmark.
    """

    def __init__(self, workloads: Sequence[dse.Workload], space: SpaceSpec,
                 constraint: dse.Constraint = None,
                 evaluator: str = "numpy",
                 sim: costmodel.SimConfig = costmodel.SimConfig(),
                 power_model=None, cycles_model=None,
                 checkpoint_every: int = 1,
                 pipeline: bool = True,
                 max_survivors: int = 2048):
        if evaluator not in EVALUATORS:
            raise ValueError(f"unknown evaluator {evaluator!r}; expected one "
                             f"of {EVALUATORS}")
        if evaluator == "fast" and (power_model is None or cycles_model is None):
            raise ValueError("evaluator='fast' needs fitted power_model and "
                             "cycles_model")
        keys = [(wl.arch, wl.shape) for wl in workloads]
        if len(set(keys)) != len(keys):
            raise ValueError(f"duplicate (arch, shape) workload keys: {keys}")
        self.workloads = list(workloads)
        self.space = space
        self.constraint = constraint if constraint is not None else dse.Constraint()
        self.evaluator = evaluator
        self.sim = sim
        self.power_model = power_model
        self.cycles_model = cycles_model
        self.checkpoint_every = max(int(checkpoint_every), 1)
        self.pipeline = bool(pipeline)
        self.max_survivors = max(int(max_survivors), 1)
        self.frontiers: Dict[WorkloadKey, StreamingFrontier] = {
            k: StreamingFrontier() for k in keys}
        self.tile_stats: List[TileStat] = []
        self.next_tile = 0

    # -- construction -------------------------------------------------------

    @classmethod
    def from_artifacts(cls, art_dir: str, space: SpaceSpec,
                       **kwargs) -> "Campaign":
        """Sweep ALL cached dry-run workloads under ``art_dir``.

        Each artifact's compiled census (``base_analysis``) is loaded ONCE
        per (arch, shape) cell and reused across every tile of the sweep.
        Colliding (arch, shape) cells from different pods are disambiguated
        by suffixing the shape with the pod tag.
        """
        arts = dataset.load_dryrun_artifacts(art_dir)
        if not arts:
            raise FileNotFoundError(f"no dry-run artifacts in {art_dir}")
        seen = {}
        for (arch, shape, pod), art in sorted(arts.items()):
            key = (arch, shape) if (arch, shape) not in seen else (
                arch, f"{shape}:{pod}")
            seen[key] = dse.Workload(
                arch=key[0], shape=key[1],
                base_analysis={k: art["hxa"][k] for k in
                               ("flops", "hbm_bytes", "collective_bytes",
                                "wire_bytes")},
                base_chips=art["roofline"]["n_chips"],
                state_gb_per_device=art["memory"]["state_gb_per_device"])
        return cls(list(seen.values()), space, **kwargs)

    @classmethod
    def from_checkpoint(cls, path: str, **kwargs) -> "Campaign":
        """Rebuild an interrupted campaign from its checkpoint file; the
        next ``run`` continues at the first unevaluated tile.

        Space, workloads, constraint, ``SimConfig``, evaluator and pipeline
        mode are all restored from the checkpoint.  Fitted predictor models
        cannot be serialized, so resuming an ``evaluator="fast"`` campaign
        requires re-passing the SAME ``power_model``/``cycles_model`` via
        kwargs (``__init__`` refuses to resume without them); supplying
        retrained models would splice two predictors into one frontier
        undetected.  A checkpoint written under a different
        ``costmodel.SIM_MODEL_VERSION`` is refused for the same reason: its
        folded-in tiles and the tiles a resume would evaluate come from
        incomparable cost models.
        """
        state = store.load_checkpoint(path)
        ckpt_model = state.get("sim_model_version")
        if ckpt_model != costmodel.SIM_MODEL_VERSION:
            raise ValueError(
                f"checkpoint {path} was written under cost-model version "
                f"{ckpt_model!r} but this build is "
                f"{costmodel.SIM_MODEL_VERSION}; resuming would splice two "
                "incomparable cost models into one frontier — re-run the "
                "campaign from scratch")
        workloads = [dse.Workload(arch=w["arch"], shape=w["shape"],
                                  base_analysis=w["base_analysis"],
                                  base_chips=w["base_chips"],
                                  state_gb_per_device=w["state_gb_per_device"])
                     for w in state["workloads"]]
        cons = dse.Constraint(**state["constraint"])
        kwargs.setdefault("sim", costmodel.SimConfig(**state["sim"]))
        # checkpoints written before the fused pipeline carry no key: they
        # ran the legacy per-workload engine, so resume must stay on it —
        # splicing the fused float32 sweep into a half-done legacy "jit"
        # campaign could flip float32 near-ties mid-frontier
        kwargs.setdefault("pipeline", state.get("pipeline", False))
        camp = cls(workloads, SpaceSpec.from_dict(state["space"]),
                   constraint=cons, evaluator=state["evaluator"], **kwargs)
        camp.next_tile = state["next_tile"]
        camp.tile_stats = [TileStat(**s) for s in state["tile_stats"]]
        for key_str, fr_state in state["frontiers"].items():
            arch, shape = key_str.split("|", 1)
            camp.frontiers[(arch, shape)] = StreamingFrontier.from_state(fr_state)
        return camp

    # -- per-workload evaluation (numpy / fast / legacy jit) ----------------

    def _evaluate_tile(self, wl: dse.Workload, batch: dse.CandidateBatch
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(energy_j, latency_s, feasible) for one workload on one tile."""
        if self.evaluator == "fast":
            return self._evaluate_tile_fast(wl, batch)
        res, feasible = dse.evaluate_workload_tile(
            wl, batch, self.constraint, sim=self.sim, engine=self.evaluator)
        return np.asarray(res.energy_j), np.asarray(res.latency_s), feasible

    def _evaluate_tile_fast(self, wl: dse.Workload, batch: dse.CandidateBatch):
        """Predictor fast path via ``dse.predict_space`` (same scoring as
        ``fast_path_search``).  Workload shapes suffixed with a pod tag
        resolve to their base shape."""
        cfg = get_config(wl.arch)
        shape = SHAPES[wl.shape.split(":", 1)[0]]
        energy, latency, feasible, _, _ = dse.predict_space(
            cfg, shape, self.power_model, self.cycles_model, batch,
            self.constraint)
        return energy, latency, feasible

    # -- fused zero-copy pipeline (jit / pallas) ----------------------------

    @property
    def fused(self) -> bool:
        """Whether tiles go through the fused multi-workload reduced path."""
        return (self.evaluator == "pallas"
                or (self.evaluator == "jit" and self.pipeline))

    @property
    def _wl_cols(self) -> np.ndarray:
        """Packed [W, len(WL_COLS)] per-workload scalar matrix (cached)."""
        cols = getattr(self, "_wl_cols_cache", None)
        if cols is None:
            cols = np.asarray(
                [[wl.base_analysis["flops"], wl.base_analysis["hbm_bytes"],
                  wl.base_analysis["collective_bytes"],
                  wl.base_analysis["wire_bytes"], wl.base_chips,
                  wl.state_gb_per_device] for wl in self.workloads],
                np.float64)
            self._wl_cols_cache = cols
        return cols

    def _padded_tile_arrays(self, batch: dse.CandidateBatch) -> Dict:
        """The tile's packed columns padded to ``chunk_size`` with a validity
        mask — every tile presents the SAME shapes to the device function,
        so jit/Pallas trace exactly once for the whole sweep (the partial
        final tile no longer retriggers a retrace)."""
        n = len(batch)
        target = max(self.space.chunk_size, n)
        pad = target - n

        def padarr(a):
            a = np.asarray(a)
            return a if pad == 0 else np.concatenate(
                [a, np.repeat(a[:1], pad, axis=0)])

        valid = np.ones(target, np.float64)
        valid[n:] = 0.0
        arrays = {
            "n_chips": padarr(batch.n_chips),
            "freq_mhz": padarr(batch.freq_mhz),
            "mesh_pod": padarr(batch.pod_axis()),
            "mesh_data": padarr(batch.mesh_data),
            "mesh_model": padarr(batch.mesh_model),
            "valid": valid,
        }
        arrays.update({k: padarr(batch.chip_cols[k])
                       for k in costmodel.SWEEP_GATHER_FIELDS})
        return arrays

    def _sweep_tile_reduced(self, batch: dse.CandidateBatch
                            ) -> costmodel.SweepReduced:
        """ONE fused launch: all workloads x one padded tile, skyline-reduced
        on device."""
        arrays = self._padded_tile_arrays(batch)
        cons = self.constraint
        if self.evaluator == "pallas":
            from repro.kernels import ops
            from repro.kernels.dse_sweep import pack_cand_cols
            return ops.dse_sweep(
                pack_cand_cols(arrays), self._wl_cols, sim=self.sim,
                constraint=cons, max_survivors=self.max_survivors,
                n_valid=len(batch))
        return costmodel.sweep_workloads_reduced_jit(
            self._wl_cols,
            {k: arrays[k] for k in costmodel.SWEEP_GATHER_FIELDS},
            arrays["n_chips"], arrays["freq_mhz"], arrays["mesh_pod"],
            arrays["mesh_data"], arrays["mesh_model"], arrays["valid"],
            sim=self.sim, max_power_w=cons.max_power_w,
            max_latency_s=cons.max_latency_s, min_hbm_fit=cons.min_hbm_fit,
            max_survivors=self.max_survivors)

    def _merge_reduced_tile(self, red: costmodel.SweepReduced, lo: int,
                            n: int, tile_no: int) -> None:
        """Fold one fused launch into every workload's frontier — reduced
        merges with lazily materialized survivor ``Candidate`` objects; the
        (rare) skyline overflow falls back to a raw full-tile merge."""
        fallback_cands = None
        for wi, wl in enumerate(self.workloads):
            fr = self.frontiers[(wl.arch, wl.shape)]
            if red.overflowed(wi):
                if fallback_cands is None:
                    fallback_cands = self.space.slice(lo, lo + n).candidates
                fr.merge(fallback_cands,
                         np.asarray(red.energy_full)[wi][:n].astype(np.float64),
                         np.asarray(red.latency_full)[wi][:n].astype(np.float64),
                         np.asarray(red.feasible_full)[wi][:n],
                         indices=np.arange(lo, lo + n, dtype=np.int64),
                         tile=tile_no)
                continue
            k = int(red.n_survivors[wi])
            local = red.surv_idx[wi][:k].astype(np.int64)
            gidx = lo + local
            cands = self.space.candidates_at(gidx)
            fr.merge_reduced(
                cands, red.surv_energy[wi][:k].astype(np.float64),
                red.surv_latency[wi][:k].astype(np.float64), gidx,
                span=(lo, lo + n), n_feasible=int(red.n_feasible[wi]),
                ref_energy_j=float(red.ref_energy[wi]),
                ref_latency_s=float(red.ref_latency[wi]), tile=tile_no)

    # -- the sweep ----------------------------------------------------------

    def run(self, checkpoint_path: Optional[str] = None,
            max_tiles: Optional[int] = None) -> CampaignResult:
        """Sweep tiles from ``next_tile`` on; returns the (possibly partial)
        campaign result.  ``max_tiles`` bounds THIS call (interruption point
        for resume demos/tests); with a ``checkpoint_path`` the state is
        persisted every ``checkpoint_every`` tiles and at the end."""
        t_start = time.perf_counter()
        done_this_call = 0
        fused = self.fused
        tiles = _TilePrefetcher(self.space.tiles(
            start_tile=self.next_tile, with_candidates=not fused))
        try:
            for tile_no, lo, batch in tiles:
                if max_tiles is not None and done_this_call >= max_tiles:
                    break
                t0 = time.perf_counter()
                if fused:
                    red = self._sweep_tile_reduced(batch)
                    self._merge_reduced_tile(red, lo, len(batch), tile_no)
                else:
                    indices = np.arange(lo, lo + len(batch), dtype=np.int64)
                    for wl in self.workloads:
                        energy, latency, feasible = self._evaluate_tile(wl, batch)
                        self.frontiers[(wl.arch, wl.shape)].merge(
                            batch.candidates, energy, latency, feasible,
                            indices=indices, tile=tile_no)
                self.tile_stats.append(TileStat(
                    tile=tile_no,
                    candidates=len(batch) * len(self.workloads),
                    wall_s=time.perf_counter() - t0))
                self.next_tile = tile_no + 1
                done_this_call += 1
                if checkpoint_path and (self.next_tile % self.checkpoint_every == 0):
                    store.save_checkpoint(self.state_dict(), checkpoint_path)
        finally:
            tiles.close()
        if checkpoint_path:
            store.save_checkpoint(self.state_dict(), checkpoint_path)
        return self._result(time.perf_counter() - t_start)

    def _result(self, wall_s: float) -> CampaignResult:
        wl_by_key = {(wl.arch, wl.shape): wl for wl in self.workloads}
        return CampaignResult(
            frontiers={k: fr.as_pareto_frontier(wl_by_key[k])
                       for k, fr in self.frontiers.items()},
            trajectories={k: list(fr.trajectory)
                          for k, fr in self.frontiers.items()},
            tile_stats=list(self.tile_stats),
            space_size=len(self.space),
            tiles_done=self.next_tile,
            n_tiles=self.space.n_tiles(),
            wall_s=wall_s)

    # -- persistence --------------------------------------------------------

    def state_dict(self) -> Dict:
        return {
            "version": 1,
            "sim_model_version": costmodel.SIM_MODEL_VERSION,
            "space": self.space.to_dict(),
            "workloads": [{
                "arch": wl.arch, "shape": wl.shape,
                "base_analysis": dict(wl.base_analysis),
                "base_chips": wl.base_chips,
                "state_gb_per_device": wl.state_gb_per_device,
            } for wl in self.workloads],
            "constraint": dataclasses.asdict(self.constraint),
            "sim": dataclasses.asdict(self.sim),
            "evaluator": self.evaluator,
            "pipeline": self.pipeline,
            "next_tile": self.next_tile,
            "tile_stats": [s.as_dict() for s in self.tile_stats],
            "frontiers": {f"{arch}|{shape}": fr.state_dict()
                          for (arch, shape), fr in self.frontiers.items()},
        }
