"""Campaign orchestrator: resumable, bounded-memory DSE over mega-spaces.

A ``Campaign`` sweeps every workload in a cached dry-run artifact set across
a ``SpaceSpec``, tile by tile.  Two tile engines exist:

* the per-workload loop (``"numpy"`` float64 — bitwise-identical to one-shot
  ``pareto_search`` — and ``"fast"`` predictors): each tile is materialized,
  evaluated per workload, constraint-masked and raw-merged into that
  workload's ``StreamingFrontier``.

* the fused zero-copy pipeline (``"jit"`` and ``"pallas"``): tiles stream as
  array-only batches (no per-candidate Python objects), padded to
  ``chunk_size`` with a validity mask so the device function compiles ONCE
  for the whole sweep, and ALL workloads are evaluated in a single launch
  per tile (``costmodel.sweep_workloads_reduced_jit`` or the Pallas
  DSE-sweep kernel).  The launch also reduces each workload's tile to its
  feasible Pareto survivors on device, so the host transfers O(survivors)
  instead of O(tile) and merges via ``StreamingFrontier.merge_reduced``
  (proven identical to the raw merge); ``Candidate`` objects are
  materialized lazily for survivors only.  A prefetch thread stages the
  next tile's arrays while the device evaluates the current one
  (double-buffering), so candidate generation overlaps execution.

The tile engine itself lives in ``TileEvaluator``, and a reduced tile is a
``TileReduction`` — a pure function of (campaign config, tile span) that is
cheap to serialize.  That split is what the distributed fabric
(``repro.dse_campaign.fabric``) exploits: remote workers run the same
``TileEvaluator`` and ship ``TileReduction`` payloads to one coordinator,
whose frontier is bitwise-identical to this module's single-process sweep.

Peak candidate memory is one tile regardless of space size.  Tiles carry
their mesh axes (pod/data/model) into the simulators, so the factorization
axis of the space differentiates the frontier on every evaluator.

Checkpointing is by tile index: the campaign state (spec, workloads,
frontiers, trajectory, next tile) round-trips through JSON, so an
interrupted sweep resumes exactly where it stopped and converges to the
same frontier a fresh run produces — on the fused engines too, because the
reduced merge reproduces the raw merge's accounting exactly.
"""

from __future__ import annotations

import dataclasses
import functools
import queue
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import SHAPES, get_config
from repro.core import costmodel, dataset, dse
from repro.dse_campaign import store
from repro.dse_campaign.config import (EVALUATORS, CampaignConfig,
                                       _CAMPAIGN_LEGACY, _EVALUATOR_LEGACY,
                                       coerce_config)
from repro.dse_campaign.frontier import StreamingFrontier
from repro.dse_campaign.space import SpaceSpec
from repro.telemetry import coerce_telemetry

WorkloadKey = Tuple[str, str]


def workload_to_dict(wl: dse.Workload) -> Dict:
    """The JSON/pickle shape of a ``Workload`` used by checkpoints and the
    fabric's worker config — one definition so the two cannot drift."""
    return {"arch": wl.arch, "shape": wl.shape,
            "base_analysis": dict(wl.base_analysis),
            "base_chips": wl.base_chips,
            "state_gb_per_device": wl.state_gb_per_device}


def workload_from_dict(d: Dict) -> dse.Workload:
    """Inverse of ``workload_to_dict``."""
    return dse.Workload(arch=d["arch"], shape=d["shape"],
                        base_analysis=d["base_analysis"],
                        base_chips=d["base_chips"],
                        state_gb_per_device=d["state_gb_per_device"])


@dataclasses.dataclass
class TileStat:
    """Wall-clock accounting for one evaluated tile (all workloads).

    ``candidates`` counts per-workload candidate evaluations
    (``len(tile) * n_workloads``); ``wall_s`` is the tile's evaluation wall
    on whichever process evaluated it.  Stats survive checkpoint/resume, so
    summing them stays consistent with the campaign's evaluated counters.
    """

    tile: int
    candidates: int
    wall_s: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CampaignResult:
    """Final (or interrupted) campaign state returned by ``Campaign.run``
    and by the distributed fabric runners.

    ``frontiers`` / ``trajectories`` are per-(arch, shape) workload;
    ``tiles_done`` counts completed tiles (on a distributed run these may
    have completed out of order — completion, not order, is the invariant).
    """

    frontiers: Dict[WorkloadKey, dse.ParetoFrontier]
    trajectories: Dict[WorkloadKey, List]
    tile_stats: List[TileStat]
    space_size: int
    tiles_done: int
    n_tiles: int
    wall_s: float

    @property
    def complete(self) -> bool:
        """True once every tile of the space has folded into the frontiers."""
        return self.tiles_done >= self.n_tiles

    @property
    def candidates_evaluated(self) -> int:
        """Per-workload candidate evaluations across all runs (tile_stats
        survives resume), including any re-issued tiles on a fabric run."""
        return sum(s.candidates for s in self.tile_stats)

    @property
    def sweep_wall_s(self) -> float:
        """Total tile-evaluation wall across ALL runs of this campaign —
        ``tile_stats`` survives checkpoint/resume, so unlike ``wall_s`` (this
        ``run`` call only) it stays consistent with ``candidates_evaluated``
        on a resumed campaign."""
        return sum(s.wall_s for s in self.tile_stats)

    @property
    def candidates_per_sec(self) -> float:
        """Per-workload candidate evaluations per second of sweep wall."""
        return self.candidates_evaluated / max(self.sweep_wall_s, 1e-9)


@dataclasses.dataclass(frozen=True)
class TileReduction:
    """One evaluated tile reduced to exactly what a frontier merge needs.

    Per workload ``w``: ``surv_gidx[w]`` (global candidate indices into the
    space), ``surv_energy[w]`` / ``surv_latency[w]`` (float64 scores), the
    tile's exact feasible count ``n_feasible[w]``, and the tile's feasible
    maxima ``ref_energy_j[w]`` / ``ref_latency_s[w]`` (``None`` when the
    tile has no feasible point).

    Invariants the fabric and the fused single-process path both rely on:

    * ``surv_gidx[w] ⊆ [lo, hi)`` and holds a FEASIBLE SUPERSET of the
      tile's per-workload Pareto skyline, so
      ``StreamingFrontier.merge_reduced`` recovers the exact skyline and
      reproduces the raw merge's accounting bitwise;
    * the payload is O(survivors), not O(tile) — cheap to pickle across a
      process (or host) boundary;
    * it is a pure function of (space, workloads, constraint, sim,
      evaluator) and the tile span — no cross-tile state — which is what
      makes a lost tile safely re-issuable to any other worker.

    Adaptive campaigns additionally carry a seeded training subsample:
    ``sample_lidx`` (LOCAL indices into the tile, shared by all workloads —
    candidate features are workload-independent) plus per-workload
    ``sample_energy`` / ``sample_latency`` rows the surrogates train on.
    The subsample is seeded by ``(adaptive.seed, lo)``, so it is a pure
    function of config x span like everything else here — a re-issued or
    replayed tile yields bitwise-identical training rows on any worker.
    ``None`` (exact campaigns) keeps the payload unchanged.
    """

    lo: int
    hi: int
    surv_gidx: Tuple[np.ndarray, ...]
    surv_energy: Tuple[np.ndarray, ...]
    surv_latency: Tuple[np.ndarray, ...]
    n_feasible: Tuple[int, ...]
    ref_energy_j: Tuple[Optional[float], ...]
    ref_latency_s: Tuple[Optional[float], ...]
    sample_lidx: Optional[np.ndarray] = None
    sample_energy: Optional[Tuple[np.ndarray, ...]] = None
    sample_latency: Optional[Tuple[np.ndarray, ...]] = None

    @property
    def n_workloads(self) -> int:
        """Workload count W (every per-workload tuple has this length)."""
        return len(self.surv_gidx)

    @property
    def n_survivors(self) -> int:
        """Total survivors across workloads — the payload's wire size is
        O(this), never O(tile)."""
        return int(sum(g.size for g in self.surv_gidx))


class _TilePrefetcher:
    """Double-buffered tile staging: a worker thread materializes the next
    tile(s) of a ``SpaceSpec.tiles`` generator while the main thread drives
    the device on the current one.  The worker does numpy-only work (no JAX
    dispatch), so it is safe alongside the evaluating thread; ``close()``
    unblocks and retires it when iteration stops early (max_tiles)."""

    _END = object()

    def __init__(self, it, depth: int = 1):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(depth)))
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, args=(it,),
                                        daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _work(self, it):
        try:
            for item in it:
                if not self._put(item):
                    return
        except BaseException as exc:  # re-raised on the consuming thread
            self._err = exc
        self._put(self._END)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._END:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()


class TileEvaluator:
    """The one-tile engine shared by ``Campaign`` and the fabric workers.

    Holds everything needed to turn a tile span of a ``SpaceSpec`` into a
    ``TileReduction``: the workload set, constraint, ``SimConfig`` and the
    evaluator tier.  ``reduce_tile`` is side-effect free with respect to
    the campaign (no frontier state lives here), so any number of
    evaluators — across threads, processes or hosts — can work on disjoint
    (or even overlapping) tiles and their reductions fold into one frontier
    without coordination beyond the merge itself.

    Constructed from a ``CampaignConfig`` (``config.evaluator`` selects the
    engine: ``"numpy"`` — float64 per-workload simulator, bitwise-identical
    to one-shot ``pareto_search`` —, ``"jit"`` — fused float32
    multi-workload sweep; ``pipeline=False`` falls back to the legacy
    per-workload jit loop —, ``"pallas"`` — the fused Pallas DSE-sweep
    kernel — or ``"fast"`` — trained predictors; requires fitted
    ``power_model``/``cycles_model`` and, being unpicklable, is refused by
    the distributed fabric).  The pre-config keyword form
    ``TileEvaluator(workloads, space, evaluator=..., ...)`` still works but
    emits a ``DeprecationWarning``.

    ``fused_launches`` counts fused multi-workload sweep launches
    (``sweep_reduced`` calls) over this evaluator's lifetime — the serving
    layer's "batched concurrent queries ride ONE launch" assertion reads
    it, so the claim is measured rather than assumed.  It is now a view
    over the evaluator's telemetry counter
    (``evaluator_fused_launches_total``); pass ``telemetry=`` to share a
    registry/tracer with the caller, or omit it for a private
    ``NullTelemetry`` (counters still count, tracing is free).
    """

    def __init__(self, workloads: Sequence[dse.Workload], config=None,
                 telemetry=None, **legacy):
        cfg = coerce_config("TileEvaluator", config, legacy,
                            _EVALUATOR_LEGACY)
        keys = [(wl.arch, wl.shape) for wl in workloads]
        if len(set(keys)) != len(keys):
            raise ValueError(f"duplicate (arch, shape) workload keys: {keys}")
        self.config = cfg
        self.workloads = list(workloads)
        self.space = cfg.resolved_space
        self.constraint = cfg.resolved_constraint
        self.evaluator = cfg.evaluator
        self.sim = cfg.sim
        self.power_model = cfg.power_model
        self.cycles_model = cfg.cycles_model
        self.pipeline = bool(cfg.pipeline)
        self.max_survivors = int(cfg.max_survivors)
        self.adaptive = cfg.adaptive
        self.train_sample = 0 if cfg.adaptive is None \
            else int(cfg.adaptive.train_sample)
        self.telemetry = coerce_telemetry(telemetry)
        # held series: the hot path pays one attribute read, not a dict hit
        self._c_fused = self.telemetry.counter("evaluator_fused_launches_total")
        self._c_candidates = self.telemetry.counter(
            "evaluator_candidates_total")
        self._c_survivors = self.telemetry.counter(
            "evaluator_survivors_total")

    @property
    def fused_launches(self) -> int:
        """Fused sweep launches so far — a view over the telemetry counter
        (kept as the historical public reading surface)."""
        return int(self._c_fused.value)

    @property
    def fused(self) -> bool:
        """Whether tiles go through the fused multi-workload reduced path."""
        return (self.evaluator == "pallas"
                or (self.evaluator == "jit" and self.pipeline))

    @property
    def workload_keys(self) -> List[WorkloadKey]:
        """(arch, shape) keys in workload order — the order every
        ``TileReduction`` tuple and frontier dict is indexed by."""
        return [(wl.arch, wl.shape) for wl in self.workloads]

    # -- per-workload evaluation (numpy / fast / legacy jit) ----------------

    def evaluate_workload(self, wl: dse.Workload, batch: dse.CandidateBatch
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(energy_j, latency_s, feasible) for one workload on one tile."""
        if self.evaluator == "fast":
            return self._evaluate_fast(wl, batch)
        res, feasible = dse.evaluate_workload_tile(
            wl, batch, self.constraint, sim=self.sim, engine=self.evaluator)
        return np.asarray(res.energy_j), np.asarray(res.latency_s), feasible

    def _evaluate_fast(self, wl: dse.Workload, batch: dse.CandidateBatch):
        """Predictor fast path via ``dse.predict_space`` (same scoring as
        ``fast_path_search``).  Workload shapes suffixed with a pod tag
        resolve to their base shape."""
        cfg = get_config(wl.arch)
        shape = SHAPES[wl.shape.split(":", 1)[0]]
        energy, latency, feasible, _, _ = dse.predict_space(
            cfg, shape, self.power_model, self.cycles_model, batch,
            self.constraint)
        return energy, latency, feasible

    # -- fused zero-copy sweep (jit / pallas) -------------------------------

    @functools.cached_property
    def wl_cols(self) -> np.ndarray:
        """Packed [W, len(WL_COLS)] per-workload scalar matrix (cached)."""
        return np.asarray(
            [[wl.base_analysis["flops"], wl.base_analysis["hbm_bytes"],
              wl.base_analysis["collective_bytes"],
              wl.base_analysis["wire_bytes"], wl.base_chips,
              wl.state_gb_per_device] for wl in self.workloads],
            np.float64)

    def padded_tile_arrays(self, batch: dse.CandidateBatch) -> Dict:
        """The tile's packed columns padded to ``chunk_size`` with a validity
        mask — every tile presents the SAME shapes to the device function,
        so jit/Pallas trace exactly once for the whole sweep (the partial
        final tile no longer retriggers a retrace)."""
        n = len(batch)
        target = max(self.space.chunk_size, n)
        pad = target - n

        def padarr(a):
            a = np.asarray(a)
            return a if pad == 0 else np.concatenate(
                [a, np.repeat(a[:1], pad, axis=0)])

        valid = np.ones(target, np.float64)
        valid[n:] = 0.0
        arrays = {
            "n_chips": padarr(batch.n_chips),
            "freq_mhz": padarr(batch.freq_mhz),
            "mesh_pod": padarr(batch.pod_axis()),
            "mesh_data": padarr(batch.mesh_data),
            "mesh_model": padarr(batch.mesh_model),
            "valid": valid,
        }
        arrays.update({k: padarr(batch.chip_cols[k])
                       for k in costmodel.SWEEP_GATHER_FIELDS})
        return arrays

    def sweep_reduced(self, batch: dse.CandidateBatch
                      ) -> costmodel.SweepReduced:
        """ONE fused launch: all workloads x one padded tile, skyline-reduced
        on device.  Spans wrap the host-side stages only — ``pad`` (array
        staging) and ``launch`` (the device dispatch); tracing never enters
        the jitted/Pallas code itself."""
        self._c_fused.inc()
        with self.telemetry.span("pad", n=len(batch)):
            arrays = self.padded_tile_arrays(batch)
        cons = self.constraint
        with self.telemetry.span("launch", evaluator=self.evaluator,
                                 n=len(batch)):
            if self.evaluator == "pallas":
                from repro.kernels import ops
                from repro.kernels.dse_sweep import pack_cand_cols
                return ops.dse_sweep(
                    pack_cand_cols(arrays), self.wl_cols, sim=self.sim,
                    constraint=cons, max_survivors=self.max_survivors,
                    n_valid=len(batch))
            return costmodel.sweep_workloads_reduced_jit(
                self.wl_cols,
                {k: arrays[k] for k in costmodel.SWEEP_GATHER_FIELDS},
                arrays["n_chips"], arrays["freq_mhz"], arrays["mesh_pod"],
                arrays["mesh_data"], arrays["mesh_model"], arrays["valid"],
                sim=self.sim, max_power_w=cons.max_power_w,
                max_latency_s=cons.max_latency_s,
                min_hbm_fit=cons.min_hbm_fit,
                max_survivors=self.max_survivors)

    # -- the normalized reduction -------------------------------------------

    def _tile_sample_lidx(self, n: int, lo: int) -> Optional[np.ndarray]:
        """Seeded training-subsample indices for the tile at ``lo`` (local,
        sorted, without replacement), or ``None`` when the campaign is not
        adaptive.  Seeded by ``(adaptive.seed, lo)`` so the draw depends
        only on config x span — never on which worker or in which round the
        tile was evaluated."""
        if self.train_sample <= 0:
            return None
        k = min(self.train_sample, n)
        rng = np.random.default_rng((self.adaptive.seed, lo))
        return np.sort(rng.choice(n, size=k, replace=False)).astype(np.int64)

    @staticmethod
    def _reduce_rows(energy: np.ndarray, latency: np.ndarray,
                     feasible: np.ndarray, lo: int):
        """Host-side reduction of one workload's raw tile rows: exact
        feasible Pareto survivors + the aggregates ``merge_reduced`` needs to
        reproduce the raw merge's accounting (proven identical by the
        ``merge_reduced``-vs-raw hypothesis property)."""
        e = np.asarray(energy, np.float64)
        l = np.asarray(latency, np.float64)
        feas = np.asarray(feasible, bool)
        loc = np.flatnonzero(dse.pareto_mask(e, l, feas))
        n_feas = int(feas.sum())
        ref_e = float(e[feas].max()) if n_feas else None
        ref_l = float(l[feas].max()) if n_feas else None
        return (lo + loc.astype(np.int64), e[loc], l[loc], n_feas,
                ref_e, ref_l)

    def reduce_tile(self, batch: dse.CandidateBatch, lo: int
                    ) -> TileReduction:
        """Evaluate one tile for ALL workloads and reduce it to a
        ``TileReduction`` — the single entry point both the in-process fused
        sweep and the fabric workers call, so the two paths cannot diverge.

        Fused evaluators keep the on-device screen survivors (a feasible
        superset of the tile skyline, float-cast to float64 exactly); a
        workload whose screened set overflowed ``max_survivors`` — and every
        non-fused evaluator — is reduced host-side to the exact feasible
        Pareto set instead.  Either way the fold through
        ``StreamingFrontier.merge_reduced`` equals the raw full-tile merge.

        With ``config.adaptive`` set, the reduction additionally carries a
        seeded per-tile training subsample (see ``TileReduction``); the
        fused path reads it off the already-materialized full rows, the
        per-workload path off each workload's evaluation — zero extra
        launches either way.
        """
        n = len(batch)
        cols = {"gidx": [], "e": [], "l": [], "nf": [], "re": [], "rl": []}
        lidx = self._tile_sample_lidx(n, lo)
        samp_e: List[np.ndarray] = []
        samp_l: List[np.ndarray] = []

        def add(gidx, e, l, nf, re, rl):
            cols["gidx"].append(gidx)
            cols["e"].append(e)
            cols["l"].append(l)
            cols["nf"].append(nf)
            cols["re"].append(re)
            cols["rl"].append(rl)

        if self.fused:
            red = self.sweep_reduced(batch)
            with self.telemetry.span("compact", n=n):
                for wi in range(len(self.workloads)):
                    if lidx is not None:
                        samp_e.append(np.asarray(
                            red.energy_full, np.float64)[wi][lidx])
                        samp_l.append(np.asarray(
                            red.latency_full, np.float64)[wi][lidx])
                    if red.overflowed(wi):
                        add(*self._reduce_rows(
                            np.asarray(red.energy_full)[wi][:n],
                            np.asarray(red.latency_full)[wi][:n],
                            np.asarray(red.feasible_full)[wi][:n], lo))
                        continue
                    k = int(red.n_survivors[wi])
                    nf = int(red.n_feasible[wi])
                    add(lo + red.surv_idx[wi][:k].astype(np.int64),
                        red.surv_energy[wi][:k].astype(np.float64),
                        red.surv_latency[wi][:k].astype(np.float64), nf,
                        float(red.ref_energy[wi]) if nf else None,
                        float(red.ref_latency[wi]) if nf else None)
        else:
            for wl in self.workloads:
                with self.telemetry.span("launch", evaluator=self.evaluator,
                                         workload=f"{wl.arch}|{wl.shape}"):
                    energy, latency, feasible = \
                        self.evaluate_workload(wl, batch)
                if lidx is not None:
                    samp_e.append(np.asarray(energy, np.float64)[lidx])
                    samp_l.append(np.asarray(latency, np.float64)[lidx])
                with self.telemetry.span("compact", n=n):
                    add(*self._reduce_rows(energy, latency, feasible, lo))
        tr = TileReduction(
            lo=lo, hi=lo + n,
            surv_gidx=tuple(cols["gidx"]), surv_energy=tuple(cols["e"]),
            surv_latency=tuple(cols["l"]), n_feasible=tuple(cols["nf"]),
            ref_energy_j=tuple(cols["re"]), ref_latency_s=tuple(cols["rl"]),
            sample_lidx=lidx,
            sample_energy=tuple(samp_e) if lidx is not None else None,
            sample_latency=tuple(samp_l) if lidx is not None else None)
        self._c_candidates.inc(n * len(self.workloads))
        self._c_survivors.inc(tr.n_survivors)
        return tr


class Campaign:
    """Streaming multi-workload DSE campaign over a ``SpaceSpec``.

    Constructed from a ``CampaignConfig`` (``Campaign(workloads, config)``);
    the pre-config keyword form ``Campaign(workloads, space,
    evaluator=..., ...)`` still works but emits a ``DeprecationWarning``.

    ``config.evaluator`` selects the tile engine: ``"numpy"`` (float64 simulator,
    bitwise-identical to one-shot ``pareto_search``), ``"jit"``
    (float32 fused multi-workload sweep, ``costmodel.sweep_workloads_
    reduced_jit``), ``"pallas"`` (the fused Pallas DSE-sweep kernel —
    float64 in interpret mode on CPU, where its frontier holds the numpy
    evaluator's exact candidate set, float32 compiled on an accelerator),
    or ``"fast"``
    (trained predictors; pass fitted ``power_model``/``cycles_model``).

    ``pipeline=False`` disables the fused path for ``"jit"`` and falls back
    to the original per-workload loop on unpadded tiles (one launch per
    workload per tile, full-tile host transfer, raw merges) — kept as the
    measured baseline for the evaluator-speedup benchmark.

    Invariant: the final frontier depends only on (space, workloads,
    constraint, sim, evaluator) — never on tile size, tile order,
    interruption points, or (via ``repro.dse_campaign.fabric``) how many
    workers evaluated the tiles.
    """

    def __init__(self, workloads: Sequence[dse.Workload], config=None,
                 telemetry=None, **legacy):
        cfg = coerce_config("Campaign", config, legacy, _CAMPAIGN_LEGACY)
        self.telemetry = coerce_telemetry(telemetry)
        self.engine = TileEvaluator(workloads, cfg,
                                    telemetry=self.telemetry)
        self.checkpoint_every = int(cfg.checkpoint_every)
        self.frontiers: Dict[WorkloadKey, StreamingFrontier] = {
            k: StreamingFrontier() for k in self.engine.workload_keys}
        self.tile_stats: List[TileStat] = []
        self.next_tile = 0

    # -- config views (the engine owns the config; Campaign owns the state) -

    @property
    def config(self) -> CampaignConfig:
        return self.engine.config

    @property
    def workloads(self) -> List[dse.Workload]:
        return self.engine.workloads

    @property
    def space(self) -> SpaceSpec:
        return self.engine.space

    @property
    def constraint(self) -> dse.Constraint:
        return self.engine.constraint

    @property
    def evaluator(self) -> str:
        return self.engine.evaluator

    @property
    def sim(self) -> costmodel.SimConfig:
        return self.engine.sim

    @property
    def pipeline(self) -> bool:
        return self.engine.pipeline

    @property
    def max_survivors(self) -> int:
        return self.engine.max_survivors

    @property
    def fused(self) -> bool:
        """Whether tiles go through the fused multi-workload reduced path."""
        return self.engine.fused

    def _sweep_tile_reduced(self, batch: dse.CandidateBatch
                            ) -> costmodel.SweepReduced:
        """One fused launch on one tile (kernel-test entry point)."""
        return self.engine.sweep_reduced(batch)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_artifacts(cls, art_dir: str, config=None,
                       **kwargs) -> "Campaign":
        """Sweep ALL cached dry-run workloads under ``art_dir``.

        ``config`` is a ``CampaignConfig`` (or, deprecated, a ``SpaceSpec``
        plus the old keyword set — forwarded to the constructor shim).
        Each artifact's compiled census (``base_analysis``) is loaded ONCE
        per (arch, shape) cell and reused across every tile of the sweep.
        Colliding (arch, shape) cells from different pods are disambiguated
        by suffixing the shape with the pod tag.
        """
        arts = dataset.load_dryrun_artifacts(art_dir)
        if not arts:
            raise FileNotFoundError(f"no dry-run artifacts in {art_dir}")
        seen = {}
        for (arch, shape, pod), art in sorted(arts.items()):
            key = (arch, shape) if (arch, shape) not in seen else (
                arch, f"{shape}:{pod}")
            seen[key] = dse.Workload(
                arch=key[0], shape=key[1],
                base_analysis={k: art["hxa"][k] for k in
                               ("flops", "hbm_bytes", "collective_bytes",
                                "wire_bytes")},
                base_chips=art["roofline"]["n_chips"],
                state_gb_per_device=art["memory"]["state_gb_per_device"])
        return cls(list(seen.values()), config, **kwargs)

    @classmethod
    def from_checkpoint(cls, path: str, **kwargs) -> "Campaign":
        """Rebuild an interrupted campaign from its checkpoint file; the
        next ``run`` continues at the first unevaluated tile.

        Space, workloads, constraint, ``SimConfig``, evaluator and pipeline
        mode are all restored from the checkpoint into a ``CampaignConfig``;
        extra keyword arguments override config fields on the rebuilt
        config.  Fitted predictor models
        cannot be serialized, so resuming an ``evaluator="fast"`` campaign
        requires re-passing the SAME ``power_model``/``cycles_model`` via
        kwargs (``__init__`` refuses to resume without them); supplying
        retrained models would splice two predictors into one frontier
        undetected.  A checkpoint written under a different
        ``costmodel.SIM_MODEL_VERSION`` is refused for the same reason: its
        folded-in tiles and the tiles a resume would evaluate come from
        incomparable cost models.

        A checkpoint written by the distributed fabric also loads here:
        ``next_tile`` is the contiguous done prefix, and any out-of-order
        tiles the fabric already folded re-merge as exact no-ops (span
        idempotence), so a single-process resume still converges to the
        same frontier.

        Corrupt checkpoints do not crash the resume: ``store.load_checkpoint``
        verifies the integrity CRC, quarantines a bad file to ``*.corrupt``
        and falls back to the newest valid generation (see
        ``docs/resilience.md``); only when no copy on disk verifies does a
        ``CheckpointCorruptionError`` surface.
        """
        state = store.load_checkpoint(path)
        return cls.from_state(state, source=path, **kwargs)

    @classmethod
    def from_state(cls, state: Dict, source: str = "<state>",
                   **kwargs) -> "Campaign":
        """Rebuild a campaign from an already-loaded ``state_dict`` (the
        verified-load half of ``from_checkpoint`` — callers that need the
        corruption-recovery report use ``store.load_checkpoint_recovering``
        and hand the state here)."""
        ckpt_model = state.get("sim_model_version")
        if ckpt_model != costmodel.SIM_MODEL_VERSION:
            raise ValueError(
                f"checkpoint {source} was written under cost-model version "
                f"{ckpt_model!r} but this build is "
                f"{costmodel.SIM_MODEL_VERSION}; resuming would splice two "
                "incomparable cost models into one frontier.  To upgrade, "
                "re-run the campaign from scratch under the current model "
                "(and rebuild any FrontierIndex derived from this "
                "checkpoint)")
        workloads = [workload_from_dict(w) for w in state["workloads"]]
        cfg = CampaignConfig(
            space=SpaceSpec.from_dict(state["space"]),
            evaluator=state["evaluator"],
            constraint=dse.Constraint(**state["constraint"]),
            sim=costmodel.SimConfig(**state["sim"]),
            # checkpoints written before the fused pipeline carry no key:
            # they ran the legacy per-workload engine, so resume must stay
            # on it — splicing the fused float32 sweep into a half-done
            # legacy "jit" campaign could flip float32 near-ties
            # mid-frontier
            pipeline=state.get("pipeline", False))
        telemetry = kwargs.pop("telemetry", None)
        if kwargs:
            unknown = set(kwargs) - {f.name for f in
                                     dataclasses.fields(CampaignConfig)}
            if unknown:
                raise TypeError(f"from_state: unexpected keyword "
                                f"arguments {sorted(unknown)}")
            cfg = cfg.replace(**kwargs)
        camp = cls(workloads, cfg, telemetry=telemetry)
        camp.next_tile = state["next_tile"]
        camp.tile_stats = [TileStat(**s) for s in state["tile_stats"]]
        for key_str, fr_state in state["frontiers"].items():
            arch, shape = key_str.split("|", 1)
            camp.frontiers[(arch, shape)] = StreamingFrontier.from_state(fr_state)
        return camp

    # -- folding ------------------------------------------------------------

    def merge_reduction(self, tr: TileReduction, tile_no: int = -1) -> None:
        """Fold one ``TileReduction`` into every workload's frontier, with
        survivor ``Candidate`` objects materialized lazily from the space.

        Idempotent at tile granularity: re-folding an already-folded tile —
        a duplicate delivery on the fabric, or a replayed tile after a
        resume — changes neither the frontier nor its accounting."""
        for wi, wl in enumerate(self.workloads):
            gidx = tr.surv_gidx[wi]
            self.frontiers[(wl.arch, wl.shape)].merge_reduced(
                self.space.candidates_at(gidx), tr.surv_energy[wi],
                tr.surv_latency[wi], gidx, span=(tr.lo, tr.hi),
                n_feasible=tr.n_feasible[wi],
                ref_energy_j=tr.ref_energy_j[wi],
                ref_latency_s=tr.ref_latency_s[wi], tile=tile_no)

    # -- the sweep ----------------------------------------------------------

    def run(self, checkpoint_path: Optional[str] = None,
            max_tiles: Optional[int] = None) -> CampaignResult:
        """Sweep tiles from ``next_tile`` on; returns the (possibly partial)
        campaign result.  ``max_tiles`` bounds THIS call (interruption point
        for resume demos/tests); with a ``checkpoint_path`` (defaulting to
        ``config.checkpoint_path``) the state is persisted every
        ``checkpoint_every`` tiles and at the end."""
        if checkpoint_path is None:
            checkpoint_path = self.config.checkpoint_path
        tel = self.telemetry
        clock = tel.clock
        c_tiles = tel.counter("campaign_tiles_total")
        c_ckpt = tel.counter("campaign_checkpoint_writes_total")
        t_start = clock()
        done_this_call = 0
        fused = self.fused
        engine = self.engine
        tiles = _TilePrefetcher(self.space.tiles(
            start_tile=self.next_tile, with_candidates=not fused))
        try:
            for tile_no, lo, batch in tiles:
                if max_tiles is not None and done_this_call >= max_tiles:
                    break
                t0 = clock()
                with tel.span("tile_eval", tile=tile_no, n=len(batch)):
                    if fused:
                        tr = engine.reduce_tile(batch, lo)
                        with tel.span("merge", tile=tile_no):
                            self.merge_reduction(tr, tile_no)
                    else:
                        indices = np.arange(lo, lo + len(batch),
                                            dtype=np.int64)
                        for wl in self.workloads:
                            with tel.span(
                                    "launch", evaluator=engine.evaluator,
                                    workload=f"{wl.arch}|{wl.shape}"):
                                energy, latency, feasible = \
                                    engine.evaluate_workload(wl, batch)
                            with tel.span("merge", tile=tile_no):
                                self.frontiers[(wl.arch, wl.shape)].merge(
                                    batch.candidates, energy, latency,
                                    feasible, indices=indices, tile=tile_no)
                        engine._c_candidates.inc(
                            len(batch) * len(self.workloads))
                c_tiles.inc()
                self.tile_stats.append(TileStat(
                    tile=tile_no,
                    candidates=len(batch) * len(self.workloads),
                    wall_s=clock() - t0))
                self.next_tile = tile_no + 1
                done_this_call += 1
                if checkpoint_path and (self.next_tile % self.checkpoint_every == 0):
                    with tel.span("checkpoint_write", tile=tile_no):
                        store.save_checkpoint(self.state_dict(),
                                              checkpoint_path)
                    c_ckpt.inc()
        finally:
            tiles.close()
        if checkpoint_path:
            with tel.span("checkpoint_write", tile=self.next_tile - 1):
                store.save_checkpoint(self.state_dict(), checkpoint_path)
            c_ckpt.inc()
        return self._result(clock() - t_start)

    def _result(self, wall_s: float, tiles_done: Optional[int] = None
                ) -> CampaignResult:
        wl_by_key = {(wl.arch, wl.shape): wl for wl in self.workloads}
        return CampaignResult(
            frontiers={k: fr.as_pareto_frontier(wl_by_key[k])
                       for k, fr in self.frontiers.items()},
            trajectories={k: list(fr.trajectory)
                          for k, fr in self.frontiers.items()},
            tile_stats=list(self.tile_stats),
            space_size=len(self.space),
            tiles_done=self.next_tile if tiles_done is None else tiles_done,
            n_tiles=self.space.n_tiles(),
            wall_s=wall_s)

    # -- persistence --------------------------------------------------------

    def state_dict(self) -> Dict:
        """Full JSON-serializable campaign state (schema version 1), stamped
        with ``SIM_MODEL_VERSION`` so ``from_checkpoint`` can refuse to splice
        two cost models into one frontier."""
        return {
            "version": 1,
            "sim_model_version": costmodel.SIM_MODEL_VERSION,
            "space": self.space.to_dict(),
            "workloads": [workload_to_dict(wl) for wl in self.workloads],
            "constraint": dataclasses.asdict(self.constraint),
            "sim": dataclasses.asdict(self.sim),
            "evaluator": self.evaluator,
            "pipeline": self.pipeline,
            "next_tile": self.next_tile,
            "tile_stats": [s.as_dict() for s in self.tile_stats],
            "frontiers": {f"{arch}|{shape}": fr.state_dict()
                          for (arch, shape), fr in self.frontiers.items()},
        }
