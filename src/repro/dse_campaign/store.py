"""JSON persistence for campaigns: checkpoints + BENCH_*.json artifacts.

Two artifact kinds:
  * checkpoint — the full resumable ``Campaign.state_dict()`` (spec,
    workloads, constraint, per-workload frontier state, next tile), written
    atomically so an interrupt mid-write never corrupts the resume point.
    A distributed run (``repro.dse_campaign.fabric``) writes the SAME
    schema (version 1) plus an optional ``"fabric"`` key holding done-tile
    intervals and outstanding leases; ``next_tile`` is the contiguous done
    prefix, so either resume path — ``FabricCoordinator.from_checkpoint``
    (skips all done tiles) or plain ``Campaign.from_checkpoint`` (replays
    out-of-prefix tiles as exact merge no-ops) — converges to the same
    frontier.
  * campaign report — the ``BENCH_dse_campaign.json`` shape consumed by CI:
    frontier members + per-tile trajectory + throughput, diffable across PRs
    the same way the other ``BENCH_*``/bench ``run.json`` artifacts are.

Checkpoint durability (PR 10) layers three defenses on the atomic rename:

  * integrity envelope — every checkpoint carries an ``"integrity"`` key
    with a CRC32 over the canonical (sorted, compact) JSON of the rest of
    the state plus a monotonically increasing generation number; loads
    verify the CRC and treat a mismatch exactly like unparseable JSON.
  * write-ahead journal — ``<path>.journal`` gets an fsync'd, CRC-stamped
    record (generation, payload CRC, byte count, next_tile) *before* the
    rename publishes the new checkpoint, so after any crash the journal
    tells you which generation was durable last and how far the campaign
    had progressed.  Torn journal lines self-identify via the per-line CRC
    prefix and are skipped.
  * generations + quarantine — each save also lands as ``<path>.g<NNN>``;
    retention keeps the newest ``keep`` generations.  A corrupt checkpoint
    is renamed aside to ``*.corrupt`` (evidence, not deleted) and the load
    falls back to the newest generation that verifies, so a flipped bit or
    truncated write costs at most ``checkpoint_every`` tiles of rework —
    never a traceback, never a silently wrong frontier.
"""

from __future__ import annotations

import json
import os
import platform
import re
import zlib
from typing import Dict, List, Optional, Tuple

from repro.core.costmodel import SIM_MODEL_VERSION
from repro.dse_campaign.frontier import candidate_to_dict

CAMPAIGN_BENCH_NAME = "BENCH_dse_campaign.json"

# checkpoint generations kept on disk (newest K); the published path itself
# is a hardlink/copy of the newest generation and does not count
KEEP_GENERATIONS = 3

INTEGRITY_KEY = "integrity"

_GEN_RE = re.compile(r"\.g(\d{8})$")


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint failed CRC/parse verification and no fallback survived."""


def _fsync_dir(d: str) -> None:
    """fsync a directory so a rename within it survives power loss.

    Best-effort: some filesystems (and non-POSIX platforms) refuse to open
    directories; the rename is still atomic in the namespace there.
    """
    try:
        fd = os.open(d or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write_text(data: str, path: str) -> int:
    """tmp + flush + fsync + rename + parent-dir fsync; returns bytes written."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    raw = data.encode("utf-8")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(raw)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    # os.replace is atomic in the namespace but the *rename itself* lives in
    # the directory — without this fsync a power cut can resurrect the old
    # directory entry even though the file data was durable
    _fsync_dir(d)
    return len(raw)


def atomic_write_json(payload: Dict, path: str) -> int:
    """Write ``payload`` as JSON via tmp-file + ``os.replace``.

    The temp file is flushed and fsync'd before the rename, and the parent
    directory is fsync'd after it: ``os.replace`` is atomic in the namespace
    but says nothing about durability of either the data or the rename, so
    without both fsyncs a crash could leave a truncated-but-named checkpoint
    or roll the rename back — exactly the corruption the fabric's resume
    path assumes cannot happen.  Returns the bytes written (journal
    accounting).
    """
    return _atomic_write_text(json.dumps(payload, indent=1), path)


# pre-PR-7 private name, kept for any out-of-tree callers
_atomic_write_json = atomic_write_json


def checkpoint_crc(state: Dict) -> int:
    """CRC32 over the canonical JSON of ``state`` (integrity key excluded)."""
    body = {k: v for k, v in state.items() if k != INTEGRITY_KEY}
    canon = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return zlib.crc32(canon.encode("utf-8")) & 0xFFFFFFFF


def generation_paths(path: str) -> List[Tuple[int, str]]:
    """On-disk ``(generation, path)`` pairs for ``path``, oldest first."""
    d = os.path.dirname(path) or "."
    base = os.path.basename(path)
    out = []
    try:
        names = os.listdir(d)
    except OSError:
        return []
    for name in names:
        if not name.startswith(base):
            continue
        m = _GEN_RE.search(name)
        if m and name == base + m.group(0):
            out.append((int(m.group(1)), os.path.join(d, name)))
    return sorted(out)


class CheckpointJournal:
    """Append-only write-ahead journal next to a checkpoint path.

    One JSONL record per save, each line prefixed with its own CRC32
    (``"<crc32:08x> <json>\\n"``) so a torn final line after a crash is
    detected and skipped rather than mistaken for history.  Appends are
    fsync'd *before* the checkpoint rename — write-ahead: if the journal
    lacks generation N, generation N was never promised.
    """

    SUFFIX = ".journal"

    def __init__(self, checkpoint_path: str):
        self.checkpoint_path = checkpoint_path
        self.path = checkpoint_path + self.SUFFIX

    def append(self, record: Dict) -> int:
        """fsync'd append of one CRC-prefixed record; returns bytes appended."""
        body = json.dumps(record, sort_keys=True, separators=(",", ":"))
        crc = zlib.crc32(body.encode("utf-8")) & 0xFFFFFFFF
        line = f"{crc:08x} {body}\n"
        raw = line.encode("utf-8")
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "ab") as f:
            f.write(raw)
            f.flush()
            os.fsync(f.fileno())
        return len(raw)

    def records(self) -> Tuple[List[Dict], int]:
        """All intact records (oldest first) and the count of torn lines."""
        if not os.path.exists(self.path):
            return [], 0
        records, torn = [], 0
        with open(self.path, "rb") as f:
            for raw in f:
                line = raw.decode("utf-8", errors="replace").rstrip("\n")
                if len(line) < 10 or line[8] != " ":
                    torn += 1
                    continue
                prefix, body = line[:8], line[9:]
                try:
                    crc = int(prefix, 16)
                except ValueError:
                    torn += 1
                    continue
                if zlib.crc32(body.encode("utf-8")) & 0xFFFFFFFF != crc:
                    torn += 1
                    continue
                try:
                    records.append(json.loads(body))
                except json.JSONDecodeError:
                    torn += 1
            return records, torn

    def last_generation(self) -> int:
        records, _ = self.records()
        gens = [int(r.get("generation", 0)) for r in records]
        return max(gens) if gens else 0


def _read_generation(path: str) -> int:
    """Generation stamped inside a checkpoint file; 0 if unreadable/legacy."""
    try:
        with open(path) as f:
            state = json.load(f)
        return int(state.get(INTEGRITY_KEY, {}).get("generation", 0))
    except (OSError, ValueError):
        return 0


def save_checkpoint(state: Dict, path: str, keep: int = KEEP_GENERATIONS,
                    journal: bool = True) -> str:
    """Persist a ``Campaign.state_dict()`` durably; returns ``path``.

    Order of operations (each step durable before the next):

    1. stamp the state with its integrity envelope (CRC32 + generation);
    2. append the write-ahead journal record (fsync'd);
    3. write the generation file ``<path>.g<NNN>`` atomically;
    4. publish it at ``path`` (hardlink + rename, copy fallback);
    5. prune generations beyond ``keep``.

    A crash between any two steps leaves either the previous checkpoint
    intact or the new one fully published — and the journal always knows
    which.
    """
    gens = generation_paths(path)
    gen = max([g for g, _ in gens] + [_read_generation(path), 0]) + 1
    body = {k: v for k, v in state.items() if k != INTEGRITY_KEY}
    crc = checkpoint_crc(body)
    stamped = dict(body)
    stamped[INTEGRITY_KEY] = {"crc32": crc, "generation": gen,
                              "algo": "crc32/json-c14n"}
    data = json.dumps(stamped, indent=1)
    if journal:
        CheckpointJournal(path).append({
            "generation": gen,
            "crc32": crc,
            "bytes": len(data.encode("utf-8")),
            "next_tile": state.get("next_tile"),
        })
    gen_path = f"{path}.g{gen:08d}"
    _atomic_write_text(data, gen_path)
    # publish as a separate inode (not a hardlink): in-place corruption of
    # the canonical file must not also corrupt the generation it falls back to
    _atomic_write_text(data, path)
    for _, old in generation_paths(path)[:-keep] if keep > 0 else []:
        try:
            os.unlink(old)
        except OSError:
            pass
    return path


def _load_verified(path: str) -> Dict:
    """Parse + CRC-verify one checkpoint file; CheckpointCorruptionError on
    any parse/CRC failure.  Legacy checkpoints without an integrity envelope
    are accepted (nothing to verify against)."""
    try:
        with open(path) as f:
            state = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as exc:
        raise CheckpointCorruptionError(
            f"checkpoint {path} is unreadable: {exc}") from exc
    if not isinstance(state, dict):
        raise CheckpointCorruptionError(
            f"checkpoint {path} is not a JSON object")
    envelope = state.get(INTEGRITY_KEY)
    if envelope is not None:
        try:
            expected = int(envelope["crc32"])
        except (TypeError, KeyError, ValueError) as exc:
            raise CheckpointCorruptionError(
                f"checkpoint {path} has a malformed integrity envelope"
            ) from exc
        actual = checkpoint_crc(state)
        if actual != expected:
            raise CheckpointCorruptionError(
                f"checkpoint {path} CRC mismatch: stored {expected:#010x}, "
                f"computed {actual:#010x}")
    return state


def _quarantine(path: str) -> Optional[str]:
    """Rename a corrupt file aside to ``<path>.corrupt`` (kept as evidence)."""
    dst = path + ".corrupt"
    try:
        os.replace(path, dst)
        _fsync_dir(os.path.dirname(path))
        return dst
    except OSError:
        return None


def load_checkpoint_recovering(path: str) -> Tuple[Dict, Dict]:
    """Load a checkpoint, surviving corruption; returns ``(state, report)``.

    The canonical file is verified first; on corruption it is quarantined to
    ``*.corrupt`` and the newest generation file that verifies is used
    instead (corrupt generations are quarantined too).  Raises
    ``CheckpointCorruptionError`` only when no copy on disk verifies, and
    ``FileNotFoundError`` when nothing exists at all.

    ``report`` keys: ``path`` (file actually loaded), ``quarantined`` (files
    renamed aside), ``fallback_generation`` (generation recovered from, or
    ``None`` when the canonical file was healthy).
    """
    report = {"path": path, "quarantined": [], "fallback_generation": None}
    candidates: List[Tuple[Optional[int], str]] = []
    if os.path.exists(path):
        candidates.append((None, path))
    candidates.extend((g, p) for g, p in reversed(generation_paths(path)))
    if not candidates:
        raise FileNotFoundError(path)
    last_exc: Optional[Exception] = None
    for gen, p in candidates:
        try:
            state = _load_verified(p)
        except CheckpointCorruptionError as exc:
            last_exc = exc
            q = _quarantine(p)
            if q:
                report["quarantined"].append(q)
            continue
        report["path"] = p
        report["fallback_generation"] = gen
        state.pop(INTEGRITY_KEY, None)
        return state, report
    raise CheckpointCorruptionError(
        f"checkpoint {path}: no valid copy on disk "
        f"(quarantined {report['quarantined']})") from last_exc


def load_checkpoint(path: str, fallback: bool = True) -> Dict:
    """Load + verify a campaign checkpoint.

    ``fallback=True`` (default) recovers from corruption via
    ``load_checkpoint_recovering``; ``fallback=False`` raises
    ``CheckpointCorruptionError`` on the first bad byte (tests, forensics).
    """
    if fallback:
        state, _ = load_checkpoint_recovering(path)
    else:
        state = _load_verified(path)
        state.pop(INTEGRITY_KEY, None)
    version = state.get("version")
    if version != 1:
        raise ValueError(f"unsupported campaign checkpoint version {version!r} "
                         f"in {path}")
    return state


def campaign_payload(result, space_dict: Dict, constraint: Dict,
                     evaluator: str, seed: int = 0,
                     extra: Dict = None) -> Dict:
    """``CampaignResult`` -> the BENCH_dse_campaign.json payload.

    ``extra`` keys (e.g. a ``"telemetry"`` metrics snapshot from
    ``Telemetry.snapshot()``) are merged on top of the standard payload —
    additive observability only, never overriding a standard key."""
    frontiers = {}
    for (arch, shape), front in sorted(result.frontiers.items()):
        frontiers[f"{arch}|{shape}"] = {
            "feasible_count": front.feasible_count,
            "points": [{
                **candidate_to_dict(c),
                "energy_j": float(e),
                "latency_s": float(l),
                "index": int(i),
            } for c, e, l, i in zip(front.candidates, front.energy_j,
                                    front.latency_s, front.indices)],
        }
    trajectories = {
        f"{arch}|{shape}": [s.as_dict() for s in snaps]
        for (arch, shape), snaps in sorted(result.trajectories.items())}
    if extra:
        overlap = extra.keys() & {
            "bench", "seed", "python", "sim_model_version", "space",
            "constraint", "evaluator", "workloads", "tiles_done", "n_tiles",
            "complete", "throughput", "frontiers", "trajectory"}
        if overlap:
            raise ValueError(f"campaign_payload: extra keys {sorted(overlap)} "
                             "would override standard payload keys")
    return {
        **(extra or {}),
        "bench": "dse_campaign",
        "seed": seed,
        "python": platform.python_version(),
        # intentional cost-model changes bump this; the CI frontier compare
        # only gates hypervolume between same-version artifacts
        "sim_model_version": SIM_MODEL_VERSION,
        "space": space_dict,
        "constraint": constraint,
        "evaluator": evaluator,
        "workloads": sorted(f"{a}|{s}" for a, s in result.frontiers),
        "tiles_done": result.tiles_done,
        "n_tiles": result.n_tiles,
        "complete": result.complete,
        "throughput": {
            "candidates_evaluated": result.candidates_evaluated,
            "wall_s": result.sweep_wall_s,      # all runs, resume-consistent
            "candidates_per_sec": result.candidates_per_sec,
        },
        "frontiers": frontiers,
        "trajectory": trajectories,
    }


def save_campaign(result, space_dict: Dict, constraint: Dict, evaluator: str,
                  out_dir: str, seed: int = 0,
                  fname: str = CAMPAIGN_BENCH_NAME,
                  extra: Dict = None) -> str:
    """Write the campaign report JSON; returns the path."""
    payload = campaign_payload(result, space_dict, constraint, evaluator,
                               seed=seed, extra=extra)
    path = os.path.join(out_dir, fname)
    atomic_write_json(payload, path)
    return path
