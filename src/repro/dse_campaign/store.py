"""JSON persistence for campaigns: checkpoints + BENCH_*.json artifacts.

Two artifact kinds:
  * checkpoint — the full resumable ``Campaign.state_dict()`` (spec,
    workloads, constraint, per-workload frontier state, next tile), written
    atomically so an interrupt mid-write never corrupts the resume point.
    A distributed run (``repro.dse_campaign.fabric``) writes the SAME
    schema (version 1) plus an optional ``"fabric"`` key holding done-tile
    intervals and outstanding leases; ``next_tile`` is the contiguous done
    prefix, so either resume path — ``FabricCoordinator.from_checkpoint``
    (skips all done tiles) or plain ``Campaign.from_checkpoint`` (replays
    out-of-prefix tiles as exact merge no-ops) — converges to the same
    frontier.
  * campaign report — the ``BENCH_dse_campaign.json`` shape consumed by CI:
    frontier members + per-tile trajectory + throughput, diffable across PRs
    the same way the other ``BENCH_*``/bench ``run.json`` artifacts are.
"""

from __future__ import annotations

import json
import os
import platform
from typing import Dict

from repro.core.costmodel import SIM_MODEL_VERSION
from repro.dse_campaign.frontier import candidate_to_dict

CAMPAIGN_BENCH_NAME = "BENCH_dse_campaign.json"


def atomic_write_json(payload: Dict, path: str) -> str:
    """Write ``payload`` as JSON via tmp-file + ``os.replace``.

    The temp file is flushed and fsync'd before the rename: ``os.replace``
    is atomic in the namespace but says nothing about data durability, so
    without the fsync a crash after the rename could leave a
    truncated-but-named checkpoint — exactly the corruption the fabric's
    resume path assumes cannot happen.
    """
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


# pre-PR-7 private name, kept for any out-of-tree callers
_atomic_write_json = atomic_write_json


def save_checkpoint(state: Dict, path: str) -> str:
    """Persist a ``Campaign.state_dict()`` atomically (tmp + fsync + rename)."""
    return atomic_write_json(state, path)


def load_checkpoint(path: str) -> Dict:
    with open(path) as f:
        state = json.load(f)
    version = state.get("version")
    if version != 1:
        raise ValueError(f"unsupported campaign checkpoint version {version!r} "
                         f"in {path}")
    return state


def campaign_payload(result, space_dict: Dict, constraint: Dict,
                     evaluator: str, seed: int = 0,
                     extra: Dict = None) -> Dict:
    """``CampaignResult`` -> the BENCH_dse_campaign.json payload.

    ``extra`` keys (e.g. a ``"telemetry"`` metrics snapshot from
    ``Telemetry.snapshot()``) are merged on top of the standard payload —
    additive observability only, never overriding a standard key."""
    frontiers = {}
    for (arch, shape), front in sorted(result.frontiers.items()):
        frontiers[f"{arch}|{shape}"] = {
            "feasible_count": front.feasible_count,
            "points": [{
                **candidate_to_dict(c),
                "energy_j": float(e),
                "latency_s": float(l),
                "index": int(i),
            } for c, e, l, i in zip(front.candidates, front.energy_j,
                                    front.latency_s, front.indices)],
        }
    trajectories = {
        f"{arch}|{shape}": [s.as_dict() for s in snaps]
        for (arch, shape), snaps in sorted(result.trajectories.items())}
    if extra:
        overlap = extra.keys() & {
            "bench", "seed", "python", "sim_model_version", "space",
            "constraint", "evaluator", "workloads", "tiles_done", "n_tiles",
            "complete", "throughput", "frontiers", "trajectory"}
        if overlap:
            raise ValueError(f"campaign_payload: extra keys {sorted(overlap)} "
                             "would override standard payload keys")
    return {
        **(extra or {}),
        "bench": "dse_campaign",
        "seed": seed,
        "python": platform.python_version(),
        # intentional cost-model changes bump this; the CI frontier compare
        # only gates hypervolume between same-version artifacts
        "sim_model_version": SIM_MODEL_VERSION,
        "space": space_dict,
        "constraint": constraint,
        "evaluator": evaluator,
        "workloads": sorted(f"{a}|{s}" for a, s in result.frontiers),
        "tiles_done": result.tiles_done,
        "n_tiles": result.n_tiles,
        "complete": result.complete,
        "throughput": {
            "candidates_evaluated": result.candidates_evaluated,
            "wall_s": result.sweep_wall_s,      # all runs, resume-consistent
            "candidates_per_sec": result.candidates_per_sec,
        },
        "frontiers": frontiers,
        "trajectory": trajectories,
    }


def save_campaign(result, space_dict: Dict, constraint: Dict, evaluator: str,
                  out_dir: str, seed: int = 0,
                  fname: str = CAMPAIGN_BENCH_NAME,
                  extra: Dict = None) -> str:
    """Write the campaign report JSON; returns the path."""
    payload = campaign_payload(result, space_dict, constraint, evaluator,
                               seed=seed, extra=extra)
    return atomic_write_json(payload, os.path.join(out_dir, fname))
