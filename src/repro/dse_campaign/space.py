"""Generator-backed campaign design spaces: mega-spaces that never materialize.

``dse.default_space`` builds every ``Candidate`` into a Python list, which
caps practical spaces at a few thousand points.  A ``SpaceSpec`` is the
declarative alternative: it describes the cross product

    chip set x chip-count range x mesh factorizations
             x dense DVFS frequency lattice x heterogeneous-slice variants

and addresses it purely by index arithmetic.  The flat candidate index
decomposes as ``(row, freq_point)`` where a *row* is one
(chip, variant, mesh) combination — there are only tens-to-hundreds of rows
even for million-point spaces, so the spec's resident footprint is the row
table, never the candidates.  ``slice(lo, hi)`` materializes any sub-range
as a ``CandidateBatch`` with vectorized array construction, and ``tiles()``
streams the whole space in fixed ``chunk_size`` chunks — peak candidate-array
memory is bounded by ``chunk_size`` no matter how large the space is, and any
tile index is addressable for campaign resume.

Heterogeneous-slice variants model mixed-bin / mixed-generation slices at the
cost-model level: the slice clock is governed by its slowest member, so a
variant applies a worst-bin frequency derate (``freq_scale``) to the top of
the DVFS band.  The uniform variant (scale 1.0) reproduces
``hw.frequency_sweep`` bitwise.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterator, Tuple

import numpy as np

from repro.core.dse import Candidate, CandidateBatch
from repro.hw import (CHIP_TABLE, CHIPS, ChipTable, mesh_factorizations,
                      normalize_mesh)


@dataclasses.dataclass(frozen=True)
class SliceVariant:
    """One slice-composition variant: ``freq_scale`` derates the top of the
    DVFS band (worst-bin clock governs the slice)."""

    name: str = "uniform"
    freq_scale: float = 1.0


DEFAULT_VARIANTS = (SliceVariant("uniform", 1.0),
                    SliceVariant("worst-bin-85", 0.85))


@dataclasses.dataclass(frozen=True)
class _Row:
    """One (chip, variant, mesh) combination; spans ``freq_points`` indices."""

    chip: str
    variant: SliceVariant
    mesh: Tuple[int, ...]
    n_chips: int


@dataclasses.dataclass(frozen=True)
class SpaceSpec:
    """Declarative, never-materialized campaign design space.

    ``chip_counts`` are slice sizes; every ``mesh_factorizations`` arrangement
    of each count enters the space (edge parts with ``ici_bw == 0`` collapse
    to a single-chip 1x1 mesh).  With ``mesh_dims=3`` the leading pod factor
    is carried as the candidates' ``mesh_pod`` axis and priced by the
    topology-aware collective model (it is no longer silently dropped).
    ``freq_points`` is the per-row DVFS lattice density.  Total size is
    ``rows * freq_points``; only the row table is resident.
    """

    chips: Tuple[str, ...] = tuple(CHIPS)
    chip_counts: Tuple[int, ...] = (16, 64, 256)
    freq_points: int = 12
    mesh_dims: int = 2
    variants: Tuple[SliceVariant, ...] = (SliceVariant(),)
    chunk_size: int = 4096

    def __post_init__(self):
        if self.freq_points < 1:
            raise ValueError("freq_points must be >= 1")
        if self.chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        unknown = [c for c in self.chips if c not in CHIPS]
        if unknown:
            raise ValueError(f"unknown chips {unknown}; known: {list(CHIPS)}")

    # -- row table (the only resident state; O(chips x variants x meshes)) --

    @functools.cached_property
    def _rows(self) -> Tuple[_Row, ...]:
        rows = []
        for chip in self.chips:
            if CHIPS[chip].ici_bw == 0:
                meshes = ((1, 1),)
            else:
                meshes = tuple(m for n in self.chip_counts
                               for m in mesh_factorizations(n, self.mesh_dims))
            for variant in self.variants:
                for mesh in meshes:
                    rows.append(_Row(chip, variant, mesh,
                                     int(np.prod(mesh))))
        return tuple(rows)

    @functools.cached_property
    def _row_arrays(self) -> Dict[str, np.ndarray]:
        """Per-row columns for vectorized slicing (row count is tiny)."""
        rows = self._rows
        table = CHIP_TABLE
        chip_idx = table.indices([r.chip for r in rows])
        f_min = table.min_freq_mhz[chip_idx]
        f_max = table.max_freq_mhz[chip_idx]
        scale = np.asarray([r.variant.freq_scale for r in rows], np.float64)
        # worst-bin derate shrinks the top of the band, clamped into it
        f_hi = np.clip(f_max * scale, f_min, f_max)
        axes = [normalize_mesh(r.mesh) for r in rows]    # (pod, data, model)
        return {
            "chip_idx": chip_idx,
            "n_chips": np.asarray([r.n_chips for r in rows], np.int64),
            "mesh_pod": np.asarray([a[0] for a in axes], np.int64),
            "mesh_data": np.asarray([a[1] for a in axes], np.int64),
            "mesh_model": np.asarray([a[2] for a in axes], np.int64),
            "f_lo": f_min,
            "f_hi": f_hi,
        }

    def __len__(self) -> int:
        return len(self._rows) * self.freq_points

    @property
    def n_rows(self) -> int:
        """Resident row count — the actual memory footprint of the spec
        (``len(self)`` candidates are addressed, never materialized)."""
        return len(self._rows)

    def n_tiles(self, chunk_size: int = None) -> int:
        """Number of ``chunk_size`` tiles covering the space (last may be
        partial).  This is the fabric's unit of work."""
        c = chunk_size or self.chunk_size
        return -(-len(self) // c)

    # -- index arithmetic ---------------------------------------------------

    def _freqs(self, row: np.ndarray, k: np.ndarray) -> np.ndarray:
        """Frequency of lattice point ``k`` on each ``row``; the arithmetic is
        the same IEEE expression as ``hw.frequency_lattice`` (endpoints pinned
        exactly), so the uniform variant matches ``frequency_sweep`` bitwise.
        """
        cols = self._row_arrays
        lo, hi = cols["f_lo"][row], cols["f_hi"][row]
        if self.freq_points == 1:
            return hi.copy()
        f = lo + k * (hi - lo) / (self.freq_points - 1)
        return np.where(k == 0, lo, np.where(k == self.freq_points - 1, hi, f))

    def candidate(self, i: int) -> Candidate:
        """Materialize the single candidate at flat index ``i``."""
        return self.candidates_at([i])[0]

    def candidates_at(self, indices) -> list:
        """Materialize the candidates at arbitrary flat ``indices``, batched.

        The lazy-survivor path of the fused campaign evaluators: a whole
        tile streams through the device candidate-less, and only its
        frontier survivors (typically tens per tile) become ``Candidate``
        objects — in one vectorized pass instead of a per-index ``divmod``
        + frequency recomputation."""
        idx = np.asarray(indices, np.int64)
        n = len(self)
        if idx.size and (idx.min() < 0 or idx.max() >= n):
            raise IndexError(f"indices outside [0, {n}): "
                             f"[{idx.min()}, {idx.max()}]")
        row, k = np.divmod(idx, self.freq_points)
        freq = self._freqs(row, k)
        rows = self._rows
        return [Candidate(rows[r].chip, rows[r].n_chips, rows[r].mesh,
                          float(f)) for r, f in zip(row, freq)]

    def slice(self, lo: int, hi: int,
              with_candidates: bool = True) -> CandidateBatch:
        """Candidates [lo, hi) as a ``CandidateBatch``, built array-natively.

        Any sub-range of the space is addressable without touching the rest —
        this is what makes campaigns resumable from an arbitrary tile index.
        ``with_candidates=False`` skips the per-candidate ``Candidate``
        construction (the only O(tile) Python cost of a slice) and returns an
        array-only batch — the zero-copy campaign paths materialize scalar
        candidates lazily via ``candidate(i)`` for frontier survivors only.
        """
        n = len(self)
        lo, hi = max(lo, 0), min(hi, n)
        if hi <= lo:
            raise ValueError(f"empty slice [{lo}, {hi}) of space of {n}")
        idx = np.arange(lo, hi)
        row, k = np.divmod(idx, self.freq_points)
        cols = self._row_arrays
        chip_idx = cols["chip_idx"][row]
        freq = self._freqs(row, k)
        rows = self._rows
        candidates = None
        if with_candidates:
            candidates = tuple(
                Candidate(rows[r].chip, rows[r].n_chips, rows[r].mesh,
                          float(f))
                for r, f in zip(row, freq))
        return CandidateBatch(
            candidates=candidates,
            chip_idx=chip_idx,
            n_chips=cols["n_chips"][row],
            mesh_data=cols["mesh_data"][row],
            mesh_model=cols["mesh_model"][row],
            freq_mhz=freq,
            mesh_pod=cols["mesh_pod"][row],
            chip_cols=CHIP_TABLE.gather(chip_idx))

    def tiles(self, start_tile: int = 0, chunk_size: int = None,
              with_candidates: bool = True
              ) -> Iterator[Tuple[int, int, CandidateBatch]]:
        """Stream the space as (tile_index, flat_lo, batch) chunks.

        Each batch holds at most ``chunk_size`` candidates; ``start_tile``
        skips already-evaluated prefixes on resume without materializing them.
        ``with_candidates=False`` streams array-only batches (see ``slice``).
        """
        c = chunk_size or self.chunk_size
        n = len(self)
        for t in range(start_tile, self.n_tiles(c)):
            lo = t * c
            yield t, lo, self.slice(lo, min(lo + c, n),
                                    with_candidates=with_candidates)

    # -- persistence --------------------------------------------------------

    def to_dict(self) -> Dict:
        """Declarative JSON form of the spec (the *recipe*, never the rows);
        carries ``size`` so ``from_dict`` can detect index-space drift."""
        return {
            "chips": list(self.chips),
            "chip_counts": list(self.chip_counts),
            "freq_points": self.freq_points,
            "mesh_dims": self.mesh_dims,
            "variants": [[v.name, v.freq_scale] for v in self.variants],
            "chunk_size": self.chunk_size,
            "size": len(self),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "SpaceSpec":
        """Rebuild a spec from ``to_dict`` output, refusing if the rebuilt
        index space has a different size (global indices would be invalid)."""
        spec = cls(chips=tuple(d["chips"]),
                   chip_counts=tuple(d["chip_counts"]),
                   freq_points=d["freq_points"],
                   mesh_dims=d["mesh_dims"],
                   variants=tuple(SliceVariant(n, s) for n, s in d["variants"]),
                   chunk_size=d["chunk_size"])
        if "size" in d and len(spec) != d["size"]:
            raise ValueError(
                f"space spec resolves to {len(spec)} candidates but the "
                f"checkpoint recorded {d['size']} — chip registry changed?")
        return spec


def default_campaign_space(chunk_size: int = 4096) -> SpaceSpec:
    """The default mega-space: every 2D/3D mesh factorization of power-of-two
    slice sizes 4..1024 x a dense 320-point DVFS lattice x two slice variants
    — >100k candidates, several hundred times ``dse.default_space``'s 192."""
    return SpaceSpec(
        chips=tuple(CHIPS),
        chip_counts=(4, 8, 16, 32, 64, 128, 256, 512, 1024),
        freq_points=320,
        mesh_dims=3,
        variants=DEFAULT_VARIANTS,
        chunk_size=chunk_size)


def tiny_campaign_space(chunk_size: int = 256) -> SpaceSpec:
    """A small seeded sub-space for tests / CI smoke (hundreds of points)."""
    return SpaceSpec(
        chips=("tpu-v5e", "tpu-v4", "tpu-edge"),
        chip_counts=(16, 64, 256),
        freq_points=16,
        mesh_dims=2,
        variants=DEFAULT_VARIANTS,
        chunk_size=chunk_size)
