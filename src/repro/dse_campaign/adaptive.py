"""Surrogate-guided adaptive campaigns: learned search, not just faster sweep.

``Campaign`` evaluates every candidate of a ``SpaceSpec`` exactly, so cost
grows linearly with space size.  ``AdaptiveCampaign`` spends an evaluation
budget (default 10% of the space) where the frontier actually moves:

  1. **seed** — evaluate an evenly-spaced slice of tiles exactly (the same
     ``TileEvaluator`` fused-jit/pallas path the exact sweep uses) and fit
     per-workload energy/latency random forests (``core/predictors.py``)
     on a seeded subsample of the evaluated rows;
  2. **acquire** — score every *unevaluated* tile with batched forest
     inference (``dse.predict_tile_scores`` features) and rank tiles by
     expected hypervolume gain: each candidate's LCB-optimistic prediction
     ``exp(mu - explore_weight * sigma)`` is scored with
     ``frontier.hypervolume_gain_2d`` against the current frontier
     staircase and the campaign's pinned acquisition reference point,
     after an analytic feasibility screen (predicted slice power is
     exactly ``energy/latency``, HBM fit is exact arithmetic on the
     feature columns).  Forest spread doubles as the exploration term —
     inside the LCB and as the ranking tie-break (sole signal while no
     predicted point lands inside the reference box);
  3. **evaluate + retrain** — evaluate only the top-ranked tiles exactly,
     fold them into the ``StreamingFrontier`` exactly like the sweep
     would, warm-start-refit the forests (``partial_fit``), and repeat
     until the frontier hypervolume plateaus or the budget is spent.

Only exactly-evaluated points ever merge, so the adaptive frontier is by
construction a subset of the exactly-evaluated candidates — a predicted
value can steer the search but never land on the frontier.  With
``budget_fraction >= 1`` the loop degenerates to the exact sweep (same
``reduce_tile`` + ``merge_reduced`` fold over every tile in index order),
bitwise.

Determinism is the load-bearing property, arranged so the same config
yields the same frontier on every execution shape:

* training rows are a pure function of config x tile span (seeded
  subsample attached to each ``TileReduction``), and each round's rows are
  concatenated in sorted-tile order before the single ``partial_fit`` call
  per model — delivery order cannot perturb the bootstrap draws;
* the acquisition reference point is pinned per workload as the maximum
  feasible (energy, latency) over a whole round's reductions — a
  round-barrier maximum, independent of merge order — and is explicitly
  serialized in checkpoints so a resumed campaign computes the same
  acquisition scores as an uninterrupted one;
* forests are rebuilt slot-seeded (``default_rng((seed, call, slot))``),
  so replaying the recorded rounds against re-evaluated tiles reproduces
  the surrogate state bitwise — which is exactly how ``from_checkpoint``
  restores it (re-evaluating at most the spent budget instead of
  persisting megabytes of training rows).

The distributed path (``run_adaptive_distributed``) keeps one coordinator
(selection, fitting, folding) and farms tile evaluation to a persistent
pool of fabric workers; each round's tiles are leased in acquisition order
through a ``LeaseBoard`` priority ranking.  Worker loss re-pends the tile;
duplicate deliveries are no-ops — the result is bitwise-identical to the
single-process adaptive run.
"""

from __future__ import annotations

import dataclasses
import multiprocessing as mp
import queue as queue_mod
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dse
from repro.core.predictors import RandomForestRegressor
from repro.dse_campaign import store
from repro.dse_campaign.config import AdaptiveConfig, CampaignConfig
from repro.dse_campaign.fabric import (FaultInjection, LeaseBoard,
                                       _worker_main, campaign_config,
                                       tile_span)
from repro.dse_campaign.frontier import hypervolume_2d, hypervolume_gain_2d
from repro.dse_campaign.runner import (Campaign, CampaignResult,
                                       TileReduction, TileStat, WorkloadKey)
from repro.telemetry import coerce_telemetry

# feature-column positions the analytic feasibility screen reads
_F_N_CHIPS = dse.SURROGATE_FEATURES.index("n_chips")
_F_HBM_BYTES = dse.SURROGATE_FEATURES.index("hbm_bytes")

# one (tile, reduction, busy_s) delivery from whichever backend ran the tile
RoundDelivery = Tuple[int, TileReduction, float]


@dataclasses.dataclass
class AdaptiveResult:
    """Outcome of an adaptive campaign.

    ``result`` is the standard ``CampaignResult`` view (frontiers,
    trajectories, tile stats) over the tiles that were actually evaluated;
    the adaptive fields say how the budget was spent: ``rounds`` (tile
    indices per round, acquisition order), ``hv_history`` (total frontier
    hypervolume against the pinned acquisition refs after each round),
    ``stopped_on`` (``"plateau"`` / ``"budget"`` / ``"exhausted"``, or
    ``"max_rounds"`` when interrupted), and ``fraction_evaluated`` — the
    headline gate quantity: unique candidates evaluated over space size.
    """

    result: CampaignResult
    rounds: List[List[int]]
    hv_history: List[float]
    stopped_on: str
    tiles_evaluated: int
    n_tiles: int
    candidates_evaluated: int       # unique candidates (tile spans, no dups)
    space_size: int

    @property
    def frontiers(self):
        return self.result.frontiers

    @property
    def fraction_evaluated(self) -> float:
        """Unique candidates evaluated / space size (the <=10% gate)."""
        return self.candidates_evaluated / max(self.space_size, 1)


def _predict_padded(model: RandomForestRegressor, X: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """``predict_log_stats`` with the row count padded to the next power of
    two, so the jitted forest walk retraces O(log space) times per campaign
    instead of once per round (the pending count shrinks every round)."""
    n = X.shape[0]
    target = 1 << max(0, (n - 1).bit_length())
    if target > n:
        X = np.concatenate([X, np.repeat(X[:1], target - n, axis=0)])
    mu, sd = model.predict_log_stats(X)
    return mu[:n], sd[:n]


class AdaptiveCampaign:
    """Active-learning campaign over one ``CampaignConfig`` (which must
    carry an ``AdaptiveConfig`` in ``config.adaptive``).

    Owns an internal ``Campaign`` for everything the exact sweep already
    does right — frontiers, reduction folding, checkpoint schema — and
    adds the surrogate state (per-workload energy/latency forests), the
    acquisition loop and the adaptive checkpoint extension (an
    ``"adaptive"`` key the plain campaign schema ignores).

    The public surface mirrors ``Campaign``: construct, ``run()``
    (optionally ``max_rounds`` as an interruption point), or
    ``from_checkpoint`` to resume — a resumed run selects, evaluates and
    stops exactly like the uninterrupted one.
    """

    def __init__(self, workloads: Sequence[dse.Workload],
                 config: CampaignConfig, telemetry=None,
                 _campaign: Optional[Campaign] = None):
        if config.adaptive is None:
            raise ValueError(
                "AdaptiveCampaign needs config.adaptive (an AdaptiveConfig); "
                "for an exact sweep use Campaign")
        self.telemetry = coerce_telemetry(telemetry)
        self._campaign = _campaign if _campaign is not None else Campaign(
            workloads, config, telemetry=self.telemetry)
        if _campaign is not None:
            self.telemetry = self._campaign.telemetry
        self.engine = self._campaign.engine
        self.acfg: AdaptiveConfig = config.adaptive
        self.space = self.engine.space
        # surrogate state: two forests per workload, created unfitted
        self.models: Dict[WorkloadKey, Dict[str, RandomForestRegressor]] = {
            key: {"energy": self._make_forest(), "latency": self._make_forest()}
            for key in self.engine.workload_keys}
        self.rounds: List[List[int]] = []
        self.acq_refs: Dict[WorkloadKey, Optional[Tuple[float, float]]] = {
            key: None for key in self.engine.workload_keys}
        self.hv_history: List[float] = []
        self.plateau = 0
        self.stopped_on: Optional[str] = None
        self._done: set = set()
        # backend hook: the distributed runner swaps in the worker pool
        self._evaluate_round: Callable[[List[int]], List[RoundDelivery]] = \
            self._evaluate_round_local
        tel = self.telemetry
        self._c_rounds = tel.counter("adaptive_rounds_total")
        self._c_evaluated = tel.counter("adaptive_tiles_evaluated_total")
        self._c_skipped = tel.counter("adaptive_tiles_skipped_total")
        self._c_refits = tel.counter("adaptive_refits_total")

    # -- views --------------------------------------------------------------

    @property
    def config(self) -> CampaignConfig:
        return self._campaign.config

    @property
    def workloads(self) -> List[dse.Workload]:
        return self._campaign.workloads

    @property
    def frontiers(self):
        return self._campaign.frontiers

    def _make_forest(self) -> RandomForestRegressor:
        a = self.acfg
        return RandomForestRegressor(
            n_trees=a.n_trees, max_depth=a.max_depth, min_leaf=a.min_leaf,
            refresh_trees=a.refresh_trees, log_target=True)

    def _model_seed(self, wi: int, target: str) -> int:
        """Stable per-(workload, target) bootstrap seed — distinct models
        must not share tree draws."""
        return self.acfg.seed * 1_000_003 + wi * 2 + (target == "latency")

    # -- tile evaluation backends ------------------------------------------

    def _evaluate_round_local(self, tiles: List[int]) -> List[RoundDelivery]:
        """Single-process backend: evaluate ``tiles`` in the given
        (acquisition) order on the campaign's own ``TileEvaluator``."""
        clock = self.telemetry.clock
        out: List[RoundDelivery] = []
        for t in tiles:
            lo, hi = tile_span(self.space, t)
            t0 = clock()
            with self.telemetry.span("tile_eval", tile=t):
                batch = self.space.slice(
                    lo, hi, with_candidates=not self.engine.fused)
                tr = self.engine.reduce_tile(batch, lo)
            out.append((t, tr, clock() - t0))
        return out

    # -- folding + training -------------------------------------------------

    def _fold_round(self, tiles: List[int],
                    deliveries: List[RoundDelivery],
                    fit: bool = True) -> None:
        """Merge a completed round into the campaign state: frontiers, tile
        stats, the done set, acquisition refs and the surrogates.  Runs at
        the round barrier, after which every derived quantity (frontier
        set, refs, forests) is independent of delivery order."""
        w = len(self.workloads)
        reductions: Dict[int, TileReduction] = {}
        for tile, tr, busy in deliveries:
            first = tile not in reductions
            reductions[tile] = tr
            self._campaign.merge_reduction(tr, tile)       # dup = exact no-op
            if first:
                self._campaign.tile_stats.append(TileStat(
                    tile=tile, candidates=(tr.hi - tr.lo) * w, wall_s=busy))
        self._done.update(reductions)
        self._c_evaluated.inc(len(reductions))
        self._campaign.next_tile = self._contiguous_prefix()
        self.rounds.append([int(t) for t in tiles])
        self._pin_refs(reductions)
        if fit:
            with self.telemetry.span("refit", rows=sum(
                    r.sample_lidx.size for r in reductions.values())):
                self._fit_round(reductions)
        self._track_hypervolume()
        self._c_rounds.inc()

    def _contiguous_prefix(self) -> int:
        p = 0
        while p in self._done:
            p += 1
        return p

    def _pin_refs(self, reductions: Dict[int, TileReduction]) -> None:
        """Pin each workload's acquisition reference point at the first
        round that saw feasible points: the maximum feasible
        (energy, latency) across the WHOLE round — a barrier maximum, so
        the refs cannot depend on merge/delivery order."""
        for wi, key in enumerate(self.engine.workload_keys):
            if self.acq_refs[key] is not None:
                continue
            es = [tr.ref_energy_j[wi] for tr in reductions.values()
                  if tr.ref_energy_j[wi] is not None]
            ls = [tr.ref_latency_s[wi] for tr in reductions.values()
                  if tr.ref_latency_s[wi] is not None]
            if es:
                self.acq_refs[key] = (float(max(es)), float(max(ls)))

    def _fit_round(self, reductions: Dict[int, TileReduction]) -> None:
        """ONE ``partial_fit`` per model on the round's training rows,
        concatenated in sorted-tile order — the canonical order that makes
        the forests a pure function of WHICH tiles ran, never of how their
        results arrived."""
        tiles = sorted(reductions)
        x_parts: List[np.ndarray] = []
        for t in tiles:
            tr = reductions[t]
            lo, hi = tile_span(self.space, t)
            feats = dse.surrogate_features(
                self.space.slice(lo, hi, with_candidates=False))
            x_parts.append(feats[tr.sample_lidx])
        X = np.concatenate(x_parts)
        for wi, key in enumerate(self.engine.workload_keys):
            y_e = np.concatenate(
                [reductions[t].sample_energy[wi] for t in tiles])
            y_l = np.concatenate(
                [reductions[t].sample_latency[wi] for t in tiles])
            self.models[key]["energy"].partial_fit(
                X, y_e, seed=self._model_seed(wi, "energy"))
            self.models[key]["latency"].partial_fit(
                X, y_l, seed=self._model_seed(wi, "latency"))
            self._c_refits.inc(2)

    def _track_hypervolume(self) -> None:
        """Total frontier hypervolume against the pinned acquisition refs
        (0 until a ref pins); drives the plateau stop."""
        hv = 0.0
        for key, refs in self.acq_refs.items():
            if refs is None:
                continue
            fr = self.frontiers[key]
            hv += hypervolume_2d(fr.energy_j, fr.latency_s, *refs)
        if self.hv_history:
            prev = self.hv_history[-1]
            rel = ((hv - prev) / abs(prev)) if prev > 0 else (
                1.0 if hv > 0 else 0.0)
            self.plateau = self.plateau + 1 if rel < self.acfg.plateau_tol \
                else 0
        self.hv_history.append(hv)

    # -- acquisition --------------------------------------------------------

    def _rank_pending(self, pending: List[int]) -> List[int]:
        """Pending tiles ranked best-first by expected hypervolume gain
        (max over the tile's candidates, summed across workload frontiers),
        tie-broken by mean forest spread (exploration) then tile index."""
        sizes = []
        x_parts = []
        for t in pending:
            lo, hi = tile_span(self.space, t)
            feats = dse.surrogate_features(
                self.space.slice(lo, hi, with_candidates=False))
            x_parts.append(feats)
            sizes.append(hi - lo)
        X = np.concatenate(x_parts)
        n = X.shape[0]
        beta = self.acfg.explore_weight
        cons = self.engine.constraint
        gain = np.zeros(n, np.float64)
        spread = np.zeros(n, np.float64)
        for wi, key in enumerate(self.engine.workload_keys):
            wl = self.workloads[wi]
            e_mu, e_sd = _predict_padded(self.models[key]["energy"], X)
            l_mu, l_sd = _predict_padded(self.models[key]["latency"], X)
            spread += e_sd + l_sd
            refs = self.acq_refs[key]
            if refs is None:
                continue
            # analytic feasibility screen on LCB-lenient predictions:
            # slice power is exactly energy/latency, HBM fit is exact
            # arithmetic on the feature columns
            feas = np.ones(n, bool)
            if cons.max_power_w is not None:
                feas &= ((e_mu - beta * e_sd) - (l_mu + beta * l_sd)
                         <= np.log(cons.max_power_w))
            if cons.max_latency_s is not None:
                feas &= l_mu - beta * l_sd <= np.log(cons.max_latency_s)
            if cons.min_hbm_fit:
                state_pd = (wl.state_gb_per_device * wl.base_chips
                            / X[:, _F_N_CHIPS].astype(np.float64))
                feas &= (state_pd * 1e9
                         <= X[:, _F_HBM_BYTES].astype(np.float64) * 0.9)
            fr = self.frontiers[key]
            g = hypervolume_gain_2d(
                np.exp(e_mu - beta * e_sd), np.exp(l_mu - beta * l_sd),
                fr.energy_j, fr.latency_s, refs[0], refs[1])
            g[~feas] = 0.0
            gain += g
        offsets = np.cumsum([0] + sizes)[:-1]
        tile_gain = np.maximum.reduceat(gain, offsets)
        tile_spread = np.add.reduceat(spread, offsets) / np.asarray(
            sizes, np.float64)
        # best-first: gain desc, spread desc, then tile index asc —
        # a total, deterministic order
        order = np.lexsort((np.asarray(pending), -tile_spread, -tile_gain))
        return [pending[i] for i in order]

    def _select_round(self, ranked: List[int], budget_cands: int,
                      spent: int, k_round: int) -> List[int]:
        """Top-ranked tiles that fit the remaining candidate budget, at most
        ``k_round`` of them."""
        sel: List[int] = []
        for t in ranked:
            if len(sel) >= k_round:
                break
            lo, hi = tile_span(self.space, t)
            if spent + (hi - lo) > budget_cands:
                continue
            sel.append(t)
            spent += hi - lo
        return sel

    # -- the loop -----------------------------------------------------------

    def _spent_candidates(self) -> int:
        return sum(tile_span(self.space, t)[1] - tile_span(self.space, t)[0]
                   for t in self._done)

    def _seed_tiles(self, n_tiles: int, budget_cands: int) -> List[int]:
        """Evenly spaced seed tiles (every region of the space represented),
        truncated to the budget."""
        k = max(2, int(round(self.acfg.seed_fraction * n_tiles)))
        k = min(k, n_tiles)
        tiles = np.unique(np.linspace(0, n_tiles - 1, k).round()
                          .astype(int)).tolist()
        sel, spent = [], 0
        for t in tiles:
            lo, hi = tile_span(self.space, t)
            if spent + (hi - lo) > budget_cands:
                break
            sel.append(int(t))
            spent += hi - lo
        return sel

    def run(self, checkpoint_path: Optional[str] = None,
            max_rounds: Optional[int] = None) -> AdaptiveResult:
        """Run (or continue) the adaptive loop; ``max_rounds`` bounds THIS
        call — the interruption point resume tests exercise.  With a
        ``checkpoint_path`` (default ``config.checkpoint_path``) the full
        state persists after every round."""
        if checkpoint_path is None:
            checkpoint_path = self.config.checkpoint_path
        tel = self.telemetry
        clock = tel.clock
        t_start = clock()
        n_tiles = self.space.n_tiles()
        space_size = len(self.space)
        acfg = self.acfg

        if acfg.budget_fraction >= 1.0:
            return self._run_exact(checkpoint_path, t_start)

        budget_cands = int(np.floor(acfg.budget_fraction * space_size))
        k_round = max(1, int(round(acfg.round_fraction * n_tiles)))
        rounds_this_call = 0
        was_stopped = self.stopped_on is not None

        def out_of_rounds() -> bool:
            return max_rounds is not None and rounds_this_call >= max_rounds

        # seed round (skipped on a resumed campaign that already has one)
        if not self.rounds and not out_of_rounds():
            seed = self._seed_tiles(n_tiles, budget_cands)
            if not seed:
                raise ValueError(
                    f"budget_fraction={acfg.budget_fraction} cannot afford "
                    f"a single seed tile of chunk {self.space.chunk_size}")
            with tel.span("round", kind="seed", tiles=len(seed)):
                self._fold_round(seed, self._evaluate_round(seed))
            rounds_this_call += 1
            if checkpoint_path:
                self.checkpoint(checkpoint_path)

        while self.stopped_on is None and not out_of_rounds():
            pending = [t for t in range(n_tiles) if t not in self._done]
            if not pending:
                self.stopped_on = "exhausted"
                break
            if self.plateau >= acfg.plateau_rounds:
                self.stopped_on = "plateau"
                break
            spent = self._spent_candidates()
            with tel.span("round", kind="acquire", pending=len(pending)):
                with tel.span("acquisition", pending=len(pending)):
                    ranked = self._rank_pending(pending)
                    sel = self._select_round(ranked, budget_cands, spent,
                                             k_round)
                if not sel:
                    self.stopped_on = "budget"
                    break
                self._fold_round(sel, self._evaluate_round(sel))
            rounds_this_call += 1
            if checkpoint_path:
                self.checkpoint(checkpoint_path)
        if self.stopped_on is None and out_of_rounds():
            stopped = "max_rounds"       # interrupted, not finished
        else:
            stopped = self.stopped_on or "exhausted"
            if self.stopped_on is not None and not was_stopped:
                # counted once, when THIS call reaches the stop
                self._c_skipped.inc(n_tiles - len(self._done))
        if checkpoint_path:
            self.checkpoint(checkpoint_path)
        return self._result(stopped, clock() - t_start)

    def _run_exact(self, checkpoint_path: Optional[str],
                   t_start: float) -> AdaptiveResult:
        """budget >= 100%: the degenerate exact sweep — every tile in index
        order through the same reduce/merge fold, bitwise-identical to
        ``Campaign.run`` on the same config."""
        tiles = [t for t in range(self.space.n_tiles())
                 if t not in self._done]
        with self.telemetry.span("round", kind="exact", tiles=len(tiles)):
            # full coverage: the surrogates have nothing left to steer, so
            # skip the (pointless) whole-space forest fit
            self._fold_round(tiles, self._evaluate_round(tiles), fit=False)
        self.stopped_on = "budget"
        if checkpoint_path:
            self.checkpoint(checkpoint_path)
        return self._result("budget", self.telemetry.clock() - t_start)

    def _result(self, stopped: str, wall_s: float) -> AdaptiveResult:
        return AdaptiveResult(
            result=self._campaign._result(wall_s, tiles_done=len(self._done)),
            rounds=[list(r) for r in self.rounds],
            hv_history=list(self.hv_history),
            stopped_on=stopped,
            tiles_evaluated=len(self._done),
            n_tiles=self.space.n_tiles(),
            candidates_evaluated=self._spent_candidates(),
            space_size=len(self.space))

    # -- persistence --------------------------------------------------------

    def state_dict(self) -> Dict:
        """Campaign schema version 1 plus an ``"adaptive"`` key: the
        adaptive config, per-round tile lists, the EXPLICIT acquisition
        reference points, the hypervolume history and the plateau/stop
        state — everything a resume needs to compute the same acquisition
        scores as an uninterrupted run (the forests are reconstructed by
        replaying the recorded rounds, not persisted)."""
        state = self._campaign.state_dict()
        state["adaptive"] = {
            "config": self.acfg.to_dict(),
            "rounds": [list(map(int, r)) for r in self.rounds],
            "acq_refs": {f"{a}|{s}": list(v) if v is not None else None
                         for (a, s), v in self.acq_refs.items()},
            "hv_history": [float(h) for h in self.hv_history],
            "plateau": int(self.plateau),
            "stopped_on": self.stopped_on,
        }
        return state

    def checkpoint(self, path: str) -> str:
        with self.telemetry.span("checkpoint_write", rounds=len(self.rounds)):
            return store.save_checkpoint(self.state_dict(), path)

    @classmethod
    def from_checkpoint(cls, path: str, telemetry=None,
                        **kwargs) -> "AdaptiveCampaign":
        """Resume an adaptive campaign: frontiers and accounting load
        through ``Campaign.from_checkpoint`` (same schema/version gates),
        the acquisition refs and round ledger come from the ``"adaptive"``
        key, and the forests are rebuilt bitwise by replaying each recorded
        round — re-evaluating its tiles for training rows only (a pure
        function of config x span; costs at most the spent budget, which
        the adaptive loop bounds at ~10% of a sweep)."""
        state = store.load_checkpoint(path)
        ad = state.get("adaptive")
        if not ad:
            raise ValueError(
                f"checkpoint {path} has no 'adaptive' state — resume it "
                "with Campaign.from_checkpoint instead")
        acfg = AdaptiveConfig.from_dict(ad["config"])
        camp = Campaign.from_checkpoint(path, adaptive=acfg,
                                        telemetry=telemetry, **kwargs)
        obj = cls(camp.workloads, camp.config, telemetry=camp.telemetry,
                  _campaign=camp)
        obj.rounds = [list(map(int, r)) for r in ad["rounds"]]
        for key_str, v in ad["acq_refs"].items():
            arch, shape = key_str.split("|", 1)
            obj.acq_refs[(arch, shape)] = tuple(v) if v is not None else None
        obj.hv_history = [float(h) for h in ad["hv_history"]]
        obj.plateau = int(ad["plateau"])
        obj.stopped_on = ad["stopped_on"]
        obj._done = {t for r in obj.rounds for t in r}
        with obj.telemetry.span("adaptive_replay", rounds=len(obj.rounds)):
            for rtiles in obj.rounds:
                reductions = {}
                for t in sorted(set(rtiles)):
                    lo, hi = tile_span(obj.space, t)
                    batch = obj.space.slice(
                        lo, hi, with_candidates=not obj.engine.fused)
                    reductions[t] = obj.engine.reduce_tile(batch, lo)
                obj._fit_round(reductions)
        return obj


# ---------------------------------------------------------------------------
# distributed adaptive: one coordinator, a persistent fabric worker pool
# ---------------------------------------------------------------------------

class _WorkerPool:
    """Persistent pool of fabric worker processes for the adaptive loop.

    Reuses ``fabric._worker_main`` (same protocol, same warm-up, same
    crash semantics) but keeps the processes alive ACROSS rounds — the
    fused evaluators compile once per worker, not once per round.  Each
    ``evaluate_round`` drives a per-round ``LeaseBoard`` restricted to the
    selected tiles, leased in acquisition order via ``set_priority``;
    worker death re-pends its tile to a survivor.
    """

    def __init__(self, engine, n_workers: int,
                 fault: Optional[FaultInjection] = None):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        fault = fault or FaultInjection()
        if fault.hang_worker is not None:
            raise ValueError("hang_worker is a LocalFabric-only injection")
        cfg = campaign_config(engine)
        self.n_tiles = engine.space.n_tiles()
        ctx = mp.get_context("spawn")  # jax is not fork-safe
        self.result_q = ctx.Queue()
        self.task_qs: Dict[int, object] = {}
        self.procs: Dict[int, mp.Process] = {}
        self.lost: set = set()
        self.duplicate_pending = fault.duplicate
        self.stats = {"deliveries": 0, "duplicates": 0, "reissued_tiles": 0,
                      "lost_workers": [], "n_workers": int(n_workers)}
        for w in range(n_workers):
            worker_cfg = {}
            if fault.kill_worker == w:
                worker_cfg["die_on_nth_tile"] = fault.kill_after_tiles + 1
            self.task_qs[w] = ctx.Queue()
            p = ctx.Process(target=_worker_main,
                            args=(w, cfg, worker_cfg, self.task_qs[w],
                                  self.result_q), daemon=True)
            p.start()
            self.procs[w] = p
        # ready barrier: leases are only issued once the fleet is warm
        self.idle: List[int] = []
        ready: set = set()
        while len(ready | self.lost) < n_workers:
            try:
                kind, w, _, payload, _ = self.result_q.get(timeout=0.1)
            except queue_mod.Empty:
                kind = None
            if kind == "ready":
                ready.add(w)
                self.idle.append(w)
            elif kind == "error":
                raise RuntimeError(f"adaptive worker {w} failed: {payload}")
            self._reap()
        if not self.idle:
            raise RuntimeError("adaptive worker pool: all workers died "
                               "during warm-up")

    def _reap(self) -> None:
        for w, p in self.procs.items():
            if w not in self.lost and not p.is_alive():
                self.lost.add(w)
                self.stats["lost_workers"].append(w)
                if w in self.idle:
                    self.idle.remove(w)

    def evaluate_round(self, tiles: List[int]) -> List[RoundDelivery]:
        """Evaluate ``tiles`` across the pool; returns every delivery
        (duplicates included — folding dedups).  Raises if the whole fleet
        dies with tiles outstanding."""
        board = LeaseBoard(
            self.n_tiles,
            done=[t for t in range(self.n_tiles) if t not in set(tiles)])
        board.set_priority(tiles)
        holding: Dict[int, int] = {}
        out: List[RoundDelivery] = []
        while not board.all_done:
            while self.idle:
                w = self.idle[0]
                tile = board.next_tile(w)
                if tile is None:
                    break
                self.idle.pop(0)
                holding[w] = tile
                self.task_qs[w].put(tile)
            try:
                kind, w, tile, payload, busy = self.result_q.get(timeout=0.05)
            except queue_mod.Empty:
                kind = None
            if kind == "result":
                out.append((tile, payload, busy))
                board.complete(tile)
                holding.pop(w, None)
                self.stats["deliveries"] += 1
                if w not in self.lost:
                    self.idle.append(w)
                if self.duplicate_pending:
                    self.duplicate_pending = False
                    out.append((tile, payload, 0.0))
                    self.stats["duplicates"] += 1
            elif kind == "error":
                raise RuntimeError(f"adaptive worker {w} failed: {payload}")
            self._reap()
            for w in list(holding):
                if w in self.lost:
                    tile = holding.pop(w)
                    re_pended = board.revoke_worker(w)
                    self.stats["reissued_tiles"] += len(re_pended)
            if not board.all_done and len(self.lost) == len(self.procs):
                raise RuntimeError(
                    "adaptive pool stalled: all workers lost with "
                    f"{board.n_pending} tiles pending")
        return out

    def close(self) -> None:
        for w, p in self.procs.items():
            if p.is_alive():
                try:
                    self.task_qs[w].put(None)
                except Exception:
                    pass
        for p in self.procs.values():
            p.join(timeout=5)
            if p.is_alive():
                p.terminate()
        # drain terminal metrics payloads so the queue's feeder can retire
        while True:
            try:
                self.result_q.get(timeout=0.2)
            except queue_mod.Empty:
                break


def run_adaptive_distributed(workloads: Sequence[dse.Workload],
                             config: CampaignConfig,
                             fault: Optional[FaultInjection] = None,
                             telemetry=None
                             ) -> Tuple[AdaptiveResult, Dict]:
    """One-call distributed adaptive campaign; returns
    ``(AdaptiveResult, pool stats)``.

    The coordinator (this process) keeps every decision — acquisition,
    surrogate fitting, frontier folding, plateau stop — and only tile
    evaluation fans out to ``config.n_workers`` fabric worker processes.
    Because training rows, acquisition refs and frontier folds are all
    order-canonicalized at round barriers, the result is bitwise-identical
    to the single-process ``AdaptiveCampaign.run`` on the same config —
    under injected worker crashes and duplicate deliveries too.
    """
    adaptive = AdaptiveCampaign(workloads, config, telemetry=telemetry)
    pool = _WorkerPool(adaptive.engine, config.n_workers, fault=fault)
    try:
        adaptive._evaluate_round = pool.evaluate_round
        result = adaptive.run(checkpoint_path=config.checkpoint_path)
    finally:
        pool.close()
    return result, dict(pool.stats)
