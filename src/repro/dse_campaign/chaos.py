"""Chaos harness for the campaign fabric: scripted failures, one invariant.

``ChaosPolicy`` is a declarative, seeded schedule of faults — worker kills,
coordinator restarts, checkpoint bit-flips/truncations, slow workers,
duplicate deliveries, plus an optional poison tile — and ``ChaosRunner``
replays it against a simulated fleet under a ``FakeClock``: every run is
bit-reproducible from ``(workloads, config, policy)`` alone, no wall clock,
no scheduler nondeterminism.

The runner is deliberately the HARSHEST client of the resilience layer:

  * a coordinator restart throws the live ``FabricCoordinator`` away and
    rebuilds it with ``FabricCoordinator.from_checkpoint`` — everything not
    yet checkpointed is re-evaluated, outstanding leases re-pend;
  * checkpoint corruption flips/truncates real bytes on disk, so the next
    restart exercises the store's CRC verify → quarantine → generation
    fallback path (``repro.dse_campaign.store``);
  * killed workers respawn after a ``RetryPolicy`` backoff on the virtual
    clock; a poison tile kills every worker that touches it until the
    coordinator's quarantine parks it;
  * slow workers hold their lease past expiry and deliver late — the fold
    must be a no-op.

THE invariant (gated in ``benchmarks/chaos.py`` and the resilience tests):
whatever the policy does, the final frontiers are bitwise-identical to the
fault-free single-process ``Campaign.run`` on the same config.  Survival is
not enough — recovery must be *exact*.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.dse_campaign import store
from repro.dse_campaign.config import CampaignConfig
from repro.dse_campaign.fabric import (FabricCoordinator, FakeClock,
                                       tile_span)
from repro.dse_campaign.runner import Campaign, CampaignResult
from repro.runtime.fault_tolerance import RetryPolicy
from repro.telemetry import NullTelemetry

# event kinds a ChaosPolicy may schedule
CHAOS_KINDS = ("kill_worker", "restart_coordinator", "corrupt_checkpoint",
               "truncate_checkpoint", "slow_worker", "duplicate_delivery")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault: fired when the run reaches ``at_completion``
    delivered tile completions.  ``arg`` parameterizes the kind: victim
    selector for kills/slowdowns (index into the alive fleet), byte offset
    for ``corrupt_checkpoint``, kept-byte count for ``truncate_checkpoint``,
    unused otherwise."""

    at_completion: int
    kind: str
    arg: int = 0

    def __post_init__(self):
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; expected "
                             f"one of {CHAOS_KINDS}")
        if self.at_completion < 0:
            raise ValueError("at_completion must be >= 0")


@dataclasses.dataclass(frozen=True)
class ChaosPolicy:
    """A declarative, seeded fault schedule.

    ``events`` fire in order as the completion counter passes their
    ``at_completion``; ``poison_tile`` (if set) additionally kills every
    worker that receives that tile; ``seed`` drives the interleaving rng
    AND any randomized event details, so a policy fully determines a run.
    """

    events: Tuple[ChaosEvent, ...] = ()
    poison_tile: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    @classmethod
    def random(cls, seed: int, n_events: int, horizon: int,
               kinds: Sequence[str] = CHAOS_KINDS) -> "ChaosPolicy":
        """A seeded random schedule: ``n_events`` faults drawn from
        ``kinds``, spread over completions ``[1, horizon]`` — the sweep mode
        of the chaos benchmark (hand-scripted scenarios test the named
        failure modes; random policies hunt the unnamed ones)."""
        rng = np.random.default_rng(seed)
        events = tuple(sorted(
            (ChaosEvent(at_completion=int(rng.integers(1, max(horizon, 2))),
                        kind=str(rng.choice(list(kinds))),
                        arg=int(rng.integers(0, 1 << 16)))
             for _ in range(n_events)),
            key=lambda e: (e.at_completion, e.kind, e.arg)))
        return cls(events=events, seed=seed)

    def to_dict(self) -> Dict:
        return {"events": [dataclasses.asdict(e) for e in self.events],
                "poison_tile": self.poison_tile, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: Dict) -> "ChaosPolicy":
        return cls(events=tuple(ChaosEvent(**e) for e in d["events"]),
                   poison_tile=d.get("poison_tile"), seed=d.get("seed", 0))


def _corrupt_file(path: str, offset: int) -> bool:
    """Flip one byte of ``path`` at ``offset`` (mod size)."""
    try:
        with open(path, "r+b") as f:
            raw = f.read()
            if not raw:
                return False
            pos = offset % len(raw)
            f.seek(pos)
            f.write(bytes([raw[pos] ^ 0xFF]))
    except OSError:
        return False
    return True


def _truncate_file(path: str, keep: int) -> bool:
    """Cut ``path`` down to ``keep`` bytes (mod size)."""
    try:
        size = os.path.getsize(path)
        if size == 0:
            return False
        with open(path, "r+b") as f:
            f.truncate(keep % size)
    except OSError:
        return False
    return True


class ChaosRunner:
    """Replay a ``ChaosPolicy`` against a simulated fabric fleet.

    Structure follows ``LocalFabric`` (seeded interleaving, shared
    evaluator, virtual clock advancing 1.0 per iteration) plus the full
    resilience surface: checkpoint every completion, coordinator restarts
    via ``from_checkpoint``, worker respawns on a ``RetryPolicy`` backoff,
    slow workers that deliver after lease expiry, and on-disk checkpoint
    corruption.  ``run`` returns ``(CampaignResult, report)`` where the
    report aggregates fault/recovery telemetry across every coordinator
    incarnation.
    """

    def __init__(self, workloads, config: CampaignConfig,
                 policy: ChaosPolicy, n_workers: int = 3,
                 lease_timeout_s: float = 8.0, poison_threshold: int = 2,
                 retry: Optional[RetryPolicy] = None,
                 slow_for_s: Optional[float] = None):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.workloads = list(workloads)
        self.config = config
        self.policy = policy
        self.n_workers = int(n_workers)
        self.lease_timeout_s = float(lease_timeout_s)
        self.poison_threshold = int(poison_threshold)
        self.retry = retry or RetryPolicy(base_s=1.0, max_s=4.0, seed=policy.seed)
        # how long a slow_worker stays asleep: past the lease timeout, so
        # expiry + late delivery is actually exercised
        self.slow_for_s = (float(slow_for_s) if slow_for_s is not None
                           else 2.0 * self.lease_timeout_s + 1.0)

    def run(self, checkpoint_path: str) -> Tuple[CampaignResult, Dict]:
        clock = FakeClock()
        tel = NullTelemetry(clock=clock)
        campaign = Campaign(self.workloads, self.config, telemetry=tel)
        coord = FabricCoordinator(campaign,
                                  lease_timeout_s=self.lease_timeout_s,
                                  clock=clock,
                                  poison_threshold=self.poison_threshold)
        engine = campaign.engine
        space = campaign.space
        rng = np.random.default_rng(self.policy.seed)
        n_tiles = space.n_tiles()

        alive: List[int] = list(range(self.n_workers))
        for w in alive:
            coord.register_worker(w)
        holding: Dict[int, int] = {}
        asleep: Dict[int, float] = {}           # worker -> wake time
        respawns: List[Tuple[float, int]] = []  # (due time, new worker id)
        next_wid = self.n_workers
        n_respawned = 0
        # stable sort: events at the same completion fire in authored order
        # (corrupt-then-restart is a different scenario than restart-then-
        # corrupt — the author's sequence is part of the policy)
        pending_events = sorted(self.policy.events,
                                key=lambda e: e.at_completion)
        duplicate_next = 0
        n_completions = 0
        report = {
            "events_fired": [],
            "kills": 0, "restarts": 0, "corruptions": 0, "truncations": 0,
            "slowdowns": 0, "duplicates_injected": 0, "respawns": 0,
            "quarantined_files": [], "recoveries": [],
            "poison_tiles": [], "poison_retried": [],
            "reissued_tiles": 0, "worker_crashes": 0, "clean_exits": 0,
            "deliveries": 0, "duplicates_folded": 0,
            "recovery_virtual_s": 0.0,
        }
        # stats survive coordinator restarts only through this fold
        def fold_stats(c: FabricCoordinator):
            report["reissued_tiles"] += c.stats["reissued_tiles"]
            report["worker_crashes"] += len(c.stats["worker_crashes"])
            report["clean_exits"] += len(c.stats["worker_clean_exits"])
            report["deliveries"] += c.stats["deliveries"]
            report["duplicates_folded"] += c.stats["duplicates"]
            report["poison_tiles"] = sorted(
                set(report["poison_tiles"]) | set(c.stats["poison_tiles"]))
            report["poison_retried"] = sorted(
                set(report["poison_retried"])
                | set(c.stats["poison_retried"]))

        def crash_worker(w: int):
            nonlocal next_wid, n_respawned
            if w in alive:
                alive.remove(w)
            holding.pop(w, None)
            asleep.pop(w, None)
            coord.worker_lost(w, crashed=True)
            respawns.append((clock() + self.retry.backoff_s(n_respawned),
                             next_wid))
            n_respawned += 1
            next_wid += 1

        def fire(event: ChaosEvent):
            report["events_fired"].append(
                {"t": clock(), "completion": n_completions,
                 "kind": event.kind, "arg": event.arg})
            if event.kind == "kill_worker":
                if alive:
                    report["kills"] += 1
                    crash_worker(alive[event.arg % len(alive)])
            elif event.kind == "slow_worker":
                candidates = [w for w in alive if w in holding
                              and w not in asleep]
                if candidates:
                    report["slowdowns"] += 1
                    asleep[candidates[event.arg % len(candidates)]] = (
                        clock() + self.slow_for_s)
            elif event.kind == "duplicate_delivery":
                nonlocal duplicate_next
                duplicate_next += 1
                report["duplicates_injected"] += 1
            elif event.kind == "corrupt_checkpoint":
                if _corrupt_file(checkpoint_path, event.arg):
                    report["corruptions"] += 1
            elif event.kind == "truncate_checkpoint":
                if _truncate_file(checkpoint_path, max(event.arg, 1)):
                    report["truncations"] += 1
            elif event.kind == "restart_coordinator":
                restart()

        def restart():
            # the coordinator dies WITHOUT a goodbye checkpoint — recovery
            # starts from whatever the store last made durable
            nonlocal coord
            report["restarts"] += 1
            t_down = clock()
            fold_stats(coord)
            coord = FabricCoordinator.from_checkpoint(
                checkpoint_path, lease_timeout_s=self.lease_timeout_s,
                clock=clock, poison_threshold=self.poison_threshold,
                telemetry=tel)
            rec = coord.stats["recovery"]
            report["recoveries"].append(rec)
            report["quarantined_files"].extend(rec["quarantined"])
            # in-flight work is gone: workers re-register with the new
            # coordinator and start from fresh leases
            holding.clear()
            asleep.clear()
            for w in alive:
                coord.register_worker(w)
            report["recovery_virtual_s"] += clock() - t_down

        def deliver(w: int, tile: int):
            nonlocal duplicate_next, n_completions
            lo, hi = tile_span(space, tile)
            t0 = clock()
            batch = space.slice(lo, hi, with_candidates=not engine.fused)
            reduction = engine.reduce_tile(batch, lo)
            coord.deliver(w, tile, reduction, busy_s=clock() - t0)
            if duplicate_next > 0:
                duplicate_next -= 1
                coord.deliver(w, tile, reduction, busy_s=0.0)
            n_completions += 1
            coord.checkpoint(checkpoint_path)

        def issue_leases():
            for w in alive:
                if w not in holding and w not in asleep:
                    tile = coord.lease(w)
                    if tile is not None:
                        holding[w] = tile

        issue_leases()
        t_start = clock()
        max_iters = 1000 * n_tiles + 10000
        iters = 0
        while not coord.all_done:
            if coord.board.all_settled and not respawns and not holding:
                break  # only parked poison tiles remain
            iters += 1
            if iters > max_iters:
                raise RuntimeError(
                    f"chaos run did not converge in {max_iters} iterations "
                    f"({coord.board.n_done}/{n_tiles} tiles done)")
            while (pending_events
                   and pending_events[0].at_completion <= n_completions):
                fire(pending_events.pop(0))
            active = [w for w in holding
                      if w in alive and w not in asleep]
            if active:
                w = active[int(rng.integers(len(active)))]
                tile = holding.pop(w)
                if tile == self.policy.poison_tile:
                    # touching the poison tile kills the worker; repeated
                    # crash attribution parks the tile at the threshold
                    crash_worker(w)
                else:
                    deliver(w, tile)
            clock.advance(1.0)
            for w, tiles in coord.expire().items():
                # a slow worker keeps its held tile: it will deliver LATE,
                # after the lease re-pended — the fold must be a no-op
                if w not in asleep:
                    holding.pop(w, None)
            for w, wake_at in list(asleep.items()):
                if clock() >= wake_at:
                    del asleep[w]
                    tile = holding.pop(w, None)
                    coord.register_worker(w)
                    if tile is not None and not coord.board.all_done:
                        deliver(w, tile)  # late delivery of the stale lease
            for due, nw in [r for r in respawns if clock() >= r[0]]:
                respawns.remove((due, nw))
                report["respawns"] += 1
                coord.register_worker(nw)
                alive.append(nw)
            issue_leases()
            if not coord.all_done and not alive and not respawns:
                raise RuntimeError(
                    f"chaos fleet extinct with {coord.board.n_pending} "
                    "tiles pending")
        if coord.board.parked_tiles:
            coord.retry_parked()
        coord.checkpoint(checkpoint_path)
        result = coord.result(clock() - t_start)
        fold_stats(coord)
        report["n_completions"] = n_completions
        report["virtual_s"] = clock() - t_start
        report["n_tiles"] = n_tiles
        return result, report
