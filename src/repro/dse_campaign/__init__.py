"""Streaming DSE campaigns: generator-backed mega-spaces, incremental Pareto
frontiers, resumable orchestration, persisted trajectory artifacts — and a
distributed fabric that shards a campaign across workers.

The layer between the batch primitives (``repro.core.dse`` /
``repro.core.costmodel``) and the report scripts: a ``SpaceSpec`` describes a
100-1000x larger space than ``dse.default_space`` without materializing it, a
``Campaign`` streams it tile-by-tile over every cached workload with
checkpoint/resume, and each workload's ``StreamingFrontier`` maintains a
skyline provably identical to one-shot ``dse.pareto_search``.  The
``fabric`` module distributes the same sweep across worker processes —
coordinator leases tile indices, workers ship ``TileReduction`` payloads —
with a frontier bitwise-identical to the single-process run regardless of
worker count, interleaving, or worker loss.

The ``adaptive`` module turns the sweep into a learned search:
``AdaptiveCampaign`` evaluates a seed slice exactly, fits surrogate forests
on it, and spends the rest of a bounded budget (default 10% of the space)
on the tiles with the highest expected hypervolume gain — same frontiers,
same checkpoints, same distributed fabric, a fraction of the evaluations.

Every entry point — ``Campaign``, ``TileEvaluator``, ``run_distributed``,
``AdaptiveCampaign``, and the serving layer's ``SelectionEngine``
(``repro.select``) — constructs from one frozen ``CampaignConfig``; the
pre-config keyword constructors still work but emit ``DeprecationWarning``.
"""

from repro.dse_campaign.adaptive import (AdaptiveCampaign, AdaptiveResult,
                                         run_adaptive_distributed)
from repro.dse_campaign.chaos import (CHAOS_KINDS, ChaosEvent, ChaosPolicy,
                                      ChaosRunner)
from repro.dse_campaign.config import (EVALUATORS, AdaptiveConfig,
                                       CampaignConfig)
from repro.dse_campaign.fabric import (FabricCoordinator, FakeClock,
                                       FaultInjection, LeaseBoard,
                                       LocalFabric, MultiprocessFabric,
                                       campaign_config, evaluator_from_config,
                                       run_distributed, tile_span)
from repro.dse_campaign.frontier import (FrontierSnapshot, StreamingFrontier,
                                         candidate_from_dict,
                                         candidate_to_dict,
                                         canonical_frontier,
                                         frontiers_identical,
                                         hypervolume_2d,
                                         hypervolume_gain_2d)
from repro.dse_campaign.runner import (Campaign, CampaignResult, TileEvaluator,
                                       TileReduction, TileStat)
from repro.dse_campaign.space import (DEFAULT_VARIANTS, SliceVariant,
                                      SpaceSpec, default_campaign_space,
                                      tiny_campaign_space)
from repro.dse_campaign import store

__all__ = [
    "AdaptiveCampaign", "AdaptiveConfig", "AdaptiveResult",
    "CHAOS_KINDS", "Campaign", "CampaignConfig", "CampaignResult",
    "ChaosEvent", "ChaosPolicy", "ChaosRunner", "DEFAULT_VARIANTS",
    "EVALUATORS", "FabricCoordinator", "FakeClock", "FaultInjection",
    "FrontierSnapshot", "LeaseBoard", "LocalFabric", "MultiprocessFabric",
    "SliceVariant", "SpaceSpec", "StreamingFrontier", "TileEvaluator",
    "TileReduction", "TileStat", "campaign_config", "candidate_from_dict",
    "candidate_to_dict", "canonical_frontier", "default_campaign_space",
    "evaluator_from_config", "frontiers_identical", "hypervolume_2d",
    "hypervolume_gain_2d", "run_adaptive_distributed", "run_distributed",
    "store", "tile_span", "tiny_campaign_space",
]
