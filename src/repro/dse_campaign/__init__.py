"""Streaming DSE campaigns: generator-backed mega-spaces, incremental Pareto
frontiers, resumable orchestration, persisted trajectory artifacts.

The layer between the batch primitives (``repro.core.dse`` /
``repro.core.costmodel``) and the report scripts: a ``SpaceSpec`` describes a
100-1000x larger space than ``dse.default_space`` without materializing it, a
``Campaign`` streams it tile-by-tile over every cached workload with
checkpoint/resume, and each workload's ``StreamingFrontier`` maintains a
skyline provably identical to one-shot ``dse.pareto_search``.
"""

from repro.dse_campaign.frontier import (FrontierSnapshot, StreamingFrontier,
                                         candidate_from_dict,
                                         candidate_to_dict,
                                         canonical_frontier,
                                         frontiers_identical,
                                         hypervolume_2d)
from repro.dse_campaign.runner import Campaign, CampaignResult, TileStat
from repro.dse_campaign.space import (DEFAULT_VARIANTS, SliceVariant,
                                      SpaceSpec, default_campaign_space,
                                      tiny_campaign_space)
from repro.dse_campaign import store

__all__ = [
    "Campaign", "CampaignResult", "DEFAULT_VARIANTS", "FrontierSnapshot",
    "SliceVariant", "SpaceSpec", "StreamingFrontier", "TileStat",
    "candidate_from_dict", "candidate_to_dict", "canonical_frontier",
    "default_campaign_space", "frontiers_identical", "hypervolume_2d",
    "store", "tiny_campaign_space",
]
