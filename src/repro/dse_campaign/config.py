"""The one campaign configuration object.

``CampaignConfig`` is the single, frozen description of *how* to evaluate a
design space: the space itself, the evaluator tier, the constraint and
``SimConfig``, pipeline/survivor knobs, checkpoint policy and the
distributed-fabric options.  Every entry point of the campaign stack —
``Campaign``, ``TileEvaluator``, ``fabric.run_distributed`` and the serving
layer's ``SelectionEngine`` — constructs from one of these, so a config can
be built once and handed to any of the four without translation.  Workloads
are deliberately NOT part of the config: they are data (the thing being
evaluated), and the same config is reused across workload sets — offline
campaigns, fabric workers and serving mini-campaigns all share it.

The pre-config keyword constructors (``Campaign(workloads, space,
evaluator=...)`` etc.) still work through a thin shim that builds the
equivalent ``CampaignConfig`` and emits a ``DeprecationWarning``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Tuple

from repro.core import costmodel, dse
from repro.dse_campaign.space import SpaceSpec

# evaluator tiers understood by TileEvaluator (see runner.py for semantics)
EVALUATORS = ("numpy", "jit", "fast", "pallas")


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs of the surrogate-guided adaptive campaign (``adaptive.py``).

    Budgets are fractions of the space's candidate count, rounded up to
    whole tiles: ``seed_fraction`` is evaluated exactly up front (evenly
    spaced tiles, so the surrogates see every region of the space),
    ``round_fraction`` is evaluated per acquisition round, and the loop
    hard-stops once ``budget_fraction`` has been spent.  ``budget_fraction
    >= 1`` short-circuits to the exact sweep (bitwise identical — the
    degenerate-mode gate).

    Acquisition = expected hypervolume gain against the frontier's
    pinned-ref proxy, computed from LCB-optimistic surrogate predictions
    (``exp(mu - explore_weight * sigma)``, sigma = per-tree forest spread),
    with predicted-infeasible candidates screened out.  The loop stops
    early once the frontier hypervolume has improved by less than
    ``plateau_tol`` (relative) for ``plateau_rounds`` consecutive rounds.

    ``train_sample`` rows per (workload, tile) are subsampled for surrogate
    training (seeded by tile index, so any evaluation order yields the same
    rows); ``n_trees`` / ``refresh_trees`` / ``max_depth`` / ``min_leaf``
    size the per-target forests — smaller than the offline predictors
    because they are refit every round.
    """

    budget_fraction: float = 0.10
    seed_fraction: float = 0.04
    round_fraction: float = 0.01
    explore_weight: float = 1.0
    plateau_rounds: int = 2
    plateau_tol: float = 1e-3
    train_sample: int = 64
    n_trees: int = 16
    refresh_trees: int = 8
    max_depth: int = 10
    min_leaf: int = 4
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.budget_fraction <= 1.0:
            raise ValueError("budget_fraction must be in (0, 1]")
        if not 0.0 < self.seed_fraction:
            raise ValueError("seed_fraction must be > 0")
        if not 0.0 < self.round_fraction:
            raise ValueError("round_fraction must be > 0")
        if self.explore_weight < 0.0:
            raise ValueError("explore_weight must be >= 0")
        if self.plateau_rounds < 1:
            raise ValueError("plateau_rounds must be >= 1")
        if self.plateau_tol < 0.0:
            raise ValueError("plateau_tol must be >= 0")
        if self.train_sample < 1:
            raise ValueError("train_sample must be >= 1")
        if self.n_trees < 1 or self.refresh_trees < 1:
            raise ValueError("n_trees and refresh_trees must be >= 1")
        if self.refresh_trees > self.n_trees:
            raise ValueError("refresh_trees must be <= n_trees")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "AdaptiveConfig":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    """Frozen configuration shared by every campaign/serving entry point.

    Field groups:

    * evaluation — ``space`` (the ``SpaceSpec`` to sweep; ``chunk_size``
      optionally overrides its tile size without rebuilding it),
      ``evaluator`` (one of ``EVALUATORS``), ``constraint`` (``None`` means
      the default ``dse.Constraint()``), ``sim``, ``pipeline`` /
      ``max_survivors`` (fused-path knobs), and the fitted
      ``power_model`` / ``cycles_model`` the ``"fast"`` evaluator and the
      serving layer's predictor paths need (unserializable — never
      checkpointed, must be re-passed on resume);
    * checkpointing — ``checkpoint_every`` (tiles between saves) and
      ``checkpoint_path`` (default path ``Campaign.run`` persists to);
    * fabric — ``n_workers`` / ``lease_timeout_s`` for
      ``run_distributed``;
    * adaptive — an optional ``AdaptiveConfig`` enabling the
      surrogate-guided campaign mode (``repro.dse_campaign.adaptive``);
      ``None`` (the default) keeps every entry point on the exact sweep.

    The dataclass is frozen so a config can be shared between a campaign,
    its fabric workers and a serving engine without aliasing surprises; use
    ``replace`` to derive variants.
    """

    space: SpaceSpec
    evaluator: str = "numpy"
    constraint: Optional[dse.Constraint] = None
    sim: costmodel.SimConfig = costmodel.SimConfig()
    power_model: Any = None
    cycles_model: Any = None
    pipeline: bool = True
    max_survivors: int = 2048
    chunk_size: Optional[int] = None
    checkpoint_every: int = 1
    checkpoint_path: Optional[str] = None
    n_workers: int = 2
    lease_timeout_s: float = 300.0
    adaptive: Optional[AdaptiveConfig] = None

    def __post_init__(self):
        if self.adaptive is not None and not isinstance(self.adaptive,
                                                        AdaptiveConfig):
            raise TypeError(
                f"CampaignConfig.adaptive must be an AdaptiveConfig, got "
                f"{type(self.adaptive).__name__}")
        if not isinstance(self.space, SpaceSpec):
            raise TypeError(f"CampaignConfig.space must be a SpaceSpec, got "
                            f"{type(self.space).__name__}")
        if self.evaluator not in EVALUATORS:
            raise ValueError(f"unknown evaluator {self.evaluator!r}; expected "
                             f"one of {EVALUATORS}")
        if self.evaluator == "fast" and (self.power_model is None
                                         or self.cycles_model is None):
            raise ValueError("evaluator='fast' needs fitted power_model and "
                             "cycles_model")
        if self.chunk_size is not None and self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.max_survivors < 1:
            raise ValueError("max_survivors must be >= 1")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        if self.n_workers < 1:
            raise ValueError("n_workers must be >= 1")

    @property
    def resolved_space(self) -> SpaceSpec:
        """``space`` with the ``chunk_size`` override applied (if any)."""
        if self.chunk_size is None or self.chunk_size == self.space.chunk_size:
            return self.space
        return dataclasses.replace(self.space, chunk_size=self.chunk_size)

    @property
    def resolved_constraint(self) -> dse.Constraint:
        """``constraint`` with ``None`` resolved to the default."""
        return self.constraint if self.constraint is not None else dse.Constraint()

    def replace(self, **changes) -> "CampaignConfig":
        """A copy with ``changes`` applied (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)


# keyword names the legacy constructor shims accept, per entry point; the
# shim maps them 1:1 onto CampaignConfig fields
_EVALUATOR_LEGACY = ("constraint", "evaluator", "sim", "power_model",
                     "cycles_model", "pipeline", "max_survivors")
_CAMPAIGN_LEGACY = _EVALUATOR_LEGACY + ("checkpoint_every",)


def coerce_config(owner: str, config, legacy: Dict,
                  allowed: Tuple[str, ...]) -> CampaignConfig:
    """Resolve an entry point's ``(config, **kwargs)`` into a CampaignConfig.

    ``config`` is either a ``CampaignConfig`` (the documented surface — any
    extra keyword then raises) or, on the deprecated pre-config surface, the
    old positional ``space`` argument (alternatively passed as ``space=``)
    plus the old keyword set in ``legacy``; that path still works but emits
    a ``DeprecationWarning`` pointing at ``CampaignConfig``.
    """
    if isinstance(config, CampaignConfig):
        if legacy:
            raise TypeError(
                f"{owner}: pass either a CampaignConfig or the legacy "
                f"keyword arguments, not both (got {sorted(legacy)})")
        return config
    if isinstance(config, SpaceSpec):
        if "space" in legacy:
            raise TypeError(f"{owner}: space given both positionally and by "
                            "keyword")
        legacy = {"space": config, **legacy}
    elif config is not None:
        raise TypeError(
            f"{owner}: second argument must be a CampaignConfig (or, "
            f"deprecated, a SpaceSpec), got {type(config).__name__}")
    unknown = set(legacy) - set(allowed) - {"space"}
    if unknown:
        raise TypeError(f"{owner}: unexpected keyword arguments "
                        f"{sorted(unknown)}")
    if "space" not in legacy:
        raise TypeError(f"{owner}: no space given — pass a CampaignConfig")
    warnings.warn(
        f"{owner}(workloads, space, ...) keyword construction is "
        "deprecated: build a repro.dse_campaign.CampaignConfig and pass it "
        "as the single configuration argument", DeprecationWarning,
        stacklevel=3)
    return CampaignConfig(**legacy)
