"""The one campaign configuration object.

``CampaignConfig`` is the single, frozen description of *how* to evaluate a
design space: the space itself, the evaluator tier, the constraint and
``SimConfig``, pipeline/survivor knobs, checkpoint policy and the
distributed-fabric options.  Every entry point of the campaign stack —
``Campaign``, ``TileEvaluator``, ``fabric.run_distributed`` and the serving
layer's ``SelectionEngine`` — constructs from one of these, so a config can
be built once and handed to any of the four without translation.  Workloads
are deliberately NOT part of the config: they are data (the thing being
evaluated), and the same config is reused across workload sets — offline
campaigns, fabric workers and serving mini-campaigns all share it.

The pre-config keyword constructors (``Campaign(workloads, space,
evaluator=...)`` etc.) still work through a thin shim that builds the
equivalent ``CampaignConfig`` and emits a ``DeprecationWarning``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Tuple

from repro.core import costmodel, dse
from repro.dse_campaign.space import SpaceSpec

# evaluator tiers understood by TileEvaluator (see runner.py for semantics)
EVALUATORS = ("numpy", "jit", "fast", "pallas")


@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    """Frozen configuration shared by every campaign/serving entry point.

    Field groups:

    * evaluation — ``space`` (the ``SpaceSpec`` to sweep; ``chunk_size``
      optionally overrides its tile size without rebuilding it),
      ``evaluator`` (one of ``EVALUATORS``), ``constraint`` (``None`` means
      the default ``dse.Constraint()``), ``sim``, ``pipeline`` /
      ``max_survivors`` (fused-path knobs), and the fitted
      ``power_model`` / ``cycles_model`` the ``"fast"`` evaluator and the
      serving layer's predictor paths need (unserializable — never
      checkpointed, must be re-passed on resume);
    * checkpointing — ``checkpoint_every`` (tiles between saves) and
      ``checkpoint_path`` (default path ``Campaign.run`` persists to);
    * fabric — ``n_workers`` / ``lease_timeout_s`` for
      ``run_distributed``.

    The dataclass is frozen so a config can be shared between a campaign,
    its fabric workers and a serving engine without aliasing surprises; use
    ``replace`` to derive variants.
    """

    space: SpaceSpec
    evaluator: str = "numpy"
    constraint: Optional[dse.Constraint] = None
    sim: costmodel.SimConfig = costmodel.SimConfig()
    power_model: Any = None
    cycles_model: Any = None
    pipeline: bool = True
    max_survivors: int = 2048
    chunk_size: Optional[int] = None
    checkpoint_every: int = 1
    checkpoint_path: Optional[str] = None
    n_workers: int = 2
    lease_timeout_s: float = 300.0

    def __post_init__(self):
        if not isinstance(self.space, SpaceSpec):
            raise TypeError(f"CampaignConfig.space must be a SpaceSpec, got "
                            f"{type(self.space).__name__}")
        if self.evaluator not in EVALUATORS:
            raise ValueError(f"unknown evaluator {self.evaluator!r}; expected "
                             f"one of {EVALUATORS}")
        if self.evaluator == "fast" and (self.power_model is None
                                         or self.cycles_model is None):
            raise ValueError("evaluator='fast' needs fitted power_model and "
                             "cycles_model")
        if self.chunk_size is not None and self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.max_survivors < 1:
            raise ValueError("max_survivors must be >= 1")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        if self.n_workers < 1:
            raise ValueError("n_workers must be >= 1")

    @property
    def resolved_space(self) -> SpaceSpec:
        """``space`` with the ``chunk_size`` override applied (if any)."""
        if self.chunk_size is None or self.chunk_size == self.space.chunk_size:
            return self.space
        return dataclasses.replace(self.space, chunk_size=self.chunk_size)

    @property
    def resolved_constraint(self) -> dse.Constraint:
        """``constraint`` with ``None`` resolved to the default."""
        return self.constraint if self.constraint is not None else dse.Constraint()

    def replace(self, **changes) -> "CampaignConfig":
        """A copy with ``changes`` applied (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)


# keyword names the legacy constructor shims accept, per entry point; the
# shim maps them 1:1 onto CampaignConfig fields
_EVALUATOR_LEGACY = ("constraint", "evaluator", "sim", "power_model",
                     "cycles_model", "pipeline", "max_survivors")
_CAMPAIGN_LEGACY = _EVALUATOR_LEGACY + ("checkpoint_every",)


def coerce_config(owner: str, config, legacy: Dict,
                  allowed: Tuple[str, ...]) -> CampaignConfig:
    """Resolve an entry point's ``(config, **kwargs)`` into a CampaignConfig.

    ``config`` is either a ``CampaignConfig`` (the documented surface — any
    extra keyword then raises) or, on the deprecated pre-config surface, the
    old positional ``space`` argument (alternatively passed as ``space=``)
    plus the old keyword set in ``legacy``; that path still works but emits
    a ``DeprecationWarning`` pointing at ``CampaignConfig``.
    """
    if isinstance(config, CampaignConfig):
        if legacy:
            raise TypeError(
                f"{owner}: pass either a CampaignConfig or the legacy "
                f"keyword arguments, not both (got {sorted(legacy)})")
        return config
    if isinstance(config, SpaceSpec):
        if "space" in legacy:
            raise TypeError(f"{owner}: space given both positionally and by "
                            "keyword")
        legacy = {"space": config, **legacy}
    elif config is not None:
        raise TypeError(
            f"{owner}: second argument must be a CampaignConfig (or, "
            f"deprecated, a SpaceSpec), got {type(config).__name__}")
    unknown = set(legacy) - set(allowed) - {"space"}
    if unknown:
        raise TypeError(f"{owner}: unexpected keyword arguments "
                        f"{sorted(unknown)}")
    if "space" not in legacy:
        raise TypeError(f"{owner}: no space given — pass a CampaignConfig")
    warnings.warn(
        f"{owner}(workloads, space, ...) keyword construction is "
        "deprecated: build a repro.dse_campaign.CampaignConfig and pass it "
        "as the single configuration argument", DeprecationWarning,
        stacklevel=3)
    return CampaignConfig(**legacy)
