"""Optimizer registry with a uniform (init / apply / specs) interface."""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.optim import adafactor as _af
from repro.optim import adamw as _aw
from repro.optim.adamw import (AdamWConfig, OptState, global_norm,  # noqa: F401
                               quantize_i8, dequantize_i8, warmup_cosine)
from repro.optim.adafactor import (AdafactorConfig, AdafactorState,  # noqa: F401
                                   FactoredV)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable          # params -> state
    apply: Callable         # (params, grads, state) -> (params, state, metrics)
    specs: Callable         # (param_spec_tree, params_shape) -> state spec tree


def make_optimizer(name: str, lr: float = 3e-4, total_steps: int = 10000) -> Optimizer:
    if name == "adafactor":
        cfg = _af.make_adafactor(lr, total_steps)
        return Optimizer(
            name=name,
            init=lambda p: _af.init_state(p, cfg),
            apply=lambda p, g, s: _af.apply_adafactor(p, g, s, cfg),
            specs=lambda ps, shp: _af.state_specs(ps, shp, cfg))
    cfg = _aw.make_optimizer(name, lr, total_steps)

    def specs(ps, shp):
        from jax.sharding import PartitionSpec as P
        return _aw.OptState(step=P(), m=ps, v=ps)

    return Optimizer(
        name=name,
        init=lambda p: _aw.init_opt_state(p, cfg),
        apply=lambda p, g, s: _aw.apply_adamw(p, g, s, cfg),
        specs=specs)
