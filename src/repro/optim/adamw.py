"""AdamW with optional int8 block-quantized moments, schedules, clipping.

``adamw8bit`` stores both Adam moments as int8 with per-block fp32 scales
(block = last-dim rows of 256), cutting optimizer state from 8 to ~2.06
bytes/param — what lets 671B-scale training state fit 16 GB/chip meshes.
Quantization is error-compensated by re-quantizing AFTER the moment update
(the standard bitsandbytes-style scheme, dynamic per block).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

BLOCK = 256


# --- int8 block quantization -------------------------------------------------------

def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize_i8(x: jnp.ndarray):
    flat, n = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32),
            "shape": x.shape, "n": n}


def dequantize_i8(qs) -> jnp.ndarray:
    flat = (qs["q"].astype(jnp.float32) * qs["scale"]).reshape(-1)
    return flat[: qs["n"]].reshape(qs["shape"])


# --- schedules -----------------------------------------------------------------------

def warmup_cosine(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return sched


# --- AdamW -----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False      # int8 block-quantized (single-host scale)
    moment_dtype: str = "float32"       # "bfloat16" halves optimizer state and
                                        # shards EXACTLY like the param (671B fit)


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def _zeros_like_moment(p, cfg: AdamWConfig):
    z = jnp.zeros(p.shape, jnp.dtype(cfg.moment_dtype))
    return quantize_i8(z) if cfg.quantize_moments else z


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    mk = lambda p: _zeros_like_moment(p, cfg)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree_util.tree_map(mk, params),
                    v=jax.tree_util.tree_map(mk, params))


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def _is_moment_leaf(x):
    return isinstance(x, dict) and set(x) == {"q", "scale", "shape", "n"}


def apply_adamw(params, grads, state: OptState, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        if cfg.quantize_moments:
            m_f = dequantize_i8(m)
            v_f = jnp.square(dequantize_i8(v))   # v stored in sqrt domain:
        else:                                    # halves its dynamic range
            m_f = m.astype(jnp.float32)
            v_f = v.astype(jnp.float32)
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd_ = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
        if cfg.quantize_moments:
            m_f, v_f = quantize_i8(m_f), quantize_i8(jnp.sqrt(v_f))
        else:
            m_f = m_f.astype(m.dtype)
            v_f = v_f.astype(v.dtype)
        return new_p.astype(p.dtype), m_f, v_f

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, OptState(step, new_m, new_v), metrics


def make_optimizer(name: str, lr=3e-4, total_steps: int = 10000) -> AdamWConfig:
    sched = warmup_cosine(lr, warmup=min(500, total_steps // 10 + 1), total=total_steps)
    if name == "adamw8bit":
        return AdamWConfig(lr=sched, quantize_moments=True)
    if name in ("adamw_bf16", "adamw_lowmem"):
        return AdamWConfig(lr=sched, moment_dtype="bfloat16")
    return AdamWConfig(lr=sched)
