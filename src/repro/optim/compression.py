"""Gradient compression for cross-pod sync: int8 + error feedback.

At multi-pod scale the `pod` axis rides DCN-class links an order of magnitude
slower than ICI; compressing the cross-pod gradient all-reduce 4x (bf16->int8
blockwise) is the classic distributed-optimization trick.  Error feedback
(residual carried to the next step) keeps it convergent (1-bit-Adam lineage).

Usage: grad_transform hook in make_train_step; the residual tree is part of
training state.  Correctness properties are unit-tested (tests/test_optim.py):
compression error decays and compressed-SGD tracks exact-SGD on quadratics.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro import compat

BLOCK = 256


def _blockwise_quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _blockwise_dequant(q, scale, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_decompress(x: jnp.ndarray) -> jnp.ndarray:
    """Quantize-roundtrip (what the wire would carry)."""
    q, s = _blockwise_quant(x.astype(jnp.float32))
    return _blockwise_dequant(q, s, x.shape)


def init_residual(grads: Any) -> Any:
    return jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_grads_with_feedback(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """error-feedback compression: send Q(g + e); carry e' = (g + e) - Q(g + e)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        sent = compress_decompress(target)
        return sent.astype(g.dtype), target - sent

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(residual)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def crosspod_compressed_psum(grads: Any, residual: Any, mesh, pod_axis: str = "pod"):
    """shard_map helper: int8-compress, psum over `pod`, decompress; grads are
    already reduce-scattered within a pod by the backward pass."""
    from jax.sharding import PartitionSpec as P

    def body(g, e):
        sent, new_e = compressed_grads_with_feedback(g, e)
        summed = jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x, pod_axis), sent)
        return summed, new_e

    spec = jax.tree_util.tree_map(lambda _: P(), grads)
    return compat.shard_map(body, mesh=mesh, in_specs=(spec, spec),
                            out_specs=(spec, spec),
                            check_vma=False)(grads, residual)
