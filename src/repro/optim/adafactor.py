"""Adafactor with momentum — the canonical TPU-scale optimizer (T5-style).

The second moment of any >=2-D parameter with both trailing dims >= 128 is
FACTORED into row/column statistics (r: mean over the last dim, c: mean over
the second-to-last), cutting v from O(d_in*d_out) to O(d_in + d_out).  With a
bf16 first moment this brings 671B-scale optimizer state to ~4.1 bytes/param
— the difference between fitting and not fitting a 16 GB/chip single pod
(EXPERIMENTS.md §Dry-run).

Factored leaves shard exactly like their parameter minus the reduced dim —
``factored_spec`` derives the PartitionSpec tree used by the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import global_norm, warmup_cosine


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9                  # momentum (bf16)
    decay: float = 0.99              # running second-moment decay (paper: 1-t^-0.8)
    eps: float = 1e-30
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    min_dim_factor: int = 128
    moment_dtype: str = "bfloat16"


class FactoredV(NamedTuple):
    r: Any   # [..., d_in]  (mean over last dim)
    c: Any   # [..., d_out] (mean over second-to-last dim)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any   # per-leaf: FactoredV or full array


def _factorable(shape, cfg: AdafactorConfig) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.min_dim_factor
            and shape[-2] >= cfg.min_dim_factor)


def init_state(params, cfg: AdafactorConfig) -> AdafactorState:
    def mk_v(p):
        if _factorable(p.shape, cfg):
            return FactoredV(r=jnp.zeros(p.shape[:-1], jnp.float32),
                             c=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    mk_m = lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.moment_dtype))
    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree_util.tree_map(mk_m, params),
                          v=jax.tree_util.tree_map(mk_v, params))


def factored_spec(param_spec: P, shape, cfg: AdafactorConfig):
    """PartitionSpecs for the v leaf derived from the param's spec."""
    if not _factorable(shape, cfg):
        return param_spec
    axes = list(param_spec) + [None] * (len(shape) - len(param_spec))
    return FactoredV(r=P(*axes[:-1]), c=P(*(axes[:-2] + [axes[-1]])))


def state_specs(param_specs_tree, params_shape, cfg: AdafactorConfig):
    v_specs = jax.tree_util.tree_map(
        lambda spec, s: factored_spec(spec, s.shape, cfg),
        param_specs_tree, params_shape,
        is_leaf=lambda x: isinstance(x, P))
    return AdafactorState(step=P(), m=param_specs_tree, v=v_specs)


def apply_adafactor(params, grads, state: AdafactorState, cfg: AdafactorConfig):
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    d = cfg.decay

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        g2 = g * g + cfg.eps
        if isinstance(v, FactoredV):
            r = d * v.r + (1 - d) * jnp.mean(g2, axis=-1)
            c = d * v.c + (1 - d) * jnp.mean(g2, axis=-2)
            # rank-1 reconstruction: v_ij ~ r_i * c_j / mean(r)
            denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), cfg.eps)
            vhat = (r[..., :, None] * c[..., None, :]) / denom[..., None]
            new_v = FactoredV(r=r, c=c)
        else:
            vhat = d * v + (1 - d) * g2
            new_v = vhat
        u = g / jnp.sqrt(vhat + cfg.eps)
        # Adafactor update clipping (RMS(u) <= 1)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        m_f = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * u
        new_p = (p.astype(jnp.float32)
                 - lr * (m_f + cfg.weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), m_f.astype(m.dtype), new_v

    is_v_leaf = lambda x: isinstance(x, FactoredV)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, AdafactorState(step, new_m, new_v), metrics


def make_adafactor(lr=3e-4, total_steps: int = 10000) -> AdafactorConfig:
    return AdafactorConfig(lr=warmup_cosine(lr, min(500, total_steps // 10 + 1),
                                            total_steps))
