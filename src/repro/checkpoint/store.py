"""Atomic, async, ELASTIC checkpointing.

Layout (mesh-agnostic: arrays are saved unsharded so restore can re-shard
onto any device count — elastic scaling):

  <dir>/step_<N>.tmp/...   -> atomic rename -> <dir>/step_<N>/
      manifest.json        (step, tree structure, dtypes, shapes, data state)
      arr_<i>.npy          one file per leaf

Async: ``save_async`` snapshots to host memory synchronously (cheap) and
writes in a daemon thread, so the train loop never blocks on disk.  A
failure mid-write never corrupts the latest checkpoint (tmp+rename).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _fsync_dir(d: str) -> None:
    """fsync a directory so the rename publishing a checkpoint survives power
    loss (the rename lives in the parent's directory entries, which plain
    file fsyncs never touch).  Best-effort on filesystems that refuse it."""
    try:
        fd = os.open(d or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = _leaf_paths(tree)
    import pickle
    manifest = {"step": step, "n_leaves": len(flat),
                "treedef_pkl": pickle.dumps(treedef).hex(),
                "extra": extra or {}, "dtypes": []}
    for i, leaf in enumerate(flat):
        arr = np.asarray(leaf)
        manifest["dtypes"].append(str(arr.dtype))
        if arr.dtype.name == "bfloat16":     # numpy can't round-trip ml_dtypes
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish
    _fsync_dir(ckpt_dir)                        # ... durable, not just atomic
    _gc(ckpt_dir, keep=3)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        (int(d.split("_")[1]), d) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for _, d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-then-write-in-background; at most one write in flight."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None):
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree, extra), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[int, Any, Dict]:
    """Restore; with ``shardings`` (possibly for a DIFFERENT mesh/device count
    than at save time) arrays are placed sharded — elastic restart."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    import pickle
    treedef = pickle.loads(bytes.fromhex(manifest["treedef_pkl"]))
    leaves = []
    dtypes = manifest.get("dtypes", [])
    for i in range(manifest["n_leaves"]):
        arr = np.load(os.path.join(d, f"arr_{i}.npy"))
        if i < len(dtypes) and dtypes[i] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return step, tree, manifest.get("extra", {})
