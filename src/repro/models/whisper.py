"""Whisper-small encoder-decoder backbone.

The conv/log-mel frontend is a STUB per the assignment: inputs are precomputed
frame embeddings [B, num_frames, D].  Encoder: bidirectional pre-LN blocks.
Decoder: causal self-attention + cross-attention over encoder output.
LayerNorm (with bias) + plain GELU MLP + learned positions, per the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import shard_act
from repro.models.transformer import _remat

Params = dict


def init_params(key, cfg, max_seq: int = 4096) -> Params:
    ks = jax.random.split(key, 8)
    dt = L.dtype_of(cfg)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": L.init_layernorm(cfg.d_model),
                "attn": L.init_attention(k1, cfg),
                "ln2": L.init_layernorm(cfg.d_model),
                "ffn": L.init_ffn(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": L.init_layernorm(cfg.d_model),
                "self_attn": L.init_attention(k1, cfg),
                "ln2": L.init_layernorm(cfg.d_model),
                "cross_attn": L.init_attention(k2, cfg),
                "ln3": L.init_layernorm(cfg.d_model),
                "ffn": L.init_ffn(k3, cfg)}

    return {
        "embed": L.init_embed(ks[0], cfg),
        "enc_pos": {"pos_w": L.dense_init(ks[1], (cfg.num_frames, cfg.d_model), dt)},
        "dec_pos": {"pos_w": L.dense_init(ks[2], (max_seq, cfg.d_model), dt)},
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[3], cfg.encoder_layers)),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(ks[4], cfg.num_layers)),
        "enc_norm": L.init_layernorm(cfg.d_model),
        "dec_norm": L.init_layernorm(cfg.d_model),
    }


def encode(params: Params, cfg, frames, dist=None):
    """frames: [B, num_frames, D] (stubbed frontend output)."""
    x = frames.astype(L.dtype_of(cfg)) + params["enc_pos"]["pos_w"][None]
    if dist is not None:
        x = shard_act(x, dist, dist.dp, None, None)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        h = L.norm(lp["ln1"], x, cfg.norm_eps)
        x = x + L.attention_encode(lp["attn"], cfg, h, positions)
        x = x + L.ffn_block(lp["ffn"], cfg, L.norm(lp["ln2"], x, cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_layers"])
    return L.norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer_fwd(lp, cfg, x, positions, enc_out, collect_kv=False):
    h = L.norm(lp["ln1"], x, cfg.norm_eps)
    kv = None
    if collect_kv:
        a, kv = L.attention_prefill(lp["self_attn"], cfg, h, positions)
    else:
        a = L.attention_block(lp["self_attn"], cfg, h, positions)
    x = x + a
    h = L.norm(lp["ln2"], x, cfg.norm_eps)
    ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
    cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
    x = x + L.attention_block(lp["cross_attn"], cfg, h, positions, kv_override=(ck, cv))
    x = x + L.ffn_block(lp["ffn"], cfg, L.norm(lp["ln3"], x, cfg.norm_eps))
    if collect_kv:
        return x, (kv, (ck, cv))
    return x, None


def forward(params: Params, cfg, tokens, frames, dist=None, collect_kv=False):
    enc_out = encode(params, cfg, frames, dist)
    x = L.embed(params["embed"], tokens) + params["dec_pos"]["pos_w"][None, : tokens.shape[1]]
    if dist is not None:
        x = shard_act(x, dist, dist.dp, None, None)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(x, lp):
        out = _dec_layer_fwd(lp, cfg, x, positions, enc_out, collect_kv)
        if collect_kv:
            return out
        x, _ = out
        return x, None

    x, kvs = jax.lax.scan(_remat(body, cfg), x, params["dec_layers"])
    h = L.norm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(None, params["embed"], h)          # whisper ties embeddings
    return h, logits, kvs


def loss_fn(params: Params, cfg, tokens, labels, frames, dist=None):
    _, logits, _ = forward(params, cfg, tokens, frames, dist)
    loss = L.cross_entropy(logits[:, :-1], labels[:, 1:])
    return loss, {"nll": loss}


def init_cache(cfg, batch: int, max_len: int) -> Params:
    dt = L.dtype_of(cfg)
    kv, hd, nl = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    return {
        "len": jnp.zeros((), jnp.int32),
        "self": {"k": jnp.zeros((nl, batch, max_len, kv, hd), dt),
                 "v": jnp.zeros((nl, batch, max_len, kv, hd), dt)},
        # cross K/V precomputed at prefill
        "cross": {"k": jnp.zeros((nl, batch, cfg.num_frames, kv, hd), dt),
                  "v": jnp.zeros((nl, batch, cfg.num_frames, kv, hd), dt)},
    }


def decode_step(params: Params, cfg, tokens, cache, dist=None):
    cache_len = cache["len"]
    x = L.embed(params["embed"], tokens) + \
        jax.lax.dynamic_slice_in_dim(params["dec_pos"]["pos_w"], cache_len, 1, 0)[None]

    def body(x, inp):
        lp, self_c, ck, cv = inp
        h = L.norm(lp["ln1"], x, cfg.norm_eps)
        a, new_c = L.attention_decode(lp["self_attn"], cfg, h, self_c, cache_len)
        x = x + a
        h = L.norm(lp["ln2"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
        o = L.decode_attention(q, ck, cv, ck.shape[1], scale=cfg.head_dim ** -0.5)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["cross_attn"]["wo"])
        x = x + L.ffn_block(lp["ffn"], cfg, L.norm(lp["ln3"], x, cfg.norm_eps))
        return x, new_c

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"],
                  cache["cross"]["k"], cache["cross"]["v"]))
    h = L.norm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(None, params["embed"], h)
    return logits, {"len": cache_len + 1, "self": new_self, "cross": cache["cross"]}
