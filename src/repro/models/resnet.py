"""ResNet-50 — the paper's own CNN-inference domain.

NHWC bottleneck ResNet.  BatchNorm uses batch statistics in train mode and
stored running statistics in inference mode (running stats are part of the
state and updated by the train step).  The 3x3 convs are the hot spot the
conv2d Pallas kernel targets.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict


def _conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
    return (w * (2.0 / fan_in) ** 0.5).astype(dtype)


def _bn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32),
            "mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}


def conv2d(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm(p, x, train: bool, eps=1e-5):
    if train:
        mean = jnp.mean(x.astype(jnp.float32), axis=(0, 1, 2))
        var = jnp.var(x.astype(jnp.float32), axis=(0, 1, 2))
    else:
        mean, var = p["mean"], p["var"]
    out = (x.astype(jnp.float32) - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def _bottleneck_init(key, cin, cmid, stride, dtype):
    ks = jax.random.split(key, 4)
    cout = cmid * 4
    p = {"conv1": {"conv": _conv_init(ks[0], 1, 1, cin, cmid, dtype)}, "bn1": _bn_init(cmid),
         "conv2": {"conv": _conv_init(ks[1], 3, 3, cmid, cmid, dtype)}, "bn2": _bn_init(cmid),
         "conv3": {"conv": _conv_init(ks[2], 1, 1, cmid, cout, dtype)}, "bn3": _bn_init(cout)}
    if stride != 1 or cin != cout:
        p["proj"] = {"conv": _conv_init(ks[3], 1, 1, cin, cout, dtype)}
        p["bn_proj"] = _bn_init(cout)
    return p


def _bottleneck(p, x, stride, train):
    h = jax.nn.relu(batchnorm(p["bn1"], conv2d(x, p["conv1"]["conv"]), train))
    h = jax.nn.relu(batchnorm(p["bn2"], conv2d(h, p["conv2"]["conv"], stride), train))
    h = batchnorm(p["bn3"], conv2d(h, p["conv3"]["conv"]), train)
    if "proj" in p:
        x = batchnorm(p["bn_proj"], conv2d(x, p["proj"]["conv"], stride), train)
    return jax.nn.relu(x + h)


def init_params(key, cfg) -> Params:
    dt = L.dtype_of(cfg)
    w = cfg.cnn_width
    ks = jax.random.split(key, 2 + sum(cfg.cnn_stages))
    p: Params = {"stem": {"conv": _conv_init(ks[0], 7, 7, 3, w, dt)}, "bn_stem": _bn_init(w)}
    cin, i = w, 1
    for s, n_blocks in enumerate(cfg.cnn_stages):
        cmid = w * (2 ** s)
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            p[f"stage{s}_block{b}"] = _bottleneck_init(ks[i], cin, cmid, stride, dt)
            cin = cmid * 4
            i += 1
    p["fc"] = {"fc": L.dense_init(ks[i], (cin, cfg.vocab_size), dt)}
    return p


def forward(params: Params, cfg, images, train: bool = False):
    """images: [B, H, W, 3] -> logits [B, classes]."""
    x = images.astype(L.dtype_of(cfg))
    x = jax.nn.relu(batchnorm(params["bn_stem"], conv2d(x, params["stem"]["conv"], 2), train))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    for s, n_blocks in enumerate(cfg.cnn_stages):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            x = _bottleneck(params[f"stage{s}_block{b}"], x, stride, train)
    x = jnp.mean(x.astype(jnp.float32), axis=(1, 2))
    return jnp.einsum("bd,dv->bv", x.astype(L.dtype_of(cfg)), params["fc"]["fc"]).astype(jnp.float32)


def loss_fn(params: Params, cfg, images, labels, dist=None):
    logits = forward(params, cfg, images, train=True)
    loss = L.cross_entropy(logits[:, None, :], labels[:, None])
    return loss, {"nll": loss}
