"""Unified per-family model API.

``Model`` bundles init / loss / prefill / decode for one architecture family
so the launcher, dry-run, trainer and server never branch on family.

``make_train_step`` / ``make_serve_step`` build the jit-able step functions
plus the matching in/out sharding trees — the single source of truth used by
launch/train.py, launch/serve.py and launch/dryrun.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import layers as L
from repro.models import mamba, resnet, transformer, whisper, zamba
from repro.models.dist import Dist
from repro.models.sharding import param_specs


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable            # (key, max_seq) -> params
    loss: Callable             # (params, batch, dist) -> (loss, metrics)
    prefill: Optional[Callable]    # (params, batch, dist) -> (logits, cache)
    decode: Optional[Callable]     # (params, batch, cache, dist) -> (logits, cache)
    init_cache: Optional[Callable]  # (batch, max_len) -> cache


def _stub_embeds_shape(cfg, batch):
    if cfg.family == "vlm":
        return (batch, cfg.num_patches, cfg.d_model)
    if cfg.family == "audio":
        return (batch, cfg.num_frames, cfg.d_model)
    return None


def build_model(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def init(key, max_seq=0):
            return transformer.init_params(key, cfg)

        def loss(params, batch, dist=None):
            return transformer.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                                       dist, batch.get("prefix_embeds"))

        def prefill(params, batch, dist=None):
            return transformer.prefill(params, cfg, batch["tokens"], dist,
                                       batch.get("prefix_embeds"))

        def decode(params, batch, cache, dist=None):
            return transformer.decode_step(params, cfg, batch["tokens"], cache, dist)

        return Model(cfg, init, loss, prefill, decode,
                     functools.partial(transformer.init_cache, cfg))
    if fam == "ssm":
        return Model(
            cfg,
            lambda key, max_seq=0: mamba.init_params(key, cfg),
            lambda params, batch, dist=None: mamba.loss_fn(
                params, cfg, batch["tokens"], batch["labels"], dist),
            lambda params, batch, dist=None: mamba.prefill(params, cfg, batch["tokens"], dist),
            lambda params, batch, cache, dist=None: mamba.decode_step(
                params, cfg, batch["tokens"], cache, dist),
            functools.partial(mamba.init_cache, cfg))
    if fam == "hybrid":
        return Model(
            cfg,
            lambda key, max_seq=0: zamba.init_params(key, cfg),
            lambda params, batch, dist=None: zamba.loss_fn(
                params, cfg, batch["tokens"], batch["labels"], dist),
            lambda params, batch, dist=None: zamba.prefill(params, cfg, batch["tokens"], dist),
            lambda params, batch, cache, dist=None: zamba.decode_step(
                params, cfg, batch["tokens"], cache, dist),
            functools.partial(zamba.init_cache, cfg))
    if fam == "audio":
        def init(key, max_seq=4096):
            return whisper.init_params(key, cfg, max_seq=max_seq)

        def loss(params, batch, dist=None):
            return whisper.loss_fn(params, cfg, batch["tokens"], batch["labels"],
                                   batch["frames"], dist)

        def prefill(params, batch, dist=None):
            _, logits, kvs = whisper.forward(params, cfg, batch["tokens"],
                                             batch["frames"], dist, collect_kv=True)
            self_kv, cross_kv = kvs
            cache = {"len": jnp.asarray(batch["tokens"].shape[1], jnp.int32),
                     "self": {"k": self_kv[0], "v": self_kv[1]},
                     "cross": {"k": cross_kv[0], "v": cross_kv[1]}}
            return logits, cache

        def decode(params, batch, cache, dist=None):
            return whisper.decode_step(params, cfg, batch["tokens"], cache, dist)

        return Model(cfg, init, loss, prefill, decode,
                     functools.partial(whisper.init_cache, cfg))
    if fam == "cnn":
        return Model(
            cfg,
            lambda key, max_seq=0: resnet.init_params(key, cfg),
            lambda params, batch, dist=None: resnet.loss_fn(
                params, cfg, batch["images"], batch["labels"], dist),
            None, None, None)
    raise ValueError(f"unknown family {fam}")


# --- input specs (ShapeDtypeStruct stand-ins; never allocates) -----------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig, dist: Optional[Dist] = None) -> Dict:
    """Abstract inputs for one (arch, shape) cell — the dry-run contract."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    dt = L.dtype_of(cfg)

    def _dp_n():
        n = 1
        sizes = dict(zip(dist.mesh.axis_names, dist.mesh.devices.shape))
        for a in dist.dp_axes:
            n *= sizes[a]
        return n

    def sharded(spec_axes, shp, dtype):
        if dist is None:
            return sd(shp, dtype)
        axes = list(spec_axes)
        # batch dim is axis 0 by convention: replicate when indivisible (B=1)
        if axes and axes[0] is not None and shp[0] % _dp_n() != 0:
            axes[0] = None
        return sd(shp, dtype, sharding=NamedSharding(dist.mesh, P(*axes)))

    dp = dist.dp if dist is not None else None
    if cfg.family == "cnn":
        r = cfg.image_size
        return {"images": sharded((dp,), (B, r, r, 3), dt),
                "labels": sharded((dp,), (B,), jnp.int32)}
    batch: Dict[str, Any] = {}
    if shape.kind == "train":
        text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
        batch["tokens"] = sharded((dp,), (B, text), jnp.int32)
        batch["labels"] = sharded((dp,), (B, text), jnp.int32)
    elif shape.kind == "prefill":
        text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
        batch["tokens"] = sharded((dp,), (B, text), jnp.int32)
    else:  # decode: one token in
        batch["tokens"] = sharded((dp,), (B, 1), jnp.int32)
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["prefix_embeds"] = sharded((dp,), (B, cfg.num_patches, cfg.d_model), dt)
    if cfg.family == "audio" and shape.kind != "decode":
        batch["frames"] = sharded((dp,), (B, cfg.num_frames, cfg.d_model), dt)
    return batch


def cache_specs(model: Model, shape: ShapeConfig, dist: Optional[Dist]) -> Any:
    """Abstract KV/SSM cache for decode cells (sized to shape.seq_len)."""
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    if dist is None:
        return cache_shape
    specs = cache_sharding_specs(cache_shape, dist)
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(dist.mesh, s)),
        cache_shape, specs)


def cache_sharding_specs(cache_tree, dist: Dist):
    """Caches shard batch over dp; KV-head dim over model when divisible.

    Layouts: [L, B, S, KV, hd] (gqa), [L, B, S, r] (mla), ssm state
    [L, B, nh, hp, ds], conv [L, B, w, C].
    """
    sizes = dict(zip(dist.mesh.axis_names, dist.mesh.devices.shape))
    dp_n = 1
    for a in (dist.dp_axes if isinstance(dist.dp, tuple) else (dist.dp,)):
        dp_n *= sizes[a]
    mdl_n = sizes.get(dist.model_axis, 1)

    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        shp = leaf.shape
        # find batch dim: axis 1 for stacked [L, B, ...]; axis 0 otherwise
        axes = [None] * leaf.ndim
        bdim = 1 if leaf.ndim >= 2 else 0
        if shp[bdim] % dp_n == 0 and shp[bdim] > 1:
            axes[bdim] = dist.dp
        name = [str(getattr(q, "key", "")) for q in path]
        if leaf.ndim == 5:
            # gqa KV cache [L,B,S,KV,hd] or head-major [L,B,KV,S,hd]
            # / ssm state [L,B,nh,hp,ds]
            if "state" in name:
                hdim, sdim = 2, None
            else:
                sdim = 2 if shp[2] >= shp[3] else 3     # seq is the big dim
                hdim = 3 if sdim == 2 else 2
            if shp[hdim] % mdl_n == 0:
                axes[hdim] = dist.model_axis
            elif sdim is not None and shp[sdim] % mdl_n == 0:
                # KV heads indivisible (e.g. 8 heads on 16-way TP): shard the
                # SEQUENCE dim over model instead — flash-decode partial
                # softmax; XLA inserts the cross-shard max/sum combine.
                axes[sdim] = dist.model_axis
        elif leaf.ndim == 4 and any(k in ("c_kv", "k_rope") for k in name):
            # MLA compressed cache [L,B,S,r]: latent is per-token, shard S
            if shp[2] % mdl_n == 0:
                axes[2] = dist.model_axis
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


# --- step builders ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: Any


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, c: TrainState(params=c[0], opt=c[1]))


def make_train_step(model: Model, optimizer, dist: Optional[Dist] = None,
                    grad_transform=None):
    """optimizer: repro.optim.Optimizer bundle.  grad_transform: optional
    (grads -> grads) hook, e.g. int8 compressed cross-pod psum."""
    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, dist), has_aux=True)(state.params)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, opt_metrics = optimizer.apply(
            state.params, grads, state.opt)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_serve_step(model: Model, kind: str, dist: Optional[Dist] = None):
    if kind == "prefill":
        def serve_step(params, batch):
            return model.prefill(params, batch, dist)
    else:
        def serve_step(params, batch, cache):
            return model.decode(params, batch, cache, dist)
    return serve_step


# --- sharding trees for jit in/out ----------------------------------------------------

def state_specs(model: Model, optimizer, dist: Dist, max_seq: int = 4096):
    """PartitionSpec trees + abstract shapes for TrainState.

    Optimizer state specs come from the optimizer bundle (AdamW moments mirror
    params; Adafactor factored stats drop the reduced dim)."""
    params_shape = jax.eval_shape(
        functools.partial(model.init, max_seq=max_seq), jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, dist)
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    opt_specs = optimizer.specs(pspecs, params_shape)
    state_specs_ = TrainState(params=pspecs, opt=opt_specs)
    state_shape = TrainState(params=params_shape, opt=opt_shape)
    return state_specs_, state_shape
