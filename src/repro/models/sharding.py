"""Parameter & activation sharding rules.

One table maps (leaf name, rank) -> logical axes; logical axes map onto mesh
axes through the ``Dist``; any dimension that doesn't divide its mesh axis
falls back to replication (e.g. mamba2-130m's 24 SSD heads on a 16-way model
axis).  This gives DP(+pod) × FSDP × TP/EP sharding:

  * embeddings:   vocab over `model`, d_model over `data` (FSDP)
  * attention:    heads over `model`, d_model over `data`
  * FFN:          hidden over `model`, d_model over `data`
  * MoE experts:  experts over `model` (EP), d_model over `data`
  * SSD:          heads/channels over `model` when divisible, else replicated
  * norms/biases: replicated
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.dist import Dist

# (name, ndim) -> tuple of logical axes, one per dim.
# logical axes: "fsdp" (d_model-ish dims), "tp" (head/hidden/vocab dims), None.
_RULES = {
    # embeddings / heads
    ("embed_w", 2): ("tp", "fsdp"),          # [V, D]
    ("head_w", 2): ("fsdp", "tp"),           # [D, V]
    ("pos_w", 2): (None, "fsdp"),            # [S, D] learned positions
    # gqa attention
    ("wq", 3): ("fsdp", "tp", None),
    ("wk", 3): ("fsdp", "tp", None),
    ("wv", 3): ("fsdp", "tp", None),
    ("wo", 3): ("tp", None, "fsdp"),
    ("bq", 2): ("tp", None),
    ("bk", 2): ("tp", None),
    ("bv", 2): ("tp", None),
    # mla
    ("wq_a", 2): ("fsdp", "tp"),
    ("wq_b", 3): ("fsdp", "tp", None),
    ("wq", 3): ("fsdp", "tp", None),
    ("wkv_a", 2): ("fsdp", "tp"),
    ("wkv_b", 3): ("fsdp", "tp", None),
    # mtp projection
    ("proj", 2): ("fsdp", "tp"),
    # dense ffn / moe shared
    ("w_in", 2): ("fsdp", "tp"),
    ("w_gate", 2): ("fsdp", "tp"),
    ("w_out", 2): ("tp", "fsdp"),
    # moe experts
    ("w_in", 3): ("tp", "fsdp", None),
    ("w_gate", 3): ("tp", "fsdp", None),
    ("w_out", 3): ("tp", None, "fsdp"),
    ("router", 2): ("fsdp", "tp"),
    # ssd / mamba
    ("in_z", 2): ("fsdp", "tp"),
    ("in_xbc", 2): ("fsdp", "tp"),
    ("in_dt", 2): ("fsdp", "tp"),
    ("out_proj", 2): ("tp", "fsdp"),
    ("conv_w", 2): (None, "tp"),
    ("conv_b", 1): ("tp",),
    ("dt_bias", 1): ("tp",),
    ("A_log", 1): ("tp",),
    ("D", 1): ("tp",),
    # cnn
    ("conv", 4): (None, None, None, "tp"),   # [kh, kw, cin, cout]
    ("fc", 2): ("fsdp", "tp"),
}


def _mesh_axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(path, shape, dist: Dist) -> P:
    """PartitionSpec for one parameter leaf."""
    name = None
    for p in reversed(path):
        key = getattr(p, "key", getattr(p, "name", None))
        if key is not None:
            name = str(key)
            break
    rule = _RULES.get((name, len(shape)))
    if rule is None:
        return P()
    sizes = _mesh_axis_sizes(dist.mesh)
    axes = []
    for dim, logical in zip(shape, rule):
        if logical == "tp":
            mesh_ax = dist.model_axis
        elif logical == "fsdp":
            mesh_ax = dist.fsdp_axis
        else:
            mesh_ax = None
        if mesh_ax is not None and dim % sizes.get(mesh_ax, 1) != 0:
            mesh_ax = None                     # indivisible -> replicate
        axes.append(mesh_ax)
    while axes and axes[-1] is None:
        axes.pop()
    return P(*axes)


def _stacked_spec(path, aval, dist: Dist) -> P:
    """Stacked (scanned) layer params carry a leading L dim -> prepend None."""
    is_stacked = any(
        str(getattr(p, "key", getattr(p, "name", ""))).endswith("layers")
        for p in path
    )
    shape = aval.shape
    if is_stacked and len(shape) >= 1:
        inner = spec_for(path, shape[1:], dist)
        return P(None, *inner)
    return spec_for(path, shape, dist)


def param_specs(params_tree, dist: Dist):
    """Tree of PartitionSpec mirroring a params (or params-shape) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _stacked_spec(path, leaf, dist), params_tree)


def param_shardings(params_tree, dist: Dist):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(dist.mesh, s), param_specs(params_tree, dist))


# --- activation constraints -------------------------------------------------------

def shard_act(x, dist: Optional[Dist], *axes):
    """with_sharding_constraint helper; no-op when dist is None."""
    if dist is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(dist.mesh, P(*axes)))


def batch_spec(dist: Optional[Dist], ndim: int) -> P:
    if dist is None:
        return P()
    return P(dist.dp, *([None] * (ndim - 1)))
