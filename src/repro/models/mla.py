"""Multi-head Latent Attention (DeepSeek V2/V3).

Two execution modes:
  * train/prefill — decompress the KV latent to per-head K/V and run the
    chunked flash path (exact).
  * decode (absorbed) — the famous inference trick: fold W_UK into the query
    and W_UV into the output so the per-token cache is just the compressed
    latent  c_kv [kv_lora] + k_rope [rope_dim]  (e.g. 512+64 for V3 instead of
    128 heads x 256 = 32768 floats: a 57x KV-cache shrink).  This is the
    memory-roofline lever exercised in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_mla(key, cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    p = {}
    if qr:
        p["wq_a"] = L.dense_init(ks[0], (d, qr), dt)
        p["q_a_norm"] = L.init_rmsnorm(qr)
        p["wq_b"] = L.dense_init(ks[1], (qr, h, nope + rope_d), dt)
    else:
        p["wq"] = L.dense_init(ks[0], (d, h, nope + rope_d), dt)
    p["wkv_a"] = L.dense_init(ks[2], (d, kvr + rope_d), dt)
    p["kv_a_norm"] = L.init_rmsnorm(kvr)
    p["wkv_b"] = L.dense_init(ks[3], (kvr, h, nope + vd), dt)
    p["wo"] = L.dense_init(ks[4], (h, vd, d), dt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5)
    return p


def _project_q(p, cfg, x, positions):
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        qa = L.rmsnorm(p["q_a_norm"], jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qa, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p, cfg, x, positions):
    kvr, rope_d = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = L.rmsnorm(p["kv_a_norm"], kv[..., :kvr], cfg.norm_eps)
    k_rope = L.apply_rope(kv[..., kvr:][:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,rope]
    return c_kv, k_rope


def mla_block(p, cfg, x, positions, prefix_len: int = 0) -> jnp.ndarray:
    """Train/prefill: decompress latent, run flash attention."""
    nope, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c_kv, k_rope = _project_kv_latent(p, cfg, x, positions)
    kv_up = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"])
    k_nope, v = kv_up[..., :nope], kv_up[..., nope:]
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope, (*k_rope.shape[:2], h, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = (nope + cfg.qk_rope_head_dim) ** -0.5
    o = L.flash_attention(q, k, v, scale=scale, prefix_len=prefix_len)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def mla_prefill(p, cfg, x, positions, prefix_len: int = 0) -> tuple:
    """Prefill emitting the COMPRESSED cache entries (c_kv, k_rope)."""
    nope = cfg.qk_nope_head_dim
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c_kv, k_rope = _project_kv_latent(p, cfg, x, positions)
    kv_up = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"])
    k_nope, v = kv_up[..., :nope], kv_up[..., nope:]
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope, (*k_rope.shape[:2], h, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = (nope + cfg.qk_rope_head_dim) ** -0.5
    o = L.flash_attention(q, k, v, scale=scale, prefix_len=prefix_len)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (c_kv, k_rope[:, :, 0])


def init_mla_cache(cfg, batch: int, max_len: int, num_layers: int) -> dict:
    """Compressed cache: latent + rope key only."""
    dt = L.dtype_of(cfg)
    return {
        "c_kv": jnp.zeros((num_layers, batch, max_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((num_layers, batch, max_len, cfg.qk_rope_head_dim), dt),
    }


def mla_decode(p, cfg, x, cache, cache_len) -> tuple:
    """Absorbed single-token decode against the compressed cache.

    cache: {"c_kv": [B, Smax, kvr], "k_rope": [B, Smax, rope]} (this layer's slice).
    """
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    B = x.shape[0]
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    q_nope, q_rope = _project_q(p, cfg, x, positions)          # [B,1,H,*]
    c_new, k_rope_new = _project_kv_latent(p, cfg, x, positions)
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), cache_len, axis=1)
    r_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new[:, :, 0].astype(cache["k_rope"].dtype), cache_len, axis=1)

    w_uk = p["wkv_b"][..., :nope]                               # [kvr, H, nope]
    w_uv = p["wkv_b"][..., nope:]                               # [kvr, H, vd]
    # Absorb W_UK into q: q_lat [B,1,H,kvr]
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
    s = jnp.einsum("bshr,btr->bhst", q_lat, c_cache.astype(jnp.float32))
    s = s + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                       r_cache.astype(jnp.float32))
    s = s * (nope + rope_d) ** -0.5
    pos = jnp.arange(c_cache.shape[1])
    s = jnp.where((pos <= cache_len)[None, None, None], s, L.NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", attn, c_cache.astype(jnp.float32))  # [B,1,H,kvr]
    o = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, {"c_kv": c_cache, "k_rope": r_cache}
