"""Distribution context threaded through model code."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Dist:
    """Mesh + axis-name bundle.

    dp_axes:   batch-sharding axes, ('data',) or ('pod', 'data').
    model_axis: TP/EP axis.
    fsdp_axis: parameter/optimizer-state sharding axis (ZeRO-3 style).
    use_ep:    route MoE through the shard_map expert-parallel path.
    """

    mesh: Any
    dp_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp_axis: Optional[str] = "data"
    use_ep: bool = True

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def axis_size(self, name) -> int:
        if name is None:
            return 1
        names = name if isinstance(name, tuple) else (name,)
        n = 1
        for a in names:
            n *= dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[a]
        return n


def make_dist(mesh, multi_pod: bool | None = None) -> Dist:
    names = tuple(mesh.axis_names)
    if multi_pod is None:
        multi_pod = "pod" in names
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    return Dist(mesh=mesh, dp_axes=dp_axes)
