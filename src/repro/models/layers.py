"""Core neural-net layers, pure functional JAX.

Conventions:
  * params are nested dicts of jnp arrays; ``init_*`` builds them, ``apply``-style
    functions consume them.
  * activations are [B, S, ...]; attention uses BSHD layout.
  * matmuls run in the config dtype (bf16); softmax/norm statistics in fp32.
  * the chunked `flash_attention` is the XLA-level oracle matching the Pallas
    kernel in ``repro.kernels.flash_attention`` (same online-softmax math).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def _norm_init(key, shape):
    return jnp.ones(shape, jnp.float32)


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --- normalization -------------------------------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return layernorm(p, x, eps) if "bias" in p else rmsnorm(p, x, eps)


# --- rotary embeddings -----------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- chunked flash attention (XLA path; oracle for the Pallas kernel) -------------

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, prefix_len: int):
    """Causal mask with an optional bidirectional prefix (PaliGemma)."""
    m = k_pos[None, :] <= q_pos[:, None]
    if prefix_len:
        m = m | (k_pos[None, :] < prefix_len)
    return m


def flash_attention(q, k, v, *, scale: float, prefix_len: int = 0,
                    chunk: int = 1024) -> jnp.ndarray:
    """Causal chunked attention with online softmax.

    q: [B, S, H, hd]; k, v: [B, S, KV, hd(v)].  GQA via head grouping (never
    materializes repeated KV).  The python loop over query chunks is STATIC, so
    query chunk ``i``'s inner scan covers exactly its ``i+1`` causally-visible
    KV chunks — compiled FLOPs match true causal FLOPs (no masked-away waste),
    which keeps the roofline compute term honest.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    hv = v.shape[-1]
    G = H // KV
    c = min(chunk, S)
    S_real = S
    if S % c:
        # pad to a chunk multiple; padded KV positions sit above every real
        # query position, so the causal mask hides them for free.
        pad = c - S % c
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    n = S // c

    qg = q.reshape(B, n, c, KV, G, hd)
    outs = []
    for i in range(n):
        qi = qg[:, i]                                     # [B, c, KV, G, hd]
        q_pos = i * c + jnp.arange(c)

        def step(carry, k_lo, qi=qi, q_pos=q_pos):
            # dynamic-slice the KV block in place — never materializes stacked
            # prefix copies (flash semantics: read each block exactly once).
            m_prev, l_prev, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(k, k_lo, c, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, k_lo, c, axis=1)
            k_pos = k_lo + jnp.arange(c)
            # bf16 operands, fp32 accumulation: MXU-native, no fp32 KV copies
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_pos, k_pos, prefix_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        carry = (jnp.full((B, KV, G, c), NEG_INF, jnp.float32),
                 jnp.zeros((B, KV, G, c), jnp.float32),
                 jnp.zeros((B, KV, G, c, hv), jnp.float32))
        n_blk = i + 1                                     # causal horizon, STATIC
        if n_blk == 1:
            carry, _ = step(carry, jnp.asarray(0, jnp.int32))
        else:
            carry, _ = jax.lax.scan(step, carry, jnp.arange(n_blk) * c)
        m_f, l_f, acc = carry
        o = acc / jnp.maximum(l_f[..., None], 1e-30)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, c, H, hv))
    out = jnp.concatenate(outs, axis=1).astype(q.dtype)
    return out[:, :S_real]


def decode_attention(q, k_cache, v_cache, cache_len, *, scale: float) -> jnp.ndarray:
    """Single-step decode: q [B, 1, H, hd]; caches [B, Smax, KV, hd]."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    hv = v_cache.shape[-1]
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(qg.dtype),
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    s = jnp.where((pos < cache_len)[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hv).astype(q.dtype)


# --- GQA attention block ----------------------------------------------------------

def init_attention(key, cfg) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dt),
        "wk": dense_init(ks[1], (d, kv, hd), dt),
        "wv": dense_init(ks[2], (d, kv, hd), dt),
        "wo": dense_init(ks[3], (h, hd, d), dt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((kv, hd), dt)
        p["bv"] = jnp.zeros((kv, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _qkv(p, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q, k = rmsnorm(p["q_norm"], q, cfg.norm_eps), rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p: Params, cfg, x, positions, prefix_len: int = 0,
                    kv_override=None) -> jnp.ndarray:
    """Full-sequence (train/prefill) attention.  kv_override: (k, v) for cross-attn."""
    q, k, v = _qkv(p, cfg, x, positions)
    if kv_override is not None:
        k, v = kv_override
        scale = cfg.head_dim ** -0.5
        # cross attention: non-causal over the encoder sequence
        s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
        o = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(s, -1), v.astype(jnp.float32)).astype(x.dtype)
    else:
        o = flash_attention(q, k, v, scale=cfg.head_dim ** -0.5, prefix_len=prefix_len)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attention_encode(p: Params, cfg, x, positions) -> jnp.ndarray:
    """Bidirectional (encoder) attention."""
    q, k, v = _qkv(p, cfg, x, positions)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    o = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(s, -1), v.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attention_prefill(p: Params, cfg, x, positions, prefix_len: int = 0) -> tuple:
    """Prefill: full-sequence attention that also emits (k, v) for the cache."""
    q, k, v = _qkv(p, cfg, x, positions)
    o = flash_attention(q, k, v, scale=cfg.head_dim ** -0.5, prefix_len=prefix_len)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), (k, v)


def decode_attention_hm(q, k_cache, v_cache, cache_len, *, scale: float):
    """Head-major decode: caches [B, KV, Smax, hd] — the dot consumes the
    cache in storage order (no per-step transpose of the whole cache)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[1]
    G = H // KV
    hv = v_cache.shape[-1]
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache.astype(qg.dtype),
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[2])
    s = jnp.where((pos < cache_len)[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hv).astype(q.dtype)


def attention_decode(p: Params, cfg, x, cache, cache_len) -> tuple:
    """Single-token decode.  cache layout per cfg.cache_layout:
    seq_major {"k": [B,Smax,KV,hd]} | head_major {"k": [B,KV,Smax,hd]}."""
    positions = jnp.full((x.shape[0], 1), cache_len, jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions)
    if cfg.cache_layout == "head_major":
        k_t = k.transpose(0, 2, 1, 3).astype(cache["k"].dtype)   # [B,KV,1,hd]
        v_t = v.transpose(0, 2, 1, 3).astype(cache["v"].dtype)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_t, cache_len, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_t, cache_len, axis=2)
        o = decode_attention_hm(q, k_cache, v_cache, cache_len + 1,
                                scale=cfg.head_dim ** -0.5)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
        o = decode_attention(q, k_cache, v_cache, cache_len + 1,
                             scale=cfg.head_dim ** -0.5)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": k_cache, "v": v_cache}


def init_kv_cache(cfg, batch: int, max_len: int, layers: int) -> Params:
    dt = dtype_of(cfg)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.cache_layout == "head_major":
        shape = (layers, batch, kv, max_len, hd)
    else:
        shape = (layers, batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# --- feed-forward ------------------------------------------------------------------

def init_ffn(key, cfg, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d, f), dt),
         "w_out": dense_init(ks[1], (f, d), dt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5)}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], (d, f), dt)
    return p


def _act(cfg, x):
    return jax.nn.silu(x) if cfg.act_fn == "silu" else jax.nn.gelu(x)


def ffn_block(p: Params, cfg, x) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if "w_gate" in p:
        h = _act(cfg, jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * h
    else:
        h = _act(cfg, h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


# --- embeddings / head ----------------------------------------------------------------

def init_embed(key, cfg) -> Params:
    dt = dtype_of(cfg)
    return {"embed_w": dense_init(key, (cfg.vocab_size, cfg.d_model), dt,
                                  scale=1.0 / cfg.d_model ** 0.5)}


def embed(p: Params, tokens) -> jnp.ndarray:
    return jnp.take(p["embed_w"], tokens, axis=0)


def unembed(p_head: Optional[Params], p_embed: Params, x) -> jnp.ndarray:
    w = p_embed["embed_w"].T if p_head is None else p_head["head_w"]
    return jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token NLL in fp32; logits [B,S,V], labels [B,S] (−1 = pad/ignore)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
