"""Zamba2 hybrid: Mamba2 backbone + one SHARED attention block.

38 SSM layers; after every ``attn_every`` (6) of them the single shared
attention+MLP block runs (tied weights at every call site, per-site KV cache).
The SSM path keeps long-context decode O(1); the shared block's decode
attention is O(context) per step — sub-quadratic overall, so `long_500k` runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssd
from repro.models.sharding import shard_act
from repro.models.transformer import _remat

Params = dict


def _n_sites(cfg) -> int:
    return cfg.num_layers // cfg.attn_every


def init_params(key, cfg) -> Params:
    ks = jax.random.split(key, 4)

    def one(k):
        return {"ln": L.init_rmsnorm(cfg.d_model), "mix": ssd.init_mamba_block(k, cfg)}

    shared_key1, shared_key2 = jax.random.split(ks[2])
    p = {
        "embed": L.init_embed(ks[0], cfg),
        "mamba_layers": jax.vmap(one)(jax.random.split(ks[1], cfg.num_layers)),
        "shared_attn": {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(shared_key1, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "ffn": L.init_ffn(shared_key2, cfg),
        },
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = {"head_w": L.dense_init(ks[3], (cfg.d_model, cfg.vocab_size),
                                            L.dtype_of(cfg))}
    return p


def _segments(cfg):
    """Static (start, stop) layer ranges; shared block runs after each full one."""
    e = cfg.attn_every
    return [(i * e, min((i + 1) * e, cfg.num_layers))
            for i in range(-(-cfg.num_layers // e))]


def _shared_fwd(sp, cfg, x, positions, collect_kv=False):
    h = L.norm(sp["ln1"], x, cfg.norm_eps)
    if collect_kv:
        a, kv = L.attention_prefill(sp["attn"], cfg, h, positions)
    else:
        a, kv = L.attention_block(sp["attn"], cfg, h, positions), None
    x = x + a
    x = x + L.ffn_block(sp["ffn"], cfg, L.norm(sp["ln2"], x, cfg.norm_eps))
    return x, kv


def forward(params: Params, cfg, tokens, dist=None, collect_cache=False):
    x = L.embed(params["embed"], tokens)
    if dist is not None:
        x = shard_act(x, dist, dist.dp, None, None)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(x, lp):
        out = ssd.mamba_block(lp["mix"], cfg, L.norm(lp["ln"], x, cfg.norm_eps),
                              return_cache=collect_cache)
        dx, c = out if collect_cache else (out, None)
        return x + dx, c

    body = _remat(body, cfg)
    ssm_caches, kv_caches = [], []
    for (lo, hi) in _segments(cfg):
        seg = jax.tree_util.tree_map(lambda a: a[lo:hi], params["mamba_layers"])
        x, c = jax.lax.scan(body, x, seg)
        if collect_cache:
            ssm_caches.append(c)
        if hi - lo == cfg.attn_every:         # full segment -> shared block
            x, kv = _shared_fwd(params["shared_attn"], cfg, x, positions,
                                collect_kv=collect_cache)
            if collect_cache:
                kv_caches.append(kv)
    h = L.norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("head"), params["embed"], h)
    caches = None
    if collect_cache:
        ssm = jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *ssm_caches)
        kvs = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *kv_caches)
        caches = (ssm, kvs)
    return h, logits, caches


def loss_fn(params: Params, cfg, tokens, labels, dist=None):
    _, logits, _ = forward(params, cfg, tokens, dist)
    loss = L.cross_entropy(logits[:, :-1], labels[:, 1:])
    return loss, {"nll": loss}


def init_cache(cfg, batch: int, max_len: int) -> Params:
    n = _n_sites(cfg)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "len": jnp.zeros((), jnp.int32),
        "ssm": ssd.init_ssm_cache(cfg, batch, cfg.num_layers),
        "attn": {"k": jnp.zeros((n, batch, max_len, kv, hd), L.dtype_of(cfg)),
                 "v": jnp.zeros((n, batch, max_len, kv, hd), L.dtype_of(cfg))},
    }


def decode_step(params: Params, cfg, tokens, cache, dist=None):
    x = L.embed(params["embed"], tokens)
    cache_len = cache["len"]

    def body(x, inp):
        lp, cl = inp
        dx, nc = ssd.mamba_decode(lp["mix"], cfg, L.norm(lp["ln"], x, cfg.norm_eps), cl)
        return x + dx, nc

    new_ssm, new_k, new_v = [], [], []
    site = 0
    for (lo, hi) in _segments(cfg):
        seg = jax.tree_util.tree_map(lambda a: a[lo:hi], params["mamba_layers"])
        seg_cache = jax.tree_util.tree_map(lambda a: a[lo:hi], cache["ssm"])
        x, nc = jax.lax.scan(body, x, (seg, seg_cache))
        new_ssm.append(nc)
        if hi - lo == cfg.attn_every:
            sp = params["shared_attn"]
            h = L.norm(sp["ln1"], x, cfg.norm_eps)
            site_cache = {"k": cache["attn"]["k"][site], "v": cache["attn"]["v"][site]}
            a, nkv = L.attention_decode(sp["attn"], cfg, h, site_cache, cache_len)
            x = x + a
            x = x + L.ffn_block(sp["ffn"], cfg, L.norm(sp["ln2"], x, cfg.norm_eps))
            new_k.append(nkv["k"])
            new_v.append(nkv["v"])
            site += 1
    h = L.norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("head"), params["embed"], h)
    new_cache = {
        "len": cache_len + 1,
        "ssm": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *new_ssm),
        "attn": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
    }
    return logits, new_cache


def prefill(params: Params, cfg, tokens, dist=None):
    _, logits, caches = forward(params, cfg, tokens, dist, collect_cache=True)
    ssm, kvs = caches
    conv_tail, final_state = ssm
    k, v = kvs
    return logits, {
        "len": jnp.asarray(tokens.shape[1], jnp.int32),
        "ssm": {"conv": conv_tail, "state": final_state},
        "attn": {"k": k, "v": v},
    }
