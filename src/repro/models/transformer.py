"""Unified decoder-only transformer LM.

Covers: dense GQA/MQA (qwen2/3, granite, stablelm), MLA+MoE (deepseek v2/v3,
incl. MTP head), prefix-VLM (paligemma: bidirectional patch-embedding prefix).

Layer stacks are SCANNED (params carry a leading L dim) with a selectable
remat policy — this keeps HLO size and compile time flat in depth and gives
XLA a single steady-state loop body to software-pipeline collectives into.
DeepSeek's leading dense layers form a second, separate scan stack.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models.sharding import shard_act

Params = Dict[str, Any]


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


# --- layer init -----------------------------------------------------------------

def init_layer(key, cfg, moe: bool) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"ln1": L.init_rmsnorm(cfg.d_model), "ln2": L.init_rmsnorm(cfg.d_model)}
    if cfg.attn_type == "mla":
        p["attn"] = MLA.init_mla(k1, cfg)
    else:
        p["attn"] = L.init_attention(k1, cfg)
    p["moe" if moe else "ffn"] = MOE.init_moe(k2, cfg) if moe else L.init_ffn(k2, cfg)
    return p


def _stack(key, cfg, n: int, moe: bool) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_layer(k, cfg, moe))(keys)


def init_params(key, cfg) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"embed": L.init_embed(ks[0], cfg),
                 "final_norm": L.init_rmsnorm(cfg.d_model)}
    if cfg.num_experts:
        n_dense, n_moe = cfg.first_k_dense, cfg.num_layers - cfg.first_k_dense
        if n_dense:
            p["dense_layers"] = _stack(ks[1], cfg, n_dense, moe=False)
        p["moe_layers"] = _stack(ks[2], cfg, n_moe, moe=True)
    else:
        p["layers"] = _stack(ks[1], cfg, cfg.num_layers, moe=False)
    if not cfg.tie_embeddings:
        p["head"] = {"head_w": L.dense_init(ks[3], (cfg.d_model, cfg.vocab_size),
                                            L.dtype_of(cfg))}
    if cfg.mtp_depth:
        p["mtp"] = {"proj": L.dense_init(ks[4], (2 * cfg.d_model, cfg.d_model),
                                         L.dtype_of(cfg)),
                    "layer": init_layer(ks[5], cfg, moe=False),
                    "norm": L.init_rmsnorm(cfg.d_model)}
    return p


# --- forward (train / prefill) -----------------------------------------------------

def _layer_fwd(lp, cfg, x, positions, prefix_len, dist, *, moe: bool, collect_kv: bool):
    h = L.norm(lp["ln1"], x, cfg.norm_eps)
    kv = None
    if cfg.attn_type == "mla":
        if collect_kv:
            a, kv = MLA.mla_prefill(lp["attn"], cfg, h, positions, prefix_len)
        else:
            a = MLA.mla_block(lp["attn"], cfg, h, positions, prefix_len)
    else:
        if collect_kv:
            a, kv = L.attention_prefill(lp["attn"], cfg, h, positions, prefix_len)
        else:
            a = L.attention_block(lp["attn"], cfg, h, positions, prefix_len)
    x = x + a
    h = L.norm(lp["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if moe:
        f, aux = MOE.moe_block(lp["moe"], cfg, h, dist)
    else:
        f = L.ffn_block(lp["ffn"], cfg, h)
    x = x + f
    if dist is not None:
        x = shard_act(x, dist, dist.dp, None, None)
    return x, aux, kv


def _run_stack(stack_params, cfg, x, positions, prefix_len, dist, *, moe: bool,
               collect_kv: bool):
    body = functools.partial(_layer_fwd, cfg=cfg, positions=positions,
                             prefix_len=prefix_len, dist=dist, moe=moe,
                             collect_kv=collect_kv)

    def scan_body(carry, lp):
        x, aux = carry
        x, aux_l, kv = body(lp, x=x)
        return (x, aux + aux_l), kv

    scan_body = _remat(scan_body, cfg)
    (x, aux), kvs = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                                 stack_params)
    return x, aux, kvs


def forward(params: Params, cfg, tokens, dist=None, prefix_embeds=None,
            collect_kv: bool = False):
    """tokens: [B, S_text].  Returns (hidden [B,S,D], logits fp32, aux, kv_caches)."""
    x = L.embed(params["embed"], tokens)
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)   # gemma embedding scale
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    if dist is not None:
        x = shard_act(x, dist, dist.dp, None, None)

    aux = jnp.zeros((), jnp.float32)
    kvs = {}
    if cfg.num_experts:
        if "dense_layers" in params:
            x, a0, kv0 = _run_stack(params["dense_layers"], cfg, x, positions,
                                    prefix_len, dist, moe=False, collect_kv=collect_kv)
            aux += a0
            kvs["dense"] = kv0
        x, a1, kv1 = _run_stack(params["moe_layers"], cfg, x, positions,
                                prefix_len, dist, moe=True, collect_kv=collect_kv)
        aux += a1
        kvs["moe"] = kv1
    else:
        x, aux, kv = _run_stack(params["layers"], cfg, x, positions, prefix_len,
                                dist, moe=False, collect_kv=collect_kv)
        kvs["layers"] = kv
    h = L.norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("head"), params["embed"], h)
    return h, logits, aux, (kvs if collect_kv else None)


def loss_fn(params: Params, cfg, tokens, labels, dist=None, prefix_embeds=None):
    """Mean NLL (+ MTP auxiliary loss for DeepSeek-V3)."""
    h, logits, aux, _ = forward(params, cfg, tokens, dist, prefix_embeds)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1]:]
    loss = L.cross_entropy(logits[:, :-1], labels[:, 1:])
    metrics = {"nll": loss, "moe_aux": aux}
    if cfg.mtp_depth and "mtp" in params:
        # MTP: predict token t+2 from (h_t, embed(t+1))
        emb_next = L.embed(params["embed"], tokens[:, 1:])
        h_in = jnp.concatenate([h[:, :-1], emb_next], axis=-1)
        h_mtp = jnp.einsum("bsd,dk->bsk", h_in, params["mtp"]["proj"])
        pos = jnp.arange(h_mtp.shape[1])[None, :]
        h_mtp, _, _ = _layer_fwd(params["mtp"]["layer"], cfg, h_mtp, pos, 0, dist,
                                 moe=False, collect_kv=False)
        h_mtp = L.norm(params["mtp"]["norm"], h_mtp, cfg.norm_eps)
        mtp_logits = L.unembed(params.get("head"), params["embed"], h_mtp)
        mtp_loss = L.cross_entropy(mtp_logits[:, :-1], labels[:, 2:])
        loss = loss + 0.3 * mtp_loss
        metrics["mtp_nll"] = mtp_loss
    if cfg.num_experts and cfg.router_fn == "softmax":
        loss = loss + 0.001 * aux        # classic load-balance aux loss (V2)
    return loss, metrics


# --- decode ------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int) -> Params:
    """Per-stack KV caches (+ scalar length)."""
    cache: Params = {"len": jnp.zeros((), jnp.int32)}
    if cfg.num_experts:
        n_dense, n_moe = cfg.first_k_dense, cfg.num_layers - cfg.first_k_dense
        mk = (MLA.init_mla_cache if cfg.attn_type == "mla" else
              functools.partial(L.init_kv_cache, max_len=max_len))
        if cfg.attn_type == "mla":
            if n_dense:
                cache["dense"] = MLA.init_mla_cache(cfg, batch, max_len, n_dense)
            cache["moe"] = MLA.init_mla_cache(cfg, batch, max_len, n_moe)
        else:
            if n_dense:
                cache["dense"] = L.init_kv_cache(cfg, batch, max_len, n_dense)
            cache["moe"] = L.init_kv_cache(cfg, batch, max_len, n_moe)
    else:
        if cfg.attn_type == "mla":
            cache["layers"] = MLA.init_mla_cache(cfg, batch, max_len, cfg.num_layers)
        else:
            cache["layers"] = L.init_kv_cache(cfg, batch, max_len, cfg.num_layers)
    return cache


def _layer_decode(lp, cfg, x, cache_l, cache_len, dist, *, moe: bool):
    h = L.norm(lp["ln1"], x, cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, new_cache = MLA.mla_decode(lp["attn"], cfg, h, cache_l, cache_len)
    else:
        a, new_cache = L.attention_decode(lp["attn"], cfg, h, cache_l, cache_len)
    x = x + a
    h = L.norm(lp["ln2"], x, cfg.norm_eps)
    if moe:
        f, _ = MOE.moe_block(lp["moe"], cfg, h, dist)
    else:
        f = L.ffn_block(lp["ffn"], cfg, h)
    return x + f, new_cache


def _decode_stack(stack_params, cfg, x, cache_stack, cache_len, dist, *, moe: bool):
    def body(x, inp):
        lp, cl = inp
        x, new_c = _layer_decode(lp, cfg, x, cl, cache_len, dist, moe=moe)
        return x, new_c

    return jax.lax.scan(body, x, (stack_params, cache_stack))


def decode_step(params: Params, cfg, tokens, cache, dist=None):
    """One-token decode.  tokens: [B, 1].  Returns (logits, new_cache)."""
    x = L.embed(params["embed"], tokens)
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    cache_len = cache["len"]
    new_cache: Params = {"len": cache_len + 1}
    if cfg.num_experts:
        if "dense" in cache:
            x, nc = _decode_stack(params["dense_layers"], cfg, x, cache["dense"],
                                  cache_len, dist, moe=False)
            new_cache["dense"] = nc
        x, nc = _decode_stack(params["moe_layers"], cfg, x, cache["moe"],
                              cache_len, dist, moe=True)
        new_cache["moe"] = nc
    else:
        x, nc = _decode_stack(params["layers"], cfg, x, cache["layers"],
                              cache_len, dist, moe=False)
        new_cache["layers"] = nc
    h = L.norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("head"), params["embed"], h)
    return logits, new_cache


def prefill(params: Params, cfg, tokens, dist=None, prefix_embeds=None):
    """Prefill: logits + populated cache (cache max_len = prompt length)."""
    _, logits, _, kvs = forward(params, cfg, tokens, dist, prefix_embeds,
                                collect_kv=True)
    S = tokens.shape[1] + (prefix_embeds.shape[1] if prefix_embeds is not None else 0)
    cache: Params = {"len": jnp.asarray(S, jnp.int32)}
    for name, kv in kvs.items():
        key = {"layers": "layers", "dense": "dense", "moe": "moe"}[name]
        if kv is None:
            continue
        if cfg.attn_type == "mla":
            c_kv, k_rope = kv
            cache[key] = {"c_kv": c_kv, "k_rope": k_rope}
        else:
            k, v = kv
            cache[key] = {"k": k, "v": v}
    return logits, cache
