"""Mixture-of-Experts: dense reference path + expert-parallel (EP) path.

Paths:
  * ``moe_dense``  — computes every expert for every token, exact combine.
    O(E/topk) FLOP waste: used as the smoke/test oracle only.
  * ``moe_ep``     — production path inside ``jax.shard_map``: experts sharded
    over the `model` mesh axis (EP), expert weights additionally FSDP-sharded
    over `data` (gathered per layer, reduce-scattered on the backward pass).
    Dispatch is "gather mode": every model-group selects, from the local
    token set, the (token, expert) assignments routed to its experts with a
    fixed capacity, runs a grouped-GEMM over per-expert capacity buffers, and
    the groups' partial outputs are psum-combined.  For top-8 over 16 groups
    this moves the same bytes as a two-hop all-to-all while being drop-robust;
    an `alltoall` dispatch variant is evaluated in EXPERIMENTS.md §Perf.

Routing: softmax (DeepSeek-V2) or sigmoid+bias (DeepSeek-V3 aux-loss-free;
bias is a non-learned buffer, stop-gradient'd).  A load-balance auxiliary
metric is returned for telemetry either way.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import layers as L


def init_moe(key, cfg) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, e), jnp.float32, scale=0.006),
        "router_bias": jnp.zeros((e,), jnp.float32),
        "w_in": L.dense_init(ks[1], (e, d, f), dt),
        "w_gate": L.dense_init(ks[2], (e, d, f), dt),
        "w_out": L.dense_init(ks[3], (e, f, d), dt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5),
    }
    if cfg.num_shared_experts:
        import dataclasses
        shared_cfg = dataclasses.replace(cfg, d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
        p["shared"] = L.init_ffn(ks[4], shared_cfg)
    return p


def _route(p, cfg, xf):
    """xf: [T, D] -> (topk idx [T,k], combine weights [T,k], aux metrics)."""
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    k = cfg.experts_per_token
    if cfg.router_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + jax.lax.stop_gradient(p["router_bias"])   # bias only biases SELECTION
        _, idx = jax.lax.top_k(sel, k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance telemetry (Switch-style): E * sum_e f_e * p_e
    E = cfg.num_experts
    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    mean_p = (jax.nn.softmax(logits, -1) if cfg.router_fn == "sigmoid" else probs).mean(0)
    aux = E * jnp.sum(frac * mean_p)
    return idx, w, aux


def _expert_ffn(xb, w_in, w_gate, w_out):
    """xb: [E_loc, C, D] capacity buffers; weights [E_loc, D, F] / [E_loc, F, D]."""
    h = jnp.einsum("ecd,edf->ecf", xb, w_in)
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)


def moe_dense(p, cfg, x) -> tuple:
    """Reference: compute all experts densely, exact combine.  x: [B,S,D]."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    idx, w, aux = _route(p, cfg, xf)
    combine = jnp.zeros((xf.shape[0], cfg.num_experts), jnp.float32)
    combine = combine.at[jnp.arange(xf.shape[0])[:, None], idx].add(w)
    ys = _expert_ffn(jnp.broadcast_to(xf, (cfg.num_experts, *xf.shape)),
                     p["w_in"], p["w_gate"], p["w_out"])        # [E, T, D]
    out = jnp.einsum("te,etd->td", combine.astype(x.dtype), ys)
    if "shared" in p:
        out = out + L.ffn_block(p["shared"], cfg, x).reshape(-1, D)
    return out.reshape(B, S, D), aux


def _capacity(cfg, tokens: int) -> int:
    c = int(tokens * cfg.experts_per_token * cfg.capacity_factor / max(cfg.num_experts, 1))
    return max(8, -(-c // 8) * 8)   # round up to 8, floor 8 (decode shapes)


def moe_ep_local(p_local, cfg, x_loc, *, model_axis: str, fsdp_axis: Optional[str],
                 dp_axes: tuple = ()):
    """Body run per-device inside shard_map.

    x_loc: [b, s, D] local batch shard (replicated over `model_axis`).
    p_local: expert weights sharded [E_loc, ...] over model (+ FSDP on D dim).
    """
    n_groups = compat.axis_size(model_axis)
    g = jax.lax.axis_index(model_axis)
    E, k = cfg.num_experts, cfg.experts_per_token
    E_loc = E // n_groups
    b, s, D = x_loc.shape
    T = b * s
    xf = x_loc.reshape(T, D)

    w_in, w_gate, w_out = p_local["w_in"], p_local["w_gate"], p_local["w_out"]
    n_fsdp = compat.axis_size(fsdp_axis) if fsdp_axis is not None else 1
    C_cap = _capacity(cfg, T)
    F = w_in.shape[-1]
    mode = cfg.moe_fsdp
    if mode == "auto" and n_fsdp > 1:
        # weights gathered vs activations psum'd+gathered, bytes per layer:
        bytes_w = 3.0 * (E_loc * D * F) * 2
        bytes_a = 2.0 * 2.0 * E_loc * C_cap * F * 4 + E_loc * C_cap * D * 2
        mode = "partial" if bytes_a < bytes_w else "gather"
    if n_fsdp > 1 and mode != "partial":
        # ZeRO-3: gather this layer's expert weights just-in-time
        w_in = jax.lax.all_gather(w_in, fsdp_axis, axis=1, tiled=True)
        w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
        w_out = jax.lax.all_gather(w_out, fsdp_axis, axis=2, tiled=True)

    idx, wts, aux = _route(p_local, cfg, xf)                    # router replicated
    C = _capacity(cfg, T)

    # flatten assignments; keep only those routed to my expert group
    rid = jnp.repeat(jnp.arange(T), k)                          # [T*k]
    eid = idx.reshape(-1)
    wv = wts.reshape(-1)
    mine = (eid // E_loc) == g
    eloc = jnp.where(mine, eid % E_loc, E_loc)                  # sentinel E_loc = drop
    # position within expert via one-hot cumsum (stable, order-preserving)
    onehot = jax.nn.one_hot(eloc, E_loc, dtype=jnp.int32)       # [T*k, E_loc]
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_e = jnp.sum(pos * onehot, axis=1)                    # [T*k]
    valid = mine & (pos_in_e < C)
    # scatter token rows into per-expert capacity buffers (drop overflow)
    e_idx = jnp.where(valid, eloc, E_loc)                       # out-of-range -> dropped
    pos_c = jnp.where(valid, pos_in_e, 0)
    # slot->row index map (tiny int32), then ONE [E_loc, C, D] gather — never
    # materializes the [T*k, D] expanded copy of the token embeddings.
    slot_rid = jnp.full((E_loc + 1, C), T, jnp.int32)
    slot_rid = slot_rid.at[e_idx, pos_c].set(
        jnp.where(valid, rid, T), mode="drop")[: E_loc]
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)])
    buf = xf_pad[slot_rid]                                      # [E_loc, C, D]
    if n_fsdp > 1 and mode == "partial":
        # partial-contraction FSDP: contract each device's D-shard of the
        # expert weights against the matching slice of the rows, psum the
        # small [E_loc, C, F] activations, and all-gather the D-sharded
        # output — never materializes gathered weights (the decode-path
        # collective killer: activations << weights there).
        D_loc = D // n_fsdp
        f_idx = jax.lax.axis_index(fsdp_axis)
        buf_d = jax.lax.dynamic_slice_in_dim(buf, f_idx * D_loc, D_loc, axis=2)
        h = jnp.einsum("ecd,edf->ecf", buf_d, w_in)
        g = jnp.einsum("ecd,edf->ecf", buf_d, w_gate)
        hg = jax.lax.psum(jnp.stack([h, g]), fsdp_axis)
        act = jax.nn.silu(hg[1]) * hg[0]
        y_shard = jnp.einsum("ecf,efd->ecd", act.astype(buf.dtype), w_out)
        y = jax.lax.all_gather(y_shard, fsdp_axis, axis=2, tiled=True)
    else:
        y = _expert_ffn(buf, w_in, w_gate, w_out)               # [E_loc, C, D]
    # combine back: weight slots in place, scatter-add [E_loc*C, D] (not
    # [T*k, D]) — invalid slots carry rid=T and land on the dropped pad row.
    w_slot = jnp.zeros((E_loc + 1, C), jnp.float32)
    w_slot = w_slot.at[e_idx, pos_c].set(wv * valid, mode="drop")[: E_loc]
    y_w = y.astype(jnp.float32) * w_slot[..., None]
    out = jnp.zeros((T + 1, D), jnp.float32)
    out = out.at[slot_rid.reshape(-1)].add(y_w.reshape(-1, D), mode="drop")[:T]
    if "shared" in p_local:
        # shared expert: F dim TP-sharded over `model`; D dim FSDP-gathered.
        ps = p_local["shared"]
        if fsdp_axis is not None and compat.axis_size(fsdp_axis) > 1:
            ps = {"w_in": jax.lax.all_gather(ps["w_in"], fsdp_axis, axis=0, tiled=True),
                  "w_gate": jax.lax.all_gather(ps["w_gate"], fsdp_axis, axis=0, tiled=True),
                  "w_out": jax.lax.all_gather(ps["w_out"], fsdp_axis, axis=1, tiled=True)}
        out = out + L.ffn_block(ps, cfg, x_loc).reshape(T, D).astype(jnp.float32)
    out = jax.lax.psum(out.astype(jnp.dtype(cfg.moe_combine_dtype)), model_axis)
    aux = jax.lax.pmean(aux, axis_name=tuple(dp_axes) + (model_axis,))
    return out.reshape(b, s, D).astype(x_loc.dtype), aux


def moe_block(p, cfg, x, dist=None) -> tuple:
    """Dispatch to dense (no mesh) or EP (distributed) path.  Returns (y, aux)."""
    if dist is None or not dist.use_ep:
        return moe_dense(p, cfg, x)
    from jax.sharding import PartitionSpec as P
    dp, mdl, fsdp = dist.dp_axes, dist.model_axis, dist.fsdp_axis
    spec_x = P(dp, None, None)
    in_specs = (
        {
            "router": P(None, None),
            "router_bias": P(None),
            "w_in": P(mdl, fsdp, None),
            "w_gate": P(mdl, fsdp, None),
            "w_out": P(mdl, None, fsdp),
            **({"shared": {"w_in": P(fsdp, mdl), "w_gate": P(fsdp, mdl),
                           "w_out": P(mdl, fsdp)}} if "shared" in p else {}),
        },
        spec_x,
    )
    dp_tuple = dp if isinstance(dp, tuple) else (dp,)
    fn = functools.partial(moe_ep_local, cfg=cfg, model_axis=mdl, fsdp_axis=fsdp,
                           dp_axes=dp_tuple)
    y, aux = compat.shard_map(
        lambda pp, xx: fn(pp, x_loc=xx),
        mesh=dist.mesh,
        in_specs=in_specs,
        out_specs=(spec_x, P()),
        check_vma=False,
    )(p, x)
    return y, aux
