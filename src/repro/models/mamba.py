"""Mamba2 LM (pure SSM, attention-free)."""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssd
from repro.models.sharding import shard_act
from repro.models.transformer import _remat

Params = Dict[str, Any]


def init_params(key, cfg) -> Params:
    ks = jax.random.split(key, 3)

    def one(k):
        k1, k2 = jax.random.split(k)
        return {"ln": L.init_rmsnorm(cfg.d_model), "mix": ssd.init_mamba_block(k2, cfg)}

    p = {
        "embed": L.init_embed(ks[0], cfg),
        "layers": jax.vmap(one)(jax.random.split(ks[1], cfg.num_layers)),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = {"head_w": L.dense_init(ks[2], (cfg.d_model, cfg.vocab_size),
                                            L.dtype_of(cfg))}
    return p


def forward(params: Params, cfg, tokens, dist=None, collect_cache: bool = False):
    x = L.embed(params["embed"], tokens)
    if dist is not None:
        x = shard_act(x, dist, dist.dp, None, None)

    def body(x, lp):
        out = ssd.mamba_block(lp["mix"], cfg, L.norm(lp["ln"], x, cfg.norm_eps),
                              return_cache=collect_cache)
        if collect_cache:
            dx, cache_l = out
        else:
            dx, cache_l = out, None
        x = x + dx
        if dist is not None:
            x = shard_act(x, dist, dist.dp, None, None)
        return x, cache_l

    x, caches = jax.lax.scan(_remat(body, cfg), x, params["layers"])
    h = L.norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("head"), params["embed"], h)
    return h, logits, caches


def loss_fn(params: Params, cfg, tokens, labels, dist=None):
    _, logits, _ = forward(params, cfg, tokens, dist)
    loss = L.cross_entropy(logits[:, :-1], labels[:, 1:])
    return loss, {"nll": loss}


def init_cache(cfg, batch: int, max_len: int) -> Params:
    del max_len  # constant-size recurrent state: the SSM long-context win
    return {"len": jnp.zeros((), jnp.int32),
            "ssm": ssd.init_ssm_cache(cfg, batch, cfg.num_layers)}


def decode_step(params: Params, cfg, tokens, cache, dist=None):
    x = L.embed(params["embed"], tokens)

    def body(x, inp):
        lp, cl = inp
        dx, new_c = ssd.mamba_decode(lp["mix"], cfg, L.norm(lp["ln"], x, cfg.norm_eps), cl)
        return x + dx, new_c

    x, new_ssm = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
    h = L.norm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("head"), params["embed"], h)
    return logits, {"len": cache["len"] + 1, "ssm": new_ssm}


def prefill(params: Params, cfg, tokens, dist=None):
    """SSM prefill: chunked scan; the per-layer final recurrent state and conv
    tail come out of the same pass (exact, no replay)."""
    _, logits, caches = forward(params, cfg, tokens, dist, collect_cache=True)
    conv_tail, final_state = caches
    cache = {"len": jnp.asarray(tokens.shape[1], jnp.int32),
             "ssm": {"conv": conv_tail, "state": final_state}}
    return logits, cache
