"""Mamba2 SSD (state-space duality) blocks.

Chunked SSD algorithm (arXiv:2405.21060 §6): sequence split into chunks of
length Q; within-chunk outputs are a masked matmul (MXU-friendly — this is the
"duality"), cross-chunk influence flows through a per-chunk recurrent state
carried by ``lax.scan``.  Matches ``repro.kernels.ssd_scan`` (Pallas) and is
its oracle.

Projections are split (z / x / B / C / dt) so the inner channels shard
head-aligned over the TP axis when divisible (zamba2: 64 heads / 16-way TP;
mamba2-130m's 24 heads replicate — recorded in the roofline notes).

Decode is the O(1) recurrent update: state <- state*exp(dt*A) + dt*B⊗x.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_mamba_block(key, cfg) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    ng, ds, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    cw = cfg.ssm_conv_width
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 8)
    return {
        "in_z": L.dense_init(ks[0], (d, di), dt),
        "in_x": L.dense_init(ks[1], (d, di), dt),
        "in_b": L.dense_init(ks[2], (d, ng * ds), dt),
        "in_c": L.dense_init(ks[3], (d, ng * ds), dt),
        "in_dt": L.dense_init(ks[4], (d, nh), dt),
        "conv_w": L.dense_init(ks[5], (cw, di), dt, scale=0.2),
        "conv_b": jnp.zeros((di,), dt),
        "conv_bc_w": L.dense_init(ks[6], (cw, 2 * ng * ds), dt, scale=0.2),
        "conv_bc_b": jnp.zeros((2 * ng * ds,), dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": L.init_rmsnorm(di),
        "out_proj": L.dense_init(ks[7], (di, d), dt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5),
    }


def _project_in(p, x):
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"])
    bc = jnp.concatenate([jnp.einsum("bsd,de->bse", x, p["in_b"]),
                          jnp.einsum("bsd,de->bse", x, p["in_c"])], axis=-1)
    dt_raw = jnp.einsum("bsd,de->bse", x, p["in_dt"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    return z, xs, bc, dt


def _causal_conv(xin, w, b):
    """Depthwise causal conv1d.  xin: [B, S, C]; w: [cw, C]."""
    cw = w.shape[0]
    pad = jnp.pad(xin, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xin.shape[1]] * w[i] for i in range(cw))
    return jax.nn.silu(out + b)


def _segsum(x):
    """Log-space segment sums: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i>=j)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x: [b, S, nh, hp]; dt: [b, S, nh]; A: [nh] (negative);
    B, C: [b, S, ng, ds].  Returns y [b, S, nh, hp] (fp32).
    """
    b, S, nh, hp = x.shape
    ng, ds = B.shape[-2], B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} % chunk {Q} != 0"
    nc = S // Q
    rep = nh // ng

    xc = x.reshape(b, nc, Q, nh, hp).astype(jnp.float32)
    dtc = dt.reshape(b, nc, Q, nh).astype(jnp.float32)
    Bc = B.reshape(b, nc, Q, ng, ds).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, ng, ds).astype(jnp.float32)
    dA = dtc * A                                         # [b, nc, Q, nh]
    dA_cum = jnp.cumsum(dA, axis=2)

    # --- within-chunk (diagonal block): masked matmul — the "dual" form
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))    # [b, nc, nh, Q, Q]
    CB = jnp.einsum("bcqgs,bckgs->bcgqk", Cc, Bc)        # [b, nc, ng, Q, Q]
    CB = jnp.repeat(CB, rep, axis=2)                     # -> per-head
    xdt = xc * dtc[..., None]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", CB * Lmat, xdt)

    # --- per-chunk end states: S_c = sum_q (B_q * decay_to_end_q) ⊗ (x*dt)_q
    decay_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # [b, nc, Q, nh]
    Bh = jnp.repeat(Bc, rep, axis=3)                     # [b, nc, Q, nh, ds]
    states = jnp.einsum("bcqhs,bcqhp->bchps", Bh * decay_end[..., None], xdt)

    # --- cross-chunk recurrence (lax.scan over chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])           # [b, nc, nh]

    def scan_fn(carry, inp):
        st, dec = inp
        return carry * dec[..., None, None] + st, carry  # emit PREVIOUS state

    init = jnp.zeros((b, nh, hp, ds), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # [b, nc, nh, hp, ds]

    # --- off-diagonal: prior state flowing into this chunk
    decay_in = jnp.exp(dA_cum)
    Ch = jnp.repeat(Cc, rep, axis=3)
    y_off = jnp.einsum("bcqhs,bchps->bcqhp", Ch * decay_in[..., None], prev_states)

    return (y_diag + y_off).reshape(b, S, nh, hp), final_state


def mamba_block(p, cfg, x, return_cache: bool = False):
    """Full-sequence Mamba2 block.  x: [B, S, D]."""
    di, ng, ds, nh, hp = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                          cfg.ssm_nheads, cfg.ssm_headdim)
    B_, S, _ = x.shape
    z, xs_raw, bc_raw, dt = _project_in(p, x)
    xs = _causal_conv(xs_raw, p["conv_w"], p["conv_b"])
    bc = _causal_conv(bc_raw, p["conv_bc_w"], p["conv_bc_b"])
    Bm = bc[..., : ng * ds].reshape(B_, S, ng, ds)
    Cm = bc[..., ng * ds:].reshape(B_, S, ng, ds)
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(xs.reshape(B_, S, nh, hp), dt, A, Bm, Cm,
                                 cfg.ssm_chunk)
    y = y + p["D"][:, None] * xs.reshape(B_, S, nh, hp).astype(jnp.float32)
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = L.rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_cache:
        cw = cfg.ssm_conv_width
        conv_tail = jnp.concatenate([xs_raw, bc_raw], axis=-1)[:, S - (cw - 1):]
        return out, (conv_tail, final_state)
    return out


def init_ssm_cache(cfg, batch: int, num_layers: int) -> dict:
    ch = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jnp.zeros((num_layers, batch, cfg.ssm_conv_width - 1, ch),
                          L.dtype_of(cfg)),
        "state": jnp.zeros((num_layers, batch, cfg.ssm_nheads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32),
    }


def mamba_decode(p, cfg, x, cache) -> tuple:
    """Single-token recurrent step.  x: [B, 1, D]; cache {"conv","state"} (layer slice)."""
    di, ng, ds, nh, hp = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                          cfg.ssm_nheads, cfg.ssm_headdim)
    B_, _, D = x.shape
    z, xs, bc, dt = _project_in(p, x)
    xbc = jnp.concatenate([xs, bc], axis=-1)              # [B, 1, di+2ngds]
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # [B, cw, C]
    new_conv = hist[:, 1:]
    w_all = jnp.concatenate([p["conv_w"], p["conv_bc_w"]], axis=1)
    b_all = jnp.concatenate([p["conv_b"], p["conv_bc_b"]])
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w_all) + b_all)
    xv = conv[:, :di].reshape(B_, nh, hp).astype(jnp.float32)
    Bv = conv[:, di: di + ng * ds].reshape(B_, ng, ds).astype(jnp.float32)
    Cv = conv[:, di + ng * ds:].reshape(B_, ng, ds).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dtv = dt[:, 0]                                        # [B, nh]
    rep = nh // ng
    Bh = jnp.repeat(Bv, rep, axis=1)
    Ch = jnp.repeat(Cv, rep, axis=1)
    decay = jnp.exp(dtv * A)
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bhs,bhp->bhps", Bh * dtv[..., None], xv)
    y = jnp.einsum("bhs,bhps->bhp", Ch, state)
    y = y + p["D"][:, None] * xv
    y = y.reshape(B_, 1, di).astype(x.dtype)
    y = L.rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), \
        {"conv": new_conv, "state": state}
