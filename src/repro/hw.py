"""Accelerator hardware specification registry — the DSE space.

The paper explores "which GPGPU at which DVFS frequency" for CNN inference.
TPU-native adaptation: the design space is (TPU generation, chips, mesh shape,
core frequency).  Frequency scaling follows the paper's DVFS study ([5], V100S
397-1590 MHz): peak FLOP/s scales linearly with f, dynamic power scales ~f^3
(CMOS P_dyn = C V^2 f with V roughly proportional to f in the DVFS band).

All numbers below are per-chip.  v5e numbers are the roofline constants
mandated for this repro: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip hardware specification (one point in the accelerator space)."""

    name: str
    peak_flops_bf16: float      # FLOP/s at nominal frequency
    hbm_bw: float               # bytes/s
    hbm_bytes: float            # HBM capacity, bytes
    ici_bw: float               # bytes/s per link
    ici_links: int              # links per chip (torus degree)
    nominal_freq_mhz: float     # frequency at which peak_flops holds
    min_freq_mhz: float
    max_freq_mhz: float
    tdp_watts: float            # max board power
    idle_watts: float           # static/idle power
    vmem_bytes: float           # on-chip vector memory
    mxu_dim: int = 128          # systolic array tile edge
    ici_links_per_axis: int = 2  # usable links per mesh axis (2 = torus
                                 # wraparound, both ring directions; 0 = none)
    ici_hop_s: float = 1e-6     # per-hop ICI latency (one ring step), seconds

    def at_frequency(self, freq_mhz: float) -> "ChipSpec":
        """Return a derated/overclocked view of this chip at ``freq_mhz``.

        Compute scales linearly with f; HBM/ICI are on separate clock domains
        and held constant (matching observed V100S DVFS behaviour where memory
        bandwidth is flat across the core-clock sweep).
        """
        freq_mhz = float(min(max(freq_mhz, self.min_freq_mhz), self.max_freq_mhz))
        s = freq_mhz / self.nominal_freq_mhz
        return dataclasses.replace(
            self,
            peak_flops_bf16=self.peak_flops_bf16 * s,
            nominal_freq_mhz=freq_mhz,
        )

    def dynamic_power(self, freq_mhz: float, utilization: float) -> float:
        """CMOS dynamic power at (freq, utilization), watts.

        P = P_idle + (TDP - P_idle) * util * (f/f_max)^3, capped at TDP.
        The cubic term models V~f scaling in the DVFS band (paper ref [5]).
        """
        f = min(max(freq_mhz, self.min_freq_mhz), self.max_freq_mhz)
        u = min(max(utilization, 0.0), 1.0)
        p = self.idle_watts + (self.tdp_watts - self.idle_watts) * u * (f / self.max_freq_mhz) ** 3
        return min(p, self.tdp_watts)


# --- Registry -----------------------------------------------------------------
# v5e constants are the graded roofline constants.  v5p / v4 / v5e-derated
# entries populate the DSE space (the paper's "different GPGPUs").

CHIPS: Dict[str, ChipSpec] = {
    "tpu-v5e": ChipSpec(
        name="tpu-v5e",
        peak_flops_bf16=197e12,
        hbm_bw=819e9,
        hbm_bytes=16e9,
        ici_bw=50e9,
        ici_links=4,
        nominal_freq_mhz=1600.0,
        min_freq_mhz=400.0,
        max_freq_mhz=1600.0,
        tdp_watts=220.0,
        idle_watts=55.0,
        vmem_bytes=128e6,
    ),
    "tpu-v5p": ChipSpec(
        name="tpu-v5p",
        peak_flops_bf16=459e12,
        hbm_bw=2765e9,
        hbm_bytes=95e9,
        ici_bw=100e9,
        ici_links=6,
        nominal_freq_mhz=1750.0,
        min_freq_mhz=500.0,
        max_freq_mhz=1750.0,
        tdp_watts=350.0,
        idle_watts=85.0,
        vmem_bytes=128e6,
    ),
    "tpu-v4": ChipSpec(
        name="tpu-v4",
        peak_flops_bf16=275e12,
        hbm_bw=1228e9,
        hbm_bytes=32e9,
        ici_bw=50e9,
        ici_links=6,
        nominal_freq_mhz=1050.0,
        min_freq_mhz=400.0,
        max_freq_mhz=1050.0,
        tdp_watts=262.0,
        idle_watts=70.0,
        vmem_bytes=128e6,
    ),
    # Edge-class part: the paper's IoT/edge motivation (Jetson TX1 analogue).
    "tpu-edge": ChipSpec(
        name="tpu-edge",
        peak_flops_bf16=8e12,
        hbm_bw=68e9,
        hbm_bytes=8e9,
        ici_bw=0.0,
        ici_links=0,
        nominal_freq_mhz=950.0,
        min_freq_mhz=250.0,
        max_freq_mhz=950.0,
        tdp_watts=15.0,
        idle_watts=2.5,
        vmem_bytes=16e6,
        ici_links_per_axis=0,    # edge-class: no inter-chip links at all
        ici_hop_s=0.0,
    ),
}

DEFAULT_CHIP = "tpu-v5e"


# --- Struct-of-arrays chip table ---------------------------------------------
# Batched DSE evaluates thousands of candidates per call; chip lookup must be
# an array gather (table.field[chip_idx]), not a dict hit per candidate.

_TABLE_FIELDS = ("peak_flops_bf16", "hbm_bw", "hbm_bytes", "ici_bw",
                 "ici_links", "nominal_freq_mhz", "min_freq_mhz",
                 "max_freq_mhz", "tdp_watts", "idle_watts", "vmem_bytes",
                 "mxu_dim", "ici_links_per_axis", "ici_hop_s")


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: ndarray fields
class ChipTable:
    """``CHIPS`` packed field-per-array (float64), indexed by chip id."""

    names: Tuple[str, ...]
    specs: Tuple[ChipSpec, ...]
    peak_flops_bf16: np.ndarray
    hbm_bw: np.ndarray
    hbm_bytes: np.ndarray
    ici_bw: np.ndarray
    ici_links: np.ndarray
    nominal_freq_mhz: np.ndarray
    min_freq_mhz: np.ndarray
    max_freq_mhz: np.ndarray
    tdp_watts: np.ndarray
    idle_watts: np.ndarray
    vmem_bytes: np.ndarray
    mxu_dim: np.ndarray
    ici_links_per_axis: np.ndarray
    ici_hop_s: np.ndarray

    @classmethod
    def from_chips(cls, chips: Dict[str, ChipSpec]) -> "ChipTable":
        names = tuple(chips)
        cols = {f: np.asarray([getattr(chips[n], f) for n in names], np.float64)
                for f in _TABLE_FIELDS}
        return cls(names=names, specs=tuple(chips[n] for n in names), **cols)

    def __len__(self) -> int:
        return len(self.names)

    def index(self, name: str) -> int:
        return self.names.index(name)

    def indices(self, names) -> np.ndarray:
        lut = {n: i for i, n in enumerate(self.names)}
        return np.asarray([lut[n] for n in names], np.int32)

    def spec(self, idx: int) -> ChipSpec:
        return self.specs[int(idx)]

    def gather(self, chip_idx) -> Dict[str, np.ndarray]:
        """All columns gathered at ``chip_idx`` — precompute once per
        candidate batch so repeated sweeps skip the per-call fancy-indexing."""
        idx = np.asarray(chip_idx)
        return {f: getattr(self, f)[idx] for f in _TABLE_FIELDS}


CHIP_TABLE = ChipTable.from_chips(CHIPS)


def chip_index(name: str = DEFAULT_CHIP) -> int:
    return CHIP_TABLE.index(name)


def get_chip(name: str = DEFAULT_CHIP, freq_mhz: float | None = None) -> ChipSpec:
    spec = CHIPS[name]
    if freq_mhz is not None:
        spec = spec.at_frequency(freq_mhz)
    return spec


def frequency_lattice(lo: float, hi: float, points: int) -> list:
    """``points`` DVFS values in [lo, hi] with EXACT endpoints.

    The naive ``lo + i*(hi-lo)/(points-1)`` formula can drift past ``hi`` by
    an ulp at the last point (e.g. 1600.0000000000002 MHz), which made swept
    lattices platform-dependent after clamping; the interior keeps that
    formula (so existing sweeps are unchanged) but both endpoints are pinned
    to the band bounds.  ``points == 1`` collapses to the nominal top of the
    band rather than dividing by zero.
    """
    if points <= 1:
        return [float(hi)]
    vals = [lo + i * (hi - lo) / (points - 1) for i in range(points)]
    vals[0], vals[-1] = float(lo), float(hi)
    return vals


def frequency_sweep(name: str = DEFAULT_CHIP, points: int = 12) -> list:
    """DVFS sweep analogous to the paper's 397-1590 MHz V100S sweep."""
    spec = CHIPS[name]
    return frequency_lattice(spec.min_freq_mhz, spec.max_freq_mhz, points)


# --- Topology / link model ----------------------------------------------------
# The collective-time model is topology-aware: a mesh axis of extent k forms a
# bidirectional ring.  Axes with extent >= 3 close the ring with a torus
# wraparound link (both directions usable -> 2 links per axis); extent-2 axes
# are a line (the wrap link would parallel the direct link -> 1 link); and the
# chip's total link budget caps what concurrent axes can use, so e.g. a 3D
# mesh on a 4-link v5e degrades to 1 link/axis while a 6-link v5p keeps 2.
# Edge-class chips (``ici_links_per_axis == 0``) have no usable axis links.
# Everything here is written against a numpy-compatible array namespace ``xp``
# so the scalar simulator, ``simulate_batch`` and its jit variant share the
# exact same arithmetic.


def normalize_mesh(mesh) -> Tuple[int, int, int]:
    """A mesh tuple -> (pod, data, model) axis extents.

    The trailing two extents are the (data, model) axes (matching
    ``features.extract``'s reading of ``mesh_shape``); any leading extents
    collapse into a single pod axis.  1D meshes are (1, 1, model)."""
    mesh = tuple(int(m) for m in mesh)
    if not mesh or any(m < 1 for m in mesh):
        raise ValueError(f"mesh extents must be >= 1, got {mesh}")
    model = mesh[-1]
    data = mesh[-2] if len(mesh) >= 2 else 1
    pod = 1
    for m in mesh[:-2]:
        pod *= m
    return pod, data, model


def axis_link_counts(mesh_pod, mesh_data, mesh_model, ici_links,
                     links_per_axis, xp=np):
    """Usable links per (pod, data, model) axis, vectorized over candidates.

    want(k) = 2 for a torus ring (k >= 3), 1 for a 2-chip line, 0 for an
    inactive axis; the per-axis budget ``ici_links // n_active_axes`` (floored
    at 1) models sharing the chip's link complement across concurrently
    active axes.  All-float arithmetic so numpy float64 and jax float32
    agree elementwise with the scalar path."""
    kp = xp.asarray(mesh_pod) * 1.0
    kd = xp.asarray(mesh_data) * 1.0
    km = xp.asarray(mesh_model) * 1.0
    per_axis = xp.asarray(links_per_axis) * 1.0
    total = xp.asarray(ici_links) * 1.0
    n_active = ((kp > 1) * 1.0 + (kd > 1) * 1.0 + (km > 1) * 1.0)
    budget = xp.maximum(xp.floor(total / xp.maximum(n_active, 1.0)), 1.0)

    def links(k):
        want = xp.where(k >= 3, 2.0, xp.where(k >= 2, 1.0, 0.0))
        return xp.minimum(xp.minimum(want, per_axis), budget)

    return links(kp), links(kd), links(km)


@dataclasses.dataclass(frozen=True)
class Topology:
    """Per-axis interconnect view of one mesh on one chip.

    ``links[i]`` is the usable link count of axis i under the chip's budget,
    ``wraparound[i]`` whether the axis closes into a torus ring, ``hops[i]``
    the worst-case hop count (ring diameter) along the axis."""

    chip: str
    mesh: Tuple[int, ...]
    links: Tuple[int, ...]
    wraparound: Tuple[bool, ...]
    hops: Tuple[int, ...]

    @property
    def n_chips(self) -> int:
        n = 1
        for m in self.mesh:
            n *= m
        return n


def topology_for(chip: ChipSpec, mesh) -> Topology:
    """The ``Topology`` of ``mesh`` on ``chip`` (scalar view of the link
    model the batched simulators apply via ``axis_link_counts``)."""
    pod, data, model = normalize_mesh(mesh)
    lp, ld, lm = axis_link_counts(pod, data, model, chip.ici_links,
                                  chip.ici_links_per_axis)
    links, wraps, hops = [], [], []
    for k, l in zip((pod, data, model), (lp, ld, lm)):
        wrap = k >= 3 and chip.ici_links_per_axis >= 2
        links.append(int(l))
        wraps.append(bool(wrap))
        hops.append(0 if k <= 1 else (k // 2 if wrap else k - 1))
    return Topology(chip=chip.name, mesh=(pod, data, model),
                    links=tuple(links), wraparound=tuple(wraps),
                    hops=tuple(hops))


def mesh_factorizations(n_chips: int, dims: int = 2) -> Tuple[Tuple[int, ...], ...]:
    """All nondecreasing mesh factorizations of ``n_chips`` into 2 (or 3) axes.

    The campaign design space sweeps every way to arrange a slice of
    ``n_chips`` chips as a (data, model) 2D mesh — or (pod, data, model) with
    ``dims=3`` — rather than the handful of hand-picked meshes in
    ``dse.default_space``.  Factors are sorted nondecreasing so each physical
    arrangement appears once; 3D meshes require a real pod dimension (leading
    factor >= 2) since a leading-1 3D mesh is the 2D mesh already listed.
    Results are deterministic and sorted.
    """
    if n_chips < 1:
        raise ValueError(f"n_chips must be >= 1, got {n_chips}")
    out = set()
    for a in range(1, int(n_chips ** 0.5) + 1):
        if n_chips % a:
            continue
        out.add((a, n_chips // a))
    if dims >= 3:
        for a in range(2, int(n_chips ** (1 / 3)) + 2):
            if n_chips % a:
                continue
            rem = n_chips // a
            for b in range(a, int(rem ** 0.5) + 1):
                if rem % b == 0:
                    out.add((a, b, rem // b))
    return tuple(sorted(out, key=lambda m: (len(m), m)))
