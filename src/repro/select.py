"""The accelerator-selection facade: ``repro.select``.

The documented import surface for serving selection queries — everything a
client needs to build, persist, load and query a selection service:

    from repro import select

    index = select.FrontierIndex.from_checkpoint("campaign.ckpt.json")
    index.save("frontier_index.json")

    engine = select.SelectionEngine(select.FrontierIndex.load(
        "frontier_index.json"))
    answer = engine.select(workload)          # -> SelectionAnswer
    answer.provenance                         # one of select.PROVENANCES
    answer.choices[0].candidate               # best accelerator config

The implementation lives in ``repro.serving`` (the engine) and
``repro.dse_campaign`` (the campaign stack the index is built from); this
module only re-exports the stable names.  See ``docs/serving.md`` for the
query flow and the index build/refresh runbook.
"""

from repro.dse_campaign.config import CampaignConfig
from repro.serving.engine import (PROVENANCES, RankedChoice, SelectionAnswer,
                                  SelectionEngine, SelectionQuery)
from repro.serving.frontier_index import (INDEX_SCHEMA_VERSION, FrontierIndex,
                                          IndexEntry, family_key)

__all__ = [
    "CampaignConfig", "FrontierIndex", "INDEX_SCHEMA_VERSION", "IndexEntry",
    "PROVENANCES", "RankedChoice", "SelectionAnswer", "SelectionEngine",
    "SelectionQuery", "family_key",
]
