"""Span tracer: nested timing spans with a Chrome ``trace_event`` exporter.

``SpanTracer.span("tile_eval", tile=7)`` is a context manager that records
one ``SpanRecord`` — name, span/parent ids, nesting depth, thread id,
monotonic start/end from the injected clock, a wall-clock anchor, and the
keyword attributes.  Records land in a bounded ring buffer (a deque), so a
week-long campaign traces its most recent window instead of growing without
bound.

Two hard rules the instrumented call sites follow:

* spans wrap HOST code only — a span may surround a ``pallas_call`` or
  jitted dispatch, but tracing never happens inside traced/compiled code
  (there is no clock in there, and a retrace would perturb the thing being
  measured);
* a span is a *reading*: nothing downstream may branch on span contents
  (the frontier identity gates stay bitwise with tracing on or off).

``chrome_trace()`` renders the buffer as Chrome ``trace_event`` JSON
(complete ``"X"`` events + ``"M"`` metadata), so a sweep's trace opens
directly in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``;
``tools/trace_report.py`` summarizes and validates the same file in CI.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import json
import os
import threading
import time
from typing import Callable, Dict, List, Optional

# process-wide span id sequence: ids stay unique when several tracers run
# in one process (campaign + coordinator + tests), which the trace-report
# nesting check relies on after traces are merged
_SPAN_IDS = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span (perf timestamps are the tracer clock's).

    Materialized lazily by ``SpanTracer.records`` — the hot path appends a
    plain tuple to the ring; ``wall_t0`` is derived from the tracer's wall
    anchor (``wall_epoch + (t0 - epoch)``), never a per-span syscall.
    """

    name: str
    sid: int
    parent: int            # enclosing span's sid on this thread, -1 if root
    depth: int             # nesting depth on this thread (0 = root)
    thread_id: int
    t0: float              # injected-clock start
    t1: float              # injected-clock end
    wall_t0: float         # wall-clock anchor of t0
    attrs: Dict

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _Span:
    """The live context manager; lands in the ring as a tuple on exit.

    The exit path is the instrumented sweep's per-tile cost, so it stays
    allocation-light: one tuple append onto a deque (GIL-atomic, no lock)
    and two injected-clock reads — the <2% overhead gate in
    ``benchmarks/dse_campaign.py`` rides on this."""

    __slots__ = ("tracer", "name", "attrs", "sid", "parent", "depth", "t0")

    def __init__(self, tracer: "SpanTracer", name: str, attrs: Dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "_Span":
        tracer = self.tracer
        stack = tracer._stack()
        self.sid = next(_SPAN_IDS)
        self.parent = stack[-1].sid if stack else -1
        self.depth = len(stack)
        stack.append(self)
        self.t0 = tracer.clock()            # last: exclude setup from dur
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tracer = self.tracer
        t1 = tracer.clock()
        stack = tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        tracer._buf.append((self.name, self.sid, self.parent, self.depth,
                            threading.get_ident(), self.t0, t1, self.attrs))
        return False


class SpanTracer:
    """Thread-aware span recorder over an injected clock.

    Nesting is tracked per thread (a prefetcher-thread span is a root on
    its own thread, not a child of whatever the main thread is doing);
    the ring buffer is shared — deque appends are GIL-atomic, so no lock
    sits on the span exit path — and one export sees every thread's spans.
    ``capacity`` bounds retained spans: eviction drops the OLDEST records,
    keeping the most recent window.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 wall_clock: Callable[[], float] = time.time,
                 capacity: int = 65536):
        self.clock = clock
        self.wall_clock = wall_clock
        self.capacity = int(capacity)
        self.epoch = clock()                # ts origin for chrome export
        self.wall_epoch = wall_clock()      # wall anchor of the epoch
        self._buf = collections.deque(maxlen=self.capacity)
        self._local = threading.local()

    def _stack(self) -> List[_Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, **attrs) -> _Span:
        """A context manager timing one named span (attrs are free-form
        JSON-safe scalars: tile index, worker id, evaluator tier...)."""
        return _Span(self, name, attrs)

    @property
    def records(self) -> List[SpanRecord]:
        """Snapshot copy of the retained spans as ``SpanRecord``s, oldest
        first (``list(deque)`` is atomic under the GIL while writers
        append)."""
        epoch, wall_epoch = self.epoch, self.wall_epoch
        return [SpanRecord(name, sid, parent, depth, tid, t0, t1,
                           wall_epoch + (t0 - epoch), attrs)
                for name, sid, parent, depth, tid, t0, t1, attrs
                in list(self._buf)]

    def clear(self) -> None:
        self._buf.clear()

    # -- Chrome trace_event export ------------------------------------------

    def chrome_trace(self, process_name: str = "repro-campaign") -> Dict:
        """The buffer as Chrome ``trace_event`` JSON (the object form).

        Complete events (``"ph": "X"``) carry microsecond ``ts`` relative
        to the tracer's epoch and ``dur``; span/parent ids, depth and the
        user attrs ride in ``args`` (``tools/trace_report.py`` validates
        nesting from them).  Open the written file in Perfetto or
        ``chrome://tracing`` as-is.
        """
        pid = os.getpid()
        records = self.records
        events = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": process_name},
        }]
        for r in sorted(records, key=lambda r: (r.t0, r.sid)):
            events.append({
                "name": r.name, "cat": "repro", "ph": "X", "pid": pid,
                "tid": r.thread_id,
                "ts": (r.t0 - self.epoch) * 1e6,
                "dur": r.dur * 1e6,
                "args": {**r.attrs, "sid": r.sid, "parent": r.parent,
                         "depth": r.depth},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"epoch_wall_s": None if not records
                              else records[0].wall_t0}}

    def export(self, path: str, process_name: str = "repro-campaign") -> str:
        """Write ``chrome_trace()`` to ``path``; returns the path."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(process_name), f, indent=1)
        return path


class _NullSpan:
    """The shared do-nothing span — one instance for the whole process, so
    the disabled tracing path allocates nothing per call."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracer that records nothing (the ``NullTelemetry`` default).  Its
    ``span()`` returns the process-wide ``NULL_SPAN`` singleton; the only
    per-call cost left is the caller's argument evaluation."""

    capacity = 0
    records: List[SpanRecord] = []

    def span(self, name: str = "", **attrs) -> _NullSpan:
        return NULL_SPAN

    def clear(self) -> None:
        pass

    def chrome_trace(self, process_name: str = "repro-campaign") -> Dict:
        return {"traceEvents": [], "displayTimeUnit": "ms", "otherData": {}}


NULL_TRACER = NullTracer()
