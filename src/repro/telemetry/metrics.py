"""Metrics registry: labeled Counter / Gauge / Histogram series.

The registry is the campaign stack's one source of runtime counters — the
evaluator's ``fused_launches``, the fabric's delivery/duplicate/lease
ledgers, the serving engine's per-path latency distributions all live here
as named, labeled series instead of ad-hoc instance attributes.  Design
rules, in the order they matter:

* **instrumented values never feed computation** — a metric is a reading,
  not an input; the frontier identity gates stay bitwise whether or not
  anything reads the registry (``tests/test_telemetry.py`` pins this);
* **the clock is injected** — every series stamps ``updated_at`` from the
  registry's ``clock`` (default ``time.perf_counter``), so a ``FakeClock``
  (``repro.dse_campaign.fabric.FakeClock``) makes readings fully
  deterministic in tests;
* **snapshots are plain JSON** — ``MetricsRegistry.snapshot()`` returns a
  dict that drops straight into the ``BENCH_*.json`` artifacts and the
  fabric's worker->coordinator wire messages (it must pickle cheaply);
* **hot-path cost is one dict hit** — ``counter()/gauge()/histogram()``
  return the (cached) series object; instrumented code holds the series and
  calls ``inc``/``set``/``observe``, which are O(1) scalar ops.

Histogram quantiles follow ``numpy.percentile``'s default linear
interpolation exactly (the test oracle); samples live in a bounded ring so
a long campaign cannot grow memory, while ``count``/``sum`` keep the exact
totals across evictions.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

LabelItems = Tuple[Tuple[str, str], ...]


def _label_items(labels: Dict[str, object]) -> LabelItems:
    """Normalized, hashable label set (values stringified, keys sorted)."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing numeric series (int or float increments)."""

    __slots__ = ("name", "labels", "_clock", "_value", "updated_at")

    def __init__(self, name: str, labels: LabelItems, clock):
        self.name = name
        self.labels = labels
        self._clock = clock
        self._value = 0.0
        self.updated_at: Optional[float] = None

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        self._value += n
        self.updated_at = self._clock()

    @property
    def value(self) -> float:
        return self._value

    def as_dict(self) -> Dict:
        return {"name": self.name, "labels": dict(self.labels),
                "value": self._value, "updated_at": self.updated_at}


class Gauge:
    """Last-written value series (``None`` until first ``set``/``add``)."""

    __slots__ = ("name", "labels", "_clock", "_value", "updated_at")

    def __init__(self, name: str, labels: LabelItems, clock):
        self.name = name
        self.labels = labels
        self._clock = clock
        self._value: Optional[float] = None
        self.updated_at: Optional[float] = None

    def set(self, v: float) -> None:
        self._value = float(v)
        self.updated_at = self._clock()

    def add(self, dv: float) -> None:
        """Accumulate onto the gauge (starting from 0.0 when unset) — the
        per-worker busy-time gauges are running totals, not last-values."""
        self._value = (self._value or 0.0) + float(dv)
        self.updated_at = self._clock()

    @property
    def value(self) -> Optional[float]:
        return self._value

    def as_dict(self) -> Dict:
        return {"name": self.name, "labels": dict(self.labels),
                "value": self._value, "updated_at": self.updated_at}


class Histogram:
    """Sample distribution with exact totals and windowed quantiles.

    ``count``/``sum``/``min``/``max`` are exact over every observation;
    quantiles are computed over the most recent ``max_samples`` (bounded
    ring — a mega-campaign cannot grow the registry without bound) with
    ``numpy.percentile``'s default linear interpolation, which is the
    oracle ``tests/test_telemetry.py`` checks against.
    """

    __slots__ = ("name", "labels", "_clock", "_samples", "count", "sum",
                 "min", "max", "updated_at")

    def __init__(self, name: str, labels: LabelItems, clock,
                 max_samples: int = 8192):
        self.name = name
        self.labels = labels
        self._clock = clock
        self._samples = collections.deque(maxlen=int(max_samples))
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.updated_at: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        self._samples.append(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self.updated_at = self._clock()

    def quantile(self, q: float) -> Optional[float]:
        """Linear-interpolated quantile of the retained window, matching
        ``numpy.percentile(samples, q * 100)`` exactly; ``None`` when no
        sample has been observed."""
        if not self._samples:
            return None
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        s = sorted(self._samples)
        pos = q * (len(s) - 1)
        lo = int(pos)
        frac = pos - lo
        if frac == 0.0:
            return s[lo]
        return s[lo] + (s[lo + 1] - s[lo]) * frac

    @property
    def samples(self) -> List[float]:
        """The retained window (oldest first) — for tests and exports."""
        return list(self._samples)

    def as_dict(self) -> Dict:
        return {"name": self.name, "labels": dict(self.labels),
                "count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.quantile(0.5), "p90": self.quantile(0.9),
                "p99": self.quantile(0.99), "updated_at": self.updated_at}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Process-local registry of labeled metric series.

    One registry per telemetry owner (campaign, fabric worker, serving
    engine): series with the same name must share one kind, and
    ``snapshot()`` renders every series deterministically sorted so two
    snapshots of identical activity are equal — the property the FakeClock
    determinism test pins.  Thread-safe: the fabric coordinator thread and
    the campaign prefetcher may both touch it.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._series: Dict[Tuple[str, str, LabelItems], object] = {}
        self._kinds: Dict[str, str] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str, labels: Dict, **kw):
        items = _label_items(labels)
        key = (kind, name, items)
        series = self._series.get(key)
        if series is not None:
            return series
        with self._lock:
            series = self._series.get(key)
            if series is not None:
                return series
            prior = self._kinds.get(name)
            if prior is not None and prior != kind:
                raise ValueError(f"metric {name!r} already registered as a "
                                 f"{prior}, cannot re-register as a {kind}")
            self._kinds[name] = kind
            series = _KINDS[kind](name, items, self.clock, **kw)
            self._series[key] = series
            return series

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, max_samples: int = 8192,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels, max_samples=max_samples)

    def snapshot(self) -> Dict:
        """All series as one JSON-ready dict, deterministically ordered."""
        out = {"clock_s": self.clock(),
               "counters": [], "gauges": [], "histograms": []}
        with self._lock:
            items = sorted(self._series.items())
        for (kind, _, _), series in items:
            out[kind + "s"].append(series.as_dict())
        return out


def metric_value(snapshot: Dict, name: str, kind: str = "counters",
                 default=None, **labels):
    """Read one series' value back out of a ``snapshot()`` dict — the
    helper the fabric coordinator uses on worker-shipped snapshots (and
    tests use on artifacts) so consumers never hand-parse the schema."""
    want = dict(_label_items(labels))
    for row in snapshot.get(kind, ()):
        if row["name"] == name and row.get("labels", {}) == want:
            return row.get("value", row)
    return default
