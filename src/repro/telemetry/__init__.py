"""Campaign telemetry: one injectable observability object for the stack.

``Telemetry`` bundles the two observability pieces every layer shares:

* a ``MetricsRegistry`` (labeled Counter/Gauge/Histogram series,
  ``snapshot()`` -> JSON) — see ``repro.telemetry.metrics``;
* a ``SpanTracer`` (nested timing spans, bounded ring buffer, Chrome
  ``trace_event`` export for Perfetto) — see ``repro.telemetry.trace``;

plus the injected monotonic ``clock`` both read, which is also the clock
the instrumented call sites (``Campaign.run`` tile walls, fabric busy
windows, serving latencies) use instead of raw ``time.perf_counter()`` —
inject ``repro.dse_campaign.fabric.FakeClock`` and every telemetry
timestamp in the system becomes deterministic.

``NullTelemetry`` is the default everywhere and the disabled-path
contract: **metrics still count** (they are O(1) scalar writes, and
back-compat surfaces like ``TileEvaluator.fused_launches`` read them) but
**tracing is free** — ``span()`` returns a process-wide no-op singleton,
nothing is buffered, and the instrumented hot paths add <2% throughput
overhead (gated in ``benchmarks/dse_campaign.py``).

The one rule that keeps observability safe: no instrumented value may feed
computation.  Metrics and spans are readings; the frontier identity gates
(streamed == one-shot, distributed == single-process, instrumented ==
uninstrumented) stay bitwise with telemetry on, off, or null.

Usage::

    from repro.telemetry import Telemetry

    tel = Telemetry()
    campaign = Campaign(workloads, config, telemetry=tel)
    campaign.run()
    tel.snapshot()                        # metrics -> JSON dict
    tel.export_trace("trace.json")        # open in Perfetto

See ``docs/observability.md`` for the span/metric glossary.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry, metric_value)
from repro.telemetry.trace import (NULL_SPAN, NULL_TRACER, NullTracer,
                                   SpanRecord, SpanTracer)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullTelemetry",
    "SpanRecord", "SpanTracer", "Telemetry", "coerce_telemetry",
    "metric_value",
]


class Telemetry:
    """The injectable observability bundle: metrics + tracer + clock."""

    tracing = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 wall_clock: Callable[[], float] = time.time,
                 trace_capacity: int = 65536):
        self.clock = clock
        self.metrics = MetricsRegistry(clock=clock)
        self.tracer = SpanTracer(clock=clock, wall_clock=wall_clock,
                                 capacity=trace_capacity)

    # -- tracing -------------------------------------------------------------

    def span(self, name: str, **attrs):
        """Context manager timing one named span (see ``SpanTracer.span``)."""
        return self.tracer.span(name, **attrs)

    def chrome_trace(self, process_name: str = "repro-campaign") -> Dict:
        return self.tracer.chrome_trace(process_name)

    def export_trace(self, path: str,
                     process_name: str = "repro-campaign") -> str:
        return self.tracer.export(path, process_name)

    # -- metrics -------------------------------------------------------------

    def counter(self, name: str, **labels) -> Counter:
        return self.metrics.counter(name, **labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self.metrics.gauge(name, **labels)

    def histogram(self, name: str, max_samples: int = 8192,
                  **labels) -> Histogram:
        return self.metrics.histogram(name, max_samples=max_samples, **labels)

    def snapshot(self) -> Dict:
        return self.metrics.snapshot()


class NullTelemetry(Telemetry):
    """The default: real (cheap) metrics, no tracing.

    Every component that is not handed a ``Telemetry`` constructs its OWN
    ``NullTelemetry`` — registries are per-owner, so two engines' counters
    never alias (``engine.fused_launches`` stays an engine-local reading).
    ``span()`` short-circuits to the shared no-op singleton.
    """

    tracing = False

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.metrics = MetricsRegistry(clock=clock)
        self.tracer = NULL_TRACER

    def span(self, name: str = "", **attrs):
        return NULL_SPAN


def coerce_telemetry(telemetry: Optional[Telemetry]) -> Telemetry:
    """``None`` -> a fresh per-owner ``NullTelemetry`` (the default path)."""
    return telemetry if telemetry is not None else NullTelemetry()
