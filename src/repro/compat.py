"""Version shims for the JAX surface this repo touches.

The codebase targets the modern ``jax.shard_map`` API (``check_vma``); older
releases (< 0.5) only ship ``jax.experimental.shard_map.shard_map`` with the
flag spelled ``check_rep``.  Same story for ``Compiled.cost_analysis``, which
returned a one-element list of dicts before returning the dict directly.
"""

from __future__ import annotations

from typing import Any, Dict

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new JAX, the experimental spelling on old JAX."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def axis_size(axis_name):
    """``jax.lax.axis_size`` where available, psum-of-ones otherwise."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis(compiled) -> Dict[str, Any]:
    """Normalize ``Compiled.cost_analysis()`` to a flat dict across versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
