"""Fault-tolerance runtime: heartbeats, straggler detection, preemption.

Mechanisms (all host-side, unit-testable, wired into launch/train.py):

  * HeartbeatMonitor — per-host liveness registry with timeout-based failure
    flags; at real scale this fronts the coordination service, here it is the
    same logic over an in-process clock.
  * StragglerDetector — rolling per-step wall-times; a step slower than
    median + k*MAD marks the step (and offending host telemetry) straggling.
    Policy hook decides: log, rebalance, or checkpoint-and-restart.
  * PreemptionHandler — SIGTERM/SIGINT -> checkpoint-now-then-exit flag
    (maintenance-event behaviour on TPU pods).
  * recoverable_step — retries a step through jax transient errors after
    device reset, the restart half of checkpoint/restart.
  * RetryPolicy — the one retry/backoff schedule shared by every layer that
    retries (fabric worker respawn, lease-expiry sweeps, chaos recovery):
    bounded exponential backoff with deterministic jitter, all timing off an
    injected clock/sleep so tests and chaos runs never wall-sleep.
"""

from __future__ import annotations

import collections
import dataclasses
import random
import signal
import statistics
import time
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    ``backoff_s(attempt)`` = ``min(base_s * multiplier**attempt, max_s)``
    scaled by a jitter factor drawn uniformly from ``[1 - jitter_frac,
    1 + jitter_frac]``.  The jitter rng is seeded from ``(seed, attempt)``
    (integer mix, no process-salted hashing), so the same policy produces
    the same schedule in every process and every run — chaos scenarios stay
    bit-reproducible while still desynchronizing real fleets.

    The transport timeouts the ``MultiprocessFabric`` used to hard-code
    live here too (``poll_s`` result-queue poll, ``join_timeout_s`` worker
    shutdown, ``drain_timeout_s`` result drain), so one policy object
    describes every time constant a fabric run uses.
    """

    base_s: float = 0.05
    multiplier: float = 2.0
    max_s: float = 2.0
    jitter_frac: float = 0.1
    max_attempts: int = 5
    seed: int = 0
    poll_s: float = 0.05
    join_timeout_s: float = 5.0
    drain_timeout_s: float = 0.2

    def __post_init__(self):
        if self.base_s <= 0:
            raise ValueError("base_s must be > 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")
        if self.max_s < self.base_s:
            raise ValueError("max_s must be >= base_s")
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ValueError("jitter_frac must be in [0, 1)")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")

    def backoff_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (0-based), jittered, bounded by
        ``max_s * (1 + jitter_frac)``."""
        raw = min(self.base_s * self.multiplier ** attempt, self.max_s)
        rng = random.Random(self.seed * 1_000_003 + attempt)
        return raw * (1.0 + self.jitter_frac * (2.0 * rng.random() - 1.0))

    def schedule(self) -> Tuple[float, ...]:
        """The full backoff schedule, one entry per allowed retry."""
        return tuple(self.backoff_s(a) for a in range(self.max_attempts))

    def call(self, fn: Callable, *, sleep: Callable[[float], None] = time.sleep,
             retry_on: Tuple[type, ...] = (Exception,)):
        """Run ``fn()`` with up to ``max_attempts`` tries.

        ``sleep`` is injected (a FakeClock advance in tests, ``time.sleep``
        in production) so retrying code never hard-codes wall sleeps.
        """
        for attempt in range(self.max_attempts):
            try:
                return fn()
            except retry_on:
                if attempt == self.max_attempts - 1:
                    raise
                sleep(self.backoff_s(attempt))


class HeartbeatMonitor:
    """Per-host liveness registry with timeout-based failure detection.

    A host is *dead* when strictly more than ``timeout_s`` has elapsed on
    ``clock`` since its last ``beat`` (or since registration).  The clock is
    injectable, so expiry is deterministic under a fake clock in tests — the
    campaign fabric relies on this to test lease-timeout re-issue without
    sleeping.  Membership is dynamic: ``register`` admits a host mid-flight
    (workers joining a fabric) and ``forget`` retires one (confirmed-dead
    workers must be dropped, or they would report dead forever).
    """

    def __init__(self, hosts: List[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_seen: Dict[str, float] = {h: clock() for h in hosts}

    def register(self, host: str):
        """Admit ``host``, marking it alive as of now (idempotent refresh)."""
        self.last_seen[host] = self.clock()

    def forget(self, host: str):
        """Retire ``host`` from monitoring (no-op if unknown)."""
        self.last_seen.pop(host, None)

    def beat(self, host: str):
        self.last_seen[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_hosts()


class StragglerDetector:
    """Median + k*MAD outlier rule over a rolling window of step times."""

    def __init__(self, window: int = 50, k: float = 5.0, min_samples: int = 8):
        self.times = collections.deque(maxlen=window)
        self.k = k
        self.min_samples = min_samples
        self.flagged = 0

    def observe(self, step_time_s: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.min_samples:
            med = statistics.median(self.times)
            mad = statistics.median(abs(t - med) for t in self.times) or 1e-6
            if step_time_s > med + self.k * mad:
                is_straggler = True
                self.flagged += 1
        self.times.append(step_time_s)
        return is_straggler

    def summary(self) -> Dict:
        if not self.times:
            return {"median_s": 0.0, "flagged": self.flagged}
        return {"median_s": statistics.median(self.times), "flagged": self.flagged}


class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful checkpoint-then-exit."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
                signal.signal(signal.SIGINT, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True


def recoverable_step(step_fn: Callable, state, batch, max_retries: int = 2,
                     on_failure: Optional[Callable] = None):
    """Run step_fn, retrying through transient runtime failures.

    On each failure: clear jax caches (device reset stand-in) and call
    ``on_failure(attempt, exc)`` — the hook that restores from checkpoint at
    real scale.  Programming errors (TypeError, etc.) are NOT retried.
    """
    attempt = 0
    while True:
        try:
            return step_fn(state, batch)
        except (RuntimeError, jax_transient_errors()) as e:  # noqa: B030
            attempt += 1
            if attempt > max_retries:
                raise
            if on_failure is not None:
                on_failure(attempt, e)
            jax_clear_backends()


def jax_transient_errors():
    import jax
    return getattr(jax.errors, "JaxRuntimeError", RuntimeError)


def jax_clear_backends():
    import jax
    try:
        jax.clear_caches()
    except Exception:
        pass
