"""GPipe-style pipeline parallelism over a dedicated `stage` mesh axis.

shard_map + collective_permute microbatch rotation: tick t sends every
stage's activation to stage+1; stage s computes microbatch m at tick
t = s + m (the classic fill/steady/drain schedule, bubble fraction
(n_stage-1)/(n_micro+n_stage-1)).

The production 40-cell mesh uses DP x TP (+pod); this module provides the PP
axis for configurations that need it (very deep models / small batches) and
is validated for equivalence in tests/test_distributed.py.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def pipeline_apply(stage_fn: Callable, stage_params, x, mesh,
                   n_micro: int, stage_axis: str = "stage"):
    """stage_params: [n_stage, ...] (stacked per-stage weights);
    x: [B, ...] global batch.  Returns stage_{n-1}(...stage_0(x)) like a
    sequential stack, computed with pipeline rotation."""
    n_stage = dict(zip(mesh.axis_names, mesh.devices.shape))[stage_axis]
    B = x.shape[0]
    assert B % n_micro == 0
    mb = B // n_micro
    xm = x.reshape(n_micro, mb, *x.shape[1:])
    perm = [(i, i + 1) for i in range(n_stage - 1)]

    def body(w_loc, xm_loc):
        stage = jax.lax.axis_index(stage_axis)
        w = jax.tree_util.tree_map(lambda a: a[0], w_loc)
        state = jnp.zeros_like(xm_loc[0])
        out = jnp.zeros_like(xm_loc)
        T = n_micro + n_stage - 1
        for t in range(T):
            inp = xm_loc[min(t, n_micro - 1)]
            cur = jnp.where(stage == 0, inp, state)
            # valid when this stage holds microbatch m = t - stage in range
            m = t - stage
            valid = (m >= 0) & (m < n_micro)
            y = stage_fn(w, cur)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage stores its finished microbatch
            is_last = stage == n_stage - 1
            idx = jnp.clip(m, 0, n_micro - 1)
            out = jnp.where(valid & is_last,
                            out.at[idx].set(y), out)
            # rotate activations to the next stage
            state = jax.lax.ppermute(y, stage_axis, perm)
        # only the last stage holds results; share them
        return jax.lax.psum(out, stage_axis)

    w_specs = jax.tree_util.tree_map(
        lambda a: P(stage_axis, *([None] * (a.ndim - 1))), stage_params)
    out = compat.shard_map(body, mesh=mesh,
                           in_specs=(w_specs, P()), out_specs=P(),
                           check_vma=False)(stage_params, xm)
    return out.reshape(B, *x.shape[1:])
