"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the real train /
serve step with ShapeDtypeStruct inputs (no allocation), compiles, and
records memory_analysis / cost_analysis / the collective census (HxA) to a
JSON artifact per cell under ``experiments/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""

# MUST be the very first lines — jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs.base import ARCH_NAMES, SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.launch.lowering import lower_cell                   # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def art_dir() -> str:
    d = os.environ.get("REPRO_ART_DIR",
                       os.path.abspath(os.path.join(os.getcwd(), "experiments", "dryrun")))
    os.makedirs(d, exist_ok=True)
    return d


def run_cell(arch: str, shape_name: str, multi_pod: bool, save: bool = True,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    save_hlo = os.environ.get("REPRO_SAVE_HLO", "1") == "1"
    result = lower_cell(cfg, shape, mesh, overrides=overrides or {},
                        include_hlo=save_hlo)
    result["wall_s"] = round(time.time() - t0, 2)
    result["arch"] = arch
    result["shape"] = shape_name
    result["mesh"] = "2x16x16" if multi_pod else "16x16"
    if save:
        tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
        if overrides:
            tag += "__" + "_".join(f"{k}-{v}" for k, v in sorted(overrides.items()))
        hlo_text = result.pop("hlo_text", None)
        if hlo_text is not None:
            import gzip
            hdir = os.path.join(art_dir(), "hlo")
            os.makedirs(hdir, exist_ok=True)
            with gzip.open(os.path.join(hdir, tag + ".hlo.gz"), "wt") as f:
                f.write(hlo_text)
        path = os.path.join(art_dir(), tag + ".json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"[dryrun] wrote {path}")
    return result


def reanalyze(tag: str) -> dict:
    """Rebuild a cell artifact from its stored HLO (analyzer iterations
    without recompiling)."""
    import gzip
    from repro.core import costmodel, hxa
    from repro.hw import get_chip
    from repro.launch.lowering import kernel_substitution
    import dataclasses as _dc
    path = os.path.join(art_dir(), tag + ".json")
    with open(path) as f:
        art = json.load(f)
    with gzip.open(os.path.join(art_dir(), "hlo", tag + ".hlo.gz"), "rt") as f:
        text = f.read()
    analysis = hxa.analyze_hlo_text(text)
    analysis["hbm_bytes_xla"] = analysis["hbm_bytes"]
    cfg_d = art["config"]
    from repro.configs.base import get_config as _gc
    cfg = _gc(art["arch"])
    over = {k: cfg_d[k] for k in ("attn_impl", "ssm_impl", "remat")
            if cfg_d.get(k) is not None}
    cfg = _dc.replace(cfg, **over)
    shape = SHAPES[art["shape"]]
    n_chips = art["roofline"]["n_chips"]
    subst = kernel_substitution(cfg, shape, n_chips, 16)
    saved = subst["attn_bytes_saved_pd"] + subst["ssm_bytes_saved_pd"]
    if saved:
        analysis["hbm_bytes"] = max(analysis["hbm_bytes"] - saved,
                                    analysis["hbm_bytes"] * 0.05)
    analysis["kernel_substitution"] = subst
    chip = get_chip()
    art["hxa"] = {k: analysis[k] for k in
                  ("flops", "hbm_bytes", "hbm_bytes_xla", "collective_bytes",
                   "wire_bytes", "op_counts", "hbm_by_opcode", "collectives",
                   "loops", "n_computations", "kernel_substitution")}
    art["roofline"] = costmodel.roofline_terms(analysis, chip, n_chips)
    mesh_shape = tuple(int(d) for d in art["mesh"].split("x"))
    art["sim"] = costmodel.simulate(analysis, chip, n_chips,
                                    mesh=mesh_shape).as_dict()
    hlo_flops_global = analysis["flops"] * n_chips
    art["useful_flops_ratio"] = (art["model_flops"] / hlo_flops_global
                                 if hlo_flops_global else 0.0)
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return art


def applicable_cells():
    for arch in ARCH_NAMES:
        if arch == "resnet50":
            continue  # paper's own domain: separate bench, not an LM cell
        cfg = get_config(arch)
        for shape in cfg.applicable_shapes():
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="override key=value (e.g. remat=none)")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set)

    cells = list(applicable_cells()) if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape in cells:
        try:
            r = run_cell(arch, shape, args.multi_pod, overrides=overrides)
            print(f"[dryrun] {arch} x {shape} x {r['mesh']}: "
                  f"state/dev {r['memory']['state_gb_per_device']:.2f} GB, "
                  f"hxa-flops/dev {r['hxa']['flops']:.3e}, "
                  f"dominant {r['roofline']['dominant']}, wall {r['wall_s']}s")
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            traceback.print_exc()
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
