"""Serving driver: token generation, selection queries, or index builds.

Three modes (``--mode``, default ``token`` for back-compat):

  token        batched requests through the continuous-batching engine
               python -m repro.launch.serve --arch stablelm-1.6b --requests 8

  build-index  campaign checkpoint -> FrontierIndex artifact
               python -m repro.launch.serve --mode build-index \
                   --checkpoint experiments/campaign.ckpt.json \
                   --out experiments/frontier_index.json

  select       answer selection queries against a FrontierIndex
               python -m repro.launch.serve --mode select \
                   --index experiments/frontier_index.json \
                   [--queries queries.json]
               The queries file is a JSON list of
               ``{"workload": {...workload_to_dict...},
                  "constraint": {...} | absent, "deadline_s": float | absent}``;
               without it, every indexed family is queried as a self-check
               (all answers must come back ``index_exact``).
"""

from __future__ import annotations

import argparse
import json

import numpy as np


def serve(arch: str, n_requests: int = 8, slots: int = 4, max_len: int = 128,
          prompt_len: int = 8, max_new: int = 16, seed: int = 0):
    import jax

    from repro.configs.base import get_config
    from repro.models import api
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config(arch).reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed), max_seq=max_len)
    engine = ServingEngine(model, slots=slots, max_len=max_len)
    engine.load(params)
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(2, prompt_len + 1)).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n_requests)]
    for r in reqs:
        engine.submit(r)
    stats = engine.run_until_drained()
    done = sum(r.done for r in reqs)
    lat = [r.finished_s - r.arrived_s for r in reqs if r.finished_s]
    stats.update(completed=done,
                 mean_latency_s=float(np.mean(lat)) if lat else 0.0)
    return reqs, stats


def build_index(checkpoint: str, out: str) -> str:
    """Campaign checkpoint -> saved FrontierIndex; returns the path."""
    from repro.serving.frontier_index import FrontierIndex

    index = FrontierIndex.from_checkpoint(checkpoint)
    path = index.save(out)
    print(f"[serve] indexed {len(index)} workload families -> {path}")
    return path


def select_queries(index_path: str, queries_path: str = None):
    """Answer a batch of selection queries; returns the answers.

    All queries are submitted before one ``flush`` — the CLI batch IS the
    batching window, so concurrent novel queries share one fused sweep.
    """
    from repro.core import dse
    from repro.dse_campaign.runner import workload_from_dict
    from repro.serving.engine import SelectionEngine
    from repro.serving.frontier_index import FrontierIndex

    index = FrontierIndex.load(index_path)
    engine = SelectionEngine(index)
    if queries_path:
        with open(queries_path) as f:
            queries = json.load(f)
        for qd in queries:
            engine.submit(
                workload_from_dict(qd["workload"]),
                constraint=(dse.Constraint(**qd["constraint"])
                            if qd.get("constraint") else None),
                deadline_s=qd.get("deadline_s"))
    else:
        for entry in index.entries:           # self-check: all index hits
            engine.submit(entry.workload)
    answers = engine.flush()
    for a in answers:
        top = a.choices[0] if a.choices else None
        pick = (f"{top.candidate.chip} x{top.candidate.n_chips} "
                f"@ {top.candidate.freq_mhz:.0f} MHz, "
                f"{top.energy_j:.3e} J / {top.latency_s:.3e} s"
                if top else "no feasible candidate")
        print(f"[serve] q{a.qid} {a.workload.arch}|{a.workload.shape} "
              f"[{a.provenance}] {pick} ({a.wall_s * 1e3:.1f} ms)")
    print(f"[serve] {engine.stats['queries']} queries: "
          + ", ".join(f"{p}={engine.stats[p]}"
                      for p in ("index_exact", "mini_campaign",
                                "predictor_only"))
          + f"; fused launches: {engine.fused_launches}")
    return answers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("token", "select", "build-index"),
                    default="token")
    ap.add_argument("--arch", help="token mode: model architecture")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--checkpoint", help="build-index: campaign checkpoint")
    ap.add_argument("--out", help="build-index: output index path")
    ap.add_argument("--index", help="select: FrontierIndex artifact")
    ap.add_argument("--queries", help="select: JSON query batch (optional)")
    args = ap.parse_args()
    if args.mode == "build-index":
        if not (args.checkpoint and args.out):
            ap.error("--mode build-index needs --checkpoint and --out")
        build_index(args.checkpoint, args.out)
        return
    if args.mode == "select":
        if not args.index:
            ap.error("--mode select needs --index")
        select_queries(args.index, args.queries)
        return
    if not args.arch:
        ap.error("--mode token needs --arch")
    reqs, stats = serve(args.arch, n_requests=args.requests, slots=args.slots,
                        max_len=args.max_len, max_new=args.max_new)
    print(f"[serve] {stats['completed']}/{len(reqs)} done, "
          f"{stats['decoded_tokens']} tokens, {stats['tok_per_s']:.1f} tok/s, "
          f"mean latency {stats['mean_latency_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
