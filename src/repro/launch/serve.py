"""Serving driver: batched requests through the continuous-batching engine.

  python -m repro.launch.serve --arch stablelm-1.6b --requests 8 --slots 4
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import api
from repro.serving.engine import Request, ServingEngine


def serve(arch: str, n_requests: int = 8, slots: int = 4, max_len: int = 128,
          prompt_len: int = 8, max_new: int = 16, seed: int = 0):
    cfg = get_config(arch).reduced()
    model = api.build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed), max_seq=max_len)
    engine = ServingEngine(model, slots=slots, max_len=max_len)
    engine.load(params)
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(2, prompt_len + 1)).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n_requests)]
    for r in reqs:
        engine.submit(r)
    stats = engine.run_until_drained()
    done = sum(r.done for r in reqs)
    lat = [r.finished_s - r.arrived_s for r in reqs if r.finished_s]
    stats.update(completed=done,
                 mean_latency_s=float(np.mean(lat)) if lat else 0.0)
    return reqs, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    reqs, stats = serve(args.arch, n_requests=args.requests, slots=args.slots,
                        max_len=args.max_len, max_new=args.max_new)
    print(f"[serve] {stats['completed']}/{len(reqs)} done, "
          f"{stats['decoded_tokens']} tokens, {stats['tok_per_s']:.1f} tok/s, "
          f"mean latency {stats['mean_latency_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
