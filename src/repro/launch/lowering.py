"""Shared lowering/compile/analysis pipeline for dry-runs and perf iteration.

lower_cell(cfg, shape, mesh) -> dict with:
  memory   — per-device bytes from compiled.memory_analysis()
  cost     — compiled.cost_analysis() (XLA's census; counts loop bodies ONCE)
  hxa      — HxA census (loop-trip-aware flops/bytes/collective bytes)
  roofline — the three §Roofline terms + dominant bottleneck
  sim      — calibrated latency/power/energy (the slow-accurate path)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import costmodel, hxa
from repro.hw import get_chip
from repro.models import api
from repro.models.dist import make_dist
from repro import optim

_COERCE = {
    "remat": str, "capacity_factor": float, "optimizer": str, "dtype": str,
    "ssm_chunk": int, "attn_type": str, "attn_impl": str, "ssm_impl": str,
    "cache_layout": str,
}


def kernel_substitution(cfg: ArchConfig, shape: ShapeConfig, n_chips: int,
                        mesh_model: int) -> Dict[str, float]:
    """Analytic HBM-traffic delta of Pallas kernelization.

    The XLA fallback materializes fp32 attention-score / SSD-decay blocks in
    HBM every chunk; the fused Pallas kernels (kernels/flash_attention.py,
    kernels/ssd_scan.py) keep them in VMEM.  The dry-run cannot lower TPU
    pallas_call on the CPU backend, so kernelized cells substitute the
    score-block traffic analytically (documented in EXPERIMENTS.md §Perf).
    Returns bytes saved per device (>= 0).
    """
    saved = 0.0
    if shape.kind == "decode":
        return {"attn_bytes_saved_pd": 0.0, "ssm_bytes_saved_pd": 0.0}
    passes = 3.0 if shape.kind == "train" else 1.0   # fwd + bwd(recompute+grads)
    touches = 5.0                                     # s write/read, p write/read, d(p)
    if cfg.attn_impl == "pallas" and cfg.attn_type != "none" and cfg.num_heads:
        causal_pairs = shape.seq_len * shape.seq_len / 2.0
        heads = cfg.num_heads
        layers = cfg.num_layers + cfg.encoder_layers
        total = (causal_pairs * heads * layers * shape.global_batch
                 * 4.0 * touches * passes)
        saved_attn = total / n_chips
    else:
        saved_attn = 0.0
    if cfg.ssm_impl == "pallas" and cfg.ssm_state:
        Q = cfg.ssm_chunk
        nc = shape.seq_len // max(Q, 1)
        blocks = nc * Q * Q * cfg.ssm_nheads * shape.global_batch
        saved_ssm = blocks * 4.0 * touches * passes * cfg.num_layers / n_chips
    else:
        saved_ssm = 0.0
    return {"attn_bytes_saved_pd": saved_attn, "ssm_bytes_saved_pd": saved_ssm}


def apply_overrides(cfg: ArchConfig, overrides: Dict[str, str]) -> ArchConfig:
    if not overrides:
        return cfg
    kw = {}
    for k, v in overrides.items():
        field_types = {f.name: f.type for f in dataclasses.fields(cfg)}
        if k not in field_types:
            raise KeyError(f"unknown config field {k}")
        coerce = _COERCE.get(k)
        if coerce is None:
            cur = getattr(cfg, k)
            coerce = type(cur) if cur is not None else str
            if coerce is bool:
                v = v.lower() in ("1", "true", "yes")
                kw[k] = v
                continue
        kw[k] = coerce(v)
    return dataclasses.replace(cfg, **kw)


def _with_shardings(shape_tree, spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shape_tree, spec_tree)


def sharded_bytes_per_device(sds_tree) -> float:
    """Analytic per-device bytes of a sharded ShapeDtypeStruct tree.

    XLA:CPU's ``temp_size_in_bytes`` ignores buffer reuse, so residency
    ("does the state fit?") is computed from shard shapes directly — exact
    for weights/optimizer/caches, which dominate residency.
    """
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(sds_tree):
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            shard_shape = sharding.shard_shape(leaf.shape)
        else:
            shard_shape = leaf.shape
        n = 1
        for d in shard_shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return total


def _memory_dict(compiled) -> Dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        out[k] = getattr(ma, k, 0)
    out["per_device_total_gb"] = (out["argument_size_in_bytes"]
                                  + out["output_size_in_bytes"]
                                  - out["alias_size_in_bytes"]) / 1e9
    out["per_device_peak_gb"] = out["peak_memory_in_bytes"] / 1e9
    return out


def _cost_dict(compiled) -> Dict:
    try:
        from repro import compat
        ca = compat.cost_analysis(compiled)
    except Exception:
        ca = {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               overrides: Optional[Dict[str, str]] = None,
               chip_name: str = "tpu-v5e",
               include_hlo: bool = False) -> Dict:
    cfg = apply_overrides(cfg, overrides or {})
    dist = make_dist(mesh)
    model = api.build_model(cfg)
    n_chips = mesh.devices.size

    if shape.kind == "train":
        optimizer = optim.make_optimizer(cfg.optimizer)
        specs, state_shape = api.state_specs(model, optimizer, dist,
                                             max_seq=shape.seq_len)
        state_in = _with_shardings(
            state_shape,
            api.TrainState(params=specs.params, opt=specs.opt), mesh)
        batch_in = api.input_specs(cfg, shape, dist)
        step = api.make_train_step(model, optimizer, dist)
        resident = (state_in, batch_in)
        lowered = jax.jit(step, donate_argnums=(0,)).lower(state_in, batch_in)
    elif shape.kind == "prefill":
        specs, params_shape = _param_specs_only(model, dist, shape)
        params_in = _with_shardings(params_shape, specs, mesh)
        batch_in = api.input_specs(cfg, shape, dist)
        step = api.make_serve_step(model, "prefill", dist)
        resident = (params_in, batch_in)
        lowered = jax.jit(step).lower(params_in, batch_in)
    else:  # decode
        specs, params_shape = _param_specs_only(model, dist, shape)
        params_in = _with_shardings(params_shape, specs, mesh)
        batch_in = api.input_specs(cfg, shape, dist)
        cache_in = api.cache_specs(model, shape, dist)
        step = api.make_serve_step(model, "decode", dist)
        resident = (params_in, batch_in, cache_in)
        lowered = jax.jit(step, donate_argnums=(2,)).lower(
            params_in, batch_in, cache_in)

    compiled = lowered.compile()
    hlo_text = compiled.as_text()
    analysis = hxa.analyze_hlo_text(hlo_text)
    analysis["hbm_bytes_xla"] = analysis["hbm_bytes"]
    subst = kernel_substitution(cfg, shape, n_chips,
                                dict(zip(mesh.axis_names,
                                         mesh.devices.shape)).get("model", 1))
    saved = subst["attn_bytes_saved_pd"] + subst["ssm_bytes_saved_pd"]
    if saved:
        analysis["hbm_bytes"] = max(analysis["hbm_bytes"] - saved,
                                    analysis["hbm_bytes"] * 0.05)
    analysis["kernel_substitution"] = subst
    chip = get_chip(chip_name)
    roof = costmodel.roofline_terms(analysis, chip, n_chips)
    sim = costmodel.simulate(analysis, chip, n_chips,
                             mesh=mesh.devices.shape)

    mf = cfg.model_flops(shape)
    hlo_flops_global = analysis["flops"] * n_chips
    mem = _memory_dict(compiled)
    mem["state_gb_per_device"] = sharded_bytes_per_device(resident) / 1e9
    result = {
        "config": {k: v for k, v in dataclasses.asdict(cfg).items()
                   if not k.startswith("_")},
        "memory": mem,
        "cost": _cost_dict(compiled),
        "hxa": {k: analysis[k] for k in
                ("flops", "hbm_bytes", "hbm_bytes_xla", "collective_bytes",
                 "wire_bytes", "op_counts", "hbm_by_opcode", "collectives",
                 "loops", "n_computations", "kernel_substitution")},
        "roofline": roof,
        "sim": sim.as_dict(),
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_flops_global) if hlo_flops_global else 0.0,
        "hlo_chars": len(hlo_text),
    }
    if include_hlo:
        result["hlo_text"] = hlo_text
    return result


def _param_specs_only(model, dist, shape):
    from repro.models.sharding import param_specs
    import functools
    params_shape = jax.eval_shape(
        functools.partial(model.init, max_seq=shape.seq_len),
        jax.random.PRNGKey(0))
    return param_specs(params_shape, dist), params_shape
