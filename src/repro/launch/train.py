"""End-to-end training driver with fault tolerance.

  python -m repro.launch.train --arch stablelm-1.6b --steps 200 --reduced \
      --ckpt-dir /tmp/ckpt [--restore] [--mesh 1x1]

Wires together: config -> model -> optimizer -> data pipeline -> jit'd train
step -> async checkpointing -> straggler telemetry -> preemption handling.
On the CPU container it runs REDUCED configs for real (examples/quickstart);
on a TPU slice the same driver takes the full configs.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ShapeConfig, get_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.models.dist import make_dist
from repro import optim
from repro.runtime.fault_tolerance import (PreemptionHandler, StragglerDetector,
                                           recoverable_step)


def train(arch: str, steps: int = 100, reduced: bool = True,
          seq_len: int = 128, batch: int = 8, ckpt_dir: Optional[str] = None,
          restore: bool = False, ckpt_every: int = 50, mesh_shape=None,
          log_every: int = 10, lr: float = 3e-4, seed: int = 0,
          install_signals: bool = True, straggler_k: float = 5.0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("train_cli", seq_len, batch, "train")
    model = api.build_model(cfg)
    optimizer = optim.make_optimizer(cfg.optimizer, lr=lr, total_steps=steps)

    dist = None
    if mesh_shape and int(np.prod(mesh_shape)) > 1:
        mesh = make_mesh(mesh_shape, ("data", "model")[: len(mesh_shape)])
        dist = make_dist(mesh)

    params = model.init(jax.random.PRNGKey(seed), max_seq=seq_len)
    state = api.TrainState(params, optimizer.init(params))

    start_step = 0
    data_cfg = DataConfig(seed=seed + 1)
    ckpt: Optional[store.AsyncCheckpointer] = None
    if ckpt_dir:
        ckpt = store.AsyncCheckpointer(ckpt_dir)
        if restore and store.latest_step(ckpt_dir) is not None:
            start_step, state, extra = store.restore(ckpt_dir)
            print(f"[train] restored step {start_step}")

    step_fn = jax.jit(api.make_train_step(model, optimizer, dist),
                      donate_argnums=(0,))
    data = DataIterator(cfg, shape, data_cfg, start_step=start_step)
    straggler = StragglerDetector(k=straggler_k)
    preempt = PreemptionHandler(install=install_signals)

    losses = []
    try:
        for step in range(start_step, steps):
            batch_np = next(data)
            t0 = time.perf_counter()
            state, metrics = recoverable_step(step_fn, state, batch_np)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if straggler.observe(dt):
                print(f"[train] step {step}: STRAGGLER ({dt:.3f}s vs "
                      f"median {straggler.summary()['median_s']:.3f}s)")
            losses.append(float(metrics["loss"]))
            if step % log_every == 0:
                print(f"[train] step {step} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms")
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save_async(step + 1, state, extra=data.state())
            if preempt.requested:
                print("[train] preemption requested: checkpointing and exiting")
                if ckpt:
                    ckpt.save_async(step + 1, state, extra=data.state())
                break
    finally:
        data.close()
        if ckpt:
            ckpt.wait()
    return losses, state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", help="e.g. 2x4")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split("x")) if args.mesh else None
    losses, _ = train(args.arch, steps=args.steps, reduced=args.reduced,
                      seq_len=args.seq_len, batch=args.batch,
                      ckpt_dir=args.ckpt_dir, restore=args.restore,
                      ckpt_every=args.ckpt_every, mesh_shape=mesh_shape,
                      lr=args.lr)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
