"""Serving layer: the token-serving engine and the accelerator-selection
query engine.  ``repro.select`` is the documented facade for the selection
surface; import from there unless you need the internals."""

from repro.serving.engine import (PROVENANCES, Request, SelectionAnswer,
                                  SelectionEngine, SelectionQuery,
                                  ServingEngine)
from repro.serving.frontier_index import FrontierIndex, IndexEntry

__all__ = [
    "FrontierIndex", "IndexEntry", "PROVENANCES", "Request",
    "SelectionAnswer", "SelectionEngine", "SelectionQuery", "ServingEngine",
]
