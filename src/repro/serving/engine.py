"""Serving engines: token generation and accelerator selection.

Two independent engines live here:

* ``ServingEngine`` — KV-cache manager + continuous batcher for token
  serving.  Slot-based continuous batching (vLLM-style, TPU-static
  shapes): the decode step always runs the full [slots, 1] batch; free
  slots carry a pad token and are masked out.  Prefill fills one request's
  cache region; finished requests free their slot immediately for the next
  queued request.  The MLA compressed cache (c_kv + k_rope) comes straight
  from the model's init_cache — 57x smaller per token than GQA full heads
  for DeepSeek-V3, which is why decode batches of 128 x 32k fit
  (EXPERIMENTS.md §Roofline).

* ``SelectionEngine`` — the accelerator-selection query engine over a
  ``FrontierIndex``: ``select(workload, constraint) -> ranked candidates``.
  Known workload families are answered straight from the index (provenance
  ``index_exact`` — identical to the offline campaign pick by
  construction).  Novel workloads fall back to a mini-campaign: all novel
  queries of a flush ride ONE fused multi-workload sweep launch (the
  ``kernels/dse_sweep.py`` data axis is per-workload, so batching queries
  is free), optionally predictor-pruned to a top slice that is then
  verified exactly (provenance ``mini_campaign``).  A query whose deadline
  the exact path cannot meet degrades to predictor-ranked answers without
  any sweep (provenance ``predictor_only``).  See ``docs/serving.md``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.telemetry import coerce_telemetry


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [prompt_len] int32
    max_new_tokens: int = 32
    arrived_s: float = 0.0
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None


class ServingEngine:
    """Static-shape continuous batching over ``slots`` concurrent sequences."""

    def __init__(self, model: Model, slots: int = 4, max_len: int = 512,
                 greedy: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        assert model.decode is not None, "family has no decode step"
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self._clock = clock
        self.params = None
        self.cache = None
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_len = np.zeros(slots, np.int32)
        self.queue: List[Request] = []
        self._decode = jax.jit(lambda p, b, c: model.decode(p, b, c))

    def load(self, params):
        self.params = params
        self.cache = self.model.init_cache(self.slots, self.max_len)

    # --- admission ---------------------------------------------------------------

    def submit(self, req: Request):
        req.arrived_s = self._clock()
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(s, req)

    def _prefill_slot(self, slot: int, req: Request):
        """Sequential per-slot prefill: decode the prompt token-by-token into
        this slot's cache region (static-shape; prompt lengths vary per
        request).  The last prompt token's logits yield the first generated
        token immediately.  Bulk prefill for homogeneous batches uses
        model.prefill."""
        self.slot_req[slot] = req
        self.slot_len[slot] = 0
        for t in req.prompt[:-1]:
            self._step_single_token(slot, int(t))
        logits = self._step_single_token(slot, int(req.prompt[-1]))
        req.tokens_out.append(int(np.argmax(logits)))
        req.first_token_s = self._clock()
        if len(req.tokens_out) >= req.max_new_tokens:
            req.done = True
            req.finished_s = self._clock()
            self.slot_req[slot] = None

    def _step_single_token(self, slot: int, token: int):
        toks = np.zeros((self.slots, 1), np.int32)
        toks[slot, 0] = token
        logits, self.cache = self._decode(self.params, {"tokens": jnp.asarray(toks)},
                                          self.cache)
        self.slot_len[slot] += 1
        return np.asarray(logits[slot, -1])

    # --- decode loop --------------------------------------------------------------

    def step(self) -> int:
        """One engine iteration: admit, decode one token for every live slot."""
        self._admit()
        live = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not live:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            req = self.slot_req[s]
            toks[s, 0] = req.tokens_out[-1]      # never empty after prefill
        logits, self.cache = self._decode(self.params,
                                          {"tokens": jnp.asarray(toks)}, self.cache)
        logits = np.asarray(logits[:, -1])
        for s in live:
            req = self.slot_req[s]
            nxt = int(np.argmax(logits[s]))
            req.tokens_out.append(nxt)
            self.slot_len[s] += 1
            if (len(req.tokens_out) >= req.max_new_tokens
                    or self.slot_len[s] >= self.max_len - 1):
                req.done = True
                req.finished_s = self._clock()
                self.slot_req[s] = None
        return len(live)

    def run_until_drained(self, max_iters: int = 10_000) -> Dict:
        t0 = self._clock()
        decoded = 0
        for _ in range(max_iters):
            n = self.step()
            decoded += n
            if n == 0 and not self.queue:
                break
        dt = self._clock() - t0
        return {"decoded_tokens": decoded, "wall_s": dt,
                "tok_per_s": decoded / dt if dt > 0 else 0.0}


# ---------------------------------------------------------------------------
# accelerator selection
# ---------------------------------------------------------------------------

from repro.configs.base import SHAPES, get_config          # noqa: E402
from repro.core import dse as _dse                          # noqa: E402
from repro.dse_campaign.config import CampaignConfig        # noqa: E402
from repro.dse_campaign.frontier import StreamingFrontier   # noqa: E402
from repro.dse_campaign.runner import TileEvaluator         # noqa: E402
from repro.dse_campaign.space import SpaceSpec              # noqa: E402
from repro.serving.frontier_index import FrontierIndex, IndexEntry  # noqa: E402
from repro.core import costmodel as _costmodel              # noqa: E402

# answer provenance, stamped on every SelectionAnswer:
#   index_exact    — served from the FrontierIndex; identical to the offline
#                    campaign pick by construction
#   mini_campaign  — novel workload, answered by a fused exact sweep (all
#                    concurrent novel queries share ONE launch)
#   predictor_only — deadline degradation: predictor-ranked, no exact sweep
PROVENANCES = ("index_exact", "mini_campaign", "predictor_only")


@dataclasses.dataclass
class SelectionQuery:
    """One pending selection request.

    ``constraint=None`` means "the index's constraint" (the only constraint
    index entries were computed under); an explicit different constraint
    forces the mini-campaign path even for known families.  ``deadline_s``
    is a budget from submission time: if the exact path cannot meet it
    (and predictors are configured), the answer degrades to
    ``predictor_only``.
    """

    workload: _dse.Workload
    constraint: Optional[_dse.Constraint] = None
    deadline_s: Optional[float] = None
    qid: int = -1
    submitted_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class RankedChoice:
    """One ranked accelerator recommendation.  ``index`` is the candidate's
    global position in the serving space; ``exact`` is False only for
    predictor-scored (unverified) choices."""

    candidate: _dse.Candidate
    energy_j: float
    latency_s: float
    index: int
    exact: bool = True


@dataclasses.dataclass
class SelectionAnswer:
    """The engine's answer to one query: the top-k ranked choices plus the
    full frontier it ranked from (for parity checks and richer clients).

    ``verified_gidx`` is the global-index slice the fallback sweep verified
    exactly (``None`` for index hits and predictor-only answers) — a
    standalone mini-campaign on the same slice reproduces ``frontier()``
    bitwise.

    ``degraded_reason`` stamps WHY a ``predictor_only`` answer degraded:
    ``"deadline"`` (budget triage), ``"circuit_open"`` (the mini-campaign
    circuit breaker is cooling down) or ``"mini_campaign_error"`` (the exact
    sweep raised and the engine fell back).  ``None`` on exact answers.
    """

    qid: int
    workload: _dse.Workload
    provenance: str
    choices: List[RankedChoice]
    feasible_count: int
    wall_s: float
    frontier_candidates: Tuple[_dse.Candidate, ...]
    frontier_energy_j: np.ndarray
    frontier_latency_s: np.ndarray
    frontier_indices: np.ndarray
    verified_gidx: Optional[np.ndarray] = None
    degraded_reason: Optional[str] = None

    def frontier(self) -> _dse.ParetoFrontier:
        """The answer's frontier in ``dse.ParetoFrontier`` form (exact for
        ``index_exact`` / ``mini_campaign``; predicted for
        ``predictor_only``)."""
        return _dse.ParetoFrontier(
            workload=self.workload,
            candidates=tuple(self.frontier_candidates),
            energy_j=np.asarray(self.frontier_energy_j, np.float64),
            latency_s=np.asarray(self.frontier_latency_s, np.float64),
            indices=np.asarray(self.frontier_indices, np.int64),
            feasible_count=int(self.feasible_count))


class CircuitBreaker:
    """Mini-campaign circuit breaker: closed → open → half-open.

    ``record_failure`` counts consecutive exact-path failures (exceptions
    or deadline overruns); at ``fail_threshold`` the breaker OPENS and
    ``allow()`` refuses the exact path until ``cooldown_s`` has elapsed on
    the injected clock.  The first ``allow()`` after cooldown transitions to
    HALF-OPEN and admits one probe: success closes the breaker, failure
    re-opens it for another full cooldown.  All transitions are reported
    through ``on_transition`` (the engine counts them in telemetry); the
    breaker itself never sleeps and never reads a wall clock directly, so
    chaos tests drive it entirely through a ``FakeClock``.
    """

    def __init__(self, fail_threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic, on_transition=None):
        if fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        if cooldown_s < 0:
            raise ValueError("cooldown_s must be >= 0")
        self.fail_threshold = int(fail_threshold)
        self.cooldown_s = float(cooldown_s)
        self.clock = clock
        self.on_transition = on_transition
        self.state = "closed"
        self.failures = 0
        self.opened_at: Optional[float] = None

    def _transition(self, state: str) -> None:
        if state == self.state:
            return
        old, self.state = self.state, state
        if self.on_transition is not None:
            self.on_transition(old, state)

    def allow(self) -> bool:
        """Whether the exact path may run now (may flip open → half-open)."""
        if self.state == "open":
            if self.clock() - self.opened_at >= self.cooldown_s:
                self._transition("half_open")
                return True
            return False
        return True

    def record_success(self) -> None:
        self.failures = 0
        if self.state != "closed":
            self._transition("closed")

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.fail_threshold:
            self.opened_at = self.clock()
            self._transition("open")


class SelectionEngine:
    """Accelerator-selection query engine over a ``FrontierIndex``.

    Constructed like every other campaign entry point — from a
    ``CampaignConfig``.  ``config=None`` derives one from the index itself
    (same space, constraint and ``SimConfig`` the offline campaign used;
    the evaluator is coerced to a fused tier, since the fallback path's
    one-launch batching property only exists on the fused sweep).  The
    ``power_model`` / ``cycles_model`` config fields enable the predictor
    paths (top-slice pruning and deadline degradation); without them every
    novel query is answered by a full exact sweep and deadlines are
    advisory.

    Request layer: ``submit()`` queues queries, ``flush()`` answers the
    whole batch — the batching window is the caller's submit..flush span
    (``select()`` is the submit+flush one-liner).  All novel queries of a
    flush that share a constraint ride ONE fused multi-workload sweep
    launch; ``fused_launches`` counts launches across the engine's lifetime
    so the claim is measured, not assumed.  Per-row results of the fused
    sweep are lane-local, so batched answers are bitwise identical to
    sequential ones.

    Observability: pass ``telemetry=`` to share a metrics registry / tracer
    with the caller (per-path ``selection_latency_s`` histograms,
    ``selection_queries_total`` counters, the ``selection_deadline_ema_s``
    gauge and the mini-campaign spans land there); the default is a private
    ``NullTelemetry`` — counters still count, tracing is free.  The EMA the
    deadline triage BRANCHES on stays a plain attribute; the gauge only
    mirrors it (instrumented values never feed computation).
    """

    def __init__(self, index: FrontierIndex, config: CampaignConfig = None,
                 top_k: int = 5, match_rtol: float = 1e-9,
                 verify_top: int = 256, telemetry=None,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0):
        if config is None:
            config = self._config_from_index(index)
        elif not isinstance(config, CampaignConfig):
            raise TypeError("SelectionEngine: config must be a "
                            "CampaignConfig (or None to derive one from "
                            "the index)")
        self.index = index
        self.config = config
        self.space = config.resolved_space
        self.top_k = int(top_k)
        self.match_rtol = float(match_rtol)
        self.verify_top = int(verify_top)
        self.index_constraint = _dse.Constraint(**index.constraint_dict)
        self.pending: List[SelectionQuery] = []
        self.telemetry = coerce_telemetry(telemetry)
        self._clock = self.telemetry.clock
        self._c_fused = self.telemetry.counter("selection_fused_launches_total")
        self._g_ema = self.telemetry.gauge("selection_deadline_ema_s")
        self.stats: Dict[str, int] = {p: 0 for p in PROVENANCES}
        self.stats["queries"] = 0
        self.stats["degraded"] = 0
        self.stats["breaker_opens"] = 0
        self._next_qid = 0
        self._exact_ema_s: Optional[float] = None
        self._full_batch: Optional[_dse.CandidateBatch] = None
        self._g_breaker = self.telemetry.gauge("selection_breaker_open")
        self._g_breaker.set(0.0)
        self.breaker = CircuitBreaker(
            fail_threshold=breaker_threshold,
            cooldown_s=breaker_cooldown_s, clock=self._clock,
            on_transition=self._on_breaker_transition)

    def _on_breaker_transition(self, old: str, new: str) -> None:
        self.telemetry.counter("selection_breaker_transitions_total",
                               to=new).inc()
        self._g_breaker.set(1.0 if new == "open" else 0.0)
        if new == "open":
            self.stats["breaker_opens"] += 1

    @property
    def fused_launches(self) -> int:
        """Fused fallback-sweep launches over the engine's lifetime — a view
        over the ``selection_fused_launches_total`` telemetry counter (kept
        as the historical public reading surface)."""
        return int(self._c_fused.value)

    @staticmethod
    def _config_from_index(index: FrontierIndex) -> CampaignConfig:
        evaluator = (index.evaluator
                     if index.evaluator in ("jit", "pallas") else "jit")
        return CampaignConfig(
            space=SpaceSpec.from_dict(index.space_dict),
            evaluator=evaluator,
            constraint=_dse.Constraint(**index.constraint_dict),
            sim=_costmodel.SimConfig(**index.sim_dict))

    @property
    def _has_models(self) -> bool:
        return (self.config.power_model is not None
                and self.config.cycles_model is not None)

    # -- request layer ------------------------------------------------------

    def submit(self, workload: _dse.Workload,
               constraint: Optional[_dse.Constraint] = None,
               deadline_s: Optional[float] = None) -> int:
        """Queue a query for the next ``flush``; returns its qid."""
        qid = self._next_qid
        self._next_qid += 1
        self.pending.append(SelectionQuery(
            workload=workload, constraint=constraint, deadline_s=deadline_s,
            qid=qid, submitted_s=self._clock()))
        return qid

    def select(self, workload: _dse.Workload,
               constraint: Optional[_dse.Constraint] = None,
               deadline_s: Optional[float] = None) -> SelectionAnswer:
        """Answer one query now (a batching window of one)."""
        self.submit(workload, constraint, deadline_s)
        return self.flush()[-1]

    def flush(self) -> List[SelectionAnswer]:
        """Answer every pending query, in submission order.

        Index-eligible queries (known family, index constraint) are served
        from the index; the rest are triaged by deadline and the survivors
        grouped by constraint — each group is ONE fused sweep launch.
        """
        queries, self.pending = self.pending, []
        tel = self.telemetry
        answers: Dict[int, SelectionAnswer] = {}
        novel: List[SelectionQuery] = []
        for q in queries:
            t0 = self._clock()
            with tel.span("index_lookup", qid=q.qid):
                entry = (self.index.lookup(q.workload, self.match_rtol)
                         if self._index_eligible(q) else None)
            if entry is not None:
                answers[q.qid] = self._answer_from_entry(
                    q, entry, self._clock() - t0)
            else:
                novel.append(q)
        exact: List[SelectionQuery] = []
        for q in novel:
            if self._must_degrade(q):
                with tel.span("predictor_only", qid=q.qid):
                    answers[q.qid] = self._answer_predictor_only(
                        q, reason="deadline")
            elif self._has_models and not self.breaker.allow():
                # breaker open: the exact path has been failing; serve
                # predictor-ranked answers until the cooldown probe closes it
                with tel.span("predictor_only", qid=q.qid):
                    answers[q.qid] = self._answer_predictor_only(
                        q, reason="circuit_open")
            else:
                exact.append(q)
        groups: Dict[Tuple, List[SelectionQuery]] = {}
        for q in exact:
            groups.setdefault(
                dataclasses.astuple(self._query_constraint(q)),
                []).append(q)
        for group in groups.values():
            t0 = self._clock()
            try:
                with tel.span("mini_campaign", n_queries=len(group)):
                    fronts, gidx = self._mini_campaign(
                        [q.workload for q in group],
                        self._query_constraint(group[0]))
            except Exception:
                self.breaker.record_failure()
                tel.counter("selection_minicampaign_failures_total").inc()
                if not self._has_models:
                    raise      # no degraded answer is possible: surface it
                for q in group:
                    with tel.span("predictor_only", qid=q.qid):
                        answers[q.qid] = self._answer_predictor_only(
                            q, reason="mini_campaign_error")
                continue
            dt = self._clock() - t0
            if self._has_models:
                # a sweep that blew through a caller's deadline counts as a
                # breaker failure even though it produced exact answers —
                # repeated overruns should trip to predictor-only, not keep
                # serving late exact answers
                blown = [q for q in group if q.deadline_s is not None
                         and self._clock() - q.submitted_s > q.deadline_s]
                if blown:
                    self.breaker.record_failure()
                    tel.counter(
                        "selection_minicampaign_timeouts_total").inc()
                else:
                    self.breaker.record_success()
            self._exact_ema_s = (dt if self._exact_ema_s is None
                                 else 0.5 * (self._exact_ema_s + dt))
            self._g_ema.set(self._exact_ema_s)
            for q, front in zip(group, fronts):
                answers[q.qid] = self._answer_from_frontier(
                    q, front, "mini_campaign", dt / len(group),
                    verified_gidx=gidx)
        for q in queries:
            ans = answers[q.qid]
            self.stats["queries"] += 1
            self.stats[ans.provenance] += 1
            tel.counter("selection_queries_total", path=ans.provenance).inc()
            tel.histogram("selection_latency_s",
                          path=ans.provenance).observe(ans.wall_s)
        return [answers[q.qid] for q in queries]

    # -- the three answer paths ---------------------------------------------

    def _index_eligible(self, q: SelectionQuery) -> bool:
        return q.constraint is None or q.constraint == self.index_constraint

    def _query_constraint(self, q: SelectionQuery) -> _dse.Constraint:
        return (q.constraint if q.constraint is not None
                else self.index_constraint)

    def _must_degrade(self, q: SelectionQuery) -> bool:
        """Whether ``q``'s deadline forces the predictor-only answer.

        Degradation needs predictors; without them the exact sweep is the
        only possible answer and the deadline is advisory.  The exact
        path's cost estimate is an EMA of past group sweeps — before any
        sweep has run, only an already-expired deadline degrades.
        """
        if not self._has_models or q.deadline_s is None:
            return False
        remaining = q.deadline_s - (self._clock() - q.submitted_s)
        if remaining <= 0:
            return True
        return self._exact_ema_s is not None and remaining < self._exact_ema_s

    def _ranked(self, candidates: Sequence[_dse.Candidate], energy_j,
                latency_s, indices, exact: bool) -> List[RankedChoice]:
        """Top-k by (energy, latency, index) ascending — the one ranking
        rule all three provenances share."""
        e = np.asarray(energy_j, np.float64)
        l = np.asarray(latency_s, np.float64)
        i = np.asarray(indices, np.int64)
        order = np.lexsort((i, l, e))[:self.top_k]
        return [RankedChoice(candidate=candidates[j], energy_j=float(e[j]),
                             latency_s=float(l[j]), index=int(i[j]),
                             exact=exact) for j in order]

    def _answer_from_entry(self, q: SelectionQuery, entry: IndexEntry,
                           wall_s: float) -> SelectionAnswer:
        return SelectionAnswer(
            qid=q.qid, workload=q.workload, provenance="index_exact",
            choices=self._ranked(entry.candidates, entry.energy_j,
                                 entry.latency_s, entry.indices, exact=True),
            feasible_count=entry.feasible_count, wall_s=wall_s,
            frontier_candidates=tuple(entry.candidates),
            frontier_energy_j=entry.energy_j.copy(),
            frontier_latency_s=entry.latency_s.copy(),
            frontier_indices=entry.indices.copy())

    def _answer_from_frontier(self, q: SelectionQuery,
                              front: _dse.ParetoFrontier, provenance: str,
                              wall_s: float,
                              verified_gidx: Optional[np.ndarray] = None,
                              exact: bool = True) -> SelectionAnswer:
        return SelectionAnswer(
            qid=q.qid, workload=q.workload, provenance=provenance,
            choices=self._ranked(front.candidates, front.energy_j,
                                 front.latency_s, front.indices, exact=exact),
            feasible_count=int(front.feasible_count), wall_s=wall_s,
            frontier_candidates=tuple(front.candidates),
            frontier_energy_j=np.asarray(front.energy_j, np.float64),
            frontier_latency_s=np.asarray(front.latency_s, np.float64),
            frontier_indices=np.asarray(front.indices, np.int64),
            verified_gidx=verified_gidx)

    # -- predictor paths ----------------------------------------------------

    def _full_space_batch(self) -> _dse.CandidateBatch:
        """The whole serving space as one materialized batch (cached) —
        what the predictor paths score over."""
        if self._full_batch is None:
            self._full_batch = self.space.slice(0, len(self.space),
                                                with_candidates=True)
        return self._full_batch

    def _predict(self, wl: _dse.Workload, constraint: _dse.Constraint):
        """Predictor scores over the full space for one workload.

        Predictors score static (arch config x candidate) features, so a
        workload's census perturbations do not move its predictions — fine
        for ranking a top slice, which is why the slice is always verified
        exactly before being served as ``mini_campaign``.
        """
        cfg = get_config(wl.arch)
        shape = SHAPES[wl.shape.split(":", 1)[0]]
        energy, latency, feasible, _, _ = _dse.predict_space(
            cfg, shape, self.config.power_model, self.config.cycles_model,
            self._full_space_batch(), constraint)
        return energy, latency, feasible

    def _answer_predictor_only(self, q: SelectionQuery,
                               reason: str = "deadline") -> SelectionAnswer:
        t0 = self._clock()
        constraint = self._query_constraint(q)
        energy, latency, feasible = self._predict(q.workload, constraint)
        mask = _dse.pareto_mask(energy, latency, feasible)
        loc = np.flatnonzero(mask)
        batch = self._full_space_batch()
        front = _dse.ParetoFrontier(
            workload=q.workload,
            candidates=tuple(batch.candidates[i] for i in loc),
            energy_j=np.asarray(energy, np.float64)[loc],
            latency_s=np.asarray(latency, np.float64)[loc],
            indices=loc.astype(np.int64),
            feasible_count=int(np.asarray(feasible, bool).sum()))
        answer = self._answer_from_frontier(
            q, front, "predictor_only", self._clock() - t0,
            exact=False)
        answer.degraded_reason = reason
        self.stats["degraded"] += 1
        self.telemetry.counter("selection_degraded_total",
                               reason=reason).inc()
        return answer

    def _candidate_slice(self, workloads: Sequence[_dse.Workload],
                         constraint: _dse.Constraint) -> np.ndarray:
        """Global indices the fallback sweep verifies exactly: the whole
        space without predictors, else the union over workloads of each
        predictor's top slice (predicted-feasible best-energy and
        best-latency ``verify_top`` plus the predicted Pareto members)."""
        n = len(self.space)
        if not self._has_models or self.verify_top >= n:
            return np.arange(n, dtype=np.int64)
        union: List[np.ndarray] = []
        for wl in workloads:
            energy, latency, feasible = self._predict(wl, constraint)
            feas = np.flatnonzero(np.asarray(feasible, bool))
            if not feas.size:
                continue
            by_e = feas[np.argsort(energy[feas], kind="stable")]
            by_l = feas[np.argsort(latency[feas], kind="stable")]
            union.append(by_e[:self.verify_top])
            union.append(by_l[:self.verify_top])
            union.append(np.flatnonzero(
                _dse.pareto_mask(energy, latency, feasible)))
        if not union:
            return np.arange(n, dtype=np.int64)   # conservative fallback
        return np.unique(np.concatenate(union)).astype(np.int64)

    # -- the exact fallback sweep -------------------------------------------

    def _mini_campaign(self, workloads: Sequence[_dse.Workload],
                       constraint: _dse.Constraint
                       ) -> Tuple[List[_dse.ParetoFrontier], np.ndarray]:
        """Exact frontiers for ``workloads`` on the verified slice — ONE
        fused multi-workload launch for the whole group.

        Workload keys are tagged per query position (the fused sweep reads
        only the census columns, and predictor shape resolution strips the
        tag like pod tags), so concurrent queries on the same (arch, shape)
        with different censuses cannot collide.  Frontier indices are
        remapped to global space indices; on the full-space slice the
        result is bitwise identical to ``Campaign.run`` on the same config
        (tile-boundary invariance), which is what the parity tests pin.
        """
        tagged = [dse_workload_tagged(wl, i) for i, wl in enumerate(workloads)]
        cfg = self.config.replace(constraint=constraint)
        # the evaluator shares this engine's telemetry (pad/launch/compact
        # spans nest under the mini_campaign span); its lifetime counter is
        # shared too, so the launch count for THIS sweep is a delta
        ev = TileEvaluator(tagged, cfg, telemetry=self.telemetry)
        launches_before = ev._c_fused.value
        gidx = self._candidate_slice(workloads, constraint)
        if gidx.size == len(self.space):
            batch = self._full_space_batch()
        else:
            batch = _dse.CandidateBatch.from_candidates(
                self.space.candidates_at(gidx))
        tr = ev.reduce_tile(batch, 0)
        self._c_fused.inc(ev._c_fused.value - launches_before)
        fronts: List[_dse.ParetoFrontier] = []
        for wi, wl in enumerate(workloads):
            loc = tr.surv_gidx[wi]                 # local slice positions
            fr = StreamingFrontier()
            fr.merge_reduced(
                self.space.candidates_at(gidx[loc]), tr.surv_energy[wi],
                tr.surv_latency[wi], loc, span=(0, int(gidx.size)),
                n_feasible=tr.n_feasible[wi],
                ref_energy_j=tr.ref_energy_j[wi],
                ref_latency_s=tr.ref_latency_s[wi], tile=0)
            front = fr.as_pareto_frontier(wl)
            fronts.append(_dse.ParetoFrontier(
                workload=wl, candidates=front.candidates,
                energy_j=front.energy_j, latency_s=front.latency_s,
                indices=gidx[front.indices],
                feasible_count=front.feasible_count))
        return fronts, gidx


def dse_workload_tagged(wl: _dse.Workload, i: int) -> _dse.Workload:
    """``wl`` with its shape tagged by query position — unique (arch, shape)
    keys inside one fused group sweep (same mechanism as pod-tag
    disambiguation in ``Campaign.from_artifacts``)."""
    return _dse.Workload(arch=wl.arch, shape=f"{wl.shape}:q{i}",
                         base_analysis=dict(wl.base_analysis),
                         base_chips=wl.base_chips,
                         state_gb_per_device=wl.state_gb_per_device)
