"""Serving engine: KV-cache manager + continuous batcher.

Slot-based continuous batching (vLLM-style, TPU-static shapes): the decode
step always runs the full [slots, 1] batch; free slots carry a pad token and
are masked out.  Prefill fills one request's cache region; finished requests
free their slot immediately for the next queued request.

The MLA compressed cache (c_kv + k_rope) comes straight from the model's
init_cache — 57x smaller per token than GQA full heads for DeepSeek-V3,
which is why decode batches of 128 x 32k fit (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [prompt_len] int32
    max_new_tokens: int = 32
    arrived_s: float = 0.0
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None


class ServingEngine:
    """Static-shape continuous batching over ``slots`` concurrent sequences."""

    def __init__(self, model: Model, slots: int = 4, max_len: int = 512,
                 greedy: bool = True):
        assert model.decode is not None, "family has no decode step"
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.params = None
        self.cache = None
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_len = np.zeros(slots, np.int32)
        self.queue: List[Request] = []
        self._decode = jax.jit(lambda p, b, c: model.decode(p, b, c))

    def load(self, params):
        self.params = params
        self.cache = self.model.init_cache(self.slots, self.max_len)

    # --- admission ---------------------------------------------------------------

    def submit(self, req: Request):
        req.arrived_s = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(s, req)

    def _prefill_slot(self, slot: int, req: Request):
        """Sequential per-slot prefill: decode the prompt token-by-token into
        this slot's cache region (static-shape; prompt lengths vary per
        request).  The last prompt token's logits yield the first generated
        token immediately.  Bulk prefill for homogeneous batches uses
        model.prefill."""
        self.slot_req[slot] = req
        self.slot_len[slot] = 0
        for t in req.prompt[:-1]:
            self._step_single_token(slot, int(t))
        logits = self._step_single_token(slot, int(req.prompt[-1]))
        req.tokens_out.append(int(np.argmax(logits)))
        req.first_token_s = time.perf_counter()
        if len(req.tokens_out) >= req.max_new_tokens:
            req.done = True
            req.finished_s = time.perf_counter()
            self.slot_req[slot] = None

    def _step_single_token(self, slot: int, token: int):
        toks = np.zeros((self.slots, 1), np.int32)
        toks[slot, 0] = token
        logits, self.cache = self._decode(self.params, {"tokens": jnp.asarray(toks)},
                                          self.cache)
        self.slot_len[slot] += 1
        return np.asarray(logits[slot, -1])

    # --- decode loop --------------------------------------------------------------

    def step(self) -> int:
        """One engine iteration: admit, decode one token for every live slot."""
        self._admit()
        live = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not live:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            req = self.slot_req[s]
            toks[s, 0] = req.tokens_out[-1]      # never empty after prefill
        logits, self.cache = self._decode(self.params,
                                          {"tokens": jnp.asarray(toks)}, self.cache)
        logits = np.asarray(logits[:, -1])
        for s in live:
            req = self.slot_req[s]
            nxt = int(np.argmax(logits[s]))
            req.tokens_out.append(nxt)
            self.slot_len[s] += 1
            if (len(req.tokens_out) >= req.max_new_tokens
                    or self.slot_len[s] >= self.max_len - 1):
                req.done = True
                req.finished_s = time.perf_counter()
                self.slot_req[s] = None
        return len(live)

    def run_until_drained(self, max_iters: int = 10_000) -> Dict:
        t0 = time.perf_counter()
        decoded = 0
        for _ in range(max_iters):
            n = self.step()
            decoded += n
            if n == 0 and not self.queue:
                break
        dt = time.perf_counter() - t0
        return {"decoded_tokens": decoded, "wall_s": dt,
                "tok_per_s": decoded / dt if dt > 0 else 0.0}
